"""Causal temporal-convolution Pallas kernel (the TDS conv, §4.2).

Hardware adaptation: the paper launches one RISC-V thread per output
element (out_ch × mel-band) and stages the shifting input window in the
shared-memory scratchpad. Here one grid step computes an out-channel
tile across all timesteps of the decoding step; the kw-deep input window
lives in VMEM (the scratchpad analogue), staged once per grid step —
the HBM->VMEM schedule BlockSpec expresses is exactly the paper's
"setup thread stages the window into shared memory".
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Out-channel tile. The tiny model has <=3 channels; the paper model 15.
BC = 8


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kw, stride, t_out):
    x = x_ref[...]  # (T_ext, in_ch, W) — whole extended window in VMEM
    w = w_ref[...]  # (bc, in_ch, kw)
    b = b_ref[...]  # (bc,)
    acc = jnp.zeros((t_out, w.shape[0], x.shape[2]), jnp.float32) + b[None, :, None]
    for k in range(kw):  # kw is small and static: unrolled taps
        xk = jax.lax.slice_in_dim(x, k, k + (t_out - 1) * stride + 1, stride=stride, axis=0)
        acc = acc + jnp.einsum("oi,tiw->tow", w[:, :, k], xk)
    o_ref[...] = acc


def conv_pallas(x_ext, w, b, stride=1, interpret=True):
    """x_ext: (T_ext, in_ch, W) (history prepended), w: (out_ch, in_ch, kw),
    b: (out_ch,) -> (T_out, out_ch, W). Matches ``ref.conv_ref``."""
    t_ext, in_ch, width = x_ext.shape
    out_ch, in_ch_w, kw = w.shape
    assert in_ch == in_ch_w
    t_in = t_ext - (kw - 1)
    assert t_in % stride == 0, (t_in, stride)
    t_out = t_in // stride
    bc = min(BC, out_ch)
    cp = pl.cdiv(out_ch, bc) * bc
    wp = jnp.pad(w, ((0, cp - out_ch), (0, 0), (0, 0)))
    bp = jnp.pad(b, (0, cp - out_ch))
    out = pl.pallas_call(
        lambda xr, wr, br, orf: _conv_kernel(
            xr, wr, br, orf, kw=kw, stride=stride, t_out=t_out
        ),
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((t_ext, in_ch, width), lambda j: (0, 0, 0)),
            pl.BlockSpec((bc, in_ch, kw), lambda j: (j, 0, 0)),
            pl.BlockSpec((bc,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((t_out, bc, width), lambda j: (0, j, 0)),
        out_shape=jax.ShapeDtypeStruct((t_out, cp, width), x_ext.dtype),
        interpret=interpret,
    )(x_ext, wp, bp)
    return out[:, :out_ch, :]
