"""Pure-jnp reference implementations — the correctness oracle for every
Pallas kernel (pytest asserts allclose under hypothesis-driven shape
sweeps), and the ops used on the *training* path (Pallas kernels carry no
VJP; the exported inference graph uses the Pallas versions, training uses
these — identical math, verified by the kernel tests).

Conventions (mirroring rust/src/am):
 * a timestep is a flat ``[channels * width]`` vector, channel-major;
 * convolutions are causal over time, full channel mixing, kernel
   ``(out_ch, in_ch, kw)``, shared across the ``width`` mel bands;
 * FC weights are ``(out_dim, in_dim)`` (row-major like the Rust side).
"""

import jax.numpy as jnp

LN_EPS = 1e-5


def fc_ref(x, w, b, relu=False):
    """x: (T, in_dim), w: (out_dim, in_dim), b: (out_dim,)."""
    y = x @ w.T + b[None, :]
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def conv_ref(x_ext, w, b, stride=1):
    """Causal temporal conv over pre-extended input.

    x_ext: (T_ext, in_ch, width) where T_ext = (kw-1) + T_in (history or
    zero padding already prepended — mirrors the Rust streaming ``ext``).
    w: (out_ch, in_ch, kw); b: (out_ch,).
    Returns (T_out, out_ch, width) with T_out = T_in // stride and
    y[o] = b + sum_k w[:, :, k] . x_ext[o*stride + k].
    """
    t_ext, in_ch, width = x_ext.shape
    out_ch, in_ch_w, kw = w.shape
    assert in_ch == in_ch_w, (in_ch, in_ch_w)
    t_in = t_ext - (kw - 1)
    assert t_in % stride == 0
    t_out = t_in // stride
    y = jnp.zeros((t_out, out_ch, width), dtype=x_ext.dtype) + b[None, :, None]
    for k in range(kw):
        xk = x_ext[k : k + (t_out - 1) * stride + 1 : stride]  # (T_out, in_ch, W)
        y = y + jnp.einsum("oi,tiw->tow", w[:, :, k], xk)
    return y


def layernorm_ref(x, g, b):
    """Per-timestep layer norm. x: (T, D), g/b: (D,)."""
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + LN_EPS) * g[None, :] + b[None, :]


def logsoftmax_ref(x):
    """Numerically stable log-softmax over the last axis. x: (T, D)."""
    m = x.max(axis=-1, keepdims=True)
    lse = m + jnp.log(jnp.exp(x - m).sum(axis=-1, keepdims=True))
    return x - lse
