"""Layer-1 Pallas kernels (build-time only; always ``interpret=True`` —
the CPU PJRT plugin cannot execute Mosaic custom-calls, see DESIGN.md
§Hardware-Adaptation)."""

# Single switch so every kernel lowers to plain HLO.
INTERPRET = True

from .fc import fc_pallas  # noqa: E402,F401
from .tds_conv import conv_pallas  # noqa: E402,F401
from .layernorm import layernorm_pallas  # noqa: E402,F401
from .logsoftmax import logsoftmax_pallas  # noqa: E402,F401
