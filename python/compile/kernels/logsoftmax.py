"""Log-softmax Pallas kernel over token scores (the tail of the output
FC kernel: the paper's PEs use their exp/log SFUs here, §3.4)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BT = 128


def _lsm_kernel(x_ref, o_ref):
    x = x_ref[...]  # (bt, D)
    m = x.max(axis=-1, keepdims=True)
    lse = m + jnp.log(jnp.exp(x - m).sum(axis=-1, keepdims=True))
    o_ref[...] = x - lse


def logsoftmax_pallas(x, interpret=True):
    """x: (T, D) -> (T, D). Matches ``ref.logsoftmax_ref``."""
    t, d = x.shape
    bt = min(BT, t)
    tp = pl.cdiv(t, bt) * bt
    # Pad rows with zeros — padded rows produce garbage log-probs that are
    # sliced away; they cannot NaN because the row max is finite.
    xp = jnp.pad(x, ((0, tp - t), (0, 0)))
    out = pl.pallas_call(
        _lsm_kernel,
        grid=(tp // bt,),
        in_specs=[pl.BlockSpec((bt, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, d), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:t]
