"""LayerNorm Pallas kernel — one grid step normalizes a tile of
timesteps (the paper's LayerNorm kernel runs one thread per timestep,
§4.2; a row-tile per grid step is the MXU-era equivalent)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import LN_EPS

BT = 128


def _ln_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]  # (bt, D)
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    o_ref[...] = (x - mean) * jax.lax.rsqrt(var + LN_EPS) * g_ref[...][None, :] + b_ref[...][
        None, :
    ]


def layernorm_pallas(x, g, b, interpret=True):
    """x: (T, D), g/b: (D,) -> (T, D). Matches ``ref.layernorm_ref``."""
    t, d = x.shape
    bt = min(BT, t)
    tp = pl.cdiv(t, bt) * bt
    xp = jnp.pad(x, ((0, tp - t), (0, 0)))
    out = pl.pallas_call(
        _ln_kernel,
        grid=(tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, d), x.dtype),
        interpret=interpret,
    )(xp, g, b)
    return out[:t]
