"""Fully-connected Pallas kernel.

Hardware adaptation of the paper's FC kernels (§4.2: "each FC thread
computes a single neuron"): on a TPU-shaped target one *grid step*
computes a (row-tile × neuron-tile) output block on the MXU instead of
one scalar neuron per RISC-V thread. The grid dimension over neuron
tiles is exactly the paper's §5.2 kernel-splitting trick — each grid
step's weight tile (``bn × K``) is what must fit the VMEM budget, as the
paper's split FC kernels fit the 1 MB model memory.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: bn neurons × bm rows per grid step. With f32 weights a
# 128×K tile of the tiny model's widest FC (K=120) is ~61 KB — far
# inside a 512 KB VMEM budget (the shared-memory analogue, Table 2).
BM = 128
BN = 128


def _fc_kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    x = x_ref[...]  # (bm, K)
    w = w_ref[...]  # (bn, K)
    b = b_ref[...]  # (bn,)
    acc = jnp.dot(x, w.T, preferred_element_type=jnp.float32) + b[None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def fc_pallas(x, w, b, relu=False, interpret=True):
    """x: (T, in_dim), w: (out_dim, in_dim), b: (out_dim,) -> (T, out_dim).

    Pads to tile multiples outside the kernel (zero rows/neurons), runs a
    (rows/BM, neurons/BN) grid, slices the result back.
    """
    t, k = x.shape
    n = w.shape[0]
    assert w.shape == (n, k) and b.shape == (n,)
    bm, bn = min(BM, t), min(BN, n)
    tp = pl.cdiv(t, bm) * bm
    np_ = pl.cdiv(n, bn) * bn
    xp = jnp.pad(x, ((0, tp - t), (0, 0)))
    wp = jnp.pad(w, ((0, np_ - n), (0, 0)))
    bp = jnp.pad(b, (0, np_ - n))
    out = pl.pallas_call(
        lambda xr, wr, br, orf: _fc_kernel(xr, wr, br, orf, relu=relu),
        grid=(tp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((tp, np_), x.dtype),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:t, :n]
