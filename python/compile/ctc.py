"""Connectionist Temporal Classification (§2.3.2, Graves et al. 2006):
the forward (alpha) recursion in log space via ``lax.scan``, plus a
greedy collapse decoder.

The frame-wise cross-entropy trainer is the primary objective (exact
alignments are known for synthetic data); CTC is provided as the paper's
actual loss family and used for a short fine-tune stage, and is tested
against a brute-force path enumeration on tiny cases.
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30
BLANK = 0


def _logaddexp(a, b):
    m = jnp.maximum(a, b)
    return m + jnp.log(jnp.exp(a - m) + jnp.exp(b - m))


def ctc_loss(log_probs, labels, label_len, logit_len):
    """Negative log-likelihood of ``labels`` under CTC.

    log_probs: (T, V) log-softmax outputs; labels: (L,) token ids (no
    blanks); label_len, logit_len: actual lengths (static padding).
    """
    t_max, _ = log_probs.shape
    l_max = labels.shape[0]
    s = 2 * l_max + 1  # extended label: blank-interleaved
    ext = jnp.full((s,), BLANK, jnp.int32)
    ext = ext.at[1::2].set(labels)
    # alpha init: positions 0 (blank) and 1 (first label).
    init = jnp.full((s,), NEG_INF)
    init = init.at[0].set(log_probs[0, BLANK])
    init = init.at[1].set(jnp.where(label_len > 0, log_probs[0, ext[1]], NEG_INF))

    # Transition mask: alpha[s] <- alpha[s] + alpha[s-1] (+ alpha[s-2] if
    # ext[s] != blank and ext[s] != ext[s-2]).
    idx = jnp.arange(s)
    can_skip = (ext != BLANK) & (idx >= 2) & (ext != jnp.roll(ext, 2))

    def step(alpha, lp_t):
        a0 = alpha
        a1 = jnp.concatenate([jnp.array([NEG_INF]), alpha[:-1]])
        a2 = jnp.concatenate([jnp.array([NEG_INF, NEG_INF]), alpha[:-2]])
        a2 = jnp.where(can_skip, a2, NEG_INF)
        merged = _logaddexp(_logaddexp(a0, a1), a2)
        new = merged + lp_t[ext]
        return new, new

    _, alphas = jax.lax.scan(step, init, log_probs[1:])
    alphas = jnp.concatenate([init[None], alphas], axis=0)  # (T, S)
    # Read out at the true final timestep/positions.
    t_last = logit_len - 1
    end_blank = alphas[t_last, 2 * label_len]
    end_label = jnp.where(
        label_len > 0, alphas[t_last, 2 * label_len - 1], NEG_INF
    )
    ll = _logaddexp(end_blank, end_label)
    return -ll


def ctc_loss_batch(log_probs, labels, label_lens, logit_lens):
    return jax.vmap(ctc_loss)(log_probs, labels, label_lens, logit_lens).mean()


def greedy_collapse(log_probs):
    """Argmax per frame, collapse repeats, drop blanks -> token list."""
    path = jnp.argmax(log_probs, axis=-1)
    path = np_array(path)
    out = []
    last = BLANK
    for t in path:
        if t != last and t != BLANK:
            out.append(int(t))
        last = t
    return out


def np_array(x):
    import numpy as np

    return np.asarray(x)
