"""Synthetic tone-phoneme speech — the exact mirror of
``rust/src/synth/spec.rs`` and ``audio.rs``.

The model is trained on audio from this module and evaluated (from Rust)
on audio from the Rust twin; the constants below are the shared protocol
— any drift between the two implementations shows up directly as WER in
the end-to-end example.
"""

import numpy as np

# ---- mirrored constants (rust/src/synth/spec.rs) ----
SYLLABLES = [
    "ba", "be", "bi", "bo", "bu", "da", "de", "di", "do", "du", "ka", "ke",
    "ki", "ko", "ku", "la", "le", "li", "lo", "lu", "ma", "me", "mi", "mo",
    "mu", "na",
]
F1_BASE = 300.0
F1_RATIO = 1.1047
F2_MULT = 2.1
AMP1 = 0.35
AMP2 = 0.25
DUR_MS = (80, 140)
SIL_MS = (60, 120)
EDGE_SIL_MS = 100
GEMINATE_GAP_MS = 30
NOISE_STD = 0.01
NUM_WORDS = 40
SAMPLE_RATE = 16_000
HOP = 160

N_TOKENS = 1 + len(SYLLABLES)  # blank + syllables


def tone(phoneme: int):
    """(f1, f2) for 1-based phoneme id (0 is blank)."""
    assert 1 <= phoneme <= 26
    f1 = F1_BASE * F1_RATIO ** (phoneme - 1)
    return f1, f1 * F2_MULT


def vocab():
    """[(word, [token ids])] — mirror of ``spec::vocab()``."""
    out = []
    for k in range(NUM_WORDS):
        s1 = k % 26
        s2 = (9 * (k // 26) + 5 * (k % 26) + 7) % 26
        s3 = (13 * k + 11) % 26
        word = SYLLABLES[s1] + SYLLABLES[s2] + SYLLABLES[s3]
        out.append((word, [s1 + 1, s2 + 1, s3 + 1]))
    return out


def successors(word: int):
    return [
        ((word * 5 + 1) % NUM_WORDS, 3.0),
        ((word * 7 + 2) % NUM_WORDS, 2.0),
        ((word * 11 + 3) % NUM_WORDS, 1.0),
    ]


def sample_sentence(rng: np.random.Generator):
    """3–7 words from the Markov chain (10% uniform escape)."""
    length = rng.integers(3, 8)
    words = [int(rng.integers(0, NUM_WORDS))]
    for _ in range(length - 1):
        if rng.random() < 0.1:
            words.append(int(rng.integers(0, NUM_WORDS)))
        else:
            succ = successors(words[-1])
            w = np.array([s[1] for s in succ])
            words.append(succ[rng.choice(len(succ), p=w / w.sum())][0])
    return words


def _ms(ms: int) -> int:
    return SAMPLE_RATE * ms // 1000


def render(words, rng: np.random.Generator, noise_std=None):
    """Render words -> (samples f32, frame_labels int32 at HOP rate).

    Mirror of ``Synthesizer::render`` (same timeline construction, 5 ms
    ramps, amplitude jitter, geminate gaps, additive noise).
    ``noise_std`` overrides the protocol default (used by the trainer's
    noise augmentation).
    """
    if noise_std is None:
        noise_std = NOISE_STD
    voc = vocab()
    timeline = [(0, _ms(EDGE_SIL_MS))]
    for i, w in enumerate(words):
        if i > 0:
            timeline.append((0, _ms(int(rng.integers(SIL_MS[0], SIL_MS[1] + 1)))))
        for ph in voc[w][1]:
            if timeline[-1][0] == ph:
                timeline.append((0, _ms(GEMINATE_GAP_MS)))
            dur = int(rng.integers(DUR_MS[0], DUR_MS[1] + 1))
            timeline.append((ph, _ms(dur)))
    timeline.append((0, _ms(EDGE_SIL_MS)))

    total = sum(n for _, n in timeline)
    samples = np.zeros(total, np.float32)
    pos = 0
    ramp_len = max(_ms(5), 1)
    for tok, n in timeline:
        if tok != 0:
            f1, f2 = tone(tok)
            amp = 0.85 + 0.3 * rng.random()
            ph1 = rng.random() * 2 * np.pi
            ph2 = rng.random() * 2 * np.pi
            t = (pos + np.arange(n)) / SAMPLE_RATE
            k = np.arange(n)
            ramp = np.minimum(np.minimum(k, n - 1 - k) / ramp_len, 1.0)
            samples[pos : pos + n] = amp * ramp * (
                AMP1 * np.sin(2 * np.pi * f1 * t + ph1)
                + AMP2 * np.sin(2 * np.pi * f2 * t + ph2)
            )
        pos += n
    if noise_std > 0:
        samples += rng.normal(0, noise_std, total).astype(np.float32)

    # Frame labels at hop centers.
    bounds = []
    acc = 0
    for tok, n in timeline:
        bounds.append((acc, acc + n, tok))
        acc += n
    n_frames = total // HOP
    labels = np.zeros(n_frames, np.int32)
    seg = 0
    for f in range(n_frames):
        center = f * HOP + HOP // 2
        while seg + 1 < len(bounds) and center >= bounds[seg][1]:
            seg += 1
        labels[f] = bounds[seg][2]
    return samples, labels


def training_batch(cfg, mfcc_cfg, mfcc_fn, rng, batch, max_frames):
    """Render a batch, extract features, build acoustic-rate targets.

    Returns (feats (B, max_frames, n_mels), labels (B, T_ac), mask (B,
    T_ac)) with T_ac = max_frames // subsample; target t is the label of
    the newest feature frame the causal model has seen at that output.
    """
    sub = cfg.subsample
    t_ac = max_frames // sub
    # Fixed sample length so the jitted MFCC compiles exactly once
    # (frames_in(max_samples) == max_frames).
    max_samples = (max_frames - 1) * HOP + cfg.win_len
    feats = np.zeros((batch, max_frames, cfg.n_mels), np.float32)
    labels = np.zeros((batch, t_ac), np.int32)
    mask = np.zeros((batch, t_ac), np.float32)
    for i in range(batch):
        words = sample_sentence(rng)
        # Noise augmentation: the eval protocol uses NOISE_STD = 0.01,
        # but training across a noise range makes the model robust for
        # the noise-robustness ablation (examples/beam_sweep.rs).
        noise = float(rng.uniform(0.0, 0.2))
        samples, frame_labels = render(words, rng, noise_std=noise)
        padded = np.zeros(max_samples, np.float32)
        n_s = min(len(samples), max_samples)
        padded[:n_s] = samples[:n_s]
        f = np.asarray(mfcc_fn(padded))  # (max_frames, n_mels)
        n = min(max_frames, len(frame_labels))
        feats[i] = f
        n_ac = n // sub
        labels[i, :n_ac] = frame_labels[: n_ac * sub][sub - 1 :: sub]
        mask[i, :n_ac] = 1.0
    return feats, labels, mask
