"""Layer-2: the TDS acoustic model in JAX (§4.2 of the paper), mirroring
``rust/src/config/model.rs`` layer for layer and ``rust/src/am/tds.rs``
op for op.

Two execution forms over the same parameters:

* ``forward_full`` — full-sequence causal model used for training
  (reference ops, which carry gradients);
* ``streaming_step_fn`` — the fixed-shape one-decoding-step function with
  explicit conv-history state, built on the Pallas kernels, lowered by
  ``aot.py`` to ``artifacts/model_step.hlo.txt`` and executed from Rust
  through PJRT. Causality makes the two numerically identical.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from .kernels import ref


@dataclass(frozen=True)
class Group:
    channels: int
    blocks: int
    kw: int
    entry_stride: int


@dataclass(frozen=True)
class ModelConfig:
    """Mirror of ``rust/src/config/model.rs::ModelConfig``."""

    name: str = "tiny-tds"
    sample_rate: int = 16_000
    win_len: int = 400
    hop_len: int = 160
    n_mels: int = 40
    step_len: int = 1280
    groups: Tuple[Group, ...] = (
        Group(channels=2, blocks=1, kw=5, entry_stride=2),
        Group(channels=3, blocks=2, kw=5, entry_stride=1),
    )
    final_conv_kw: int | None = None
    tokens: int = 27

    @property
    def frames_per_step(self) -> int:
        return self.step_len // self.hop_len

    @property
    def subsample(self) -> int:
        s = 1
        for g in self.groups:
            s *= g.entry_stride
        return s

    @property
    def vectors_per_step(self) -> int:
        return self.frames_per_step // self.subsample

    @property
    def samples_per_step(self) -> int:
        return self.step_len + self.win_len - self.hop_len


@dataclass(frozen=True)
class Layer:
    kind: str  # 'conv' | 'fc' | 'ln'
    name: str
    # conv
    in_ch: int = 0
    out_ch: int = 0
    kw: int = 0
    stride: int = 1
    residual: bool = False
    # fc
    in_dim: int = 0
    out_dim: int = 0
    relu: bool = False
    # ln
    dim: int = 0


def build_layers(cfg: ModelConfig) -> List[Layer]:
    """Mirror of ``ModelConfig::layers()`` — same names, same order."""
    layers: List[Layer] = []
    in_ch = 1
    for gi, g in enumerate(cfg.groups):
        c = g.channels
        layers.append(
            Layer("conv", f"g{gi}.sub", in_ch=in_ch, out_ch=c, kw=g.kw, stride=g.entry_stride)
        )
        layers.append(Layer("ln", f"g{gi}.sub.ln", dim=c * cfg.n_mels))
        for b in range(g.blocks):
            dim = c * cfg.n_mels
            layers.append(
                Layer("conv", f"g{gi}.b{b}.conv", in_ch=c, out_ch=c, kw=g.kw, residual=True)
            )
            layers.append(Layer("ln", f"g{gi}.b{b}.ln0", dim=dim))
            layers.append(Layer("fc", f"g{gi}.b{b}.fc0", in_dim=dim, out_dim=dim, relu=True))
            layers.append(
                Layer("fc", f"g{gi}.b{b}.fc1", in_dim=dim, out_dim=dim, residual=True)
            )
            layers.append(Layer("ln", f"g{gi}.b{b}.ln1", dim=dim))
        in_ch = c
    last_c = cfg.groups[-1].channels
    if cfg.final_conv_kw is not None:
        layers.append(
            Layer("conv", "final.conv", in_ch=last_c, out_ch=last_c, kw=cfg.final_conv_kw)
        )
        layers.append(Layer("ln", "final.ln", dim=last_c * cfg.n_mels))
    layers.append(Layer("fc", "output.fc", in_dim=last_c * cfg.n_mels, out_dim=cfg.tokens))
    return layers


def init_params(cfg: ModelConfig, key) -> dict:
    """He-init parameters keyed ``{layer}.w/.b/.g`` (the Rust naming)."""
    params = {}
    for layer in build_layers(cfg):
        key, sub = jax.random.split(key)
        if layer.kind == "conv":
            fan_in = layer.in_ch * layer.kw
            params[f"{layer.name}.w"] = (
                jax.random.normal(sub, (layer.out_ch, layer.in_ch, layer.kw))
                * np.sqrt(2.0 / fan_in)
            ).astype(jnp.float32)
            params[f"{layer.name}.b"] = jnp.zeros((layer.out_ch,), jnp.float32)
        elif layer.kind == "fc":
            params[f"{layer.name}.w"] = (
                jax.random.normal(sub, (layer.out_dim, layer.in_dim))
                * np.sqrt(2.0 / layer.in_dim)
            ).astype(jnp.float32)
            params[f"{layer.name}.b"] = jnp.zeros((layer.out_dim,), jnp.float32)
        else:
            params[f"{layer.name}.g"] = jnp.ones((layer.dim,), jnp.float32)
            params[f"{layer.name}.b"] = jnp.zeros((layer.dim,), jnp.float32)
    return params


def _ops(use_pallas: bool):
    if use_pallas:
        return (
            lambda xe, w, b, stride: kernels.conv_pallas(xe, w, b, stride=stride),
            lambda x, w, b, relu: kernels.fc_pallas(x, w, b, relu=relu),
            kernels.layernorm_pallas,
            kernels.logsoftmax_pallas,
        )
    return (
        lambda xe, w, b, stride: ref.conv_ref(xe, w, b, stride=stride),
        lambda x, w, b, relu: ref.fc_ref(x, w, b, relu=relu),
        ref.layernorm_ref,
        ref.logsoftmax_ref,
    )


def _apply_layers(cfg, params, x, conv_states, use_pallas):
    """Shared forward: x (T, D) with per-conv extended history provided by
    ``conv_states`` (list of (kw-1, D_in) arrays, None = zeros). Returns
    (log-probs (T_out, tokens), new conv states)."""
    conv, fc, ln, lsm = _ops(use_pallas)
    new_states = []
    ci = 0
    for layer in build_layers(cfg):
        if layer.kind == "conv":
            w = params[f"{layer.name}.w"]
            b = params[f"{layer.name}.b"]
            t, d = x.shape
            state = conv_states[ci]
            if state is None:
                state = jnp.zeros((layer.kw - 1, d), x.dtype)
            ci += 1
            ext_flat = jnp.concatenate([state, x], axis=0)  # (kw-1+T, D)
            new_states.append(ext_flat[-(layer.kw - 1) :])
            ext = ext_flat.reshape(-1, layer.in_ch, cfg.n_mels)
            y = conv(ext, w, b, layer.stride)  # (T_out, out_ch, W)
            y = jnp.maximum(y, 0.0)
            if layer.residual:
                # Newest input of each window == x itself (stride 1).
                y = y + ext_flat[layer.kw - 1 :].reshape(-1, layer.in_ch, cfg.n_mels)
            x = y.reshape(y.shape[0], -1)
        elif layer.kind == "fc":
            w = params[f"{layer.name}.w"]
            b = params[f"{layer.name}.b"]
            y = fc(x, w, b, layer.relu)
            if layer.residual:
                y = y + x
            x = y
        else:
            x = ln(x, params[f"{layer.name}.g"], params[f"{layer.name}.b"])
    return lsm(x), new_states


def num_conv_layers(cfg: ModelConfig) -> int:
    return sum(1 for l in build_layers(cfg) if l.kind == "conv")


def conv_state_shapes(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """Shapes of the streaming conv-history states, in layer order."""
    shapes = []
    in_dim = cfg.n_mels
    for layer in build_layers(cfg):
        if layer.kind == "conv":
            shapes.append((layer.kw - 1, in_dim))
            in_dim = layer.out_ch * cfg.n_mels
        elif layer.kind == "fc":
            in_dim = layer.out_dim
    return shapes


def forward_full(params, cfg: ModelConfig, feats, use_pallas=False):
    """Training forward: feats (T, n_mels) -> log-probs (T/subsample,
    tokens), zero conv history (= the streaming start state)."""
    out, _ = _apply_layers(cfg, params, feats, [None] * num_conv_layers(cfg), use_pallas)
    return out


def forward_batch(params, cfg: ModelConfig, feats):
    """vmapped training forward over (B, T, n_mels)."""
    return jax.vmap(lambda f: forward_full(params, cfg, f))(feats)


def streaming_step_fn(cfg: ModelConfig, use_pallas=True):
    """Build the AOT-export function:

    ``step(feats (frames_per_step, n_mels), states..., params...) ->
    (logits (vectors_per_step, tokens), new_states...)``

    Parameter order — the Rust runtime feeds literals in exactly this
    order: feats, conv states (conv-layer order), then parameters in
    ``param_order(cfg)`` order (recorded in meta.json).
    """
    names = param_order(cfg)

    def step(feats, *rest):
        n_states = num_conv_layers(cfg)
        states = list(rest[:n_states])
        params = dict(zip(names, rest[n_states:]))
        out, new_states = _apply_layers(cfg, params, feats, states, use_pallas)
        return (out, *new_states)

    return step


def param_order(cfg: ModelConfig) -> List[str]:
    """Deterministic parameter name order for export (layer order, w/g
    before b — matches ``init_params`` insertion order)."""
    names = []
    for layer in build_layers(cfg):
        if layer.kind in ("conv", "fc"):
            names.append(f"{layer.name}.w")
            names.append(f"{layer.name}.b")
        else:
            names.append(f"{layer.name}.g")
            names.append(f"{layer.name}.b")
    return names
