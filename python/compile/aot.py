"""AOT export: train the tiny TDS model, lower the streaming step and
the MFCC front-end to HLO **text**, and write the weights + metadata the
Rust runtime consumes.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example).

Artifacts (``artifacts/``):
  model_step.hlo.txt — step(feats, conv_states..., params...) ->
                       (logits, new_states...)
  mfcc.hlo.txt       — mfcc(samples[1520]) -> (frames[8, n_mels],)
  weights.bin        — tensor container (rust/src/util/tensor_io.rs)
  meta.json          — model geometry, parameter order, training metrics

Run: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data
from .features import MfccConfig, mfcc_step_fn
from .model import (
    ModelConfig,
    conv_state_shapes,
    param_order,
    streaming_step_fn,
)
from .tensor_io import save_tensors
from .train import train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # The default printer ELIDES large constants ("constant({...})"),
    # which the 0.5.1 text parser silently reads back as zeros — the mel
    # filterbank / DCT matrices and trained weights baked as constants
    # would vanish. Print them in full; drop metadata noise.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def export(
    out_dir: Path,
    steps: int,
    ctc_steps: int,
    seed: int,
    use_pallas: bool = True,
    reuse_weights: bool = False,
):
    out_dir.mkdir(parents=True, exist_ok=True)
    cfg = ModelConfig()
    t0 = time.time()
    if reuse_weights and (out_dir / "weights.bin").exists():
        from .tensor_io import load_tensors
        import jax.numpy as _jnp

        loaded = load_tensors(out_dir / "weights.bin")
        params = {n: _jnp.asarray(a) for n, a in loaded.items()}
        try:
            metrics = json.loads((out_dir / "meta.json").read_text())["training"]
        except Exception:
            metrics = {"reused": True}
        print("[aot] reusing existing weights.bin (skipping training)")
    else:
        params, metrics = train(cfg, steps=steps, ctc_steps=ctc_steps, seed=seed)

    # ---- weights.bin ----
    names = param_order(cfg)
    tensors = [(n, np.asarray(params[n], np.float32)) for n in names]
    save_tensors(out_dir / "weights.bin", tensors)

    # ---- model_step.hlo.txt (Pallas kernels, interpret=True) ----
    step = streaming_step_fn(cfg, use_pallas=use_pallas)
    feats_spec = jax.ShapeDtypeStruct((cfg.frames_per_step, cfg.n_mels), jnp.float32)
    state_specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in conv_state_shapes(cfg)]
    param_specs = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names]
    lowered = jax.jit(step).lower(feats_spec, *state_specs, *param_specs)
    (out_dir / "model_step.hlo.txt").write_text(to_hlo_text(lowered))

    # ---- mfcc.hlo.txt ----
    mcfg = MfccConfig(cfg.sample_rate, cfg.win_len, cfg.hop_len, cfg.n_mels)
    mf, samples_per_step = mfcc_step_fn(mcfg, cfg.frames_per_step)
    assert samples_per_step == cfg.samples_per_step
    mf_lowered = jax.jit(mf).lower(
        jax.ShapeDtypeStruct((samples_per_step,), jnp.float32)
    )
    (out_dir / "mfcc.hlo.txt").write_text(to_hlo_text(mf_lowered))

    # ---- meta.json ----
    meta = {
        "model": {
            "name": cfg.name,
            "sample_rate": cfg.sample_rate,
            "win_len": cfg.win_len,
            "hop_len": cfg.hop_len,
            "n_mels": cfg.n_mels,
            "step_len": cfg.step_len,
            "groups": [
                {
                    "channels": g.channels,
                    "blocks": g.blocks,
                    "kw": g.kw,
                    "entry_stride": g.entry_stride,
                }
                for g in cfg.groups
            ],
            "final_conv_kw": cfg.final_conv_kw,
            "tokens": cfg.tokens,
        },
        "params": [{"name": n, "shape": list(params[n].shape)} for n in names],
        "states": [list(s) for s in conv_state_shapes(cfg)],
        "artifacts": {
            "model_hlo": "model_step.hlo.txt",
            "mfcc_hlo": "mfcc.hlo.txt",
            "weights": "weights.bin",
        },
        "training": metrics,
        "protocol": {
            "syllables": data.SYLLABLES,
            "num_words": data.NUM_WORDS,
            "f1_base": data.F1_BASE,
            "f1_ratio": data.F1_RATIO,
            "f2_mult": data.F2_MULT,
        },
        "use_pallas": use_pallas,
        "export_seconds": time.time() - t0,
    }
    (out_dir / "meta.json").write_text(json.dumps(meta, indent=2) + "\n")
    print(f"[aot] wrote artifacts to {out_dir} in {time.time()-t0:.0f}s")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--ctc-steps", type=int, default=60)
    ap.add_argument("--seed", type=int, default=1234)
    ap.add_argument("--no-pallas", action="store_true",
                    help="export with reference ops instead of Pallas kernels")
    ap.add_argument("--reuse-weights", action="store_true",
                    help="skip training, re-export from existing weights.bin")
    args = ap.parse_args()
    export(
        Path(args.out_dir),
        steps=args.steps,
        ctc_steps=args.ctc_steps,
        seed=args.seed,
        use_pallas=not args.no_pallas,
        reuse_weights=args.reuse_weights,
    )


if __name__ == "__main__":
    main()
