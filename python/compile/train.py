"""Build-time training of the tiny TDS model on the synthetic
tone-phoneme corpus.

Primary objective: frame-wise cross-entropy at the acoustic-vector rate
(exact alignments are known by construction — the synthesizer emits
frame labels). A short CTC fine-tune follows (the loss family the paper's
case-study system actually uses) to harden the blank/boundary behaviour.
Hand-rolled Adam; a few hundred steps train to >97% frame accuracy in
about a minute on CPU.
"""

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import ctc, data
from .features import MfccConfig, mfcc
from .model import ModelConfig, forward_batch, init_params

MAX_FRAMES = 304  # 3.04 s — covers 3–7 word sentences (longer are clipped)


def make_mfcc_fn(cfg: ModelConfig):
    mcfg = MfccConfig(cfg.sample_rate, cfg.win_len, cfg.hop_len, cfg.n_mels)
    return mcfg, lambda samples: mfcc(jnp.asarray(samples), mcfg)


def ce_loss(params, cfg, feats, labels, mask):
    logp = forward_batch(params, cfg, feats)  # (B, T_ac, V)
    picked = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -(picked * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def frame_acc(params, cfg, feats, labels, mask):
    logp = forward_batch(params, cfg, feats)
    pred = jnp.argmax(logp, axis=-1)
    correct = ((pred == labels) * mask).sum()
    return correct / jnp.maximum(mask.sum(), 1.0)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros(())}


@partial(jax.jit, static_argnums=(4,))
def adam_step(params, opt, grads, lr, wd=0.0):
    t = opt["t"] + 1.0
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree.map(
        lambda p, m_, v_: p
        - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps)
        - lr * wd * p,
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def labels_to_tokens(labels_row, mask_row):
    """Collapse an aligned label row to the CTC target token sequence."""
    toks = []
    last = 0
    for lab, m in zip(labels_row, mask_row):
        if m == 0:
            break
        if lab != last and lab != 0:
            toks.append(int(lab))
        last = lab
    return toks


def train(
    cfg: ModelConfig,
    steps: int = 400,
    ctc_steps: int = 60,
    batch: int = 16,
    lr: float = 2e-3,
    seed: int = 1234,
    log=print,
):
    """Returns (params, metrics dict)."""
    mcfg, mfcc_fn = make_mfcc_fn(cfg)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    opt = adam_init(params)

    loss_grad = jax.jit(jax.value_and_grad(partial(ce_loss, cfg=cfg)), static_argnames=())
    t0 = time.time()
    loss_hist = []
    for step in range(steps):
        feats, labels, mask = data.training_batch(cfg, mcfg, mfcc_fn, rng, batch, MAX_FRAMES)
        loss, grads = loss_grad(params, feats=feats, labels=labels, mask=mask)
        params, opt = adam_step(params, opt, grads, lr)
        loss_hist.append(float(loss))
        if step % 50 == 0 or step == steps - 1:
            acc = float(frame_acc(params, cfg, feats, labels, mask))
            log(f"[train/ce] step {step:4d} loss {float(loss):.4f} frame-acc {acc:.3f} "
                f"({time.time()-t0:.0f}s)")

    # CTC fine-tune (the case-study loss family, §4.3). Guard rail: keep
    # the fine-tuned weights only if held-out frame accuracy does not
    # degrade (CTC from a cold start can wander).
    def heldout_acc(p):
        ev = np.random.default_rng(seed + 999)
        f, l, mk = data.training_batch(cfg, mcfg, mfcc_fn, ev, 32, MAX_FRAMES)
        return float(frame_acc(p, cfg, f, l, mk))

    pre_ctc_params = params
    pre_ctc_acc = heldout_acc(params)
    t_ac = MAX_FRAMES // cfg.subsample
    l_max = 7 * 3 + 2  # 7 words × 3 phonemes + slack

    def ctc_objective(p, feats, tok_labels, tok_lens, logit_lens):
        logp = forward_batch(p, cfg, feats)
        return ctc.ctc_loss_batch(logp, tok_labels, tok_lens, logit_lens)

    ctc_grad = jax.jit(jax.value_and_grad(ctc_objective))
    for step in range(ctc_steps):
        feats, labels, mask = data.training_batch(cfg, mcfg, mfcc_fn, rng, batch, MAX_FRAMES)
        tok = np.zeros((batch, l_max), np.int32)
        tok_lens = np.zeros((batch,), np.int32)
        logit_lens = np.zeros((batch,), np.int32)
        for i in range(batch):
            ts = labels_to_tokens(labels[i], mask[i])[:l_max]
            tok[i, : len(ts)] = ts
            tok_lens[i] = len(ts)
            logit_lens[i] = max(int(mask[i].sum()), 2 * len(ts) + 1)
        logit_lens = np.minimum(logit_lens, t_ac)
        loss, grads = ctc_grad(params, feats, tok, tok_lens, logit_lens)
        params, opt = adam_step(params, opt, grads, lr * 0.1)
        loss_hist.append(float(loss))
        if step % 20 == 0 or step == ctc_steps - 1:
            log(f"[train/ctc] step {step:4d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    if ctc_steps > 0:
        post_ctc_acc = heldout_acc(params)
        if post_ctc_acc < pre_ctc_acc - 0.01:
            log(
                f"[train/ctc] reverting fine-tune: frame-acc "
                f"{pre_ctc_acc:.3f} -> {post_ctc_acc:.3f}"
            )
            params = pre_ctc_params

    # Final held-out metrics.
    eval_rng = np.random.default_rng(seed + 999)
    feats, labels, mask = data.training_batch(cfg, mcfg, mfcc_fn, eval_rng, 32, MAX_FRAMES)
    acc = float(frame_acc(params, cfg, feats, labels, mask))
    # Token-sequence accuracy via greedy collapse.
    logp = np.asarray(forward_batch(params, cfg, jnp.asarray(feats)))
    seq_ok = 0
    for i in range(32):
        n_ac = int(mask[i].sum())
        hyp = ctc.greedy_collapse(logp[i, :n_ac])
        ref = labels_to_tokens(labels[i], mask[i])
        seq_ok += int(hyp == ref)
    metrics = {
        "steps": steps,
        "ctc_steps": ctc_steps,
        "final_loss": loss_hist[-1],
        "frame_acc": acc,
        "token_seq_acc": seq_ok / 32.0,
        "train_seconds": time.time() - t0,
    }
    log(f"[train] done: frame-acc {acc:.3f}, token-seq-acc {metrics['token_seq_acc']:.3f}")
    return params, metrics
