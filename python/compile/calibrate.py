"""Accelerator-aware per-layer precision calibration (compile side).

Sweeps each conv/FC layer of the trained tiny TDS model over the
runtime's weight formats {f32, int8, int4, int4_sparse} — fake-quantized
in numpy on the exact grids of ``rust/src/am/quant.rs`` — measures
synthetic-corpus token WER with greedy CTC decoding, and assigns every
layer the cheapest format that keeps end-to-end WER within a budget of
the f32 baseline.  Cheap here is accelerator cost: the simulator charges
weight DMA at the resolved width (f32 32 b, int8 8 b, int4 4 b, 2:4
sparse int4 3 b/weight), so the sweep tries formats dearest-savings
first.

Output: ``artifacts/precision.bin`` — a u32 tensor ``precision.codes``
with one format code per ``build_layers`` entry (0=f32 1=int8 2=int4
3=int4_sparse), loadable from Rust via ``PrecisionMap::from_artifacts``
(CLI: ``--precision-map @artifacts``).  LayerNorm entries are always 0:
the runtime keeps LN gain/bias in f32 at every precision.

Run: ``cd python && python -m compile.calibrate --artifacts ../artifacts``
(needs a trained ``weights.bin``; ``make artifacts`` chains it after the
AOT export).
"""

import argparse
from pathlib import Path

import numpy as np

from .tensor_io import load_tensors, save_tensors

# Format codes shared with PrecisionMap::from_artifacts
# (rust/src/config/model.rs); bit widths mirror Precision::weight_bits().
CODES = {"f32": 0, "int8": 1, "int4": 2, "int4_sparse": 3}
WEIGHT_BITS = {"f32": 32, "int8": 8, "int4": 4, "int4_sparse": 3}
INT4_GROUP = 32  # rust/src/am/quant.rs::INT4_GROUP

# Formats in descending DMA-savings order — the sweep tries each layer's
# cheapest format first and widens only when the WER budget forces it.
SWEEP_ORDER = ["int4_sparse", "int4", "int8"]
WIDEN = {"int4_sparse": "int4", "int4": "int8", "int8": "f32"}


def fake_quant_int8(w):
    """Per-row affine int8 on the ``quantize_rows`` grid: 256 levels over
    ``[min(row, 0), max(row, 0)]``."""
    lo = np.minimum(w.min(axis=1), 0.0)
    hi = np.maximum(w.max(axis=1), 0.0)
    s = np.where(hi > lo, (hi - lo) / 255.0, 1.0).astype(np.float32)
    z = np.round(-128.0 - lo / s)
    q = np.clip(np.round(w / s[:, None]) + z[:, None], -128.0, 127.0)
    return ((q - z[:, None]) * s[:, None]).astype(np.float32)


def fake_quant_int4(w):
    """Per-(row, 32-col-group) affine int4 on the ``quantize_rows_int4``
    grid: 16 levels over ``[min(group, 0), max(group, 0)]``."""
    out = np.empty_like(w, np.float32)
    for g0 in range(0, w.shape[1], INT4_GROUP):
        seg = w[:, g0 : g0 + INT4_GROUP]
        lo = np.minimum(seg.min(axis=1), 0.0)
        hi = np.maximum(seg.max(axis=1), 0.0)
        s = np.where(hi > lo, (hi - lo) / 15.0, 1.0).astype(np.float32)
        z = np.round(-8.0 - lo / s)
        q = np.clip(np.round(seg / s[:, None]) + z[:, None], -8.0, 7.0)
        out[:, g0 : g0 + INT4_GROUP] = (q - z[:, None]) * s[:, None]
    return out


def fake_quant_int4_sparse(w):
    """2:4 magnitude pruning + per-row symmetric int4 on the
    ``prune_quantize_rows_2of4`` grid (pruned weights exactly 0.0)."""
    rows, cols = w.shape
    pad = (-cols) % 4
    blocks = np.pad(w, ((0, 0), (0, pad))).reshape(rows, -1, 4)
    # Keep the 2 largest magnitudes per block, ties to the lower index
    # (stable sort on descending |w|); padding columns are zeros and
    # dequantize to zero either way.
    order = np.argsort(-np.abs(blocks), axis=2, kind="stable")
    keep = np.zeros(blocks.shape, bool)
    np.put_along_axis(keep, order[:, :, :2], True, axis=2)
    kept = np.where(keep, blocks, 0.0)
    amax = np.abs(kept).reshape(rows, -1).max(axis=1)
    s = np.where(amax > 0.0, amax / 7.0, 1.0).astype(np.float32)
    q = np.clip(np.round(kept / s[:, None, None]), -7.0, 7.0)
    return (q * s[:, None, None]).reshape(rows, -1)[:, :cols].astype(np.float32)


FAKE_QUANT = {
    "f32": lambda w: w,
    "int8": fake_quant_int8,
    "int4": fake_quant_int4,
    "int4_sparse": fake_quant_int4_sparse,
}


def edit_distance(a, b):
    dp = list(range(len(b) + 1))
    for i, x in enumerate(a, 1):
        prev, dp[0] = dp[0], i
        for j, y in enumerate(b, 1):
            prev, dp[j] = dp[j], min(dp[j] + 1, dp[j - 1] + 1, prev + (x != y))
    return dp[-1]


def layer_params(layer):
    """Weight+bias count, mirror of ``Layer::params()``."""
    if layer.kind == "conv":
        return layer.out_ch * layer.in_ch * layer.kw + layer.out_ch
    if layer.kind == "fc":
        return layer.out_dim * layer.in_dim + layer.out_dim
    return 2 * layer.dim


def with_formats(params, cfg, fmts):
    """Fake-quantize each conv/FC layer's weight matrix at its assigned
    format (conv kernels flatten to ``(out_ch, in_ch*kw)`` rows, exactly
    the matrix the Rust quantizer sees). Biases and LN stay f32."""
    import jax.numpy as jnp

    from .model import build_layers

    out = dict(params)
    for layer in build_layers(cfg):
        fmt = fmts.get(layer.name, "f32")
        if layer.kind == "ln" or fmt == "f32":
            continue
        w = np.asarray(params[f"{layer.name}.w"], np.float32)
        m = w.reshape(w.shape[0], -1)
        out[f"{layer.name}.w"] = jnp.asarray(FAKE_QUANT[fmt](m).reshape(w.shape))
    return out


def eval_batch(cfg, mfcc_fn, rng, batch, max_frames):
    """Held-out batch at the *protocol* noise level (the trainer's batch
    augments noise up to 20x protocol, which would swamp quantization
    error)."""
    from . import data

    sub = cfg.subsample
    t_ac = max_frames // sub
    max_samples = (max_frames - 1) * data.HOP + cfg.win_len
    feats = np.zeros((batch, max_frames, cfg.n_mels), np.float32)
    labels = np.zeros((batch, t_ac), np.int32)
    mask = np.zeros((batch, t_ac), np.float32)
    for i in range(batch):
        words = data.sample_sentence(rng)
        samples, frame_labels = data.render(words, rng)
        padded = np.zeros(max_samples, np.float32)
        n_s = min(len(samples), max_samples)
        padded[:n_s] = samples[:n_s]
        feats[i] = np.asarray(mfcc_fn(padded))
        n_ac = min(max_frames, len(frame_labels)) // sub
        labels[i, :n_ac] = frame_labels[: n_ac * sub][sub - 1 :: sub]
        mask[i, :n_ac] = 1.0
    return feats, labels, mask


def calibrate(cfg, params, eval_fn, budget, log=print):
    """Greedy per-layer assignment: (1) sensitivity sweep — each layer
    alone at its cheapest in-budget format; (2) combined repair — while
    the joint map busts the budget, widen the most sensitive layer."""
    from .model import build_layers

    base = eval_fn(params)
    log(f"[calibrate] f32 baseline token WER {base:.4f}, budget +{budget:.4f}")
    quantizable = [l for l in build_layers(cfg) if l.kind in ("conv", "fc")]
    choice, sens = {}, {}
    for layer in quantizable:
        picked, errs = "f32", {}
        for fmt in SWEEP_ORDER:
            e = eval_fn(with_formats(params, cfg, {layer.name: fmt}))
            errs[fmt] = e
            if e <= base + budget:
                picked = fmt
                break
        choice[layer.name] = picked
        sens[layer.name] = errs
        swept = " ".join(f"{f}={errs[f]:.4f}" for f in SWEEP_ORDER if f in errs)
        log(f"[calibrate] {layer.name:<14} -> {picked:<12} ({swept})")
    while True:
        err = eval_fn(with_formats(params, cfg, choice))
        if err <= base + budget:
            break
        cands = [n for n in choice if choice[n] != "f32"]
        if not cands:
            break
        worst = max(cands, key=lambda n: sens[n].get(choice[n], 0.0))
        log(
            f"[calibrate] combined WER {err:.4f} over budget; widening "
            f"{worst} {choice[worst]} -> {WIDEN[choice[worst]]}"
        )
        choice[worst] = WIDEN[choice[worst]]
    log(f"[calibrate] final map token WER {err:.4f} (baseline {base:.4f})")
    return base, err, choice


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--budget", type=float, default=0.02,
                    help="allowed absolute token-WER increase over the f32 baseline")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=4321)
    args = ap.parse_args()

    art = Path(args.artifacts)
    if not (art / "weights.bin").exists():
        raise SystemExit(
            f"calibrate: no {art / 'weights.bin'} — run `make artifacts` "
            "(the AOT export) first"
        )

    import jax.numpy as jnp

    from . import ctc
    from .model import ModelConfig, build_layers, forward_batch
    from .train import MAX_FRAMES, labels_to_tokens, make_mfcc_fn

    cfg = ModelConfig()
    params = {n: jnp.asarray(a) for n, a in load_tensors(art / "weights.bin").items()}
    _, mfcc_fn = make_mfcc_fn(cfg)
    rng = np.random.default_rng(args.seed)
    feats, labels, mask = eval_batch(cfg, mfcc_fn, rng, args.batch, MAX_FRAMES)
    jfeats = jnp.asarray(feats)
    refs = [labels_to_tokens(labels[i], mask[i]) for i in range(args.batch)]

    def eval_fn(p):
        logp = np.asarray(forward_batch(p, cfg, jfeats))
        errs = words = 0
        for i, ref in enumerate(refs):
            hyp = ctc.greedy_collapse(logp[i, : int(mask[i].sum())])
            errs += edit_distance(hyp, ref)
            words += len(ref)
        return errs / max(words, 1)

    base, final, choice = calibrate(cfg, params, eval_fn, args.budget)

    layers = build_layers(cfg)
    codes = np.array(
        [CODES[choice.get(l.name, "f32")] for l in layers], np.uint32
    )
    save_tensors(art / "precision.bin", [("precision.codes", codes)])
    bits = sum(layer_params(l) * WEIGHT_BITS[choice.get(l.name, "f32")] for l in layers)
    f32_bits = sum(layer_params(l) * 32 for l in layers)
    print(
        f"[calibrate] wrote {art / 'precision.bin'}: weights "
        f"{f32_bits // 8} B f32 -> {bits // 8} B mixed "
        f"({f32_bits / max(bits, 1):.1f}x smaller), "
        f"WER {base:.4f} -> {final:.4f}"
    )


if __name__ == "__main__":
    main()
