"""MFCC front-end in JAX — the exact mirror of ``rust/src/dsp``.

Every constant and step matches the Rust implementation (Fig. 3 of the
paper: framing -> per-frame pre-emphasis -> Hamming window -> FFT power
spectrum -> HTK mel filterbank -> log -> orthonormal DCT-II), so features
computed by the exported ``mfcc.hlo.txt`` artifact agree with the native
front-end to float tolerance. An integration test asserts this.
"""

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Mirrored constants — keep in sync with rust/src/dsp/mfcc.rs.
PREEMPH = 0.97
HAMMING_A = 0.54
HAMMING_B = 0.46
FMIN_HZ = 20.0
FMAX_HZ = 7600.0
LOG_FLOOR = 1e-10


def hz_to_mel(hz):
    return 2595.0 * np.log10(1.0 + hz / 700.0)


def mel_to_hz(mel):
    return 700.0 * (10.0 ** (mel / 2595.0) - 1.0)


def mel_bank(sample_rate: int, n_fft: int, n_mels: int) -> np.ndarray:
    """Dense (n_mels, n_bins) triangular filterbank, HTK mel scale.

    Mirrors ``MelBank::new`` in rust/src/dsp/mel.rs.
    """
    n_bins = n_fft // 2 + 1
    lo, hi = hz_to_mel(FMIN_HZ), hz_to_mel(FMAX_HZ)
    pts = mel_to_hz(lo + (hi - lo) * np.arange(n_mels + 2) / (n_mels + 1))
    bin_hz = sample_rate / n_fft
    weights = np.zeros((n_mels, n_bins), dtype=np.float32)
    for m in range(n_mels):
        f_lo, f_c, f_hi = pts[m], pts[m + 1], pts[m + 2]
        f = np.arange(n_bins) * bin_hz
        up = (f - f_lo) / (f_c - f_lo)
        down = (f_hi - f) / (f_hi - f_c)
        w = np.minimum(up, down)
        w[(f <= f_lo) | (f >= f_hi)] = 0.0
        weights[m] = np.maximum(w, 0.0)
    return weights


def dct_matrix(n: int) -> np.ndarray:
    """Orthonormal DCT-II matrix, mirrors ``Dct::new``."""
    k = np.arange(n)[:, None]
    t = np.arange(n)[None, :]
    mat = np.cos(np.pi / n * (t + 0.5) * k)
    mat[0] *= np.sqrt(1.0 / n)
    mat[1:] *= np.sqrt(2.0 / n)
    return mat.astype(np.float32)


class MfccConfig:
    """Geometry + precomputed constant matrices."""

    def __init__(self, sample_rate=16_000, win_len=400, hop_len=160, n_mels=40):
        self.sample_rate = sample_rate
        self.win_len = win_len
        self.hop_len = hop_len
        self.n_mels = n_mels
        self.n_fft = 1 << (win_len - 1).bit_length()
        n = np.arange(win_len)
        self.window = (
            HAMMING_A - HAMMING_B * np.cos(2.0 * np.pi * n / (win_len - 1))
        ).astype(np.float32)
        self.bank = mel_bank(sample_rate, self.n_fft, n_mels)
        self.dct = dct_matrix(n_mels)

    def frames_in(self, n_samples: int) -> int:
        if n_samples < self.win_len:
            return 0
        return (n_samples - self.win_len) // self.hop_len + 1


@partial(jax.jit, static_argnums=1)
def mfcc(samples, cfg: MfccConfig):
    """Extract all complete frames: (n_samples,) -> (frames, n_mels)."""
    n_frames = cfg.frames_in(samples.shape[0])
    starts = jnp.arange(n_frames) * cfg.hop_len
    idx = starts[:, None] + jnp.arange(cfg.win_len)[None, :]
    frames = samples[idx]  # (F, win_len)
    # Per-frame pre-emphasis, Kaldi-style first sample (mirrors Rust).
    prev = jnp.concatenate([frames[:, :1], frames[:, :-1]], axis=1)
    emph = frames - PREEMPH * prev
    windowed = emph * cfg.window[None, :]
    padded = jnp.pad(windowed, ((0, 0), (0, cfg.n_fft - cfg.win_len)))
    spec = jnp.fft.rfft(padded, axis=1)
    power = (spec.real**2 + spec.imag**2).astype(jnp.float32)
    mel = power @ cfg.bank.T
    logmel = jnp.log(jnp.maximum(mel, LOG_FLOOR))
    return logmel @ cfg.dct.T


def mfcc_step_fn(cfg: MfccConfig, frames_per_step: int):
    """The fixed-shape per-decoding-step extractor for AOT export:
    (samples_per_step,) -> (frames_per_step, n_mels)."""
    samples_per_step = (frames_per_step - 1) * cfg.hop_len + cfg.win_len

    def fn(samples):
        assert samples.shape == (samples_per_step,)
        return (mfcc(samples, cfg),)

    return fn, samples_per_step
