"""Writer for the binary tensor container consumed by
``rust/src/util/tensor_io.rs`` (see that file for the layout spec)."""

import struct

import numpy as np

MAGIC = b"ASRPUTNS"


def save_tensors(path, tensors):
    """tensors: list of (name, np.ndarray[float32, int8 or uint32])."""
    out = bytearray()
    out += MAGIC
    out += struct.pack("<I", len(tensors))
    for name, arr in tensors:
        arr = np.ascontiguousarray(arr)
        if arr.dtype == np.float32:
            dtype = 0
        elif arr.dtype == np.int8:
            dtype = 1
        elif arr.dtype == np.uint32:
            dtype = 2
        else:
            raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
        nb = name.encode()
        out += struct.pack("<I", len(nb)) + nb
        out += struct.pack("<I", arr.ndim)
        for d in arr.shape:
            out += struct.pack("<I", d)
        payload = arr.tobytes()
        out += struct.pack("<I", dtype)
        out += struct.pack("<Q", len(payload))
        out += payload
    with open(path, "wb") as f:
        f.write(bytes(out))


def load_tensors(path):
    """Reader (for python-side round-trip tests)."""
    with open(path, "rb") as f:
        data = f.read()
    assert data[:8] == MAGIC, "bad magic"
    pos = 8
    (count,) = struct.unpack_from("<I", data, pos)
    pos += 4
    out = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<I", data, pos)
        pos += 4
        name = data[pos : pos + nlen].decode()
        pos += nlen
        (ndim,) = struct.unpack_from("<I", data, pos)
        pos += 4
        dims = struct.unpack_from(f"<{ndim}I", data, pos)
        pos += 4 * ndim
        dtype, blen = struct.unpack_from("<IQ", data, pos)
        pos += 12
        raw = data[pos : pos + blen]
        pos += blen
        np_dtype = {0: np.float32, 1: np.int8, 2: np.uint32}[dtype]
        out[name] = np.frombuffer(raw, np_dtype).reshape(dims)
    assert pos == len(data), "trailing bytes"
    return out
