"""L2 model invariants: streaming == offline, Pallas path == reference
path, parameter bookkeeping matches the Rust contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    build_layers,
    conv_state_shapes,
    forward_batch,
    forward_full,
    init_params,
    num_conv_layers,
    param_order,
    streaming_step_fn,
)

CFG = ModelConfig()


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, jax.random.PRNGKey(7))


def feats(seed, t):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(t, CFG.n_mels)).astype(np.float32))


def test_layer_inventory_matches_rust_tiny():
    layers = build_layers(CFG)
    kinds = [l.kind for l in layers]
    assert kinds.count("conv") == 5
    assert kinds.count("fc") == 7  # 3 blocks × 2 + output
    assert kinds.count("ln") == 8
    assert layers[-1].out_dim == CFG.tokens


def test_output_shape_and_logprobs(params):
    out = forward_full(params, CFG, feats(0, 16))
    assert out.shape == (8, CFG.tokens)
    np.testing.assert_allclose(
        np.exp(np.asarray(out)).sum(-1), np.ones(8), rtol=1e-4
    )


def test_streaming_equals_offline(params):
    x = feats(1, 32)
    full = forward_full(params, CFG, x)
    step = streaming_step_fn(CFG, use_pallas=False)
    names = param_order(CFG)
    states = [jnp.zeros(s, jnp.float32) for s in conv_state_shapes(CFG)]
    outs = []
    for c in range(4):
        res = step(x[c * 8 : (c + 1) * 8], *states, *[params[n] for n in names])
        outs.append(res[0])
        states = list(res[1:])
    np.testing.assert_allclose(jnp.concatenate(outs), full, rtol=1e-4, atol=1e-5)


def test_pallas_step_equals_ref_step(params):
    x = feats(2, 8)
    names = param_order(CFG)
    states = [jnp.zeros(s, jnp.float32) for s in conv_state_shapes(CFG)]
    ref_step = streaming_step_fn(CFG, use_pallas=False)
    pl_step = streaming_step_fn(CFG, use_pallas=True)
    a = ref_step(x, *states, *[params[n] for n in names])
    b = pl_step(x, *states, *[params[n] for n in names])
    assert len(a) == len(b) == 1 + num_conv_layers(CFG)
    for x1, x2 in zip(a, b):
        np.testing.assert_allclose(x1, x2, rtol=1e-4, atol=1e-5)


def test_param_order_is_deterministic_and_complete(params):
    names = param_order(CFG)
    assert len(names) == 2 * len(build_layers(CFG))
    assert names == param_order(CFG)
    assert set(names) == set(params.keys())


def test_state_shapes_chain():
    shapes = conv_state_shapes(CFG)
    assert len(shapes) == num_conv_layers(CFG)
    assert shapes[0] == (4, CFG.n_mels)  # kw 5, input 1×40
    assert shapes[1] == (4, 2 * CFG.n_mels)  # after g0 (2 channels)


def test_forward_batch_matches_single(params):
    x = jnp.stack([feats(3, 16), feats(4, 16)])
    batch = forward_batch(params, CFG, x)
    single0 = forward_full(params, CFG, x[0])
    np.testing.assert_allclose(batch[0], single0, rtol=1e-5, atol=1e-6)


def test_gradients_flow(params):
    x = feats(5, 16)

    def loss(p):
        return forward_full(p, CFG, x).sum()

    grads = jax.grad(loss)(params)
    total = sum(float(jnp.abs(g).sum()) for g in grads.values())
    assert np.isfinite(total) and total > 0
    # Every parameter receives gradient.
    for name, g in grads.items():
        assert np.isfinite(np.asarray(g)).all(), name
