"""CTC loss: log-space forward recursion vs brute-force path enumeration
on tiny cases, plus gradient and batching sanity."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.ctc import BLANK, ctc_loss, ctc_loss_batch, greedy_collapse


def brute_force_nll(log_probs, labels):
    """Sum over ALL alignments that collapse to `labels`."""
    t, v = log_probs.shape
    total = -np.inf
    for path in itertools.product(range(v), repeat=t):
        # collapse: remove repeats then blanks
        out = []
        last = None
        for s in path:
            if s != last and s != BLANK:
                out.append(s)
            last = s
        if out == list(labels):
            lp = sum(log_probs[i, s] for i, s in enumerate(path))
            total = np.logaddexp(total, lp)
    return -total


def rand_logp(rng, t, v):
    x = rng.normal(size=(t, v)).astype(np.float32)
    x = x - np.log(np.exp(x).sum(-1, keepdims=True))
    return x


@pytest.mark.parametrize("t,v,labels", [
    (3, 3, [1]),
    (4, 3, [1, 2]),
    (5, 4, [2, 2]),      # repeat needs a blank between
    (5, 3, [1, 2, 1]),
    (2, 3, [1, 2]),      # minimum-length fit
])
def test_matches_brute_force(t, v, labels):
    rng = np.random.default_rng(42 + t * 10 + v)
    logp = rand_logp(rng, t, v)
    got = float(
        ctc_loss(
            jnp.asarray(logp),
            jnp.asarray(labels, jnp.int32),
            jnp.asarray(len(labels)),
            jnp.asarray(t),
        )
    )
    want = brute_force_nll(logp, labels)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_impossible_label_is_infinite():
    # 1 frame cannot emit 2 labels.
    rng = np.random.default_rng(0)
    logp = rand_logp(rng, 1, 3)
    loss = float(
        ctc_loss(jnp.asarray(logp), jnp.asarray([1, 2], jnp.int32), jnp.asarray(2), jnp.asarray(1))
    )
    assert loss > 1e20


def test_perfect_prediction_low_loss():
    # Sharp distribution exactly on the label path.
    t, v = 6, 4
    logp = np.full((t, v), -20.0, np.float32)
    path = [1, 1, BLANK, 2, 2, BLANK]
    for i, s in enumerate(path):
        logp[i, s] = 0.0
    loss = float(
        ctc_loss(jnp.asarray(logp), jnp.asarray([1, 2], jnp.int32), jnp.asarray(2), jnp.asarray(t))
    )
    assert loss < 0.1, loss


def test_gradients_finite():
    rng = np.random.default_rng(3)
    logp = jnp.asarray(rand_logp(rng, 8, 5))
    labels = jnp.asarray([1, 3, 2], jnp.int32)

    def f(lp):
        return ctc_loss(lp, labels, jnp.asarray(3), jnp.asarray(8))

    g = jax.grad(f)(logp)
    assert np.isfinite(np.asarray(g)).all()


def test_batch_mean():
    rng = np.random.default_rng(4)
    lp = jnp.asarray(np.stack([rand_logp(rng, 6, 4), rand_logp(rng, 6, 4)]))
    labels = jnp.asarray([[1, 2, 0], [3, 0, 0]], jnp.int32)
    lens = jnp.asarray([2, 1])
    logit_lens = jnp.asarray([6, 6])
    batch = float(ctc_loss_batch(lp, labels, lens, logit_lens))
    singles = [
        float(ctc_loss(lp[i], labels[i], lens[i], logit_lens[i])) for i in range(2)
    ]
    np.testing.assert_allclose(batch, np.mean(singles), rtol=1e-5)


def test_greedy_collapse():
    logp = np.full((5, 3), -10.0, np.float32)
    for i, s in enumerate([1, 1, 0, 2, 2]):
        logp[i, s] = 0.0
    assert greedy_collapse(jnp.asarray(logp)) == [1, 2]
