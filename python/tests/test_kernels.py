"""Pallas kernels vs the pure-jnp oracle, across hypothesis-driven shape
and value sweeps — the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    conv_pallas,
    fc_pallas,
    layernorm_pallas,
    logsoftmax_pallas,
)
from compile.kernels.ref import conv_ref, fc_ref, layernorm_ref, logsoftmax_ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@given(
    t=st.integers(1, 140),
    din=st.integers(1, 150),
    dout=st.integers(1, 150),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fc_matches_ref(t, din, dout, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = arr(rng, t, din), arr(rng, dout, din), arr(rng, dout)
    got = fc_pallas(x, w, b, relu=relu)
    want = fc_ref(x, w, b, relu=relu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    t_in=st.integers(1, 24),
    in_ch=st.integers(1, 6),
    out_ch=st.integers(1, 12),
    kw=st.integers(1, 7),
    width=st.integers(1, 48),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref(t_in, in_ch, out_ch, kw, width, stride, seed):
    if t_in % stride != 0:
        t_in += stride - (t_in % stride)
    rng = np.random.default_rng(seed)
    x_ext = arr(rng, t_in + kw - 1, in_ch, width)
    w = arr(rng, out_ch, in_ch, kw)
    b = arr(rng, out_ch)
    got = conv_pallas(x_ext, w, b, stride=stride)
    want = conv_ref(x_ext, w, b, stride=stride)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(t=st.integers(1, 200), d=st.integers(2, 200), seed=st.integers(0, 2**31 - 1))
def test_layernorm_matches_ref(t, d, seed):
    rng = np.random.default_rng(seed)
    x, g, b = arr(rng, t, d), arr(rng, d), arr(rng, d)
    np.testing.assert_allclose(
        layernorm_pallas(x, g, b), layernorm_ref(x, g, b), rtol=1e-4, atol=1e-4
    )


@given(t=st.integers(1, 200), d=st.integers(2, 200), seed=st.integers(0, 2**31 - 1))
def test_logsoftmax_matches_ref(t, d, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, t, d) * 10.0
    got = logsoftmax_pallas(x)
    np.testing.assert_allclose(got, logsoftmax_ref(x), rtol=1e-4, atol=1e-4)
    # And it really is a log-distribution.
    np.testing.assert_allclose(
        np.exp(np.asarray(got)).sum(axis=-1), np.ones(t), rtol=1e-4
    )


def test_fc_tile_boundaries():
    """Exact tile-multiple and off-by-one shapes around BM/BN = 128."""
    rng = np.random.default_rng(0)
    for t in (127, 128, 129):
        for dout in (127, 128, 129):
            x, w, b = arr(rng, t, 33), arr(rng, dout, 33), arr(rng, dout)
            np.testing.assert_allclose(
                fc_pallas(x, w, b), fc_ref(x, w, b), rtol=1e-4, atol=1e-4
            )


def test_conv_extreme_values_stay_finite():
    rng = np.random.default_rng(1)
    x = arr(rng, 10, 2, 8) * 1e4
    w = arr(rng, 3, 2, 3) * 1e-4
    b = arr(rng, 3)
    out = conv_pallas(x, w, b)
    assert np.isfinite(np.asarray(out)).all()
