"""Front-end + data-protocol invariants (the python half of the
cross-language contract; the Rust half is tested in rust/tests)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.features import MfccConfig, mel_bank, dct_matrix, mfcc
from compile.tensor_io import load_tensors, save_tensors


def test_mel_bank_shape_and_positivity():
    bank = mel_bank(16_000, 512, 40)
    assert bank.shape == (40, 257)
    assert (bank >= 0).all()
    assert (bank.max(axis=1) > 0).all()


def test_dct_orthonormal():
    d = dct_matrix(40)
    np.testing.assert_allclose(d @ d.T, np.eye(40), atol=1e-5)


def test_mfcc_shapes_and_shift():
    cfg = MfccConfig()
    rng = np.random.default_rng(0)
    sig = rng.normal(size=2000).astype(np.float32) * 0.3
    f = np.asarray(mfcc(jnp.asarray(sig), cfg))
    assert f.shape == (cfg.frames_in(2000), 40)
    # Hop-shift property (mirrors the Rust test).
    f2 = np.asarray(mfcc(jnp.asarray(sig[160:]), cfg))
    np.testing.assert_allclose(f[1 : 1 + len(f2)], f2, atol=1e-3)


def test_vocab_mirrors_rust_formula():
    v = data.vocab()
    assert len(v) == 40
    # Spot-check the deterministic formula for k = 0 and k = 39.
    assert v[0][1] == [1, 8, 12]  # s1=0, s2=7, s3=11 (1-based)
    prons = [tuple(p) for _, p in v]
    assert len(set(prons)) == 40, "homophones!"


def test_geminate_gap_inserted():
    # Word 6 has s2 == s3; the rendered timeline must contain silence
    # between the repeated phonemes (labels return to blank).
    rng = np.random.default_rng(1)
    _, labels = data.render([6], rng)
    pron = data.vocab()[6][1]
    assert pron[1] == pron[2]
    # Find the segment boundaries in the label track.
    segs = []
    for lab in labels:
        if not segs or segs[-1] != lab:
            segs.append(int(lab))
    # Expect ...,s1,?,s2,0,s2,... (a blank between the repeats).
    s = segs
    i = s.index(pron[1])
    assert s[i + 1] == 0 and s[i + 2] == pron[2], f"segments {s}"


def test_sentence_chain_statistics():
    rng = np.random.default_rng(2)
    follow, total = 0, 0
    for _ in range(300):
        sent = data.sample_sentence(rng)
        assert 3 <= len(sent) <= 7
        for a, b in zip(sent, sent[1:]):
            total += 1
            follow += any(n == b for n, _ in data.successors(a))
    assert follow / total > 0.8


def test_labels_align_with_tones():
    rng = np.random.default_rng(3)
    samples, labels = data.render([0], rng, noise_std=0.0)
    # Labelled phoneme regions must carry energy; blank regions ~none
    # (away from boundaries).
    hop = data.HOP
    for f in range(2, len(labels) - 2):
        frame = samples[f * hop : (f + 1) * hop]
        rms = float(np.sqrt((frame**2).mean()))
        if labels[f - 1] == labels[f] == labels[f + 1]:  # interior frame
            if labels[f] == 0:
                assert rms < 0.05, f"silence frame {f} has energy {rms}"
            else:
                assert rms > 0.05, f"phoneme frame {f} silent ({rms})"


def test_tensor_io_roundtrip(tmp_path):
    path = tmp_path / "t.bin"
    tensors = [
        ("a.w", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("b.q", np.array([-1, 0, 127], np.int8)),
    ]
    save_tensors(path, tensors)
    out = load_tensors(path)
    np.testing.assert_array_equal(out["a.w"], tensors[0][1])
    np.testing.assert_array_equal(out["b.q"], tensors[1][1])


def test_tensor_io_rejects_bad_dtype(tmp_path):
    with pytest.raises(ValueError):
        save_tensors(tmp_path / "x.bin", [("z", np.zeros(3, np.float64))])


def test_training_batch_shapes():
    from compile.model import ModelConfig
    from compile.train import make_mfcc_fn

    cfg = ModelConfig()
    mcfg, fn = make_mfcc_fn(cfg)
    rng = np.random.default_rng(4)
    feats, labels, mask = data.training_batch(cfg, mcfg, fn, rng, 2, 64)
    assert feats.shape == (2, 64, cfg.n_mels)
    assert labels.shape == (2, 32)
    assert mask.shape == (2, 32)
    assert mask.sum() > 0
    assert (labels[mask == 0] == 0).all()
