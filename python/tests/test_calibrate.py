"""Calibration-pass invariants: the numpy fake-quantizers must honour
the documented error bounds of their Rust twins (rust/src/am/quant.rs),
the u32 tensor container must round-trip (the precision.bin payload),
and format application must leave everything but conv/FC weights alone."""

import numpy as np
import pytest

from compile.calibrate import (
    CODES,
    INT4_GROUP,
    edit_distance,
    fake_quant_int4,
    fake_quant_int4_sparse,
    fake_quant_int8,
    with_formats,
)
from compile.tensor_io import load_tensors, save_tensors


def rand_w(rng, rows, cols, scale=0.7):
    return (rng.standard_normal((rows, cols)) * scale).astype(np.float32)


@pytest.mark.parametrize("rows,cols", [(1, 1), (3, 7), (8, 32), (5, 33), (4, 100)])
def test_int8_fake_quant_error_bound(rows, cols):
    rng = np.random.default_rng(rows * 100 + cols)
    w = rand_w(rng, rows, cols)
    d = fake_quant_int8(w)
    # quantize_rows grid: half-step <= max|row| / 255 (INT8_MAX_ROW_REL_ERR).
    amax = np.abs(w).max(axis=1, keepdims=True)
    assert (np.abs(d - w) <= amax / 255.0 + 1e-6).all()


@pytest.mark.parametrize("rows,cols", [(1, 1), (3, 7), (8, 32), (5, 33), (4, 100)])
def test_int4_fake_quant_error_bound(rows, cols):
    rng = np.random.default_rng(rows * 100 + cols + 1)
    w = rand_w(rng, rows, cols)
    d = fake_quant_int4(w)
    # quantize_rows_int4 grid: per-(row, group) half-step <=
    # max|group| / 15 (INT4_MAX_GROUP_REL_ERR).
    for g0 in range(0, cols, INT4_GROUP):
        seg = w[:, g0 : g0 + INT4_GROUP]
        amax = np.abs(seg).max(axis=1, keepdims=True)
        assert (np.abs(d[:, g0 : g0 + INT4_GROUP] - seg) <= amax / 15.0 + 1e-6).all()


@pytest.mark.parametrize("rows,cols", [(1, 1), (2, 3), (3, 4), (8, 32), (5, 33), (4, 101)])
def test_sparse_fake_quant_structure_and_bound(rows, cols):
    rng = np.random.default_rng(rows * 100 + cols + 2)
    w = rand_w(rng, rows, cols)
    d = fake_quant_int4_sparse(w)
    assert d.shape == w.shape
    kept_amax = np.zeros((rows, 1), np.float32)
    for b0 in range(0, cols, 4):
        blk = d[:, b0 : b0 + 4]
        # 2:4 structure: at most 2 survivors per block, and they are the
        # block's largest magnitudes (pruned entries are exactly 0.0).
        assert ((blk != 0.0).sum(axis=1) <= 2).all()
        src = w[:, b0 : b0 + 4]
        order = np.argsort(-np.abs(src), axis=1, kind="stable")
        for r in range(rows):
            kept = set(np.nonzero(blk[r])[0])
            assert kept <= set(order[r, :2])
            kept_amax[r] = max(kept_amax[r], np.abs(src[r, order[r, :2]]).max())
    # prune_quantize_rows_2of4 grid: kept error <= max|kept in row| / 14
    # (SPARSE4_MAX_ROW_REL_ERR); zeroed entries are the pruned ones.
    kept_mask = d != 0.0
    err = np.abs(d - w)
    assert (err[kept_mask] <= (kept_amax / 14.0 + 1e-6).repeat(cols, 1)[kept_mask]).all()


def test_u32_tensor_roundtrip(tmp_path):
    codes = np.array([0, 1, 2, 3, 2, 2], np.uint32)
    p = tmp_path / "precision.bin"
    save_tensors(p, [("precision.codes", codes)])
    back = load_tensors(p)["precision.codes"]
    assert back.dtype == np.uint32
    assert (back == codes).all()
    assert set(codes.tolist()) <= set(CODES.values())


def test_edit_distance():
    assert edit_distance([], []) == 0
    assert edit_distance([1, 2, 3], [1, 2, 3]) == 0
    assert edit_distance([1, 2, 3], [1, 3]) == 1
    assert edit_distance([1, 2], [3, 4, 5]) == 3
    assert edit_distance([], [7]) == 1


def test_with_formats_touches_only_selected_weights():
    import jax

    from compile.model import ModelConfig, build_layers, init_params

    cfg = ModelConfig()
    params = init_params(cfg, jax.random.PRNGKey(0))
    fc = next(l for l in build_layers(cfg) if l.kind == "fc")
    out = with_formats(params, cfg, {fc.name: "int4"})
    assert set(out) == set(params)
    for name in params:
        same = np.array_equal(np.asarray(out[name]), np.asarray(params[name]))
        if name == f"{fc.name}.w":
            assert not same
            assert out[name].shape == params[name].shape
        else:
            assert same, name
    # f32 assignment is the identity.
    ident = with_formats(params, cfg, {fc.name: "f32"})
    assert all(
        np.array_equal(np.asarray(ident[n]), np.asarray(params[n])) for n in params
    )
