//! Batched-vs-scalar parity: the lane-batched execution core must be
//! **bit-identical** to running each lane alone — `==` on f32, no
//! epsilon. This is the contract that lets the serving coordinator batch
//! sessions opportunistically (whatever lanes happen to be ready) without
//! ever changing a transcript: batching is purely a throughput decision.
//!
//! Covered here end-to-end and per primitive: `fc`, `layer_norm`,
//! `log_softmax`, `conv_step`, `TdsModel::step_batch`,
//! `BeamDecoder::step_batch` and `Engine::step_batch`.

use asrpu::am::{ops, TdsModel, TdsState};
use asrpu::config::{DecoderConfig, ModelConfig};
use asrpu::coordinator::{Engine, Session};
use asrpu::decoder::{BeamDecoder, DecodeState};
use asrpu::lm::NgramLm;
use asrpu::synth::spec;
use asrpu::util::prop;
use asrpu::util::rng::Rng;

#[test]
fn fc_batch_parity() {
    prop::check("fc-batch-parity-e2e", 40, |g| {
        let in_dim = 1 + g.index(48);
        let out_dim = 1 + g.index(32);
        let batch = 1 + g.index(8);
        let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-1.5, 1.5));
        let b = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
        let xs = g.vec_of(batch * in_dim, |r| r.uniform(-3.0, 3.0));
        let mut fused = Vec::new();
        ops::fc_batch(&w, &b, &xs, batch, &mut fused);
        let mut lane = Vec::new();
        for l in 0..batch {
            ops::fc(&w, &b, &xs[l * in_dim..(l + 1) * in_dim], &mut lane);
            asrpu::prop_assert!(
                lane == fused[l * out_dim..(l + 1) * out_dim],
                "fc lane {l} not bit-identical"
            );
        }
        Ok(())
    });
}

#[test]
fn layer_norm_batch_parity() {
    prop::check("layer-norm-batch-parity-e2e", 40, |g| {
        let dim = 2 + g.index(64);
        let batch = 1 + g.index(8);
        let gain = g.vec_of(dim, |r| r.uniform(0.1, 2.0));
        let bias = g.vec_of(dim, |r| r.uniform(-1.0, 1.0));
        let xs = g.vec_of(batch * dim, |r| r.uniform(-5.0, 5.0));
        let mut fused = xs.clone();
        ops::layer_norm_batch(&gain, &bias, &mut fused, batch, 1e-5);
        let mut scalar = xs;
        for l in scalar.chunks_mut(dim) {
            ops::layer_norm(&gain, &bias, l, 1e-5);
        }
        asrpu::prop_assert!(fused == scalar, "layer_norm lanes not bit-identical");
        Ok(())
    });
}

#[test]
fn log_softmax_batch_parity() {
    prop::check("log-softmax-batch-parity-e2e", 40, |g| {
        let dim = 2 + g.index(64);
        let batch = 1 + g.index(8);
        let xs = g.vec_of(batch * dim, |r| r.uniform(-20.0, 20.0));
        let mut fused = xs.clone();
        ops::log_softmax_batch(&mut fused, batch);
        let mut scalar = xs;
        for l in scalar.chunks_mut(dim) {
            ops::log_softmax(l);
        }
        asrpu::prop_assert!(fused == scalar, "log_softmax lanes not bit-identical");
        Ok(())
    });
}

#[test]
fn conv_step_batch_parity() {
    prop::check("conv-batch-parity-e2e", 30, |g| {
        let in_ch = 1 + g.index(4);
        let out_ch = 1 + g.index(4);
        let kw = 1 + g.index(4);
        let width = 1 + g.index(10);
        let batch = 1 + g.index(6);
        let w = g.vec_of(out_ch * in_ch * kw, |r| r.uniform(-1.0, 1.0));
        let b = g.vec_of(out_ch, |r| r.uniform(-0.5, 0.5));
        let lane_in = in_ch * width;
        let lane_out = out_ch * width;
        let blocks: Vec<Vec<f32>> =
            (0..kw).map(|_| g.vec_of(batch * lane_in, |r| r.uniform(-2.0, 2.0))).collect();
        let window: Vec<&[f32]> = blocks.iter().map(|v| v.as_slice()).collect();
        let mut fused = Vec::new();
        ops::conv_step_batch(&w, &b, &window, batch, in_ch, out_ch, kw, width, &mut fused);
        let mut scalar = Vec::new();
        for l in 0..batch {
            let lane_win: Vec<&[f32]> = blocks
                .iter()
                .map(|blk| &blk[l * lane_in..(l + 1) * lane_in])
                .collect();
            ops::conv_step(&w, &b, &lane_win, in_ch, out_ch, kw, width, &mut scalar);
            asrpu::prop_assert!(
                scalar == fused[l * lane_out..(l + 1) * lane_out],
                "conv lane {l} not bit-identical"
            );
        }
        Ok(())
    });
}

#[test]
fn tds_model_step_batch_parity() {
    // Multi-step streaming parity: B lanes through step_batch (carrying
    // per-lane conv history) vs B independent scalar streams.
    let model = TdsModel::random(ModelConfig::tiny_tds(), 77);
    let f = model.cfg.frames_per_step() * model.cfg.n_mels;
    prop::check("tds-step-batch-parity", 10, |g| {
        let batch = 1 + g.index(6);
        let steps = 1 + g.index(3);
        let mut scalar_states: Vec<TdsState> = (0..batch).map(|_| model.state()).collect();
        let mut batch_states: Vec<TdsState> = (0..batch).map(|_| model.state()).collect();
        for _ in 0..steps {
            let feats = g.vec_of(batch * f, |r| r.uniform(-1.0, 1.0));
            let mut refs: Vec<&mut TdsState> = batch_states.iter_mut().collect();
            let fused = model.step_batch(&mut refs, &feats);
            let lane_out = fused.len() / batch;
            for (l, st) in scalar_states.iter_mut().enumerate() {
                let out = model.step(st, &feats[l * f..(l + 1) * f]);
                asrpu::prop_assert!(
                    out == fused[l * lane_out..(l + 1) * lane_out],
                    "AM lane {l} not bit-identical at batch {batch}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn beam_decoder_step_batch_parity() {
    // Random (realistically messy) log-prob frames through the synthetic
    // protocol's lexicon + LM: batched decode states must track scalar
    // ones exactly, including final transcript scores.
    let lex = spec::lexicon();
    let lm = NgramLm::estimate(&spec::sample_corpus(500, 1234), 0.4).unwrap();
    let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
    let tokens = lex.tokens.len();
    prop::check("decoder-step-batch-parity", 8, |g| {
        let batch = 1 + g.index(4);
        let frames = 4 + g.index(12);
        let mut scalar: Vec<DecodeState> = (0..batch).map(|_| dec.start()).collect();
        let mut fused: Vec<DecodeState> = (0..batch).map(|_| dec.start()).collect();
        for _ in 0..frames {
            // One sharp token per lane over a noisy floor.
            let mut block = Vec::with_capacity(batch * tokens);
            for _ in 0..batch {
                let mut row: Vec<f32> = (0..tokens).map(|_| g.rng.uniform(-9.0, -2.0)).collect();
                row[g.index(tokens)] = -0.1;
                block.extend_from_slice(&row);
            }
            for (l, st) in scalar.iter_mut().enumerate() {
                dec.step(st, &block[l * tokens..(l + 1) * tokens]);
            }
            let mut refs: Vec<&mut DecodeState> = fused.iter_mut().collect();
            dec.step_batch(&mut refs, &block);
        }
        for l in 0..batch {
            asrpu::prop_assert!(
                scalar[l].hyps == fused[l].hyps,
                "decoder lane {l} hypothesis sets diverged"
            );
            let a = dec.finish(&scalar[l]);
            let b = dec.finish(&fused[l]);
            asrpu::prop_assert!(a.text == b.text, "lane {l} text diverged");
            asrpu::prop_assert!(a.score == b.score, "lane {l} score diverged");
        }
        Ok(())
    });
}

#[test]
fn engine_step_batch_end_to_end_parity() {
    // Whole pipeline: MFCC → AM → beam search. Batched sessions must
    // produce byte-identical transcripts and bit-identical scores to
    // scalar feeds of the same audio.
    let engine = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 9))
        .decoder(DecoderConfig::default())
        .build()
        .unwrap();
    let synth = asrpu::synth::Synthesizer::default();
    let utts: Vec<Vec<f32>> = (0..3u64)
        .map(|i| {
            let mut rng = Rng::new(100 + i);
            synth.render(&[(2 * i) as u32, (2 * i + 1) as u32], &mut rng).samples
        })
        .collect();
    let scalar: Vec<_> = utts.iter().map(|u| engine.decode_utterance(u).unwrap().0).collect();
    let mut sessions: Vec<Session> =
        (0..utts.len()).map(|_| engine.open(false).unwrap()).collect();
    for (s, u) in sessions.iter_mut().zip(&utts) {
        engine.push_audio(s, u);
    }
    let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
    engine.step_batch(&mut refs).unwrap();
    for (s, reference) in sessions.iter_mut().zip(&scalar) {
        let t = engine.finish(s).unwrap();
        assert_eq!(t.text, reference.text);
        assert_eq!(t.score, reference.score);
        assert_eq!(t.words, reference.words);
    }
}
