//! Protocol v2 conformance over a real TCP socket: the hello
//! handshake, config introspection, structured error codes (every
//! `ErrCode` variant), the `resume` re-attach op, and the v1
//! line-protocol fallback.
//!
//! Reachability notes: every code is provoked over the wire below —
//! `bad_request`, `unknown_op`, `unknown_session`, `backpressure` and
//! `shutdown` through ordinary traffic, `session_shed` by saturating a
//! one-slot shard under the shed-never-started overload policy, and
//! `internal` through the engine's fault-injection hook
//! (`EngineBuilder::fault_after_steps`, env-gated as
//! `ASRPU_FAULT_AFTER_STEPS`), which fails scoring mid-serve exactly
//! like a backend would.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, ModelConfig, OverloadPolicy, ShardConfig};
use asrpu::coordinator::server::{err_json, ErrCode, OPS, PROTO_ACCEPTED, PROTO_VERSION};
use asrpu::coordinator::{Engine, Server};
use asrpu::util::json::Json;

fn start_server(queue_depth: usize) -> Server {
    Server::start(
        "127.0.0.1:0",
        || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                .batch(BatchConfig::default())
                .build()?)
        },
        queue_depth,
    )
    .unwrap()
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    /// Send one line without waiting for the reply.
    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
    }

    /// Read one reply line.
    fn recv(&mut self) -> Json {
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    }

    /// Request/response round trip.
    fn call(&mut self, line: &str) -> Json {
        self.send(line);
        self.recv()
    }
}

fn code_of(r: &Json) -> Option<String> {
    r.get("error")
        .and_then(|e| e.get("code"))
        .and_then(Json::as_str)
        .map(str::to_string)
}

#[test]
fn hello_handshake_conformance() {
    let server = start_server(64);
    let mut c = Client::connect(&server.addr);
    for _ in 0..2 {
        // Idempotent: a client may re-handshake at any time.
        let h = c.call(r#"{"op":"hello"}"#);
        assert_eq!(h.get("proto").unwrap().as_f64(), Some(PROTO_VERSION as f64));
        assert_eq!(h.get("server").unwrap().as_str(), Some("asrpu"));
        let versions: Vec<u64> = h
            .get("versions")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(Json::as_f64)
            .map(|v| v as u64)
            .collect();
        assert_eq!(versions, PROTO_ACCEPTED.to_vec());
        let ops: Vec<String> = h
            .get("ops")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(Json::as_str)
            .map(str::to_string)
            .collect();
        // Exactly the advertised op set, both directions.
        for op in OPS {
            assert!(ops.iter().any(|o| o == op), "hello missing op {op}");
        }
        assert_eq!(ops.len(), OPS.len(), "hello advertises unknown ops: {ops:?}");
    }
    server.shutdown();
}

#[test]
fn config_introspection_conformance() {
    let server = start_server(64);
    let mut c = Client::connect(&server.addr);
    let cfg = c.call(r#"{"op":"config"}"#);
    // Every introspection key a v2 client may rely on, with sane types.
    for key in [
        "proto",
        "tokens",
        "sample_rate",
        "samples_per_step",
        "step_seconds",
        "stages",
        "weight_bytes_per_step",
        "max_batch",
        "max_wait_frames",
        "workers",
        "rebalance_threshold",
        "beam",
        "max_hyps",
        "admit_sessions_per_shard",
        "retry_after_ms",
        "shed_never_started",
        "route_retries",
        "route_backoff_ms",
        "degrade_levels",
        "nbest",
        "rescore",
    ] {
        assert!(
            cfg.get(key).and_then(Json::as_f64).is_some(),
            "config missing numeric '{key}': {cfg:?}"
        );
    }
    for key in ["backend", "precision", "model"] {
        assert!(
            cfg.get(key).and_then(Json::as_str).is_some(),
            "config missing string '{key}': {cfg:?}"
        );
    }
    assert_eq!(cfg.get("proto").unwrap().as_f64(), Some(PROTO_VERSION as f64));
    assert!(cfg.get("workers").unwrap().as_f64().unwrap() >= 1.0);
    server.shutdown();
}

#[test]
fn stats_reports_overload_and_liveness_counters() {
    // The v2 stats payload carries the overload/liveness observability
    // fields even when the policy is fully off (they read zero) — a
    // dashboard can rely on the keys unconditionally.
    let server = start_server(64);
    let mut c = Client::connect(&server.addr);
    let stats = c.call(r#"{"op":"stats"}"#);
    for key in ["rejected_admission", "shed", "panics_detected"] {
        assert_eq!(
            stats.get(key).and_then(Json::as_f64),
            Some(0.0),
            "stats missing idle counter '{key}': {stats:?}"
        );
    }
    let shards = stats.get("shards").unwrap().as_arr().unwrap();
    for shard in shards {
        for key in ["degrade_level", "degraded_batches", "shed", "heartbeats"] {
            assert!(
                shard.get(key).and_then(Json::as_f64).is_some(),
                "per-shard stats missing numeric '{key}': {stats:?}"
            );
        }
        assert_eq!(shard.get("degrade_level").unwrap().as_f64(), Some(0.0));
    }
    server.shutdown();
}

#[test]
fn v1_line_protocol_still_accepted() {
    // A v1 client never sends hello/config and treats any response with
    // an "error" key as a failure — both behaviours must keep working.
    let server = start_server(64);
    let mut c = Client::connect(&server.addr);
    let opened = c.call(r#"{"op":"open"}"#);
    let session = opened.get("session").unwrap().as_f64().unwrap() as u64;
    let samples: Vec<String> = (0..1600)
        .map(|i| format!("{:.4}", (i as f32 * 0.013).sin() * 0.2))
        .collect();
    let fed = c.call(&format!(
        r#"{{"op":"feed","session":{session},"samples":[{}]}}"#,
        samples.join(",")
    ));
    assert_eq!(fed.get("steps").unwrap().as_f64(), Some(1.0));
    let done = c.call(&format!(r#"{{"op":"finish","session":{session}}}"#));
    assert!(done.get("text").is_some(), "{done:?}");
    let stats = c.call(r#"{"op":"stats"}"#);
    assert!(stats.get("summary").is_some(), "{stats:?}");
    // v1 error detection: presence of the "error" key.
    let err = c.call(r#"{"op":"finish","session":9999}"#);
    assert!(err.get("error").is_some(), "{err:?}");
    server.shutdown();
}

#[test]
fn error_code_wire_shapes_are_stable() {
    // The canonical wire shape for every code, via the same constructor
    // the server uses: {"error":{"code":..., "message":...}}.
    let expected = [
        (ErrCode::BadRequest, "bad_request"),
        (ErrCode::UnknownOp, "unknown_op"),
        (ErrCode::UnknownSession, "unknown_session"),
        (ErrCode::SessionShed, "session_shed"),
        (ErrCode::Backpressure, "backpressure"),
        (ErrCode::Shutdown, "shutdown"),
        (ErrCode::Internal, "internal"),
    ];
    assert_eq!(ErrCode::ALL.len(), expected.len());
    for (code, wire) in expected {
        assert!(ErrCode::ALL.contains(&code));
        assert_eq!(code.as_str(), wire);
        let payload = err_json(code, "boom");
        assert_eq!(code_of(&payload).as_deref(), Some(wire));
        assert_eq!(
            payload.get("error").unwrap().get("message").unwrap().as_str(),
            Some("boom")
        );
        // Round-trips through serialization.
        let parsed = Json::parse(&payload.to_string()).unwrap();
        assert_eq!(code_of(&parsed).as_deref(), Some(wire));
    }
}

#[test]
fn request_validation_error_codes_over_socket() {
    let server = start_server(64);
    let mut c = Client::connect(&server.addr);
    // bad_request: invalid JSON, missing op, missing session, missing
    // samples.
    for line in [
        "this is not json",
        r#"{"nop":1}"#,
        r#"{"op":"feed","samples":[0.0]}"#,
        r#"{"op":"finish"}"#,
        r#"{"op":"feed","session":1}"#,
    ] {
        assert_eq!(code_of(&c.call(line)).as_deref(), Some("bad_request"), "{line}");
    }
    // bad_request: resume without a session id.
    assert_eq!(code_of(&c.call(r#"{"op":"resume"}"#)).as_deref(), Some("bad_request"));
    // unknown_op.
    assert_eq!(code_of(&c.call(r#"{"op":"decode"}"#)).as_deref(), Some("unknown_op"));
    // unknown_session: feed, finish and resume against a never-opened id.
    assert_eq!(
        code_of(&c.call(r#"{"op":"feed","session":777,"samples":[0.0]}"#)).as_deref(),
        Some("unknown_session")
    );
    assert_eq!(
        code_of(&c.call(r#"{"op":"finish","session":777}"#)).as_deref(),
        Some("unknown_session")
    );
    assert_eq!(
        code_of(&c.call(r#"{"op":"resume","session":777}"#)).as_deref(),
        Some("unknown_session")
    );
    server.shutdown();
}

#[test]
fn nbest_op_over_socket() {
    // A lattice-enabled server answers `nbest` with the transcript plus
    // an exactly-scored hypothesis list; a server built without N-best
    // refuses the op with `bad_request` and keeps the session alive.
    let server = Server::start(
        "127.0.0.1:0",
        || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                .batch(BatchConfig::default())
                .nbest(3)
                .build()?)
        },
        64,
    )
    .unwrap();
    let mut c = Client::connect(&server.addr);
    let opened = c.call(r#"{"op":"open"}"#);
    let session = opened.get("session").unwrap().as_f64().unwrap() as u64;
    let samples: Vec<String> = (0..1520 + 9 * 1280)
        .map(|i| format!("{:.4}", (i as f32 * 0.013).sin() * 0.3))
        .collect();
    c.call(&format!(
        r#"{{"op":"feed","session":{session},"samples":[{}]}}"#,
        samples.join(",")
    ));
    let r = c.call(&format!(r#"{{"op":"nbest","session":{session}}}"#));
    let text = r.get("text").unwrap().as_str().unwrap().to_string();
    let score = r.get("score").unwrap().as_f64().unwrap();
    let hyps = r.get("nbest").unwrap().as_arr().unwrap();
    assert!(!hyps.is_empty() && hyps.len() <= 3, "{r:?}");
    assert_eq!(hyps[0].get("text").unwrap().as_str(), Some(text.as_str()));
    assert_eq!(hyps[0].get("score").unwrap().as_f64(), Some(score));
    let mut prev = f64::INFINITY;
    for h in hyps {
        let s = h.get("score").unwrap().as_f64().unwrap();
        assert!(s <= prev, "N-best not sorted: {r:?}");
        prev = s;
        // No rescorer configured: the second-pass column mirrors the
        // first pass.
        assert_eq!(h.get("rescore").unwrap().as_f64(), Some(s));
    }
    // The session is consumed, exactly like finish.
    let gone = c.call(&format!(r#"{{"op":"nbest","session":{session}}}"#));
    assert_eq!(code_of(&gone).as_deref(), Some("unknown_session"), "{gone:?}");
    server.shutdown();

    // Without a lattice the op is refused up front — and the refusal
    // does NOT consume the session.
    let plain = start_server(64);
    let mut c = Client::connect(&plain.addr);
    let opened = c.call(r#"{"op":"open"}"#);
    let session = opened.get("session").unwrap().as_f64().unwrap() as u64;
    let refused = c.call(&format!(r#"{{"op":"nbest","session":{session}}}"#));
    assert_eq!(code_of(&refused).as_deref(), Some("bad_request"), "{refused:?}");
    let done = c.call(&format!(r#"{{"op":"finish","session":{session}}}"#));
    assert!(done.get("text").is_some(), "refusal must not consume the session: {done:?}");
    plain.shutdown();
}

#[test]
fn shed_victims_get_session_shed_over_socket() {
    // The socket-level twin of the router's shed test: one worker, one
    // queue slot, a 400 ms reply delay wedging it mid-flush. Session B
    // books onto the saturated shard and never feeds; the next feed
    // finds the queue full and the policy sheds B. B's owner must then
    // learn the *dedicated* code — `session_shed`, with a reopen hint —
    // not an indistinguishable `unknown_session`.
    let server = Server::start(
        "127.0.0.1:0",
        || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                .batch(BatchConfig::default())
                .shards(ShardConfig {
                    workers: 1,
                    rebalance_threshold: 0,
                    checkpoint_interval: 1,
                    ..ShardConfig::default()
                })
                .overload(OverloadPolicy {
                    retry_after_ms: 30,
                    shed_never_started: true,
                    ..Default::default()
                })
                .fault_reply_delay_ms(400)
                .build()?)
        },
        1,
    )
    .unwrap();
    let mut a = Client::connect(&server.addr);
    let opened = a.call(r#"{"op":"open"}"#);
    let sess_a = opened.get("session").unwrap().as_f64().unwrap() as u64;
    // 30 decoding steps of silence; the reply-delay hook then holds the
    // worker for 400 ms after the flush.
    let zeros = vec!["0"; 1520 + 29 * 1280].join(",");
    a.send(&format!(r#"{{"op":"feed","session":{sess_a},"samples":[{zeros}]}}"#));
    std::thread::sleep(Duration::from_millis(100));
    // B's open lands in the wedged shard's one queue slot.
    let mut b = Client::connect(&server.addr);
    b.send(r#"{"op":"open"}"#);
    std::thread::sleep(Duration::from_millis(50));
    // A second feed (separate connection: conn threads are serial)
    // finds the queue full; the policy sheds never-started B and
    // bounces the feed with the structured retry hint.
    let mut a2 = Client::connect(&server.addr);
    let short = vec!["0"; 1600].join(",");
    let bounced =
        a2.call(&format!(r#"{{"op":"feed","session":{sess_a},"samples":[{short}]}}"#));
    assert_eq!(code_of(&bounced).as_deref(), Some("backpressure"), "{bounced:?}");
    assert_eq!(
        bounced.get("error").unwrap().get("retry_after_ms").and_then(Json::as_f64),
        Some(30.0),
        "{bounced:?}"
    );
    // The wedged feed completes once the worker wakes; B's open reply
    // arrives with its (already shed) session id.
    assert_eq!(a.recv().get("steps").unwrap().as_f64(), Some(30.0));
    let sess_b = b.recv().get("session").unwrap().as_f64().unwrap() as u64;
    for line in [
        format!(r#"{{"op":"feed","session":{sess_b},"samples":[{short}]}}"#),
        format!(r#"{{"op":"finish","session":{sess_b}}}"#),
        format!(r#"{{"op":"resume","session":{sess_b}}}"#),
    ] {
        let r = b.call(&line);
        assert_eq!(code_of(&r).as_deref(), Some("session_shed"), "{line}: {r:?}");
        let msg = r.get("error").unwrap().get("message").unwrap().as_str().unwrap();
        assert!(msg.contains("reopen"), "shed notice must carry a reopen hint: {msg}");
    }
    // The survivor still finishes normally.
    let done = a.call(&format!(r#"{{"op":"finish","session":{sess_a}}}"#));
    assert!(done.get("text").is_some(), "{done:?}");
    server.shutdown();
}

#[test]
fn internal_error_reachable_over_socket_via_fault_hook() {
    // A server whose engine is armed to fail after one decoding step:
    // the first feed succeeds, the second hits the injected fault and
    // must surface as a structured `internal` error over the wire — the
    // previously-unreachable code path, now provoked end to end.
    let server = Server::start(
        "127.0.0.1:0",
        || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                .batch(BatchConfig::default())
                .fault_after_steps(1)
                .build()?)
        },
        64,
    )
    .unwrap();
    let mut c = Client::connect(&server.addr);
    let opened = c.call(r#"{"op":"open"}"#);
    let session = opened.get("session").unwrap().as_f64().unwrap() as u64;
    let samples: Vec<String> = (0..1600)
        .map(|i| format!("{:.4}", (i as f32 * 0.011).sin() * 0.2))
        .collect();
    let joined = samples.join(",");
    let fed = c.call(&format!(
        r#"{{"op":"feed","session":{session},"samples":[{joined}]}}"#
    ));
    assert_eq!(fed.get("steps").unwrap().as_f64(), Some(1.0), "{fed:?}");
    let failed = c.call(&format!(
        r#"{{"op":"feed","session":{session},"samples":[{joined}]}}"#
    ));
    assert_eq!(code_of(&failed).as_deref(), Some("internal"), "{failed:?}");
    let msg = failed
        .get("error")
        .unwrap()
        .get("message")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    assert!(msg.contains("injected backend fault"), "{msg}");
    // The poisoned batch discards its sessions rather than serving
    // corrupt continuations: later ops report unknown_session.
    let fin = c.call(&format!(r#"{{"op":"finish","session":{session}}}"#));
    assert_eq!(code_of(&fin).as_deref(), Some("unknown_session"), "{fin:?}");
    // The server itself keeps serving (opens still work).
    let again = c.call(r#"{"op":"open"}"#);
    assert!(again.get("session").is_some(), "{again:?}");
    server.shutdown();
}

#[test]
fn backpressure_and_shutdown_reachable_over_socket() {
    // queue_depth 1: the router queue and every shard queue hold one
    // job each. Prober threads hammer open/finish pairs continuously
    // while a big feed (30 s of silence: (480000 − 1520) / 1280 + 1 =
    // 374 decoding steps) lands on the same worker. No sleeps: either
    // the big feed finds a probe job in the shard's one-slot queue and
    // bounces (backpressure observed directly), or it is accepted and
    // occupies the worker for the whole 374-step flush — during which
    // the still-probing threads (at most one can hold the queue slot;
    // the rest keep looping because opens are answered immediately,
    // never parked behind a flush) must bounce. Either way
    // `backpressure` is reached over the wire, in debug or release.
    let server = start_server(1);
    let mut a = Client::connect(&server.addr);
    let opened = a.call(r#"{"op":"open"}"#);
    let session = opened.get("session").unwrap().as_f64().unwrap() as u64;

    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let probers: Vec<_> = (0..4)
        .map(|_| {
            let addr = server.addr.clone();
            let stop = std::sync::Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr);
                let mut saw = false;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) && !saw {
                    // Open/finish pairs: answered immediately by the
                    // worker (never parked behind a batch flush, unlike
                    // feeds), so probers keep probing *during* the big
                    // flush — and sessions never accumulate.
                    let resp = c.call(r#"{"op":"open"}"#);
                    if code_of(&resp).as_deref() == Some("backpressure") {
                        saw = true;
                        break;
                    }
                    if let Some(id) = resp.get("session").and_then(Json::as_f64) {
                        let fin =
                            c.call(&format!(r#"{{"op":"finish","session":{id}}}"#));
                        if code_of(&fin).as_deref() == Some("backpressure") {
                            saw = true;
                        }
                    }
                }
                saw
            })
        })
        .collect();

    let zeros = vec!["0"; 480_000].join(",");
    a.send(&format!(r#"{{"op":"feed","session":{session},"samples":[{zeros}]}}"#));
    let fed = a.recv();
    let big_feed_bounced = code_of(&fed).as_deref() == Some("backpressure");
    if !big_feed_bounced {
        assert_eq!(fed.get("steps").unwrap().as_f64(), Some(374.0), "{fed:?}");
    }
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let prober_saw = probers
        .into_iter()
        .map(|h| h.join().expect("prober panicked"))
        .fold(false, |acc, saw| acc || saw);
    assert!(
        big_feed_bounced || prober_saw,
        "queue_depth=1 under concurrent load must bounce some request"
    );
    // The server keeps serving correctly after shedding load.
    let done = a.call(&format!(r#"{{"op":"finish","session":{session}}}"#));
    assert!(done.get("text").is_some(), "{done:?}");
    let mut b = Client::connect(&server.addr);
    assert!(b.call(r#"{"op":"stats"}"#).get("summary").is_some());

    // shutdown: once the router is gone, new requests get the
    // `shutdown` code. The shutdown message competes for the bounded
    // queue and the router drains briefly, so re-issue + poll.
    let mut saw_shutdown = false;
    for _ in 0..100 {
        server.shutdown();
        let mut probe = Client::connect(&server.addr);
        if code_of(&probe.call(r#"{"op":"open"}"#)).as_deref() == Some("shutdown") {
            saw_shutdown = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(saw_shutdown, "post-shutdown requests must report the shutdown code");
}
