//! Simulator-side integration: the Table 1 command flow drives the same
//! decoding-step model the reports use; simulator outputs respect
//! cross-mode and cross-config invariants (these are the properties the
//! paper's evaluation numbers rest on).

use asrpu::accel::{
    build_step_kernels, simulate_step, AsrpuDevice, Command, HypWorkload, KernelClass,
    SimMode,
};
use asrpu::config::{AccelConfig, ModelConfig, PipelineDesc};
use asrpu::power::{step_energy_j, ChipBudget};
use asrpu::util::prop;

#[test]
fn device_command_flow_matches_direct_simulation() {
    let accel = AccelConfig::paper();
    let model = ModelConfig::paper_tds();
    let direct = simulate_step(&model, &accel, &HypWorkload::default(), SimMode::Ideal);
    let mut dev = AsrpuDevice::new(accel, model, SimMode::Ideal).unwrap();
    dev.configure_all(14.0).unwrap();
    dev.issue(Command::DecodingStep { signal_addr: 0 }).unwrap();
    let via_device = dev.last_step.as_ref().unwrap();
    assert_eq!(via_device.total_cycles, direct.total_cycles);
    assert_eq!(via_device.kernels.len(), direct.kernels.len());
}

#[test]
fn ideal_is_lower_bound_of_detailed_under_random_configs() {
    prop::check("ideal<=detailed", 25, |g| {
        let mut accel = AccelConfig::paper();
        accel.num_pes = 1 + g.index(16);
        accel.mac_vector_width = 1 << g.index(5);
        accel.ext_mem_bw_bytes_per_s = 200_000_000 + g.index(8) as u64 * 2_000_000_000;
        accel.frequency_hz = 100_000_000 + g.index(10) as u64 * 100_000_000;
        if accel.validate().is_err() {
            return Ok(());
        }
        let model = ModelConfig::paper_tds();
        let hyp = HypWorkload {
            n_hyps: 1 + g.index(384) as u64,
            avg_children: 1.0 + g.rng.f64() * 20.0,
            word_commit_frac: g.rng.f64() * 0.5,
            ..Default::default()
        };
        let ideal = simulate_step(&model, &accel, &hyp, SimMode::Ideal);
        let detailed = simulate_step(&model, &accel, &hyp, SimMode::Detailed);
        crate::sim_props::assert_report_invariants(&ideal)?;
        crate::sim_props::assert_report_invariants(&detailed)?;
        asrpu::prop_assert!(
            detailed.total_cycles >= ideal.total_cycles,
            "detailed {} < ideal {}",
            detailed.total_cycles,
            ideal.total_cycles
        );
        // Same work in both modes.
        asrpu::prop_assert!(
            detailed.total_instrs == ideal.total_instrs,
            "instruction counts differ between modes"
        );
        Ok(())
    });
}

mod sim_props {
    use asrpu::accel::StepReport;

    pub fn assert_report_invariants(r: &StepReport) -> Result<(), String> {
        if r.kernels.is_empty() {
            return Err("no kernels".into());
        }
        let mut prev_end = 0;
        for k in &r.kernels {
            if k.start < prev_end {
                return Err(format!("kernel {} starts before predecessor ends", k.name));
            }
            if k.end < k.start {
                return Err(format!("kernel {} ends before start", k.name));
            }
            prev_end = k.end;
        }
        if prev_end != r.total_cycles {
            return Err("total_cycles != last kernel end".into());
        }
        let sum_instr: u64 = r.kernels.iter().map(|k| k.instrs).sum();
        if sum_instr != r.total_instrs {
            return Err("instruction sum mismatch".into());
        }
        Ok(())
    }
}

#[test]
fn throughput_scales_sublinearly_but_monotonically_with_pes() {
    let model = ModelConfig::paper_tds();
    let mut prev = u64::MAX;
    for pes in [1, 2, 4, 8, 16, 32] {
        let accel = AccelConfig { num_pes: pes, ..AccelConfig::paper() };
        let r = simulate_step(&model, &accel, &HypWorkload::default(), SimMode::Ideal);
        assert!(r.total_cycles <= prev, "{pes} PEs slower than fewer");
        prev = r.total_cycles;
    }
}

#[test]
fn energy_decreases_per_step_with_more_pes_despite_higher_power() {
    // More PEs burn more watts but finish sooner; leakage amortizes, so
    // energy/step falls (the design_space result) — pin it as a test.
    let model = ModelConfig::paper_tds();
    let e = |pes: usize| {
        let accel = AccelConfig { num_pes: pes, ..AccelConfig::paper() };
        let r = simulate_step(&model, &accel, &HypWorkload::default(), SimMode::Ideal);
        step_energy_j(&r, &accel)
    };
    assert!(e(8) < e(2), "energy should fall from 2 to 8 PEs");
}

#[test]
fn mac_width_only_affects_dot_product_kernels() {
    let model = ModelConfig::paper_tds();
    let a8 = AccelConfig::paper();
    let a16 = AccelConfig { mac_vector_width: 16, ..AccelConfig::paper() };
    let pipe = PipelineDesc::for_model(&model);
    let k8 = build_step_kernels(&pipe, &a8, &HypWorkload::default(), 1);
    let k16 = build_step_kernels(&pipe, &a16, &HypWorkload::default(), 1);
    for (x, y) in k8.iter().zip(&k16) {
        match x.class {
            KernelClass::Conv | KernelClass::Fc => {
                assert!(y.instr_per_thread < x.instr_per_thread, "{}", x.name)
            }
            _ => assert_eq!(x.instr_per_thread, y.instr_per_thread, "{}", x.name),
        }
    }
}

#[test]
fn hypothesis_workload_scales_hyp_phase_only() {
    let model = ModelConfig::paper_tds();
    let accel = AccelConfig::paper();
    let small = simulate_step(
        &model,
        &accel,
        &HypWorkload { n_hyps: 16, ..Default::default() },
        SimMode::Ideal,
    );
    let large = simulate_step(
        &model,
        &accel,
        &HypWorkload { n_hyps: 384, ..Default::default() },
        SimMode::Ideal,
    );
    assert_eq!(small.acoustic_cycles, large.acoustic_cycles);
    assert!(large.hyp_cycles > small.hyp_cycles);
}

#[test]
fn measured_engine_stats_drive_hyp_unit_rounds() {
    // The HypUnit model is fed from *measured* per-session arc counts
    // (the decoder's PruneStats/ExpandStats), not synthetic workloads:
    // a real decode's counters parameterize both the step simulator's
    // hyp phase and the unit's insert-sort round model.
    use asrpu::accel::HypUnit;
    use asrpu::am::TdsModel;
    use asrpu::coordinator::Engine;

    let engine = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 3))
        .build()
        .unwrap();
    let mut s = engine.open(false).unwrap();
    let samples: Vec<f32> =
        (0..1520 + 9 * 1280).map(|i| (i as f32 * 0.013).sin() * 0.3).collect();
    engine.feed(&mut s, &samples).unwrap();
    let prune = s.decode.stats;
    let expand = s.decode.expand;
    assert!(prune.generated > 0 && prune.rounds > 0, "{prune:?}");
    assert_eq!(expand.generated(), prune.generated, "expansion/prune books disagree");

    let hyp = HypWorkload::from_measured(&prune, &expand);
    assert!(hyp.n_hyps > 0, "{hyp:?}");
    assert!(hyp.avg_children > 0.0, "{hyp:?}");
    assert!((0.0..=1.0).contains(&hyp.word_commit_frac), "{hyp:?}");
    let model = ModelConfig::paper_tds();
    let accel = AccelConfig::paper();
    let sim = simulate_step(&model, &accel, &hyp, SimMode::Ideal);
    assert!(sim.hyp_cycles > 0);

    let unit = HypUnit::new(&accel);
    let round = unit.round_from_stats(&prune);
    assert!(round.insert_cycles > 0);
    assert_eq!(
        round.insert_cycles,
        unit.round(
            prune.generated / prune.rounds,
            (prune.generated - prune.merged - prune.beam_pruned) / prune.rounds
        )
        .insert_cycles
    );
}

#[test]
fn area_power_budget_consistent_across_sweep() {
    for pes in [1, 4, 8, 16] {
        for mem_kb in [256usize, 512, 1024, 2048] {
            let accel = AccelConfig {
                num_pes: pes,
                shared_mem_bytes: mem_kb << 10,
                ..AccelConfig::paper()
            };
            let b = ChipBudget::for_config(&accel);
            assert!(b.total_area_mm2() > 0.0);
            assert!(b.total_peak_w() > b.total_leakage_w());
            let sum: f64 = b.components.iter().map(|c| c.area_mm2).sum();
            assert!((sum - b.total_area_mm2()).abs() < 1e-9);
        }
    }
}
