//! Counting-allocator proof that the AM hot path is allocation-free in
//! steady state: after one warm-up step that grows the scratch arena to
//! its high-water mark, `step_batch_into` must perform ZERO heap
//! allocations per fused step — for both the f32 and the int8 model.
//!
//! This file intentionally holds a SINGLE `#[test]` function: the
//! counting `#[global_allocator]` is process-wide, and libtest runs
//! tests in one binary concurrently; a second test would pollute the
//! counter. Engine-level steady-state reuse is asserted separately via
//! pointer/capacity fingerprints (see `coordinator::engine` and
//! `decoder` unit tests), because a full engine step includes the
//! per-utterance backtrack arena, which legitimately grows
//! (amortized-O(log) reallocations per utterance) as words are
//! committed.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use asrpu::am::{QuantizedTdsModel, Scratch, TdsModel, TdsState};
use asrpu::config::ModelConfig;
use asrpu::util::rng::Rng;

struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

fn run_steady_state(label: &str, step: &mut dyn FnMut(), warmups: usize, measured: usize) {
    for _ in 0..warmups {
        step();
    }
    let before = allocs();
    for _ in 0..measured {
        step();
    }
    let during = allocs() - before;
    assert_eq!(
        during, 0,
        "{label}: {during} heap allocations across {measured} steady-state steps"
    );
}

#[test]
fn steady_state_am_step_batch_is_allocation_free() {
    let batch = 4;
    let f32_model = TdsModel::random(ModelConfig::tiny_tds(), 7);
    let int8_model = QuantizedTdsModel::from_model(&f32_model).unwrap();
    let f = f32_model.cfg.frames_per_step() * f32_model.cfg.n_mels;
    let mut rng = Rng::new(99);
    let feats: Vec<f32> = (0..batch * f).map(|_| rng.uniform(-1.0, 1.0)).collect();

    // f32 path.
    {
        let mut states: Vec<TdsState> = (0..batch).map(|_| f32_model.state()).collect();
        let mut refs: Vec<&mut TdsState> = states.iter_mut().collect();
        let mut sc = Scratch::default();
        let mut out = Vec::new();
        run_steady_state(
            "f32 step_batch_into",
            &mut || f32_model.step_batch_into(&mut refs[..], &feats, &mut sc, &mut out),
            2,
            8,
        );
        assert!(!out.is_empty());
    }

    // int8 path (extra scratch user: the per-lane/window partial sums).
    {
        let mut states: Vec<TdsState> = (0..batch).map(|_| int8_model.state()).collect();
        let mut refs: Vec<&mut TdsState> = states.iter_mut().collect();
        let mut sc = Scratch::default();
        let mut out = Vec::new();
        run_steady_state(
            "int8 step_batch_into",
            &mut || int8_model.step_batch_into(&mut refs[..], &feats, &mut sc, &mut out),
            2,
            8,
        );
        assert!(!out.is_empty());
    }
}
