//! Property tests for the serving `Batcher` (`util::prop` style):
//! staging is an insertion-ordered set (no duplicate ids), `take`
//! empties and resets the deadline, `remove` deletes, and the wait
//! budget only ever shrinks toward the deadline while lanes are
//! pending.

use std::collections::HashSet;

use asrpu::config::{BatchConfig, ModelConfig};
use asrpu::coordinator::Batcher;
use asrpu::prop_assert;
use asrpu::util::prop;

#[test]
fn batcher_matches_ordered_set_model() {
    // Model-based property: drive a random push/remove/take/observe
    // sequence against a reference insertion-ordered unique list.
    let model_cfg = ModelConfig::tiny_tds();
    prop::check("batcher-ordered-set", 200, |g| {
        let max_batch = 1 + g.index(6);
        let cfg = BatchConfig { max_batch, max_wait_frames: g.index(10) };
        let max_wait = cfg.max_wait(&model_cfg);
        let mut b = Batcher::new(cfg, &model_cfg);
        let mut reference: Vec<u64> = Vec::new();
        let ops = g.len(1).min(40);
        for _ in 0..ops {
            match g.index(4) {
                0 => {
                    // push: idempotent staging; reports fullness.
                    let id = g.index(8) as u64;
                    let full = b.push(id);
                    if !reference.contains(&id) {
                        reference.push(id);
                    }
                    prop_assert!(
                        full == (reference.len() >= max_batch),
                        "push fullness: got {full}, {} staged of {max_batch}",
                        reference.len()
                    );
                    prop_assert!(b.contains(id), "pushed id {id} not staged");
                }
                1 => {
                    // remove: deletes; an empty batcher resets its clock.
                    let id = g.index(8) as u64;
                    b.remove(id);
                    reference.retain(|&p| p != id);
                    prop_assert!(!b.contains(id), "removed id {id} still staged");
                    if reference.is_empty() {
                        prop_assert!(
                            b.wait_budget() == max_wait,
                            "empty batcher must reset its wait budget"
                        );
                    }
                }
                2 => {
                    // take: drains everything in insertion order, once.
                    let ids = b.take();
                    prop_assert!(
                        ids == reference,
                        "take returned {ids:?}, model has {reference:?}"
                    );
                    let unique: HashSet<&u64> = ids.iter().collect();
                    prop_assert!(unique.len() == ids.len(), "duplicate ids in {ids:?}");
                    reference.clear();
                    prop_assert!(b.is_empty(), "take must empty the batcher");
                    prop_assert!(
                        b.wait_budget() == max_wait,
                        "take must reset the wait budget"
                    );
                }
                _ => {
                    // observers agree with the model.
                    prop_assert!(
                        b.len() == reference.len(),
                        "len {} != model {}",
                        b.len(),
                        reference.len()
                    );
                    prop_assert!(
                        b.is_empty() == reference.is_empty(),
                        "is_empty mismatch"
                    );
                    prop_assert!(
                        b.is_full() == (reference.len() >= max_batch),
                        "is_full mismatch at {} of {max_batch}",
                        reference.len()
                    );
                    prop_assert!(
                        b.wait_budget() <= max_wait,
                        "budget above the configured maximum"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn wait_budget_shrinks_monotonically_toward_deadline() {
    // Once a lane is staged the clock runs: successive reads never
    // grow, later pushes never extend the deadline (it belongs to the
    // *oldest* lane), and the budget hits zero at the deadline.
    let model_cfg = ModelConfig::tiny_tds();
    prop::check("batcher-budget-monotone", 8, |g| {
        let cfg = BatchConfig { max_batch: 64, max_wait_frames: 1 + g.index(3) };
        let max_wait = cfg.max_wait(&model_cfg);
        let mut b = Batcher::new(cfg, &model_cfg);
        prop_assert!(b.wait_budget() == max_wait, "idle batcher has the full budget");
        b.push(1);
        let mut prev = b.wait_budget();
        prop_assert!(prev <= max_wait, "staged budget above maximum");
        for i in 0..6 {
            if g.bool() {
                b.push(2 + i as u64); // lane-mates never extend the deadline
            }
            let now = b.wait_budget();
            prop_assert!(now <= prev, "budget grew: {now:?} > {prev:?}");
            prev = now;
        }
        // Sleep past the deadline: the budget must saturate at zero.
        std::thread::sleep(max_wait);
        prop_assert!(
            b.wait_budget().is_zero(),
            "budget not exhausted at the deadline: {:?}",
            b.wait_budget()
        );
        // And draining restores the full budget for the next batch.
        let _ = b.take();
        prop_assert!(b.wait_budget() == max_wait, "take must re-arm the budget");
        Ok(())
    });
}
