//! Chaos soak: concurrent clients stream over real sockets while fault
//! hooks make the serving stack misbehave — every flushed feed reply is
//! delayed (slow-shard simulation) and one worker dies spontaneously
//! mid-soak (injected engine panic, no kill request anywhere). The
//! contract under test: **zero acknowledged-feed loss**. Every request
//! gets a structured answer, every session finishes, and every
//! transcript is bit-identical to an undisturbed single-engine decode
//! of exactly the audio that was acknowledged.
//!
//! Why the chaos is deterministic: session→shard assignment is a pure
//! function of open order, the panic hook fires on a per-worker step
//! odometer, and the workload is sized so the doomed shard's budget
//! (20 steps) always runs out while its one heavy session is still
//! feeding (24 steps), while the survivor — its own light session,
//! the recovered remainder, and two post-recovery sessions, ≤ 16 steps
//! in the worst case — never exhausts its identical budget.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, ModelConfig, OverloadPolicy, ShardConfig};
use asrpu::coordinator::{Engine, Server};
use asrpu::util::json::Json;
use asrpu::util::rng::Rng;

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn call(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    }

    fn open(&mut self) -> u64 {
        self.call(r#"{"op":"open"}"#).get("session").unwrap().as_f64().unwrap() as u64
    }
}

const STEP_SAMPLES: usize = 1520; // samples_per_step(tiny_tds)
const STEP_LEN: usize = 1280; // step_len(tiny_tds)

/// Audio worth exactly `steps` decoding steps during feeding; the
/// 240-sample remainder pads out to exactly one more step at finish.
fn audio_for(steps: usize) -> usize {
    STEP_SAMPLES + (steps - 1) * STEP_LEN
}

/// Stream `fed_steps` worth of silence in seeded-random chunks with
/// seeded-random pauses, asserting every single feed is acknowledged
/// with a step count; returns the total steps acknowledged.
fn stream(c: &mut Client, id: u64, fed_steps: usize, seed: u64) -> f64 {
    let total = audio_for(fed_steps);
    let mut rng = Rng::new(seed);
    let mut sent = 0usize;
    let mut acked = 0.0;
    while sent < total {
        let chunk =
            (STEP_LEN / 2 + (rng.next_u64() as usize % (2 * STEP_LEN))).min(total - sent);
        let zeros = vec!["0"; chunk].join(",");
        let fed =
            c.call(&format!(r#"{{"op":"feed","session":{id},"samples":[{zeros}]}}"#));
        // Zero acknowledged-feed loss: every request gets a normal
        // structured ack — including the one held by the dying worker,
        // which must replay on the recovery shard, not bounce.
        let steps = fed.get("steps").and_then(Json::as_f64);
        assert!(steps.is_some(), "feed lost for session {id}: {fed:?}");
        acked += steps.unwrap();
        sent += chunk;
        std::thread::sleep(std::time::Duration::from_millis(rng.next_u64() % 3));
    }
    acked
}

/// Finish `id` and check the full ledger: finish covers exactly the
/// acked feed steps plus the one padded tail step, and the transcript
/// is bit-identical to an undisturbed decode of the same audio.
fn check_finish(c: &mut Client, reference: &Engine, id: u64, fed_steps: usize) {
    let done = c.call(&format!(r#"{{"op":"finish","session":{id}}}"#));
    assert_eq!(
        done.get("steps").and_then(Json::as_f64),
        Some((fed_steps + 1) as f64),
        "session {id}: {done:?}"
    );
    let (t_ref, _) = reference.decode_utterance(&vec![0.0; audio_for(fed_steps)]).unwrap();
    assert_eq!(
        done.get("text").and_then(Json::as_str),
        Some(t_ref.text.as_str()),
        "session {id}: {done:?}"
    );
    assert_eq!(done.get("score").and_then(Json::as_f64), Some(t_ref.score as f64));
}

#[test]
fn chaos_soak_loses_no_acknowledged_feeds() {
    let server = Server::start(
        "127.0.0.1:0",
        || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                .batch(BatchConfig::default())
                .shards(ShardConfig {
                    workers: 2,
                    rebalance_threshold: 0,
                    checkpoint_interval: 1,
                    ..ShardConfig::default()
                })
                .overload(OverloadPolicy::default())
                .fault_panic_after_steps(20)
                .fault_reply_delay_ms(1)
                .build()?)
        },
        64,
    )
    .unwrap();

    // Open before feeding so placement is a pure function of order:
    // the heavy session books shard 0, the light one shard 1.
    let mut main = Client::connect(&server.addr);
    let heavy = main.open();
    let light = main.open();
    assert_eq!((heavy, light), (1, 2));

    let reference =
        Engine::builder().native(TdsModel::random(ModelConfig::tiny_tds(), 5)).build().unwrap();
    let addr = server.addr.clone();
    let light_thread = std::thread::spawn(move || {
        let mut c = Client::connect(&addr);
        let acked = stream(&mut c, light, 2, 77);
        (c, acked)
    });
    // 24 steps against a 20-step budget: shard 0's worker always dies
    // while this session is still mid-stream, holding one of these very
    // feeds staged or queued. The client never notices: detection,
    // checkpoint re-adoption and staged-feed replay happen behind the
    // blocked request.
    let mut c_heavy = Client::connect(&server.addr);
    let acked_heavy = stream(&mut c_heavy, heavy, 24, 78);
    assert_eq!(acked_heavy, 24.0, "heavy session acked-step ledger");
    let (mut c_light, acked_light) = light_thread.join().expect("light client panicked");
    assert_eq!(acked_light, 2.0, "light session acked-step ledger");

    check_finish(&mut c_heavy, &reference, heavy, 24);
    check_finish(&mut c_light, &reference, light, 2);

    // The pool keeps serving after the death: new sessions land on the
    // survivor and decode normally.
    for _ in 0..2 {
        let id = main.open();
        let acked = stream(&mut main, id, 1, 100 + id);
        assert_eq!(acked, 1.0);
        check_finish(&mut main, &reference, id, 1);
    }

    // The chaos actually happened, exactly as armed: one spontaneous
    // death, detected by the supervisor (no kill request exists in this
    // test), and one session recovered across it.
    let stats = main.call(r#"{"op":"stats"}"#);
    assert_eq!(stats.get("workers").unwrap().as_f64(), Some(2.0));
    assert_eq!(stats.get("responding").unwrap().as_f64(), Some(1.0), "{stats:?}");
    assert_eq!(stats.get("panics_detected").unwrap().as_f64(), Some(1.0), "{stats:?}");
    assert_eq!(stats.get("recovered").unwrap().as_f64(), Some(1.0), "{stats:?}");
    server.shutdown();
}

/// One feed of `n` zero samples, asserting it is acknowledged; returns
/// the acked step count.
fn feed_zeros(c: &mut Client, id: u64, n: usize) -> f64 {
    let zeros = vec!["0"; n].join(",");
    let fed = c.call(&format!(r#"{{"op":"feed","session":{id},"samples":[{zeros}]}}"#));
    let steps = fed.get("steps").and_then(Json::as_f64);
    assert!(steps.is_some(), "feed lost for session {id}: {fed:?}");
    steps.unwrap()
}

#[test]
fn teardown_window_jobs_replay_after_death() {
    // The dying worker's death report is artificially delayed (fault
    // hook) so a job can land in the dead shard's channel *between* the
    // panic-time queue rescue and the router observing the death — the
    // PR 7 teardown window. The liveness report carries the dying
    // channel's receiver, and the router drains it into the same orphan
    // replay as the rescued jobs: the client blocked on that feed gets
    // its normal ack, never a one-shot bounce.
    let server = Server::start(
        "127.0.0.1:0",
        || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                .batch(BatchConfig::default())
                .shards(ShardConfig {
                    workers: 2,
                    rebalance_threshold: 0,
                    checkpoint_interval: 1,
                    ..ShardConfig::default()
                })
                .overload(OverloadPolicy::default())
                .fault_panic_after_steps(5)
                .fault_teardown_delay_ms(400)
                .build()?)
        },
        64,
    )
    .unwrap();

    // Placement by open order: a → shard 0, filler → shard 1,
    // b → shard 0 (the session whose feed lands in the window).
    let mut main = Client::connect(&server.addr);
    let a = main.open();
    let filler = main.open();
    let b = main.open();
    assert_eq!((a, filler, b), (1, 2, 3));

    // Exhaust shard 0's five-step fault budget on `a`.
    assert_eq!(feed_zeros(&mut main, a, STEP_SAMPLES), 1.0);
    for _ in 0..4 {
        assert_eq!(feed_zeros(&mut main, a, STEP_LEN), 1.0);
    }
    // The killer feed panics the worker mid-flush; its client blocks
    // until recovery replays the staged feed on the survivor.
    let addr = server.addr.clone();
    let killer = std::thread::spawn(move || {
        let mut c = Client::connect(&addr);
        feed_zeros(&mut c, a, STEP_LEN)
    });
    // While the dying worker sleeps in its widened teardown window, a
    // feed for `b` goes into the doomed channel. Whatever the exact
    // interleaving (limbo, rescued from the queue, or post-recovery
    // reroute), it must be acknowledged with its step — never bounced.
    std::thread::sleep(std::time::Duration::from_millis(100));
    assert_eq!(feed_zeros(&mut main, b, STEP_SAMPLES), 1.0);
    assert_eq!(killer.join().expect("killer client panicked"), 1.0);

    let res = main.call(&format!(r#"{{"op":"resume","session":{a}}}"#));
    assert_eq!(res.get("steps").and_then(Json::as_f64), Some(6.0), "{res:?}");
    let reference =
        Engine::builder().native(TdsModel::random(ModelConfig::tiny_tds(), 5)).build().unwrap();
    check_finish(&mut main, &reference, a, 6);
    check_finish(&mut main, &reference, b, 1);
    let stats = main.call(r#"{"op":"stats"}"#);
    assert_eq!(stats.get("responding").unwrap().as_f64(), Some(1.0), "{stats:?}");
    assert_eq!(stats.get("panics_detected").unwrap().as_f64(), Some(1.0), "{stats:?}");
    server.shutdown();
}

#[test]
fn churn_soak_add_drain_cycles_lose_nothing() {
    // Elastic churn as the chaos source: while four clients stream on
    // shard 0, the pool repeatedly scales up (`pool add`), takes a live
    // session onto the new worker, and drains it away again mid-
    // utterance. The same contract as the panic soak: every feed is
    // acked, every transcript is bit-identical to the undisturbed
    // single-engine decode, and the pool lands back on one worker.
    let server = Server::start(
        "127.0.0.1:0",
        || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                .batch(BatchConfig::default())
                .shards(ShardConfig {
                    workers: 1,
                    rebalance_threshold: 0,
                    checkpoint_interval: 1,
                    max_workers: 3,
                    ..ShardConfig::default()
                })
                .overload(OverloadPolicy::default())
                .build()?)
        },
        64,
    )
    .unwrap();

    // Open every long-lived session before the first add so placement
    // is a pure function of open order: all of them book shard 0. The
    // pin keeps shard 0 strictly busier than a fresh worker, so each
    // cycle's churn session deterministically books the new shard.
    let mut main = Client::connect(&server.addr);
    let pin = main.open();
    let streamer_ids: Vec<u64> = (0..4).map(|_| main.open()).collect();
    assert_eq!(pin, 1);
    assert_eq!(streamer_ids, vec![2, 3, 4, 5]);

    let reference =
        Engine::builder().native(TdsModel::random(ModelConfig::tiny_tds(), 5)).build().unwrap();
    let streamers: Vec<_> = streamer_ids
        .iter()
        .map(|&id| {
            let addr = server.addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr);
                let acked = stream(&mut c, id, 6, 500 + id);
                (c, id, acked)
            })
        })
        .collect();

    // Three add → serve → drain cycles against the streaming load.
    let mut churn = Client::connect(&server.addr);
    for cycle in 0..3u64 {
        let added = churn.call(r#"{"op":"pool","action":"add"}"#);
        let shard = added.get("shard").and_then(Json::as_f64).expect("add refused") as usize;
        assert_eq!(shard, cycle as usize + 1, "{added:?}");
        // The churn session books the fresh (empty) worker, decodes two
        // acked steps there, survives the drain's live migration back
        // to shard 0, and decodes two more.
        let id = churn.open();
        assert_eq!(feed_zeros(&mut churn, id, STEP_SAMPLES + STEP_LEN), 2.0);
        let drained =
            churn.call(&format!(r#"{{"op":"pool","action":"drain","shard":{shard}}}"#));
        assert_eq!(drained.get("state").and_then(Json::as_str), Some("retired"), "{drained:?}");
        assert_eq!(drained.get("migrated").and_then(Json::as_f64), Some(1.0), "{drained:?}");
        assert_eq!(feed_zeros(&mut churn, id, 2 * STEP_LEN), 2.0);
        check_finish(&mut churn, &reference, id, 4);
    }

    for s in streamers {
        let (mut c, id, acked) = s.join().expect("streamer panicked");
        assert_eq!(acked, 6.0, "session {id} acked-step ledger");
        check_finish(&mut c, &reference, id, 6);
    }
    assert_eq!(feed_zeros(&mut main, pin, STEP_SAMPLES), 1.0);
    check_finish(&mut main, &reference, pin, 1);

    // The pool is back to one worker, with the churn history visible.
    let status = churn.call(r#"{"op":"pool","action":"status"}"#);
    assert_eq!(status.get("workers").unwrap().as_f64(), Some(1.0), "{status:?}");
    assert_eq!(status.get("draining").unwrap().as_f64(), Some(0.0), "{status:?}");
    let lifecycles: Vec<&str> = status
        .get("shards")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.get("lifecycle").unwrap().as_str().unwrap())
        .collect();
    assert_eq!(lifecycles, vec!["active", "retired", "retired", "retired"]);
    let stats = churn.call(r#"{"op":"stats"}"#);
    assert_eq!(stats.get("workers").unwrap().as_f64(), Some(1.0), "{stats:?}");
    assert_eq!(stats.get("retired").unwrap().as_f64(), Some(3.0), "{stats:?}");
    server.shutdown();
}
