//! Elastic-pool invariants (PR 9): the worker count changes *while the
//! pool serves* — `add_worker` scales up from the startup engine
//! template, `drain_worker` pipeline-migrates a shard empty and retires
//! it — and none of it may be visible in a transcript. Every scenario
//! here decodes the same audio through a pool whose shape churns
//! mid-utterance and asserts the result is **bit-identical** (text AND
//! exact score) to the static 1-worker engine, for f32 and int8.
//!
//! Why it must hold: sessions travel between shards as full state
//! snapshots (the PR 5 evict → snapshot → adopt → restore path), every
//! worker decodes from the same shared weights, and per-session decode
//! state never crosses lanes — so adding a worker, migrating onto it,
//! and retiring the donor are all transcript-invisible by construction.
//! These tests drive the real router + worker threads (no sockets, no
//! serialization), so equality really is bit-equality.

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, ModelConfig, Precision, ShardConfig};
use asrpu::coordinator::{Engine, ShardPool};
use asrpu::synth::Synthesizer;
use asrpu::util::rng::Rng;

const MODEL_SEED: u64 = 17;

fn reference_engine(precision: Precision) -> Engine {
    Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
        .precision(precision)
        .build()
        .unwrap()
}

fn pool(precision: Precision, workers: usize, max_workers: usize) -> ShardPool {
    ShardPool::start(
        move || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
                .precision(precision)
                .batch(BatchConfig { max_batch: 4, max_wait_frames: 2 })
                .shards(ShardConfig {
                    workers,
                    rebalance_threshold: 0,
                    checkpoint_interval: 1,
                    max_workers,
                    ..ShardConfig::default()
                })
                .build()?)
        },
        256,
    )
    .unwrap()
}

fn utterances(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let synth = Synthesizer::default();
    (0..n as u64)
        .map(|i| {
            let mut rng = Rng::new(seed + i);
            synth
                .render(&[(i % 10) as u32, ((i + 5) % 10) as u32], &mut rng)
                .samples
        })
        .collect()
}

fn reference_transcripts(precision: Precision, utts: &[Vec<f32>]) -> Vec<(String, f64)> {
    let engine = reference_engine(precision);
    utts.iter()
        .map(|u| {
            let (t, _) = engine.decode_utterance(u).unwrap();
            (t.text, t.score as f64)
        })
        .collect()
}

/// Per-shard lifecycle strings from `pool status`, indexed by shard.
fn lifecycles(p: &ShardPool) -> Vec<String> {
    p.pool_status()
        .unwrap()
        .get("shards")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.get("lifecycle").unwrap().as_str().unwrap().to_string())
        .collect()
}

#[test]
fn scale_up_1_to_4_under_live_load_stays_bit_identical() {
    // Start with one worker, scale to four while eight client threads
    // are mid-utterance. Sessions opened before the adds stay put;
    // later opens land on the new workers; transcripts never notice.
    let p = pool(Precision::F32, 1, 4);
    assert_eq!(p.workers(), 1);
    let utts = utterances(8, 300);
    let expected = reference_transcripts(Precision::F32, &utts);
    let handles: Vec<_> = utts
        .iter()
        .cloned()
        .enumerate()
        .map(|(i, audio)| {
            let client = p.clone();
            std::thread::spawn(move || {
                let id = client.open().unwrap();
                for c in audio.chunks(900) {
                    client.feed(id, c).unwrap();
                }
                let done = client.finish(id).unwrap();
                (i, done.text, done.score)
            })
        })
        .collect();
    // Scale up while the clients stream.
    for expect_shard in [1usize, 2, 3] {
        assert_eq!(p.add_worker().unwrap(), expect_shard);
    }
    // The pool is at its ceiling: a fourth add must be refused, not
    // spawn worker five.
    let err = format!("{:#}", p.add_worker().unwrap_err());
    assert!(err.contains("max_workers"), "{err}");
    for h in handles {
        let (i, text, score) = h.join().expect("client thread panicked");
        assert_eq!(text, expected[i].0, "utt {i} text diverged during scale-up");
        assert_eq!(score, expected[i].1, "utt {i} score diverged during scale-up");
    }
    let status = p.pool_status().unwrap();
    assert_eq!(status.get("workers").unwrap().as_f64(), Some(4.0));
    assert_eq!(status.get("max_workers").unwrap().as_f64(), Some(4.0));
    assert_eq!(lifecycles(&p), vec!["active"; 4]);
    // The grown pool serves new sessions on every shard.
    let late = utterances(4, 900);
    let late_expected = reference_transcripts(Precision::F32, &late);
    for (u, e) in late.iter().zip(&late_expected) {
        let id = p.open().unwrap();
        p.feed(id, u).unwrap();
        let done = p.finish(id).unwrap();
        assert_eq!(done.text, e.0);
        assert_eq!(done.score, e.1);
    }
    p.shutdown();
}

#[test]
fn drain_4_to_1_mid_utterance_stays_bit_identical() {
    // Eight sessions spread over four workers, each fed half its audio;
    // then shards 3, 2, 1 drain in turn — every resident migrates live,
    // state travelling as snapshots — and the second half decodes on
    // the sole survivor. Transcripts must match the static 1-worker
    // engine bit for bit, in both precisions.
    for precision in [Precision::F32, Precision::Int8] {
        let p = pool(precision, 4, 4);
        let utts = utterances(8, 500);
        let expected = reference_transcripts(precision, &utts);
        let ids: Vec<u64> = (0..8).map(|_| p.open().unwrap()).collect();
        for (id, u) in ids.iter().zip(&utts) {
            p.feed(*id, &u[..u.len() / 2]).unwrap();
        }
        let mut migrated = 0;
        for shard in [3usize, 2, 1] {
            migrated += p.drain_worker(shard).unwrap();
        }
        assert!(
            migrated >= 6,
            "the six sessions opened off shard 0 must migrate at least once: {migrated}"
        );
        assert_eq!(lifecycles(&p), vec!["active", "retired", "retired", "retired"]);
        let status = p.pool_status().unwrap();
        assert_eq!(status.get("workers").unwrap().as_f64(), Some(1.0));
        // Draining the last active worker must be refused.
        let err = format!("{:#}", p.drain_worker(0).unwrap_err());
        assert!(err.contains("last active"), "{err}");
        // `stats` reflects the shrunken pool.
        let stats = p.stats().unwrap();
        assert_eq!(stats.get("workers").unwrap().as_f64(), Some(1.0), "{stats:?}");
        assert_eq!(stats.get("retired").unwrap().as_f64(), Some(3.0), "{stats:?}");
        for (i, (id, u)) in ids.iter().zip(&utts).enumerate() {
            p.feed(*id, &u[u.len() / 2..]).unwrap();
            let done = p.finish(*id).unwrap();
            assert_eq!(done.text, expected[i].0, "{precision:?} utt {i} text diverged");
            assert_eq!(done.score, expected[i].1, "{precision:?} utt {i} score diverged");
        }
        p.shutdown();
    }
}

#[test]
fn kill_during_drain_aborts_the_drain_and_recovers_sessions() {
    // A worker dying *mid-drain* must abort the drain with a structured
    // error (not hang its caller), recover the shard's sessions from
    // their checkpoints — including ones whose evict leg died with the
    // worker — and keep every transcript bit-identical.
    let p = pool(Precision::F32, 2, 2);
    let utts = utterances(6, 700);
    let expected = reference_transcripts(Precision::F32, &utts);
    // Deterministic least-loaded assignment: odd ids → shard 0, even →
    // shard 1.
    let ids: Vec<u64> = (0..6).map(|_| p.open().unwrap()).collect();
    for (id, u) in ids.iter().zip(&utts) {
        p.feed(*id, &u[..u.len() / 2]).unwrap();
    }
    // Drain shard 1 from a helper thread (the call blocks until the
    // drain resolves) and kill the draining worker from this one.
    let drain_pool = p.clone();
    let drainer = std::thread::spawn(move || drain_pool.drain_worker(1));
    let killed = p.kill_worker(1).unwrap();
    let drained = drainer.join().expect("drain caller panicked");
    match drained {
        // The kill landed mid-drain (the drain aborts with the
        // structured died-while-draining error) — or beat the drain
        // request entirely (a dead shard cannot start draining).
        Err(e) => {
            let msg = format!("{e:#}");
            assert!(
                msg.contains("died while draining") || msg.contains("cannot drain"),
                "{msg}"
            );
        }
        // The drain emptied the shard before the kill processed; the
        // kill then found a retired (not live) shard and was a no-op.
        Ok(migrated) => {
            assert!(migrated > 0, "a completed drain must have migrated sessions");
            assert_eq!(killed, 0, "killing a retired shard recovers nothing");
        }
    }
    // Either way, every session still finishes bit-identically on the
    // survivor.
    for (i, (id, u)) in ids.iter().zip(&utts).enumerate() {
        p.feed(*id, &u[u.len() / 2..]).unwrap();
        let done = p.finish(*id).unwrap();
        assert_eq!(done.text, expected[i].0, "utt {i} text diverged");
        assert_eq!(done.score, expected[i].1, "utt {i} score diverged");
    }
    p.shutdown();
}

/// One scripted elasticity trace: open under one worker, scale to
/// three mid-stream, spread later sessions, drain a donor, finish
/// everything. Returns per-session (text, exact score) in open order.
fn churn_trace(precision: Precision) -> Vec<(String, f64)> {
    let p = pool(precision, 1, 3);
    let utts = utterances(6, 1100);
    let mut ids = Vec::new();
    for u in &utts[..3] {
        let id = p.open().unwrap();
        p.feed(id, &u[..u.len() / 2]).unwrap();
        ids.push(id);
    }
    assert_eq!(p.add_worker().unwrap(), 1);
    assert_eq!(p.add_worker().unwrap(), 2);
    for u in &utts[3..] {
        let id = p.open().unwrap();
        p.feed(id, &u[..u.len() / 2]).unwrap();
        ids.push(id);
    }
    // Shard 1 drains: its residents migrate to shards 0 and 2.
    p.drain_worker(1).unwrap();
    let mut out = Vec::new();
    for (id, u) in ids.iter().zip(&utts) {
        p.feed(*id, &u[u.len() / 2..]).unwrap();
        let done = p.finish(*id).unwrap();
        out.push((done.text, done.score));
    }
    p.shutdown();
    out
}

#[test]
fn identical_churn_traces_decode_identically_twice() {
    // Elasticity must not introduce run-to-run nondeterminism: the same
    // add/drain trace over the same audio yields byte-equal transcripts
    // and bit-equal scores — and both match the static reference.
    let one = churn_trace(Precision::F32);
    let two = churn_trace(Precision::F32);
    assert_eq!(one, two, "two identical churn traces diverged");
    let expected = reference_transcripts(Precision::F32, &utterances(6, 1100));
    for (i, (got, want)) in one.iter().zip(&expected).enumerate() {
        assert_eq!(got.0, want.0, "utt {i} text diverged from the static engine");
        assert_eq!(got.1, want.1, "utt {i} score diverged from the static engine");
    }
}
