//! Cross-layer agreement tests: the native Rust acoustic model and the
//! AOT-compiled XLA artifact must compute the same function from the
//! same weights — this pins the whole L1/L2/L3 contract (weight naming,
//! tensor layouts, causal-conv semantics, streaming-state handling).
//! Requires `make artifacts`; skips gracefully otherwise.

use asrpu::am::TdsModel;
use asrpu::config::artifacts_dir;
use asrpu::dsp::Mfcc;
use asrpu::runtime::{Meta, Runtime, XlaAm};
use asrpu::synth::Synthesizer;
use asrpu::util::rng::Rng;

fn ready() -> bool {
    let ok = artifacts_dir().join("meta.json").exists();
    if !ok {
        eprintln!("skipping: run `make artifacts` first");
    }
    ok
}

#[test]
fn native_am_from_artifact_weights_matches_xla_am() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let meta = Meta::load(&dir).unwrap();
    let native = TdsModel::from_artifacts(meta.model.clone(), &dir).unwrap();
    let rt = Runtime::cpu().unwrap();
    let xla = XlaAm::load(&rt, &dir).unwrap();

    let m = &meta.model;
    let mut rng = Rng::new(1);
    let feats_a: Vec<f32> = (0..m.frames_per_step() * m.n_mels)
        .map(|_| rng.uniform(-1.0, 1.0))
        .collect();
    let feats_b: Vec<f32> = (0..m.frames_per_step() * m.n_mels)
        .map(|_| rng.uniform(-1.0, 1.0))
        .collect();

    let mut ns = native.state();
    let mut xs = xla.state().unwrap();
    for feats in [&feats_a, &feats_b, &feats_a] {
        let n_out = native.step(&mut ns, feats);
        let x_out = xla.step(&mut xs, feats).unwrap();
        assert_eq!(n_out.len(), x_out.len());
        for (i, (a, b)) in n_out.iter().zip(&x_out).enumerate() {
            assert!(
                (a - b).abs() < 5e-3 * (1.0 + a.abs()),
                "logit[{i}]: native {a} vs xla {b}"
            );
        }
    }
}

#[test]
fn native_pipeline_matches_xla_pipeline_on_real_audio() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let meta = Meta::load(&dir).unwrap();
    let native = TdsModel::from_artifacts(meta.model.clone(), &dir).unwrap();
    let mfcc = Mfcc::for_model(&meta.model);
    let rt = Runtime::cpu().unwrap();
    let xla = XlaAm::load(&rt, &dir).unwrap();

    let mut rng = Rng::new(77);
    let u = Synthesizer::default().render(&[9, 21], &mut rng);
    let m = &meta.model;
    let mut ns = native.state();
    let mut xs = xla.state().unwrap();
    let mut max_err = 0.0f32;
    let mut offset = 0;
    let mut steps = 0;
    while offset + m.samples_per_step() <= u.samples.len() && steps < 6 {
        let window = &u.samples[offset..offset + m.samples_per_step()];
        let nf = mfcc.extract(window);
        let xf = xla.mfcc(window).unwrap();
        let n_out = native.step(&mut ns, &nf);
        let x_out = xla.step(&mut xs, &xf).unwrap();
        for (a, b) in n_out.iter().zip(&x_out) {
            max_err = max_err.max((a - b).abs());
        }
        // Both must agree on the argmax token per frame (the decode
        // decision) even where float error accumulates.
        for (ra, rb) in n_out.chunks(m.tokens).zip(x_out.chunks(m.tokens)) {
            let arg = |r: &[f32]| {
                r.iter()
                    .enumerate()
                    .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                    .unwrap()
                    .0
            };
            assert_eq!(arg(ra), arg(rb), "argmax diverged");
        }
        offset += m.step_len;
        steps += 1;
    }
    assert!(steps >= 4, "utterance too short for the test");
    assert!(max_err < 0.05, "max logit error {max_err}");
}

#[test]
fn weights_file_covers_every_meta_param() {
    if !ready() {
        return;
    }
    let dir = artifacts_dir();
    let meta = Meta::load(&dir).unwrap();
    let tf = asrpu::util::tensor_io::TensorFile::load(&dir.join(&meta.weights_file)).unwrap();
    for (name, shape) in &meta.params {
        let t = tf.require(name).unwrap();
        assert_eq!(&t.dims, shape, "tensor {name}");
        let data = t.as_f32().unwrap();
        assert!(data.iter().all(|v| v.is_finite()), "{name} has non-finite weights");
    }
    // And nothing extra.
    assert_eq!(tf.tensors.len(), meta.params.len());
}
