//! Soak: 200 synthetic utterances streamed through open/feed/finish on
//! the lane-batched serving core with a *randomized* (seeded) lane
//! arrival order — the order lanes receive audio, the chunk sizes they
//! get, how fused steps interleave with arrivals, and the finish order
//! all vary per run seed. Transcripts must be completely
//! arrival-order independent: two different arrival schedules, and the
//! plain scalar decode, must produce identical text for every
//! utterance.

use asrpu::am::TdsModel;
use asrpu::config::ModelConfig;
use asrpu::coordinator::{Engine, Session};
use asrpu::synth::Synthesizer;
use asrpu::util::rng::Rng;

const N: usize = 200;
const LANES: usize = 8;

fn engine() -> Engine {
    Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
        .build()
        .unwrap()
}

fn utterances() -> Vec<Vec<f32>> {
    // Short (one-word) utterances keep 200 end-to-end decodes cheap.
    let synth = Synthesizer::default();
    (0..N as u64)
        .map(|i| {
            let mut rng = Rng::new(5000 + i);
            synth.render(&[(i % 10) as u32], &mut rng).samples
        })
        .collect()
}

/// Stream every utterance through the batched serving core in waves of
/// `LANES` concurrent sessions. Within a wave, `order_seed` drives: the
/// per-round order lanes receive audio, each arrival's chunk size,
/// whether a fused step runs between arrivals, and the finish order.
fn run(order_seed: u64) -> Vec<String> {
    let e = engine();
    let utts = utterances();
    let mut out = vec![String::new(); N];
    let mut order = Rng::new(order_seed);
    for wave in (0..N).step_by(LANES) {
        let idx: Vec<usize> = (wave..(wave + LANES).min(N)).collect();
        let mut sessions: Vec<Session> =
            idx.iter().map(|_| e.open(false).unwrap()).collect();
        let mut offsets = vec![0usize; idx.len()];
        loop {
            let mut lanes: Vec<usize> = (0..idx.len()).collect();
            order.shuffle(&mut lanes);
            let mut any = false;
            for &l in &lanes {
                let u = &utts[idx[l]];
                if offsets[l] < u.len() {
                    let chunk = 640 * (1 + order.below(3) as usize);
                    let end = (offsets[l] + chunk).min(u.len());
                    e.push_audio(&mut sessions[l], &u[offsets[l]..end]);
                    offsets[l] = end;
                    any = true;
                }
                // Sometimes step mid-round so ready sets differ between
                // schedules; sometimes let audio pile up.
                if order.below(2) == 0 {
                    let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                    e.step_batch(&mut refs).unwrap();
                }
            }
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            e.step_batch(&mut refs).unwrap();
            if !any {
                break;
            }
        }
        let mut finish_order: Vec<usize> = (0..idx.len()).collect();
        order.shuffle(&mut finish_order);
        for l in finish_order {
            out[idx[l]] = e.finish(&mut sessions[l]).unwrap().text;
        }
    }
    out
}

#[test]
fn transcripts_are_arrival_order_independent() {
    let a = run(1);
    let b = run(2);
    assert_eq!(a.len(), N);
    let mut diverged = 0;
    for i in 0..N {
        if a[i] != b[i] {
            eprintln!("utterance {i}: {:?} != {:?}", a[i], b[i]);
            diverged += 1;
        }
    }
    assert_eq!(diverged, 0, "{diverged}/{N} transcripts depend on arrival order");
    // Spot-check against plain scalar decodes: the batched, shuffled
    // serving path must equal the textbook one-utterance-at-a-time path.
    let e = engine();
    let utts = utterances();
    for i in (0..N).step_by(13) {
        let (t, _) = e.decode_utterance(&utts[i]).unwrap();
        assert_eq!(a[i], t.text, "utterance {i} diverged from scalar decode");
    }
}
