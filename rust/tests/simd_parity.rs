//! SIMD-vs-scalar kernel parity: the dispatched AVX2/NEON variants of
//! the four AM hot kernels must be **bit-identical** to the scalar
//! kernels — not approximately equal. The SIMD paths vectorize across
//! independent outputs only (never the reduction dimension), so every
//! per-output accumulator sees exactly the scalar reduction order; int8
//! kernels accumulate in f32 and get the same treatment, so their
//! parity is exact `==` too (see DESIGN.md, "Runtime-dispatched SIMD
//! kernels").
//!
//! Shapes are drawn to hit the remainder paths hard: dimensions that
//! are not multiples of the 8-lane (AVX2) or 4-lane (NEON) registers,
//! batches across {1, 3, 16, 64}. On a host with no SIMD ISA the
//! kernel properties degenerate to nothing-to-compare and pass.

use asrpu::am::gemm;
use asrpu::am::gemm::dispatch::{self, KernelIsa};
use asrpu::am::TdsModel;
use asrpu::config::ModelConfig;
use asrpu::coordinator::Engine;
use asrpu::prop_assert;
use asrpu::synth::Synthesizer;
use asrpu::util::prop;
use asrpu::util::rng::Rng;

/// The SIMD ISA this host can run, if any. Detection, not `active()`:
/// the suite must exercise the SIMD paths even when the environment
/// pins `ASRPU_KERNEL_ISA=scalar` (the per-thread force overrides the
/// pin, so CI's scalar matrix leg still compares both paths).
fn simd_isa() -> Option<KernelIsa> {
    let d = dispatch::detect();
    (d != KernelIsa::Scalar).then_some(d)
}

/// Lane counts around and past the register tiles (TILE_ROWS ×
/// 8/4-lane blocks), including awkward remainders.
const BATCHES: [usize; 4] = [1, 3, 16, 64];

#[test]
fn fc_batch_simd_matches_scalar_bit_for_bit() {
    let Some(isa) = simd_isa() else {
        eprintln!("no SIMD kernel ISA on this host; nothing to compare");
        return;
    };
    prop::check("simd-fc-parity", 40, |g| {
        let in_dim = 1 + g.index(50);
        let out_dim = 1 + g.index(40);
        let batch = BATCHES[g.index(BATCHES.len())];
        let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-0.5, 0.5));
        let bias = g.vec_of(out_dim, |r| r.uniform(-0.2, 0.2));
        let xs = g.vec_of(batch * in_dim, |r| r.uniform(-1.0, 1.0));
        let mut out_s = vec![0.0f32; batch * out_dim];
        let mut out_v = vec![0.0f32; batch * out_dim];
        dispatch::with_forced_isa(KernelIsa::Scalar, || {
            gemm::fc_batch_into(&w, &bias, &xs, batch, &mut out_s);
        });
        dispatch::with_forced_isa(isa, || {
            gemm::fc_batch_into(&w, &bias, &xs, batch, &mut out_v);
        });
        for (i, (s, v)) in out_s.iter().zip(&out_v).enumerate() {
            prop_assert!(
                s.to_bits() == v.to_bits(),
                "fc {out_dim}x{in_dim} B{batch} out[{i}]: scalar {s} vs {isa} {v}"
            );
        }
        Ok(())
    });
}

#[test]
fn fc_batch_int8_simd_matches_scalar_exactly() {
    let Some(isa) = simd_isa() else {
        eprintln!("no SIMD kernel ISA on this host; nothing to compare");
        return;
    };
    prop::check("simd-fc-int8-parity", 40, |g| {
        let in_dim = 1 + g.index(50);
        let out_dim = 1 + g.index(40);
        let batch = BATCHES[g.index(BATCHES.len())];
        let q: Vec<i8> = g.vec_of(in_dim * out_dim, |r| r.range_i64(-128, 127) as i8);
        let scale = g.vec_of(out_dim, |r| r.uniform(0.001, 0.05));
        let zp: Vec<f32> = g.vec_of(out_dim, |r| r.range_i64(-20, 20) as f32);
        let bias = g.vec_of(out_dim, |r| r.uniform(-0.2, 0.2));
        let xs = g.vec_of(batch * in_dim, |r| r.uniform(-1.0, 1.0));
        let mut xsum_s = Vec::new();
        let mut xsum_v = Vec::new();
        let mut out_s = vec![0.0f32; batch * out_dim];
        let mut out_v = vec![0.0f32; batch * out_dim];
        dispatch::with_forced_isa(KernelIsa::Scalar, || {
            gemm::fc_batch_int8_into(
                &q, &scale, &zp, &bias, &xs, batch, &mut xsum_s, &mut out_s,
            );
        });
        dispatch::with_forced_isa(isa, || {
            gemm::fc_batch_int8_into(
                &q, &scale, &zp, &bias, &xs, batch, &mut xsum_v, &mut out_v,
            );
        });
        for (i, (s, v)) in out_s.iter().zip(&out_v).enumerate() {
            prop_assert!(
                s.to_bits() == v.to_bits(),
                "int8 fc {out_dim}x{in_dim} B{batch} out[{i}]: scalar {s} vs {isa} {v}"
            );
        }
        Ok(())
    });
}

#[test]
fn conv_steps_simd_matches_scalar_bit_for_bit() {
    let Some(isa) = simd_isa() else {
        eprintln!("no SIMD kernel ISA on this host; nothing to compare");
        return;
    };
    prop::check("simd-conv-parity", 30, |g| {
        let in_ch = 1 + g.index(6);
        let out_ch = 1 + g.index(6);
        let kw = 1 + g.index(8);
        let width = 1 + g.index(33);
        let t_out = 1 + g.index(4);
        let stride = 1 + g.index(2);
        let batch = BATCHES[g.index(BATCHES.len())];
        // ~20% exact zeros exercise the zero-weight skip both paths share.
        let w = g.vec_of(out_ch * in_ch * kw, |r| {
            if r.below(5) == 0 {
                0.0
            } else {
                r.uniform(-0.5, 0.5)
            }
        });
        let bias = g.vec_of(out_ch, |r| r.uniform(-0.2, 0.2));
        let ext_len = (kw - 1 + t_out * stride) * batch * in_ch * width;
        let ext = g.vec_of(ext_len, |r| r.uniform(-1.0, 1.0));
        let mut out_s = vec![0.0f32; t_out * batch * out_ch * width];
        let mut out_v = out_s.clone();
        dispatch::with_forced_isa(KernelIsa::Scalar, || {
            gemm::conv_steps_into(
                &w, &bias, &ext, t_out, stride, batch, in_ch, out_ch, kw, width,
                &mut out_s,
            );
        });
        dispatch::with_forced_isa(isa, || {
            gemm::conv_steps_into(
                &w, &bias, &ext, t_out, stride, batch, in_ch, out_ch, kw, width,
                &mut out_v,
            );
        });
        for (i, (s, v)) in out_s.iter().zip(&out_v).enumerate() {
            prop_assert!(
                s.to_bits() == v.to_bits(),
                "conv {out_ch}x{in_ch}x{kw} w{width} t{t_out} s{stride} B{batch} \
                 out[{i}]: scalar {s} vs {isa} {v}"
            );
        }
        Ok(())
    });
}

#[test]
fn conv_steps_int8_simd_matches_scalar_exactly() {
    let Some(isa) = simd_isa() else {
        eprintln!("no SIMD kernel ISA on this host; nothing to compare");
        return;
    };
    prop::check("simd-conv-int8-parity", 30, |g| {
        let in_ch = 1 + g.index(6);
        let out_ch = 1 + g.index(6);
        let kw = 1 + g.index(8);
        let width = 1 + g.index(33);
        let t_out = 1 + g.index(4);
        let stride = 1 + g.index(2);
        let batch = BATCHES[g.index(BATCHES.len())];
        // Exact-zero quantized weights exercise the zero skip too.
        let q: Vec<i8> = g.vec_of(out_ch * in_ch * kw, |r| {
            if r.below(5) == 0 {
                0
            } else {
                r.range_i64(-128, 127) as i8
            }
        });
        let scale = g.vec_of(out_ch, |r| r.uniform(0.001, 0.05));
        let zp: Vec<f32> = g.vec_of(out_ch, |r| r.range_i64(-20, 20) as f32);
        let bias = g.vec_of(out_ch, |r| r.uniform(-0.2, 0.2));
        let ext_len = (kw - 1 + t_out * stride) * batch * in_ch * width;
        let ext = g.vec_of(ext_len, |r| r.uniform(-1.0, 1.0));
        let mut wsum_s = Vec::new();
        let mut wsum_v = Vec::new();
        let mut out_s = vec![0.0f32; t_out * batch * out_ch * width];
        let mut out_v = out_s.clone();
        dispatch::with_forced_isa(KernelIsa::Scalar, || {
            gemm::conv_steps_int8_into(
                &q, &scale, &zp, &bias, &ext, t_out, stride, batch, in_ch, out_ch,
                kw, width, &mut wsum_s, &mut out_s,
            );
        });
        dispatch::with_forced_isa(isa, || {
            gemm::conv_steps_int8_into(
                &q, &scale, &zp, &bias, &ext, t_out, stride, batch, in_ch, out_ch,
                kw, width, &mut wsum_v, &mut out_v,
            );
        });
        for (i, (s, v)) in out_s.iter().zip(&out_v).enumerate() {
            prop_assert!(
                s.to_bits() == v.to_bits(),
                "int8 conv {out_ch}x{in_ch}x{kw} w{width} t{t_out} s{stride} B{batch} \
                 out[{i}]: scalar {s} vs {isa} {v}"
            );
        }
        Ok(())
    });
}

#[test]
fn forced_scalar_engine_transcript_parity() {
    // End-to-end: a full engine decode is ISA-invariant. Decode the
    // same audio under the auto-dispatched ISA and under a forced
    // scalar pin; transcript and score must match exactly. (On a
    // scalar-only host this degenerates to scalar-vs-scalar — still a
    // valid determinism check.)
    let engine = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
        .build()
        .unwrap();
    let audio = Synthesizer::default().render(&[1, 4], &mut Rng::new(42)).samples;
    let (auto_t, _) = engine.decode_utterance(&audio).unwrap();
    let (scalar_t, _) = dispatch::with_forced_isa(KernelIsa::Scalar, || {
        engine.decode_utterance(&audio)
    })
    .unwrap();
    assert_eq!(auto_t.text, scalar_t.text, "transcript must be ISA-invariant");
    assert_eq!(auto_t.score, scalar_t.score, "score must be bit-identical");
}
