//! Integration tests over the real AOT artifacts (require
//! `make artifacts` to have run; they are skipped with a message if the
//! artifacts directory is absent so `cargo test` works pre-build).
//!
//! These are the tests that prove the three layers compose: Pallas
//! kernels (inside the exported HLO) → JAX streaming model → PJRT
//! runtime → beam-search decoder, on audio synthesized by the Rust twin
//! of the python training-data generator.

use asrpu::config::{artifacts_dir, DecoderConfig, ModelConfig};
use asrpu::coordinator::Engine;
use asrpu::dsp::Mfcc;
use asrpu::runtime::{Runtime, XlaAm};
use asrpu::synth::{spec, Synthesizer, WerAccum};
use asrpu::util::rng::Rng;

fn artifacts_ready() -> bool {
    let dir = artifacts_dir();
    if dir.join("meta.json").exists() {
        true
    } else {
        eprintln!(
            "skipping: artifacts not built (run `make artifacts`); looked in {}",
            dir.display()
        );
        false
    }
}

#[test]
fn meta_matches_builtin_tiny_config() {
    if !artifacts_ready() {
        return;
    }
    let meta = asrpu::runtime::Meta::load(&artifacts_dir()).unwrap();
    assert_eq!(meta.model, ModelConfig::tiny_tds());
    assert!(
        meta.frame_acc > 0.9,
        "trained model frame accuracy {} too low",
        meta.frame_acc
    );
}

#[test]
fn xla_mfcc_matches_native_mfcc() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let am = XlaAm::load(&rt, &artifacts_dir()).unwrap();
    let m = &am.meta.model;
    let native = Mfcc::for_model(m);
    let mut rng = Rng::new(42);
    let mut u = Synthesizer::default().render(&[3, 17], &mut rng);
    u.samples.truncate(m.samples_per_step());
    assert_eq!(u.samples.len(), m.samples_per_step());
    let ours = native.extract(&u.samples);
    let theirs = am.mfcc(&u.samples).unwrap();
    assert_eq!(ours.len(), theirs.len());
    for (i, (a, b)) in ours.iter().zip(&theirs).enumerate() {
        assert!(
            (a - b).abs() < 2e-2 * (1.0 + a.abs()),
            "mfcc[{i}]: native {a} vs xla {b}"
        );
    }
}

#[test]
fn xla_step_produces_log_probs_and_carries_state() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let am = XlaAm::load(&rt, &artifacts_dir()).unwrap();
    let m = am.meta.model.clone();
    let mut state = am.state().unwrap();
    let feats = vec![0.25f32; m.frames_per_step() * m.n_mels];
    let l1 = am.step(&mut state, &feats).unwrap();
    assert_eq!(l1.len(), m.vectors_per_step() * m.tokens);
    for row in l1.chunks(m.tokens) {
        let total: f32 = row.iter().map(|v| v.exp()).sum();
        assert!((total - 1.0).abs() < 1e-3, "not log-probs: sum {total}");
    }
    // Same features again must differ (conv history advanced).
    let l2 = am.step(&mut state, &feats).unwrap();
    let diff: f32 = l1.iter().zip(&l2).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-3, "state had no effect");
}

#[test]
fn e2e_decodes_synthetic_utterances_with_low_wer() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let engine = Engine::builder()
        .artifacts(&rt, artifacts_dir())
        .decoder(DecoderConfig::default())
        .build()
        .unwrap();
    let synth = Synthesizer::default();
    let mut rng = Rng::new(2026);
    let mut wer = WerAccum::default();
    for _ in 0..12 {
        let words = spec::sample_sentence(&mut rng);
        let u = synth.render(&words, &mut rng);
        let (t, m) = engine.decode_utterance(&u.samples).unwrap();
        assert!(m.steps > 0);
        wer.add(&u.words, &t.words);
    }
    // The trained tiny model + lexicon + LM should transcribe nearly all
    // synthetic test utterances; allow a modest error budget.
    assert!(
        wer.wer() < 0.15,
        "e2e WER {:.3} too high ({} edits / {} words)",
        wer.wer(),
        wer.edits,
        wer.ref_words
    );
}

#[test]
fn beam_beats_greedy_baseline() {
    if !artifacts_ready() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let engine = Engine::builder()
        .artifacts(&rt, artifacts_dir())
        .decoder(DecoderConfig::default())
        .build()
        .unwrap();
    let synth = Synthesizer::default();
    let mut rng = Rng::new(555);
    let (mut beam_wer, mut greedy_wer) = (WerAccum::default(), WerAccum::default());
    for _ in 0..8 {
        let words = spec::sample_sentence(&mut rng);
        let u = synth.render(&words, &mut rng);
        let mut s = engine.open(true).unwrap();
        engine.feed(&mut s, &u.samples).unwrap();
        let beam = engine.finish(&mut s).unwrap();
        let greedy = engine.greedy_of(&s).unwrap();
        beam_wer.add(&u.words, &beam.words);
        greedy_wer.add(&u.words, &greedy.words);
    }
    assert!(
        beam_wer.wer() <= greedy_wer.wer() + 1e-9,
        "beam {:.3} worse than greedy {:.3}",
        beam_wer.wer(),
        greedy_wer.wer()
    );
}
