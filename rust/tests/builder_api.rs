//! The programmable-pipeline API surface: engines are built through
//! `EngineBuilder` only, backends are trait objects behind `AmBackend`,
//! misconfiguration comes back as typed `BuildError`s (never panics),
//! and the engine-visible stage description is the same program the
//! simulator consumes.

use asrpu::accel::{build_step_kernels, HypWorkload, KernelClass};
use asrpu::am::TdsModel;
use asrpu::config::{
    artifacts_dir, AccelConfig, BatchConfig, DecoderConfig, ModelConfig, PipelineDesc, Precision,
    PrecisionMap,
};
use asrpu::coordinator::{BuildError, Engine, NativeBackend, QuantizedBackend};
use asrpu::runtime::Runtime;
use asrpu::synth::Synthesizer;
use asrpu::util::rng::Rng;

fn utterance(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    Synthesizer::default().render(&[2, 7], &mut rng).samples
}

#[test]
fn builder_misconfiguration_returns_typed_errors() {
    // No model at all.
    assert_eq!(Engine::builder().build().err(), Some(BuildError::MissingModel));

    // Invalid decoder config.
    let model = TdsModel::random(ModelConfig::tiny_tds(), 1);
    let err = Engine::builder()
        .native(model.clone())
        .decoder(DecoderConfig { beam: -1.0, ..Default::default() })
        .build()
        .err();
    assert!(matches!(err, Some(BuildError::Decoder(_))), "{err:?}");

    // Invalid batch config.
    let err = Engine::builder()
        .native(model.clone())
        .batch(BatchConfig { max_batch: 0, max_wait_frames: 8 })
        .build()
        .err();
    assert!(matches!(err, Some(BuildError::Batch(_))), "{err:?}");

    // Artifacts that cannot load (bogus directory — also covers the
    // stub-runtime build, which refuses any artifact load).
    let rt_err = match Runtime::cpu() {
        Ok(rt) => Engine::builder()
            .artifacts(&rt, "/nonexistent/asrpu-artifacts")
            .build()
            .err(),
        // Stub runtime: Runtime::cpu() itself refuses; route the same
        // failure through the builder via a real Runtime is impossible,
        // so assert the typed shape with the error the builder produces
        // for an unloadable directory using the stub loader directly.
        Err(_) => Some(BuildError::Artifacts {
            dir: "/nonexistent/asrpu-artifacts".into(),
            message: "stub".into(),
        }),
    };
    assert!(matches!(rt_err, Some(BuildError::Artifacts { .. })), "{rt_err:?}");

    // Re-quantization request on a ready-made trait-object backend.
    let err = Engine::builder()
        .backend(Box::new(NativeBackend::new(model)))
        .precision(Precision::Int8)
        .build()
        .err();
    assert!(matches!(err, Some(BuildError::Precision(_))), "{err:?}");
}

#[test]
fn precision_map_validation_returns_typed_errors() {
    let model = TdsModel::random(ModelConfig::tiny_tds(), 3);

    // Scalar precision and map default that disagree.
    let err = Engine::builder()
        .native(model.clone())
        .precision(Precision::Int8)
        .precision_map(PrecisionMap::uniform(Precision::Int4))
        .build()
        .err();
    assert!(matches!(err, Some(BuildError::Precision(_))), "{err:?}");

    // Agreeing scalar + map default is fine, and a uniform-f32 map is
    // the plain native backend.
    let e = Engine::builder()
        .native(model.clone())
        .precision(Precision::F32)
        .precision_map(PrecisionMap::uniform(Precision::F32))
        .build()
        .unwrap();
    assert_eq!(e.backend().name(), "native-f32");

    // Re-calibration request on a ready-made trait-object backend whose
    // fixed map differs.
    let err = Engine::builder()
        .backend(Box::new(NativeBackend::new(model.clone())))
        .precision_map(PrecisionMap::parse("int4,output.fc=int8").unwrap())
        .build()
        .err();
    assert!(matches!(err, Some(BuildError::Precision(_))), "{err:?}");

    // A map naming a layer the model does not have is a model error.
    let mut bogus = PrecisionMap::uniform(Precision::Int4);
    bogus.set("no.such.layer", Precision::Int8);
    let err = Engine::builder().native(model).precision_map(bogus).build().err();
    assert!(matches!(err, Some(BuildError::Model(_))), "{err:?}");
}

#[test]
fn build_errors_are_values_not_panics() {
    // The full display path works and carries the cause.
    let e = Engine::builder().build().unwrap_err();
    let msg = e.to_string();
    assert!(msg.contains("no model"), "{msg}");
    let e = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 2))
        .decoder(DecoderConfig { beam: -3.0, ..Default::default() })
        .build()
        .unwrap_err();
    assert!(e.to_string().contains("decoder"), "{e}");
}

#[test]
fn native_backends_through_builder_and_trait_objects_are_identical() {
    // The same model served via .native()/.precision() and via an
    // explicitly boxed trait object must produce bit-identical
    // transcripts — construction route is not allowed to matter.
    let model = TdsModel::random(ModelConfig::tiny_tds(), 17);
    let audio = utterance(31);

    let f32_builder = Engine::builder().native(model.clone()).build().unwrap();
    let f32_boxed = Engine::builder()
        .backend(Box::new(NativeBackend::new(model.clone())))
        .build()
        .unwrap();
    assert_eq!(f32_builder.backend().name(), "native-f32");
    let (t_a, _) = f32_builder.decode_utterance(&audio).unwrap();
    let (t_b, _) = f32_boxed.decode_utterance(&audio).unwrap();
    assert_eq!(t_a.text, t_b.text);
    assert_eq!(t_a.score, t_b.score);

    let int8_builder = Engine::builder()
        .native(model.clone())
        .precision(Precision::Int8)
        .build()
        .unwrap();
    let int8_boxed = Engine::builder()
        .backend(Box::new(QuantizedBackend::quantize(&model).unwrap()))
        .build()
        .unwrap();
    assert_eq!(int8_builder.backend().name(), "native-int8");
    assert_eq!(int8_builder.backend().precision(), Precision::Int8);
    let (q_a, _) = int8_builder.decode_utterance(&audio).unwrap();
    let (q_b, _) = int8_boxed.decode_utterance(&audio).unwrap();
    assert_eq!(q_a.text, q_b.text);
    assert_eq!(q_a.score, q_b.score);

    // Metadata for the power model: int8 stages 4× fewer weight bytes.
    assert_eq!(
        4 * int8_builder.backend().weight_bytes_per_step(),
        f32_builder.backend().weight_bytes_per_step()
    );
}

#[test]
fn xla_backend_through_builder_matches_native_from_same_weights() {
    // Requires `make artifacts`; skipped gracefully otherwise.
    if !artifacts_dir().join("meta.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let xla = Engine::builder().artifacts(&rt, artifacts_dir()).build().unwrap();
    assert_eq!(xla.backend().name(), "xla");
    let meta = asrpu::runtime::Meta::load(&artifacts_dir()).unwrap();
    let native_model = TdsModel::from_artifacts(meta.model.clone(), &artifacts_dir()).unwrap();
    let native = Engine::builder().native(native_model).build().unwrap();
    let audio = utterance(77);
    let (t_xla, m_xla) = xla.decode_utterance(&audio).unwrap();
    let (t_nat, _) = native.decode_utterance(&audio).unwrap();
    assert!(m_xla.steps > 0);
    // Same trained weights through both backends: the trained tiny model
    // is confident on protocol utterances, so transcripts agree.
    assert_eq!(t_xla.text, t_nat.text);

    // The XLA batched path drains multiple lanes in one fused call and
    // matches its own scalar path (the closed scalar-fallback gap).
    let mut a = xla.open(false).unwrap();
    let mut b = xla.open(false).unwrap();
    xla.push_audio(&mut a, &audio);
    xla.push_audio(&mut b, &audio);
    let mut refs = vec![&mut a, &mut b];
    xla.step_batch(&mut refs).unwrap();
    let t_a = xla.finish(&mut a).unwrap();
    let t_b = xla.finish(&mut b).unwrap();
    assert_eq!(t_a.text, t_xla.text);
    assert_eq!(t_b.text, t_xla.text);
    assert!(a.metrics.batched_steps > 0, "XLA lanes must use the batched path");
}

#[test]
fn engine_pipeline_is_the_simulator_program() {
    // One source of truth: the stage description the engine publishes is
    // exactly what the simulator compiles into its kernel program.
    let engine = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 23))
        .build()
        .unwrap();
    let pipe = engine.pipeline();
    assert_eq!(pipe, PipelineDesc::for_model(&engine.model_cfg));
    pipe.validate().unwrap();

    let accel = AccelConfig::paper();
    let kernels = build_step_kernels(&pipe, &accel, &HypWorkload::default(), 1);
    let count = |c: KernelClass| kernels.iter().filter(|k| k.class == c).count();
    let (conv, fc, ln) = engine.model_cfg.kernel_counts();
    assert_eq!(count(KernelClass::FeatureExtraction), 1);
    assert_eq!(count(KernelClass::Conv), conv);
    assert_eq!(count(KernelClass::LayerNorm), ln);
    // FC kernels may split (§5.2) but never merge.
    assert!(count(KernelClass::Fc) >= fc);
    assert_eq!(
        count(KernelClass::HypExpansion),
        engine.model_cfg.vectors_per_step()
    );
}
