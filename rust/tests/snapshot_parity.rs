//! Snapshot parity: the tentpole invariant of relocatable session
//! state. A session snapshotted mid-utterance — at *any* step boundary,
//! through the full encode/decode byte round-trip — and restored on
//! another engine/shard must finish with a transcript **bit-identical**
//! (text AND score) to the uninterrupted decode, for both native
//! backends and any batch shape. On top of the engine-level property,
//! this suite drives the real router: live migrations under rebalancing
//! (N ∈ {2, 4} workers, f32 + int8) and a worker killed mid-stream with
//! every session recovered from its checkpoints.

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, ModelConfig, Precision, ShardConfig};
use asrpu::coordinator::{Engine, SessionSnapshot, ShardPool};
use asrpu::prop_assert;
use asrpu::synth::Synthesizer;
use asrpu::util::prop;
use asrpu::util::rng::Rng;

const MODEL_SEED: u64 = 21;

fn engine(precision: Precision) -> Engine {
    Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
        .precision(precision)
        .build()
        .unwrap()
}

fn utterance(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    Synthesizer::default()
        .render(&[(seed % 10) as u32, ((seed + 5) % 10) as u32], &mut rng)
        .samples
}

/// Randomized snapshot/restore points mid-utterance, B ∈ {1, 4} lanes,
/// f32 + int8: decode through the batched path, interrupt every lane at
/// its own random chunk boundary (snapshot → encode → decode → restore
/// onto a worker-clone engine), finish on the second engine, and demand
/// bit-identical transcripts vs the uninterrupted scalar decode.
#[test]
fn random_snapshot_points_are_transcript_invisible() {
    for precision in [Precision::F32, Precision::Int8, Precision::Int4, Precision::Int4Sparse] {
        let e = engine(precision);
        let w = e.clone_worker().expect("native engines clone").into_engine();
        prop::check("snapshot-parity", 4, |g| {
            let lanes = [1usize, 4][g.index(2)];
            let seed = 500 + g.rng.below(1000);
            let utts: Vec<Vec<f32>> =
                (0..lanes as u64).map(|i| utterance(seed + i)).collect();
            let expected: Vec<_> = utts
                .iter()
                .map(|u| e.decode_utterance(u).unwrap().0)
                .collect();
            // Feed in uneven chunks through the fused batch path; each
            // lane picks its own interruption chunk.
            let chunk = 700 + g.index(5) * 400;
            let cut_at: Vec<usize> = (0..lanes)
                .map(|_| g.rng.below(6) as usize + 1)
                .collect();
            let mut live: Vec<Option<asrpu::coordinator::Session>> =
                (0..lanes).map(|_| Some(e.open(false).unwrap())).collect();
            let mut moved: Vec<Option<asrpu::coordinator::Session>> =
                (0..lanes).map(|_| None).collect();
            let max_len = utts.iter().map(Vec::len).max().unwrap();
            let mut off = 0;
            let mut round = 0;
            while off < max_len {
                for (lane, u) in utts.iter().enumerate() {
                    if off >= u.len() {
                        continue;
                    }
                    let end = (off + chunk).min(u.len());
                    if let Some(s) = live[lane].as_mut() {
                        e.push_audio(s, &u[off..end]);
                    } else if let Some(s) = moved[lane].as_mut() {
                        w.push_audio(s, &u[off..end]);
                    }
                }
                off += chunk;
                round += 1;
                {
                    let mut refs: Vec<&mut asrpu::coordinator::Session> =
                        live.iter_mut().flatten().collect();
                    e.step_batch(&mut refs).unwrap();
                }
                {
                    let mut refs: Vec<&mut asrpu::coordinator::Session> =
                        moved.iter_mut().flatten().collect();
                    w.step_batch(&mut refs).unwrap();
                }
                // Interrupt due lanes: snapshot on `e`, byte round-trip,
                // restore on `w`.
                for lane in 0..lanes {
                    if round == cut_at[lane] {
                        if let Some(mut s) = live[lane].take() {
                            let bytes = e.snapshot(&mut s).unwrap().encode();
                            let snap = SessionSnapshot::decode(&bytes)
                                .map_err(|err| format!("decode failed: {err:#}"))?;
                            moved[lane] = Some(
                                w.restore(&snap)
                                    .map_err(|err| format!("restore failed: {err:#}"))?,
                            );
                        }
                    }
                }
            }
            for lane in 0..lanes {
                let t = match (live[lane].as_mut(), moved[lane].as_mut()) {
                    (Some(s), _) => e.finish(s).unwrap(),
                    (None, Some(s)) => w.finish(s).unwrap(),
                    _ => unreachable!(),
                };
                prop_assert!(
                    t.text == expected[lane].text && t.score == expected[lane].score,
                    "lane {lane} diverged ({precision:?}, chunk {chunk}, seed {seed}): \
                     {:?} vs {:?}",
                    (t.text, t.score),
                    (&expected[lane].text, expected[lane].score)
                );
            }
            Ok(())
        });
    }
}

fn pool(precision: Precision, workers: usize, rebalance: usize) -> ShardPool {
    ShardPool::start(
        move || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
                .precision(precision)
                // No batching wait: feeds flush (and checkpoint)
                // immediately, keeping the suite fast and deterministic.
                .batch(BatchConfig { max_batch: 8, max_wait_frames: 0 })
                .shards(ShardConfig {
                    workers,
                    rebalance_threshold: rebalance,
                    checkpoint_interval: 1,
                    ..ShardConfig::default()
                })
                .build()?)
        },
        256,
    )
    .unwrap()
}

/// The acceptance criterion: sessions with ≥1 executed decoding step
/// migrate between shards (N ∈ {2, 4} workers, f32/int8/int4) and finish
/// bit-identical to the unmigrated single-engine decode.
#[test]
fn live_migration_is_bit_identical_across_worker_counts() {
    for precision in [Precision::F32, Precision::Int8, Precision::Int4] {
        let reference = engine(precision);
        for workers in [2usize, 4] {
            let p = pool(precision, workers, 2);
            // Two sessions per shard, all started (≥1 step each).
            let n = 2 * workers as u64;
            let ids: Vec<u64> = (0..n).map(|_| p.open().unwrap()).collect();
            let utts: Vec<Vec<f32>> =
                ids.iter().map(|&id| utterance(300 + id)).collect();
            let halves: Vec<usize> = utts.iter().map(|u| u.len() / 2).collect();
            for (i, &id) in ids.iter().enumerate() {
                let (steps, _) = p.feed(id, &utts[i][..halves[i]]).unwrap();
                assert!(steps > 0, "session {id} must start decoding");
            }
            // Finish every session on even-index shards (ids 1, 3, …
            // alternate shards under least-loaded assignment) — enough
            // churn that rebalancing must move started sessions.
            let (to_finish, to_keep): (Vec<_>, Vec<_>) =
                ids.iter().copied().enumerate().partition(|(i, _)| i % 2 == 0);
            for &(_, id) in &to_finish {
                p.finish(id).unwrap();
            }
            let stats = p.stats().unwrap();
            let adopted: f64 = stats
                .get("shards")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|s| s.get("adopted").unwrap().as_f64().unwrap())
                .sum();
            assert!(
                adopted >= 1.0,
                "at least one started session must migrate \
                 ({precision:?}, {workers} workers): {stats:?}"
            );
            for &(i, id) in &to_keep {
                let (t_ref, _) = reference.decode_utterance(&utts[i]).unwrap();
                p.feed(id, &utts[i][halves[i]..]).unwrap();
                let done = p.finish(id).unwrap();
                assert_eq!(
                    done.text, t_ref.text,
                    "session {id} text ({precision:?}, {workers} workers)"
                );
                assert_eq!(
                    done.score, t_ref.score as f64,
                    "session {id} score ({precision:?}, {workers} workers)"
                );
            }
            p.shutdown();
        }
    }
}

/// Kill one worker mid-stream (no flush, no final checkpoints — a real
/// crash): no session may be lost, every orphan recovers from its
/// checkpoints onto survivors, and — because every feed had flushed and
/// checkpointed before its reply — final transcripts stay bit-identical
/// to the uninterrupted decode. N ∈ {2, 4} workers, f32/int8/sparse-int4.
#[test]
fn killed_worker_loses_no_sessions_and_transcripts_match() {
    for precision in [Precision::F32, Precision::Int8, Precision::Int4Sparse] {
        let reference = engine(precision);
        for workers in [2usize, 4] {
            let p = pool(precision, workers, 0); // rebalancing off
            let n = 2 * workers as u64;
            let ids: Vec<u64> = (0..n).map(|_| p.open().unwrap()).collect();
            let utts: Vec<Vec<f32>> =
                ids.iter().map(|&id| utterance(800 + id)).collect();
            let halves: Vec<usize> = utts.iter().map(|u| u.len() / 2).collect();
            for (i, &id) in ids.iter().enumerate() {
                let (steps, _) = p.feed(id, &utts[i][..halves[i]]).unwrap();
                assert!(steps > 0);
            }
            // Crash shard 0: its two sessions must re-adopt elsewhere.
            let recovered = p.kill_worker(0).unwrap();
            assert_eq!(
                recovered, 2,
                "both shard-0 sessions recover ({precision:?}, {workers} workers)"
            );
            // Every session — recovered or not — continues and finishes
            // with the uninterrupted transcript. No session loss.
            for (i, &id) in ids.iter().enumerate() {
                let res = p.resume(id).unwrap();
                assert!(res.steps > 0, "session {id} lost its progress");
                let (t_ref, _) = reference.decode_utterance(&utts[i]).unwrap();
                p.feed(id, &utts[i][halves[i]..]).unwrap();
                let done = p.finish(id).unwrap();
                assert_eq!(
                    done.text, t_ref.text,
                    "session {id} text ({precision:?}, {workers} workers)"
                );
                assert_eq!(
                    done.score, t_ref.score as f64,
                    "session {id} score ({precision:?}, {workers} workers)"
                );
            }
            let stats = p.stats().unwrap();
            assert_eq!(
                stats.get("responding").unwrap().as_f64(),
                Some(workers as f64 - 1.0),
                "{stats:?}"
            );
            assert_eq!(
                stats.get("recovered").unwrap().as_f64(),
                Some(2.0),
                "{stats:?}"
            );
            p.shutdown();
        }
    }
}
