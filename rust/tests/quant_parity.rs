//! Quantization parity: the bounded-error contracts of the sub-f32
//! serving paths (`Precision::Int8`, `Int4`, `Int4Sparse`).
//!
//! Three levels of guarantee, all asserted here:
//!  1. **Weight-level (hard bound):** quantize→dequantize error stays
//!     within the documented bounds (`INT8_MAX_ROW_REL_ERR`,
//!     `INT4_MAX_GROUP_REL_ERR`, `SPARSE4_MAX_ROW_REL_ERR`) for any
//!     weight distribution, and 2:4 pruning keeps exactly the two
//!     largest magnitudes per block (property tests).
//!  2. **Kernel-level (bit-exact):** the packed int4 and 2:4 sparse
//!     FC/conv kernels agree bit for bit with the naive unpacked
//!     oracles under *every* ISA this host can run, across
//!     remainder-heavy shapes.
//!  3. **Transcript-level:** on synthesized utterances, int8 decoding
//!     picks the same transcript as f32 whenever the f32 decode is
//!     confident relative to the *measured* logit divergence — and a
//!     mixed int4/sparse/int8 engine decodes ISA-invariantly. (With
//!     random tiny models some utterances decode near a tie; demanding
//!     f32 equality there would test tie-breaking luck, not
//!     quantization quality.)

use asrpu::accel::{build_step_kernels, HypWorkload, KernelClass};
use asrpu::am::gemm::dispatch::{self, KernelIsa};
use asrpu::am::quant::{
    dequantize, dequantize_int4, dequantize_sparse, prune_quantize_rows_2of4, quantize_rows,
    quantize_rows_int4, INT4_GROUP, INT4_MAX_GROUP_REL_ERR, INT8_MAX_ROW_REL_ERR,
    SPARSE4_MAX_ROW_REL_ERR,
};
use asrpu::am::{gemm, QuantizedTdsModel, TdsModel};
use asrpu::config::{
    AccelConfig, DecoderConfig, ModelConfig, PipelineDesc, Precision, PrecisionMap,
};
use asrpu::coordinator::Engine;
use asrpu::synth::Synthesizer;
use asrpu::util::prop;
use asrpu::util::rng::Rng;

/// Every kernel ISA this host can execute: scalar always, plus the
/// detected SIMD tier when there is one.
fn isas() -> Vec<KernelIsa> {
    let mut v = vec![KernelIsa::Scalar];
    let d = dispatch::detect();
    if d != KernelIsa::Scalar {
        v.push(d);
    }
    v
}

#[test]
fn quantize_dequantize_rel_err_within_documented_bound() {
    prop::check("int8-roundtrip-bound", 60, |g| {
        let rows = 1 + g.index(12);
        let cols = 1 + g.index(200);
        // Mix of scales per row, including near-zero and skewed rows.
        let mut w = Vec::with_capacity(rows * cols);
        for _ in 0..rows {
            let mag = g.rng.uniform(0.0, 3.0) + 1e-4;
            let skew = g.rng.uniform(-1.0, 1.0);
            for _ in 0..cols {
                w.push(g.rng.uniform(-mag, mag) + skew * mag);
            }
        }
        let qw = quantize_rows(&w, rows, cols);
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            let amax = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
            let bound = INT8_MAX_ROW_REL_ERR * amax.max(f32::EPSILON) + 1e-7;
            for c in 0..cols {
                let deq = dequantize(&qw, r, cols, c);
                asrpu::prop_assert!(
                    (deq - row[c]).abs() <= bound,
                    "row {r} col {c}: |{} - {}| > {bound}",
                    deq,
                    row[c]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn quantized_model_bit_exact_batch_parity_holds_too() {
    // The int8 path inherits the batched-vs-scalar bit-exactness contract
    // (same driver, same per-output accumulation order).
    let model = TdsModel::random(ModelConfig::tiny_tds(), 33);
    let qm = QuantizedTdsModel::from_model(&model).unwrap();
    let f = qm.cfg.frames_per_step() * qm.cfg.n_mels;
    prop::check("int8-batch-parity", 8, |g| {
        let batch = 1 + g.index(5);
        let mut scalar_states: Vec<_> = (0..batch).map(|_| qm.state()).collect();
        let mut batch_states: Vec<_> = (0..batch).map(|_| qm.state()).collect();
        for _ in 0..2 {
            let feats = g.vec_of(batch * f, |r| r.uniform(-1.0, 1.0));
            let mut refs: Vec<_> = batch_states.iter_mut().collect();
            let fused = qm.step_batch(&mut refs, &feats);
            let lane_out = fused.len() / batch;
            for (l, st) in scalar_states.iter_mut().enumerate() {
                let out = qm.step(st, &feats[l * f..(l + 1) * f]);
                asrpu::prop_assert!(
                    out == fused[l * lane_out..(l + 1) * lane_out],
                    "int8 lane {l} diverged at batch {batch}"
                );
            }
        }
        Ok(())
    });
}

/// Decode one utterance collecting logits; return (text, logits, margin)
/// where margin is the final top-2 live-hypothesis score gap.
fn decode_collect(engine: &Engine, samples: &[f32]) -> (String, Vec<f32>, f32) {
    let mut s = engine.open(true).unwrap();
    engine.feed(&mut s, samples).unwrap();
    let t = engine.finish(&mut s).unwrap();
    // With fewer than two live hypotheses every competitor fell at least
    // a full beam below the winner — use the beam as the (conservative)
    // gap rather than infinity.
    let margin = match s.decode.hyps.len() {
        0 | 1 => engine.dec_cfg.beam,
        _ => s.decode.hyps[0].score - s.decode.hyps[1].score,
    };
    (t.text, s.logits.take().unwrap(), margin)
}

#[test]
fn int8_decode_matches_f32_transcripts_on_synthesized_utterances() {
    let model = TdsModel::random(ModelConfig::tiny_tds(), 11);
    let f32_engine = Engine::builder()
        .native(model.clone())
        .decoder(DecoderConfig::default())
        .build()
        .unwrap();
    let int8_engine = Engine::builder()
        .native(model)
        .precision(Precision::Int8)
        .decoder(DecoderConfig::default())
        .build()
        .unwrap();
    assert_eq!(int8_engine.model_cfg.precision, Precision::Int8);
    let synth = Synthesizer::default();
    let seeds = [3u64, 9, 27, 41, 55, 68];
    let mut matches = 0usize;
    for &seed in &seeds {
        let mut rng = Rng::new(seed);
        let words: Vec<u32> = vec![(seed % 10) as u32, ((seed + 4) % 10) as u32];
        let u = synth.render(&words, &mut rng);
        let (text_f, logits_f, margin) = decode_collect(&f32_engine, &u.samples);
        let (text_q, logits_q, _) = decode_collect(&int8_engine, &u.samples);
        assert_eq!(logits_f.len(), logits_q.len(), "seed {seed}: logit shapes");
        // Accumulated logit divergence over the whole utterance: an upper
        // bound on the score drift any single hypothesis path can suffer.
        let tokens = f32_engine.model_cfg.tokens;
        let drift: f32 = logits_f
            .chunks(tokens)
            .zip(logits_q.chunks(tokens))
            .map(|(a, b)| {
                a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
            })
            .sum();
        // Per-frame divergence must stay small in absolute terms.
        let frames = logits_f.len() / tokens;
        assert!(
            drift / frames as f32 <= 0.5,
            "seed {seed}: mean per-frame int8 logit drift {} too large",
            drift / frames as f32
        );
        if margin > 2.0 * drift + 1e-3 {
            // The f32 decode is confident beyond any possible int8 score
            // perturbation: the transcripts MUST agree.
            assert_eq!(text_f, text_q, "seed {seed}: confident transcript flipped");
        }
        if text_f == text_q {
            matches += 1;
        }
    }
    // Transcript agreement must be the norm, not the exception — a
    // minority of genuinely near-tie utterances may flip without
    // indicting the quantizer.
    assert!(
        matches * 3 >= seeds.len() * 2,
        "int8 matched only {matches}/{} f32 transcripts",
        seeds.len()
    );
}

#[test]
fn int4_quantize_dequantize_rel_err_within_documented_bound() {
    prop::check("int4-roundtrip-bound", 60, |g| {
        let rows = 1 + g.index(8);
        let cols = 1 + g.index(100);
        let mut w = Vec::with_capacity(rows * cols);
        for _ in 0..rows {
            let mag = g.rng.uniform(0.0, 3.0) + 1e-4;
            let skew = g.rng.uniform(-1.0, 1.0);
            for _ in 0..cols {
                w.push(g.rng.uniform(-mag, mag) + skew * mag);
            }
        }
        let qw = quantize_rows_int4(&w, rows, cols);
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            for g0 in (0..cols).step_by(INT4_GROUP) {
                let seg = &row[g0..(g0 + INT4_GROUP).min(cols)];
                let amax = seg.iter().fold(0.0f32, |a, v| a.max(v.abs()));
                let bound = INT4_MAX_GROUP_REL_ERR * amax.max(f32::EPSILON) + 1e-6;
                for (j, &x) in seg.iter().enumerate() {
                    let deq = dequantize_int4(&qw, r, g0 + j);
                    asrpu::prop_assert!(
                        (deq - x).abs() <= bound,
                        "row {r} col {}: |{deq} - {x}| > {bound}",
                        g0 + j
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sparse_prune_keeps_two_largest_per_block_within_documented_bound() {
    prop::check("sparse-roundtrip-bound", 60, |g| {
        let rows = 1 + g.index(8);
        let cols = 1 + g.index(100);
        let mut w = Vec::with_capacity(rows * cols);
        for _ in 0..rows {
            let mag = g.rng.uniform(0.0, 3.0) + 1e-4;
            for _ in 0..cols {
                w.push(g.rng.uniform(-mag, mag));
            }
        }
        let qw = prune_quantize_rows_2of4(&w, rows, cols);
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            // Independently re-derive the survivor set: the 2 largest
            // magnitudes per 4-column block, ties to the lower index.
            let mut kept = vec![false; cols];
            let mut amax = 0.0f32;
            for b in 0..cols.div_ceil(4) {
                let base = b * 4;
                let len = (cols - base).min(4);
                let mut idx: Vec<usize> = (0..len).collect();
                idx.sort_by(|&a, &c| {
                    row[base + c]
                        .abs()
                        .partial_cmp(&row[base + a].abs())
                        .unwrap()
                        .then(a.cmp(&c))
                });
                for &i in idx.iter().take(2) {
                    kept[base + i] = true;
                    amax = amax.max(row[base + i].abs());
                }
            }
            let bound = SPARSE4_MAX_ROW_REL_ERR * amax.max(f32::EPSILON) + 1e-6;
            for c in 0..cols {
                let deq = dequantize_sparse(&qw, r, c);
                if kept[c] {
                    asrpu::prop_assert!(
                        (deq - row[c]).abs() <= bound,
                        "kept row {r} col {c}: |{deq} - {}| > {bound}",
                        row[c]
                    );
                } else {
                    asrpu::prop_assert!(
                        deq == 0.0,
                        "pruned row {r} col {c} dequantized to {deq}, not exactly 0"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn int4_fc_kernel_bit_exact_vs_naive_oracle_on_every_isa() {
    prop::check("int4-fc-oracle", 30, |g| {
        // Crosses the 32-column group boundary, odd widths (half-filled
        // pack bytes) and ragged SIMD lane blocks.
        let in_dim = 1 + g.index(90);
        let out_dim = 1 + g.index(40);
        let batch = [1, 3, 16, 64][g.index(4)];
        let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-0.5, 0.5));
        let bias = g.vec_of(out_dim, |r| r.uniform(-0.2, 0.2));
        let qw = quantize_rows_int4(&w, out_dim, in_dim);
        let xs = g.vec_of(batch * in_dim, |r| r.uniform(-1.0, 1.0));
        let mut want = vec![0.0f32; batch * out_dim];
        gemm::fc_batch_int4_naive_into(&qw.packed, &qw.scale, &qw.zp, &bias, &xs, batch, &mut want);
        for isa in isas() {
            let mut got = vec![0.0f32; batch * out_dim];
            let mut gsum = Vec::new();
            dispatch::with_forced_isa(isa, || {
                gemm::fc_batch_int4_into(
                    &qw.packed, &qw.scale, &qw.zp, &bias, &xs, batch, &mut gsum, &mut got,
                );
            });
            for (i, (s, v)) in want.iter().zip(&got).enumerate() {
                asrpu::prop_assert!(
                    s.to_bits() == v.to_bits(),
                    "int4 fc {out_dim}x{in_dim} B{batch} out[{i}]: naive {s} vs {isa} {v}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn sparse_fc_kernel_bit_exact_vs_naive_oracle_on_every_isa() {
    prop::check("sparse-fc-oracle", 30, |g| {
        let in_dim = 1 + g.index(90);
        let out_dim = 1 + g.index(40);
        let batch = [1, 3, 16, 64][g.index(4)];
        let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-0.5, 0.5));
        let bias = g.vec_of(out_dim, |r| r.uniform(-0.2, 0.2));
        let qw = prune_quantize_rows_2of4(&w, out_dim, in_dim);
        let xs = g.vec_of(batch * in_dim, |r| r.uniform(-1.0, 1.0));
        let mut want = vec![0.0f32; batch * out_dim];
        gemm::fc_batch_int4_sparse_naive_into(
            &qw.vals, &qw.idxs, &qw.scale, &bias, &xs, batch, &mut want,
        );
        for isa in isas() {
            let mut got = vec![0.0f32; batch * out_dim];
            dispatch::with_forced_isa(isa, || {
                gemm::fc_batch_int4_sparse_into(
                    &qw.vals, &qw.idxs, &qw.scale, &bias, &xs, batch, &mut got,
                );
            });
            for (i, (s, v)) in want.iter().zip(&got).enumerate() {
                asrpu::prop_assert!(
                    s.to_bits() == v.to_bits(),
                    "sparse fc {out_dim}x{in_dim} B{batch} out[{i}]: naive {s} vs {isa} {v}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn int4_conv_kernel_bit_exact_vs_naive_oracle_on_every_isa() {
    prop::check("int4-conv-oracle", 25, |g| {
        let in_ch = 1 + g.index(3);
        let out_ch = 1 + g.index(3);
        let kw = 1 + g.index(5);
        let width = 1 + g.index(40);
        let t_out = 1 + g.index(3);
        let stride = 1 + g.index(2);
        let batch = [1, 3, 16][g.index(3)];
        let w = g.vec_of(out_ch * in_ch * kw, |r| r.uniform(-0.5, 0.5));
        let bias = g.vec_of(out_ch, |r| r.uniform(-0.2, 0.2));
        let qw = quantize_rows_int4(&w, out_ch, in_ch * kw);
        let ext_len = (kw - 1 + t_out * stride) * batch * in_ch * width;
        let ext = g.vec_of(ext_len, |r| r.uniform(-1.0, 1.0));
        let mut want = vec![0.0f32; t_out * batch * out_ch * width];
        gemm::conv_steps_int4_naive_into(
            &qw.packed, &qw.scale, &qw.zp, &bias, &ext, t_out, stride, batch, in_ch, out_ch,
            kw, width, &mut want,
        );
        for isa in isas() {
            let mut got = vec![0.0f32; want.len()];
            let mut tmp = Vec::new();
            dispatch::with_forced_isa(isa, || {
                gemm::conv_steps_int4_into(
                    &qw.packed, &qw.scale, &qw.zp, &bias, &ext, t_out, stride, batch, in_ch,
                    out_ch, kw, width, &mut tmp, &mut got,
                );
            });
            for (i, (s, v)) in want.iter().zip(&got).enumerate() {
                asrpu::prop_assert!(
                    s.to_bits() == v.to_bits(),
                    "int4 conv {out_ch}x{in_ch}x{kw} w{width} B{batch} out[{i}]: \
                     naive {s} vs {isa} {v}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn sparse_conv_kernel_bit_exact_vs_naive_oracle_on_every_isa() {
    prop::check("sparse-conv-oracle", 25, |g| {
        let in_ch = 1 + g.index(3);
        let out_ch = 1 + g.index(3);
        let kw = 1 + g.index(5);
        let width = 1 + g.index(40);
        let t_out = 1 + g.index(3);
        let stride = 1 + g.index(2);
        let batch = [1, 3, 16][g.index(3)];
        let w = g.vec_of(out_ch * in_ch * kw, |r| r.uniform(-0.5, 0.5));
        let bias = g.vec_of(out_ch, |r| r.uniform(-0.2, 0.2));
        let qw = prune_quantize_rows_2of4(&w, out_ch, in_ch * kw);
        let ext_len = (kw - 1 + t_out * stride) * batch * in_ch * width;
        let ext = g.vec_of(ext_len, |r| r.uniform(-1.0, 1.0));
        let mut want = vec![0.0f32; t_out * batch * out_ch * width];
        gemm::conv_steps_int4_sparse_naive_into(
            &qw.vals, &qw.idxs, &qw.scale, &bias, &ext, t_out, stride, batch, in_ch, out_ch,
            kw, width, &mut want,
        );
        for isa in isas() {
            let mut got = vec![0.0f32; want.len()];
            dispatch::with_forced_isa(isa, || {
                gemm::conv_steps_int4_sparse_into(
                    &qw.vals, &qw.idxs, &qw.scale, &bias, &ext, t_out, stride, batch, in_ch,
                    out_ch, kw, width, &mut got,
                );
            });
            for (i, (s, v)) in want.iter().zip(&got).enumerate() {
                asrpu::prop_assert!(
                    s.to_bits() == v.to_bits(),
                    "sparse conv {out_ch}x{in_ch}x{kw} w{width} B{batch} out[{i}]: \
                     naive {s} vs {isa} {v}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn mixed_precision_transcripts_are_isa_invariant() {
    // The kernel-level bit-exactness contract composed end to end: a
    // mixed int4/sparse/int8 engine must produce identical transcripts
    // under every ISA, because each layer's logits are bit-identical.
    let d = dispatch::detect();
    if d == KernelIsa::Scalar {
        eprintln!("no SIMD kernel ISA on this host; nothing to compare");
        return;
    }
    let map = PrecisionMap::parse("int4,g0.sub=int4_sparse,output.fc=int8").unwrap();
    let engine = Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), 7))
        .precision_map(map)
        .build()
        .unwrap();
    let synth = Synthesizer::default();
    for seed in [1u64, 8, 21] {
        let mut rng = Rng::new(seed);
        let words: Vec<u32> = vec![(seed % 10) as u32, ((seed + 3) % 10) as u32];
        let u = synth.render(&words, &mut rng);
        let scalar = dispatch::with_forced_isa(KernelIsa::Scalar, || {
            engine.decode_utterance(&u.samples).unwrap().0.text
        });
        let simd = dispatch::with_forced_isa(d, || {
            engine.decode_utterance(&u.samples).unwrap().0.text
        });
        assert_eq!(scalar, simd, "seed {seed}: transcript changed under {d}");
    }
}

#[test]
fn simulator_charges_at_least_half_the_weight_dma_for_int4_vs_int8() {
    // The acceptance criterion the whole format exists for: on the paper
    // configuration, serving the AM at int4 must cut the simulator's
    // per-step weight DMA for the quantizable stages (conv/FC; LN stays
    // f32) to at most half of int8's, and 2:4 sparsity must cut it
    // further still.
    let model = ModelConfig::paper_tds();
    let accel = AccelConfig::paper();
    let hyp = HypWorkload::default();
    let weight_dma = |p: Precision| -> u64 {
        let pipe = PipelineDesc::for_model_mixed(&model, PrecisionMap::uniform(p));
        build_step_kernels(&pipe, &accel, &hyp, 1)
            .iter()
            .filter(|k| matches!(k.class, KernelClass::Conv | KernelClass::Fc))
            .map(|k| k.model_bytes)
            .sum()
    };
    let (int8, int4, sparse) = (
        weight_dma(Precision::Int8),
        weight_dma(Precision::Int4),
        weight_dma(Precision::Int4Sparse),
    );
    assert!(int8 >= 2 * int4, "int4 DMA {int4} not ≤ half of int8 {int8}");
    assert!(int4 > sparse, "2:4 sparse DMA {sparse} not below int4 {int4}");
    assert!(sparse > 0, "sparse stages still stream their kept weights");
}

#[test]
fn int8_model_reports_quarter_weight_bytes() {
    // Cross-layer consistency: the functional int8 model's footprint and
    // the config-level accounting agree on the 4× weight shrink.
    let cfg = ModelConfig::tiny_tds();
    let model = TdsModel::random(cfg.clone(), 5);
    let qm = QuantizedTdsModel::from_model(&model).unwrap();
    let f32_cfg_bytes = cfg.model_bytes();
    let int8_cfg_bytes = qm.cfg.model_bytes();
    assert_eq!(cfg.precision, Precision::F32);
    assert_eq!(int8_cfg_bytes * 4, f32_cfg_bytes);
}
