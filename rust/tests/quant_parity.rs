//! Int8 quantization parity: the bounded-error contract of the
//! `Precision::Int8` serving path.
//!
//! Two levels of guarantee, both asserted here:
//!  1. **Weight-level (hard bound):** per-row quantize→dequantize error
//!     stays within the documented `INT8_MAX_ROW_REL_ERR` bound for any
//!     weight distribution (property test).
//!  2. **Transcript-level:** on synthesized utterances, int8 decoding
//!     picks the same transcript as f32 whenever the f32 decode is
//!     confident relative to the *measured* logit divergence — and the
//!     measured divergence itself must stay small. (With random tiny
//!     models some utterances decode near a tie; demanding equality
//!     there would test tie-breaking luck, not quantization quality.)

use asrpu::am::quant::{dequantize, quantize_rows, INT8_MAX_ROW_REL_ERR};
use asrpu::am::{QuantizedTdsModel, TdsModel};
use asrpu::config::{DecoderConfig, ModelConfig, Precision};
use asrpu::coordinator::Engine;
use asrpu::synth::Synthesizer;
use asrpu::util::prop;
use asrpu::util::rng::Rng;

#[test]
fn quantize_dequantize_rel_err_within_documented_bound() {
    prop::check("int8-roundtrip-bound", 60, |g| {
        let rows = 1 + g.index(12);
        let cols = 1 + g.index(200);
        // Mix of scales per row, including near-zero and skewed rows.
        let mut w = Vec::with_capacity(rows * cols);
        for _ in 0..rows {
            let mag = g.rng.uniform(0.0, 3.0) + 1e-4;
            let skew = g.rng.uniform(-1.0, 1.0);
            for _ in 0..cols {
                w.push(g.rng.uniform(-mag, mag) + skew * mag);
            }
        }
        let qw = quantize_rows(&w, rows, cols);
        for r in 0..rows {
            let row = &w[r * cols..(r + 1) * cols];
            let amax = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
            let bound = INT8_MAX_ROW_REL_ERR * amax.max(f32::EPSILON) + 1e-7;
            for c in 0..cols {
                let deq = dequantize(&qw, r, cols, c);
                asrpu::prop_assert!(
                    (deq - row[c]).abs() <= bound,
                    "row {r} col {c}: |{} - {}| > {bound}",
                    deq,
                    row[c]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn quantized_model_bit_exact_batch_parity_holds_too() {
    // The int8 path inherits the batched-vs-scalar bit-exactness contract
    // (same driver, same per-output accumulation order).
    let model = TdsModel::random(ModelConfig::tiny_tds(), 33);
    let qm = QuantizedTdsModel::from_model(&model).unwrap();
    let f = qm.cfg.frames_per_step() * qm.cfg.n_mels;
    prop::check("int8-batch-parity", 8, |g| {
        let batch = 1 + g.index(5);
        let mut scalar_states: Vec<_> = (0..batch).map(|_| qm.state()).collect();
        let mut batch_states: Vec<_> = (0..batch).map(|_| qm.state()).collect();
        for _ in 0..2 {
            let feats = g.vec_of(batch * f, |r| r.uniform(-1.0, 1.0));
            let mut refs: Vec<_> = batch_states.iter_mut().collect();
            let fused = qm.step_batch(&mut refs, &feats);
            let lane_out = fused.len() / batch;
            for (l, st) in scalar_states.iter_mut().enumerate() {
                let out = qm.step(st, &feats[l * f..(l + 1) * f]);
                asrpu::prop_assert!(
                    out == fused[l * lane_out..(l + 1) * lane_out],
                    "int8 lane {l} diverged at batch {batch}"
                );
            }
        }
        Ok(())
    });
}

/// Decode one utterance collecting logits; return (text, logits, margin)
/// where margin is the final top-2 live-hypothesis score gap.
fn decode_collect(engine: &Engine, samples: &[f32]) -> (String, Vec<f32>, f32) {
    let mut s = engine.open(true).unwrap();
    engine.feed(&mut s, samples).unwrap();
    let t = engine.finish(&mut s).unwrap();
    // With fewer than two live hypotheses every competitor fell at least
    // a full beam below the winner — use the beam as the (conservative)
    // gap rather than infinity.
    let margin = match s.decode.hyps.len() {
        0 | 1 => engine.dec_cfg.beam,
        _ => s.decode.hyps[0].score - s.decode.hyps[1].score,
    };
    (t.text, s.logits.take().unwrap(), margin)
}

#[test]
fn int8_decode_matches_f32_transcripts_on_synthesized_utterances() {
    let model = TdsModel::random(ModelConfig::tiny_tds(), 11);
    let f32_engine = Engine::builder()
        .native(model.clone())
        .decoder(DecoderConfig::default())
        .build()
        .unwrap();
    let int8_engine = Engine::builder()
        .native(model)
        .precision(Precision::Int8)
        .decoder(DecoderConfig::default())
        .build()
        .unwrap();
    assert_eq!(int8_engine.model_cfg.precision, Precision::Int8);
    let synth = Synthesizer::default();
    let seeds = [3u64, 9, 27, 41, 55, 68];
    let mut matches = 0usize;
    for &seed in &seeds {
        let mut rng = Rng::new(seed);
        let words: Vec<u32> = vec![(seed % 10) as u32, ((seed + 4) % 10) as u32];
        let u = synth.render(&words, &mut rng);
        let (text_f, logits_f, margin) = decode_collect(&f32_engine, &u.samples);
        let (text_q, logits_q, _) = decode_collect(&int8_engine, &u.samples);
        assert_eq!(logits_f.len(), logits_q.len(), "seed {seed}: logit shapes");
        // Accumulated logit divergence over the whole utterance: an upper
        // bound on the score drift any single hypothesis path can suffer.
        let tokens = f32_engine.model_cfg.tokens;
        let drift: f32 = logits_f
            .chunks(tokens)
            .zip(logits_q.chunks(tokens))
            .map(|(a, b)| {
                a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
            })
            .sum();
        // Per-frame divergence must stay small in absolute terms.
        let frames = logits_f.len() / tokens;
        assert!(
            drift / frames as f32 <= 0.5,
            "seed {seed}: mean per-frame int8 logit drift {} too large",
            drift / frames as f32
        );
        if margin > 2.0 * drift + 1e-3 {
            // The f32 decode is confident beyond any possible int8 score
            // perturbation: the transcripts MUST agree.
            assert_eq!(text_f, text_q, "seed {seed}: confident transcript flipped");
        }
        if text_f == text_q {
            matches += 1;
        }
    }
    // Transcript agreement must be the norm, not the exception — a
    // minority of genuinely near-tie utterances may flip without
    // indicting the quantizer.
    assert!(
        matches * 3 >= seeds.len() * 2,
        "int8 matched only {matches}/{} f32 transcripts",
        seeds.len()
    );
}

#[test]
fn int8_model_reports_quarter_weight_bytes() {
    // Cross-layer consistency: the functional int8 model's footprint and
    // the config-level accounting agree on the 4× weight shrink.
    let cfg = ModelConfig::tiny_tds();
    let model = TdsModel::random(cfg.clone(), 5);
    let qm = QuantizedTdsModel::from_model(&model).unwrap();
    let f32_cfg_bytes = cfg.model_bytes();
    let int8_cfg_bytes = qm.cfg.model_bytes();
    assert_eq!(cfg.precision, Precision::F32);
    assert_eq!(int8_cfg_bytes * 4, f32_cfg_bytes);
}
