//! Cross-shard determinism: transcripts produced by an N-worker
//! `ShardPool` must be **bit-identical** (text AND score) to the
//! 1-worker engine on the same seeded session set, for both native
//! backends — the headline invariant of the sharded serving layer.
//!
//! Why it must hold: per-session decode state never crosses lanes,
//! `Engine::step_batch` is bit-identical to scalar decoding for every
//! lane (`tests/batch_parity.rs`), and every worker shares the same
//! weights (`Engine::clone_worker` hands out `Arc` clones) — so any
//! partition of sessions across workers, any batching schedule inside
//! each worker, and any queued-session migration the router performs
//! are all transcript-invisible. This suite drives the real router +
//! worker threads (no sockets: audio goes in as f32, scores come back
//! un-serialized, so equality really is bit-equality).

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, ModelConfig, Precision, ShardConfig};
use asrpu::coordinator::{Engine, ShardPool};
use asrpu::prop_assert;
use asrpu::synth::Synthesizer;
use asrpu::util::prop;
use asrpu::util::rng::Rng;

const MODEL_SEED: u64 = 11;

fn reference_engine(precision: Precision) -> Engine {
    Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
        .precision(precision)
        .build()
        .unwrap()
}

fn pool(precision: Precision, workers: usize) -> ShardPool {
    ShardPool::start(
        move || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
                .precision(precision)
                // Small batches + short waits so fused batches actually
                // form and flush quickly under test traffic.
                .batch(BatchConfig { max_batch: 4, max_wait_frames: 2 })
                .shards(ShardConfig {
                    workers,
                    rebalance_threshold: 2,
                    ..ShardConfig::default()
                })
                .build()?)
        },
        256,
    )
    .unwrap()
}

fn utterances(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let synth = Synthesizer::default();
    (0..n as u64)
        .map(|i| {
            let mut rng = Rng::new(seed + i);
            synth
                .render(&[(i % 10) as u32, ((i + 3) % 10) as u32], &mut rng)
                .samples
        })
        .collect()
}

fn reference_transcripts(engine: &Engine, utts: &[Vec<f32>]) -> Vec<(String, f64)> {
    utts.iter()
        .map(|u| {
            let (t, _) = engine.decode_utterance(u).unwrap();
            (t.text, t.score as f64)
        })
        .collect()
}

/// Decode the session set through a pool: one client thread per
/// utterance, feeding in `chunk`-sample pieces so lanes join and leave
/// each shard's ready set at different times. Results come back in
/// utterance order (each thread knows its own index — session ids race
/// across threads and carry no utterance meaning).
fn decode_sharded(pool: &ShardPool, utts: &[Vec<f32>], chunk: usize) -> Vec<(String, f64)> {
    let handles: Vec<_> = utts
        .iter()
        .cloned()
        .enumerate()
        .map(|(i, audio)| {
            let client = pool.clone();
            std::thread::spawn(move || {
                let id = client.open().unwrap();
                for c in audio.chunks(chunk.max(1)) {
                    client.feed(id, c).unwrap();
                }
                let done = client.finish(id).unwrap();
                (i, done.text, done.score)
            })
        })
        .collect();
    let mut out = vec![(String::new(), 0.0); utts.len()];
    for h in handles {
        let (i, text, score) = h.join().expect("client thread panicked");
        out[i] = (text, score);
    }
    out
}

#[test]
fn sharded_transcripts_match_single_worker_bit_exactly() {
    // The acceptance criterion: N ∈ {2, 4} workers, f32/int8/int4.
    for precision in [Precision::F32, Precision::Int8, Precision::Int4] {
        let reference = reference_engine(precision);
        let utts = utterances(8, 40);
        let expected = reference_transcripts(&reference, &utts);
        for workers in [2usize, 4] {
            let p = pool(precision, workers);
            let got = decode_sharded(&p, &utts, 1000);
            p.shutdown();
            for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
                assert_eq!(
                    g.0, e.0,
                    "text diverged: precision {precision:?} workers {workers} utt {i}"
                );
                assert_eq!(
                    g.1, e.1,
                    "score diverged: precision {precision:?} workers {workers} utt {i}"
                );
            }
        }
    }
}

#[test]
fn shard_parity_property_random_chunking() {
    // Property form: random session counts, worker counts, chunk sizes
    // and utterance seeds — parity must hold for every combination.
    let reference = reference_engine(Precision::F32);
    prop::check("shard-parity", 4, |g| {
        let n = 3 + g.index(4);
        let workers = [2usize, 4][g.index(2)];
        let chunk = 400 + g.index(4) * 700;
        let seed = 100 + g.rng.below(1000);
        let utts = utterances(n, seed);
        let expected = reference_transcripts(&reference, &utts);
        let p = pool(Precision::F32, workers);
        let got = decode_sharded(&p, &utts, chunk);
        p.shutdown();
        for (i, (gt, e)) in got.iter().zip(&expected).enumerate() {
            prop_assert!(
                gt.0 == e.0 && gt.1 == e.1,
                "utt {i} diverged (workers {workers}, chunk {chunk}, seed {seed}): \
                 {:?} != {:?}",
                gt,
                e
            );
        }
        Ok(())
    });
}

#[test]
fn parity_survives_rebalancing_migrations() {
    // Force the router's migration path and assert it stays
    // transcript-invisible. Assignment is deterministic (least-open,
    // lowest index on ties): sessions 1,3,5 → shard 0 and 2,4,6 →
    // shard 1. Finishing 1,3,5 empties shard 0, the imbalance (3) hits
    // the threshold (2), and one queued session (the lowest id, 2)
    // migrates — its buffered-audio handoff must not perturb decoding.
    let reference = reference_engine(Precision::F32);
    let p = pool(Precision::F32, 2);
    let ids: Vec<u64> = (0..6).map(|_| p.open().unwrap()).collect();
    assert_eq!(ids, vec![1, 2, 3, 4, 5, 6]);
    // Stage some audio on session 2 *before* it migrates, so the
    // migration actually carries a buffer.
    let utts = utterances(3, 900);
    let head = &utts[0][..800.min(utts[0].len())];
    p.feed(2, head).unwrap();
    for id in [1u64, 3, 5] {
        p.finish(id).unwrap();
    }
    let stats = p.stats().unwrap();
    let adopted: f64 = stats
        .get("shards")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.get("adopted").unwrap().as_f64().unwrap())
        .sum();
    assert_eq!(adopted, 1.0, "one queued session must migrate: {stats:?}");
    let expected = reference_transcripts(&reference, &utts);
    for (u, (id, exp)) in utts.iter().zip([2u64, 4, 6].iter().zip(&expected)) {
        let rest = if *id == 2 { &u[800.min(u.len())..] } else { &u[..] };
        p.feed(*id, rest).unwrap();
        let done = p.finish(*id).unwrap();
        assert_eq!(done.text, exp.0, "session {id}");
        assert_eq!(done.score, exp.1, "session {id}");
    }
    p.shutdown();
}
