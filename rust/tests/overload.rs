//! Overload-resilience conformance over a real TCP socket: SLO-aware
//! admission control (structured `backpressure` with a `retry_after_ms`
//! hint), deterministic graceful degradation that restores bit-identical
//! full quality once pressure drains, and liveness supervision — a
//! worker dying *spontaneously* (injected engine panic, no kill request
//! anywhere) is detected by the router's supervisor, its sessions are
//! re-adopted from checkpoints, and its staged feeds replay so the
//! in-flight client never sees a bounce.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, DecoderConfig, DegradeLevel, ModelConfig, OverloadPolicy};
use asrpu::coordinator::{Engine, Server};
use asrpu::util::json::Json;

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: &str) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn call(&mut self, line: &str) -> Json {
        writeln!(self.writer, "{line}").unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    }

    fn open(&mut self) -> Json {
        self.call(r#"{"op":"open"}"#)
    }

    fn feed(&mut self, session: u64, samples: &str) -> Json {
        self.call(&format!(r#"{{"op":"feed","session":{session},"samples":[{samples}]}}"#))
    }

    fn finish(&mut self, session: u64) -> Json {
        self.call(&format!(r#"{{"op":"finish","session":{session}}}"#))
    }
}

fn code_of(r: &Json) -> Option<String> {
    r.get("error")
        .and_then(|e| e.get("code"))
        .and_then(Json::as_str)
        .map(str::to_string)
}

fn session_of(r: &Json) -> u64 {
    r.get("session").unwrap().as_f64().unwrap() as u64
}

/// A deterministic non-silent waveform serialized exactly as it will be
/// parsed — the reference decode reuses the parsed values, so on-wire
/// float round-trips cannot break parity assertions.
fn waveform(n: usize) -> (String, Vec<f32>) {
    let rendered: Vec<String> =
        (0..n).map(|i| format!("{:.4}", (i as f32 * 0.017).sin() * 0.25)).collect();
    let values: Vec<f32> = rendered.iter().map(|s| s.parse().unwrap()).collect();
    (rendered.join(","), values)
}

fn server_with(
    workers: usize,
    overload: OverloadPolicy,
    panic_after: u64,
) -> Server {
    Server::start(
        "127.0.0.1:0",
        move || {
            let mut b = Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                .batch(BatchConfig::default())
                .shards(asrpu::config::ShardConfig {
                    workers,
                    rebalance_threshold: 0,
                    checkpoint_interval: 1,
                    ..asrpu::config::ShardConfig::default()
                })
                .overload(overload.clone());
            if panic_after > 0 {
                b = b.fault_panic_after_steps(panic_after);
            }
            Ok(b.build()?)
        },
        64,
    )
    .unwrap()
}

fn reference_engine() -> Engine {
    Engine::builder().native(TdsModel::random(ModelConfig::tiny_tds(), 5)).build().unwrap()
}

#[test]
fn admission_limit_bounces_opens_with_retry_hint_on_the_wire() {
    let server = server_with(
        1,
        OverloadPolicy {
            admit_sessions_per_shard: 1,
            retry_after_ms: 40,
            ..Default::default()
        },
        0,
    );
    let mut c = Client::connect(&server.addr);
    let first = c.open();
    let session = session_of(&first);
    // Past the admit threshold: a structured rejection carrying the
    // policy's retry hint — the SLO-aware contract a client backs off
    // on, not a hang and not a dropped connection.
    let rejected = c.open();
    assert_eq!(code_of(&rejected).as_deref(), Some("backpressure"), "{rejected:?}");
    assert_eq!(
        rejected.get("error").unwrap().get("retry_after_ms").and_then(Json::as_f64),
        Some(40.0),
        "{rejected:?}"
    );
    let stats = c.call(r#"{"op":"stats"}"#);
    assert!(
        stats.get("rejected_admission").unwrap().as_f64().unwrap() >= 1.0,
        "{stats:?}"
    );
    // Admission recovers the moment a session closes.
    assert!(c.finish(session).get("text").is_some());
    let reopened = c.open();
    assert!(reopened.get("session").is_some(), "{reopened:?}");
    // The policy is introspectable.
    let cfg = c.call(r#"{"op":"config"}"#);
    assert_eq!(cfg.get("admit_sessions_per_shard").unwrap().as_f64(), Some(1.0));
    assert_eq!(cfg.get("retry_after_ms").unwrap().as_f64(), Some(40.0));
    server.shutdown();
}

#[test]
fn degradation_is_deterministic_and_drains_to_bit_identical_full_quality() {
    let base = DecoderConfig::default();
    let ladder = OverloadPolicy {
        levels: vec![DegradeLevel {
            enter_backlog_steps: 3,
            beam: base.beam / 2.0,
            max_hyps: (base.max_hyps / 2).max(1),
            max_batch: 1,
        }],
        ..Default::default()
    };
    // 8000 samples arrive in one request: (8000 − 1520) / 1280 + 1 = 6
    // ready steps at the flush, past the 3-step rung.
    let (burst, _) = waveform(8000);
    let (calm, calm_values) = waveform(4080);
    let run = || {
        let server = server_with(1, ladder.clone(), 0);
        let mut c = Client::connect(&server.addr);
        let s1 = session_of(&c.open());
        let fed = c.feed(s1, &burst);
        assert_eq!(fed.get("steps").unwrap().as_f64(), Some(6.0), "{fed:?}");
        let stressed = c.finish(s1);
        // After the drain, a gently-fed session (≤ 2 ready steps per
        // request) must see the configured decoder untouched.
        let s2 = session_of(&c.open());
        for chunk in calm.split(',').collect::<Vec<_>>().chunks(2560) {
            c.feed(s2, &chunk.join(","));
        }
        let calm_done = c.finish(s2);
        let stats = c.call(r#"{"op":"stats"}"#);
        server.shutdown();
        (stressed, calm_done, stats)
    };
    let (s1, c1, stats) = run();
    let (s2, c2, _) = run();
    // The burst really degraded, the per-session accounting says so on
    // the wire, and two identical admitted traces decode bit for bit
    // identically — degradation is deterministic, not best-effort.
    assert!(s1.get("degraded_steps").unwrap().as_f64().unwrap() > 0.0, "{s1:?}");
    assert!(s1.get("degrade_transitions").unwrap().as_f64().unwrap() >= 1.0, "{s1:?}");
    assert_eq!(s1.get("text").unwrap().as_str(), s2.get("text").unwrap().as_str());
    assert_eq!(s1.get("score").unwrap().as_f64(), s2.get("score").unwrap().as_f64());
    assert_eq!(
        s1.get("degraded_steps").unwrap().as_f64(),
        s2.get("degraded_steps").unwrap().as_f64()
    );
    // Full quality is *restored*, bit-identically: the calm session
    // matches an engine that has no overload policy at all.
    assert_eq!(c1.get("degraded_steps").unwrap().as_f64(), Some(0.0), "{c1:?}");
    let reference = reference_engine();
    let (t_ref, _) = reference.decode_utterance(&calm_values).unwrap();
    assert_eq!(c1.get("text").unwrap().as_str(), Some(t_ref.text.as_str()), "{c1:?}");
    assert_eq!(c1.get("score").unwrap().as_f64(), Some(t_ref.score as f64));
    assert_eq!(c1.get("text").unwrap().as_str(), c2.get("text").unwrap().as_str());
    // The ladder shows up in stats and has fully stepped back down.
    let shards = stats.get("shards").unwrap().as_arr().unwrap();
    assert!(
        shards[0].get("degraded_batches").unwrap().as_f64().unwrap() >= 1.0,
        "{stats:?}"
    );
    assert_eq!(shards[0].get("degrade_level").unwrap().as_f64(), Some(0.0), "{stats:?}");
}

#[test]
fn spontaneous_worker_death_recovers_with_zero_acked_feed_loss_on_the_wire() {
    // Every worker engine is armed to panic at its 4th scoring attempt.
    // Three acked (and checkpointed) steps run on shard 0; the fourth
    // feed kills the worker thread mid-flush — spontaneously, with no
    // kill request anywhere in the system. The supervisor must detect
    // the death on its own, re-adopt the session from its checkpoint
    // onto the survivor and replay the staged feed, so the client
    // blocked on that very request gets its normal answer.
    let (all, all_values) = waveform(1520 + 3 * 1280);
    let parts: Vec<&str> = all.split(',').collect();
    let chunks = [
        parts[..1520].join(","),
        parts[1520..2800].join(","),
        parts[2800..4080].join(","),
        parts[4080..].join(","),
    ];
    let server = server_with(2, OverloadPolicy::default(), 3);
    let mut c = Client::connect(&server.addr);
    let a = session_of(&c.open()); // shard 0
    let b = session_of(&c.open()); // shard 1: keep the survivor's fault budget fresh
    assert!(c.finish(b).get("text").is_some());
    for chunk in &chunks[..3] {
        let fed = c.feed(a, chunk);
        assert_eq!(fed.get("steps").unwrap().as_f64(), Some(1.0), "{fed:?}");
    }
    // The killer feed: acked only after detection + recovery + replay.
    let replayed = c.feed(a, &chunks[3]);
    assert_eq!(
        replayed.get("steps").unwrap().as_f64(),
        Some(1.0),
        "staged feed must replay, not bounce: {replayed:?}"
    );
    let res = c.call(&format!(r#"{{"op":"resume","session":{a}}}"#));
    assert_eq!(res.get("steps").unwrap().as_f64(), Some(4.0), "{res:?}");
    let stats = c.call(r#"{"op":"stats"}"#);
    assert_eq!(stats.get("workers").unwrap().as_f64(), Some(2.0));
    assert_eq!(stats.get("responding").unwrap().as_f64(), Some(1.0), "{stats:?}");
    assert_eq!(stats.get("recovered").unwrap().as_f64(), Some(1.0), "{stats:?}");
    assert_eq!(stats.get("panics_detected").unwrap().as_f64(), Some(1.0), "{stats:?}");
    // Zero acknowledged-feed loss, bit for bit: the transcript equals an
    // undisturbed single-engine decode of everything that was acked.
    let reference = reference_engine();
    let (t_ref, _) = reference.decode_utterance(&all_values).unwrap();
    let done = c.finish(a);
    assert_eq!(done.get("text").unwrap().as_str(), Some(t_ref.text.as_str()), "{done:?}");
    assert_eq!(done.get("score").unwrap().as_f64(), Some(t_ref.score as f64));
    server.shutdown();
}
