//! Lattice/N-best parity: the exact-lattice subsystem must be
//! **decode-invisible**. Enabling lattice recording (`EngineBuilder::
//! nbest`) may not change a single bit of any transcript — text, score,
//! words — relative to a plain engine, across precisions (f32/int8),
//! batch widths, and worker counts; and the lattice's own best path
//! must *be* that transcript, bit-identical. On top of that sit the
//! subsystem's own guarantees: the N-best list is exactly scored and
//! deterministic regardless of how lanes arrived, and a mid-utterance
//! snapshot carries the lattice so a restored session produces the
//! identical list.

use asrpu::am::TdsModel;
use asrpu::config::{BatchConfig, ModelConfig, Precision, ShardConfig};
use asrpu::coordinator::{Engine, Session, SessionSnapshot, ShardPool};
use asrpu::decoder::TrigramLm;
use asrpu::synth::{spec, Synthesizer};
use asrpu::util::rng::Rng;

const MODEL_SEED: u64 = 21;

fn engine(nbest: usize, precision: Precision) -> Engine {
    Engine::builder()
        .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
        .precision(precision)
        .nbest(nbest)
        .build()
        .unwrap()
}

fn utterances(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let synth = Synthesizer::default();
    (0..n as u64)
        .map(|i| {
            let mut rng = Rng::new(seed + i);
            synth
                .render(&[(i % 10) as u32, ((i + 3) % 10) as u32], &mut rng)
                .samples
        })
        .collect()
}

/// Decode `utts` as one fused batch on `e` and return each lane's
/// `Engine::nbest` result, in lane order.
fn batched_nbest(e: &Engine, utts: &[Vec<f32>]) -> Vec<asrpu::coordinator::NbestResult> {
    let mut sessions: Vec<Session> = (0..utts.len()).map(|_| e.open(false).unwrap()).collect();
    for (s, u) in sessions.iter_mut().zip(utts) {
        e.push_audio(s, u);
    }
    let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
    e.step_batch(&mut refs).unwrap();
    sessions.iter_mut().map(|s| e.nbest(s).unwrap()).collect()
}

#[test]
fn lattice_best_is_bit_identical_to_legacy_transcript() {
    // Across f32/int8/int4 and batch widths 1/3/16: the lattice-enabled
    // engine's transcript AND its lattice's best path both equal the
    // plain engine's transcript exactly.
    for precision in [Precision::F32, Precision::Int8, Precision::Int4] {
        let plain = engine(0, precision);
        let latt = engine(4, precision);
        for batch in [1usize, 3, 16] {
            let utts = utterances(batch, 500 + batch as u64);
            let reference: Vec<_> =
                utts.iter().map(|u| plain.decode_utterance(u).unwrap().0).collect();
            for (lane, (n, r)) in batched_nbest(&latt, &utts).iter().zip(&reference).enumerate() {
                let ctx = format!("{precision:?} batch {batch} lane {lane}");
                assert_eq!(n.transcript.text, r.text, "{ctx}");
                assert_eq!(n.transcript.score, r.score, "{ctx}");
                assert_eq!(n.transcript.words, r.words, "{ctx}");
                let top = &n.entries[0];
                assert_eq!(top.text, r.text, "{ctx}: lattice best diverged");
                assert_eq!(top.score, r.score, "{ctx}: lattice best score diverged");
                assert_eq!(top.words, r.words, "{ctx}");
                assert!(n.entries.len() <= 4, "{ctx}");
                for w in n.entries.windows(2) {
                    assert!(w[0].score >= w[1].score, "{ctx}: N-best not sorted");
                }
                assert!(n.rescored.is_none(), "{ctx}: no rescorer configured");
            }
        }
    }
}

#[test]
fn nbest_is_deterministic_under_shuffled_arrival_order() {
    // The same utterance decoded alone, as the first lane of a batch,
    // and as the last lane of a differently-ordered batch must produce
    // the identical N-best list — texts, word ids and bit-equal scores.
    let latt = engine(6, Precision::F32);
    let target = utterances(1, 900).pop().unwrap();
    let decoys = utterances(3, 950);

    let nbest_at = |pos: usize, decoy_order: &[usize]| -> Vec<(Vec<u32>, String, f32)> {
        let total = decoy_order.len() + 1;
        let mut sessions: Vec<Session> =
            (0..total).map(|_| latt.open(false).unwrap()).collect();
        let mut di = 0;
        for (i, s) in sessions.iter_mut().enumerate() {
            if i == pos {
                latt.push_audio(s, &target);
            } else {
                latt.push_audio(s, &decoys[decoy_order[di]]);
                di += 1;
            }
        }
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        latt.step_batch(&mut refs).unwrap();
        let r = latt.nbest(&mut sessions[pos]).unwrap();
        r.entries.iter().map(|e| (e.words.clone(), e.text.clone(), e.score)).collect()
    };

    let mut alone = latt.open(false).unwrap();
    latt.feed(&mut alone, &target).unwrap();
    let solo: Vec<_> = latt
        .nbest(&mut alone)
        .unwrap()
        .entries
        .iter()
        .map(|e| (e.words.clone(), e.text.clone(), e.score))
        .collect();
    assert!(!solo.is_empty());
    assert_eq!(solo, nbest_at(0, &[0, 1, 2]), "target-first batch diverged");
    assert_eq!(solo, nbest_at(3, &[2, 0, 1]), "target-last shuffled batch diverged");
}

#[test]
fn snapshot_carries_a_nonempty_mid_utterance_lattice() {
    // Snapshot a session halfway through an utterance (lattice already
    // populated), round-trip the encoded bytes, and finish both the
    // original and the restored session on the remaining audio: the
    // transcripts and full N-best lists must be identical.
    let latt = engine(4, Precision::F32);
    let synth = Synthesizer::default();
    let mut rng = Rng::new(4242);
    let u = synth.render(&[1, 4, 7, 2], &mut rng).samples;

    let mut s = latt.open(false).unwrap();
    let half = u.len() / 2;
    latt.feed(&mut s, &u[..half]).unwrap();
    let arcs = s.decode.lattice().map(|l| l.num_arcs()).unwrap_or(0);
    assert!(arcs > 0, "half an utterance must have recorded arcs");

    let bytes = latt.snapshot(&mut s).unwrap().encode();
    let mut restored = latt.restore(&SessionSnapshot::decode(&bytes).unwrap()).unwrap();
    assert_eq!(
        restored.decode.lattice().map(|l| l.num_arcs()),
        Some(arcs),
        "restored lattice lost arcs"
    );

    latt.feed(&mut s, &u[half..]).unwrap();
    latt.feed(&mut restored, &u[half..]).unwrap();
    let a = latt.nbest(&mut s).unwrap();
    let b = latt.nbest(&mut restored).unwrap();
    assert_eq!(a.transcript.text, b.transcript.text);
    assert_eq!(a.transcript.score, b.transcript.score);
    assert_eq!(a.entries.len(), b.entries.len());
    for (x, y) in a.entries.iter().zip(&b.entries) {
        assert_eq!(x.words, y.words);
        assert_eq!(x.text, y.text);
        assert_eq!(x.score, y.score);
    }
}

fn nbest_pool(workers: usize) -> ShardPool {
    ShardPool::start(
        move || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
                .nbest(4)
                // Small batches + short waits so fused batches actually
                // form under test traffic.
                .batch(BatchConfig { max_batch: 4, max_wait_frames: 2 })
                .shards(ShardConfig {
                    workers,
                    rebalance_threshold: 2,
                    ..ShardConfig::default()
                })
                .build()?)
        },
        256,
    )
    .unwrap()
}

#[test]
fn sharded_nbest_matches_single_engine_reference() {
    // N-best through the real router/worker threads, 1 and 4 shards:
    // the 1-best half of every reply is bit-identical to the plain
    // single-engine decode, and the N-best top entry is that 1-best.
    let plain = engine(0, Precision::F32);
    let utts = utterances(6, 777);
    let reference: Vec<_> = utts.iter().map(|u| plain.decode_utterance(u).unwrap().0).collect();
    for workers in [1usize, 4] {
        let pool = nbest_pool(workers);
        let ids: Vec<u64> = utts.iter().map(|_| pool.open().unwrap()).collect();
        // Round-robin chunked feeding so lanes join and leave each
        // shard's ready set at different times.
        let chunk = 1600;
        let mut offs = vec![0usize; utts.len()];
        loop {
            let mut any = false;
            for (i, u) in utts.iter().enumerate() {
                if offs[i] < u.len() {
                    let end = (offs[i] + chunk).min(u.len());
                    pool.feed(ids[i], &u[offs[i]..end]).unwrap();
                    offs[i] = end;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        for (i, id) in ids.iter().enumerate() {
            let r = pool.nbest(*id).unwrap();
            assert_eq!(r.text, reference[i].text, "workers {workers}, utt {i}");
            assert_eq!(r.score, reference[i].score as f64, "workers {workers}, utt {i}");
            assert!(!r.hyps.is_empty());
            assert_eq!(r.hyps[0].text, r.text);
            assert_eq!(r.hyps[0].score, r.score);
            // No rescorer: the rescore column mirrors the first pass.
            for h in &r.hyps {
                assert_eq!(h.rescore, h.score);
            }
        }
        pool.shutdown();
    }
}

#[test]
fn sharded_nbest_reports_second_pass_when_rescoring() {
    let pool = ShardPool::start(
        || {
            let tri = TrigramLm::estimate(&spec::sample_corpus(300, 7777), 0.4)?;
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
                .nbest(3)
                .rescore(tri, 1.1)
                .build()?)
        },
        64,
    )
    .unwrap();
    let u = utterances(1, 31).pop().unwrap();
    let id = pool.open().unwrap();
    pool.feed(id, &u).unwrap();
    let r = pool.nbest(id).unwrap();
    assert!(!r.hyps.is_empty());
    assert_eq!(r.hyps[0].text, r.text, "top entry must match the transcript");
    for h in &r.hyps {
        assert!(h.rescore.is_finite());
    }
    // An engine built *without* N-best refuses the op and keeps the
    // session alive — `finish` still works afterwards.
    let no_latt = ShardPool::start(
        || {
            Ok(Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), MODEL_SEED))
                .build()?)
        },
        64,
    )
    .unwrap();
    let id2 = no_latt.open().unwrap();
    no_latt.feed(id2, &u).unwrap();
    let err = format!("{:#}", no_latt.nbest(id2).unwrap_err());
    assert!(err.contains("bad_request"), "{err}");
    no_latt.finish(id2).unwrap();
    pool.shutdown();
    no_latt.shutdown();
}
