//! Instruction-count models for every ASRPU kernel — the paper's §5.1
//! methodology: "we count the number of instructions for each kernel …
//! a loop will usually consist of two instructions for the comparison
//! and conditional jump, one instruction for the variable update and the
//! instructions for the loop body, all multiplied by the average number
//! of iterations", with every PE executing one instruction per cycle.
//!
//! Each acoustic-scoring kernel is one layer of the model (§4.2), one
//! thread per output neuron; kernels whose model data exceeds model
//! memory are split into neuron subsets (§5.2). The hypothesis-expansion
//! kernel runs one thread per live hypothesis, once per acoustic vector.

use crate::config::{AccelConfig, Layer, PipelineDesc, StageDesc};
use crate::decoder::RescoreStats;

/// Loop-body overhead per iteration: compare + conditional jump + index
/// update (§5.1's example loop shape).
pub const LOOP_OVERHEAD: u64 = 3;
/// Instructions per vector-MAC iteration body: load weight vector, load
/// input vector, vector MAC.
pub const MAC_BODY: u64 = 3;
/// Scalar f32 MAC body (load, load, mul-add) — LayerNorm/MFCC paths.
pub const SCALAR_BODY: u64 = 3;
/// Thread prologue/epilogue: stack/index setup, bias load, activation,
/// output store, exit notification.
pub const THREAD_FIXED: u64 = 14;
/// Setup-thread cost: read buffer state, compute output count, reserve
/// output space, mark inputs consumed, notify controller (§3.2).
pub const SETUP_INSTRS: u64 = 150;
/// FFT butterfly cost (2 loads, twiddle mul 4 ops, 2 add/sub, 2 stores).
const FFT_BUTTERFLY: u64 = 10;
/// Special-function-unit ops (log/exp/cos) count as one instruction —
/// the PE has dedicated SFUs (§3.4).
const SFU_OP: u64 = 1;

/// What a kernel is, for reporting/grouping (Fig. 11 splits conv vs FC
/// vs feature extraction vs hypothesis expansion).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    FeatureExtraction,
    Conv,
    Fc,
    LayerNorm,
    HypExpansion,
    /// Second-pass N-best rescoring (finish-time stage; one thread per
    /// N-best entry walking the higher-order LM).
    Rescore,
}

/// One kernel execution request for the pool scheduler.
#[derive(Debug, Clone)]
pub struct KernelExec {
    pub name: String,
    pub class: KernelClass,
    /// Number of threads launched (setup thread notifies this, §3.3).
    pub threads: u64,
    /// Instructions per thread (uniform within a kernel; hypothesis
    /// expansion uses the average — §5.1 counts averages).
    pub instr_per_thread: u64,
    /// Model data the kernel needs staged in model memory (bytes).
    pub model_bytes: u64,
    /// Shared-memory traffic (bytes in + out), for the energy model.
    pub smem_bytes: u64,
}

impl KernelExec {
    pub fn total_instrs(&self) -> u64 {
        self.threads * self.instr_per_thread
    }
}

/// Per-thread instruction count for a dot-product of length `d` using the
/// `v`-wide int8 vector MAC.
pub fn dot_thread_instrs(d: u64, v: u64) -> u64 {
    let iters = d.div_ceil(v);
    THREAD_FIXED + iters * (MAC_BODY + LOOP_OVERHEAD)
}

/// Per-thread instruction count for one LayerNorm timestep of width `d`
/// (two scalar passes: mean+var fused, then normalize with gain/bias;
/// f32 scalar ALU, no vector MAC).
pub fn layernorm_thread_instrs(d: u64) -> u64 {
    let pass1 = d * (SCALAR_BODY + LOOP_OVERHEAD); // accumulate x, x²
    let pass2 = d * (4 + LOOP_OVERHEAD); // load, sub, mul-add gain/bias, store
    THREAD_FIXED + pass1 + pass2 + 2 * SFU_OP + 6 // rsqrt etc.
}

/// Per-thread instruction count for one MFCC frame (§2.1 pipeline).
pub fn mfcc_thread_instrs(win_len: u64, n_fft: u64, n_mels: u64) -> u64 {
    let preemph_window = win_len * (3 + LOOP_OVERHEAD); // load, sub-mul, mul-store
    let log2n = 63 - n_fft.leading_zeros() as u64;
    let fft = (n_fft / 2) * log2n * (FFT_BUTTERFLY + LOOP_OVERHEAD / 2);
    let n_bins = n_fft / 2 + 1;
    let power = n_bins * (4 + LOOP_OVERHEAD);
    // Triangular filters: each spectrum bin contributes to ≤2 filters.
    let mel = 2 * n_bins * (SCALAR_BODY + LOOP_OVERHEAD);
    let log = n_mels * (SFU_OP + 2 + LOOP_OVERHEAD);
    let dct = n_mels * n_mels * (SCALAR_BODY) + n_mels * LOOP_OVERHEAD;
    THREAD_FIXED + preemph_window + fft + power + mel + log + dct
}

/// Average per-thread cost of hypothesis expansion (§4.3): fetch the
/// hypothesis and its lexicon node, walk every outgoing link producing a
/// child hypothesis, plus the CTC blank and repeat hypotheses, plus the
/// LM walk for the fraction of links that complete a word. Each emitted
/// hypothesis is sent to the hypothesis unit (one store + handshake).
pub fn hyp_expansion_thread_instrs(avg_children: f64, word_commit_frac: f64) -> u64 {
    let fetch = 18u64; // hyp record + lexicon node header
    let per_child = 26.0; // link fetch, score add (SFU log-add), emit
    let per_commit = 34.0; // LM node fetch, score lookup, backoff test, emit
    let blank_repeat = 2 * 16u64;
    let children = (avg_children * (per_child + word_commit_frac * per_commit)) as u64;
    THREAD_FIXED + fetch + children + blank_repeat
}

/// Nominal word count per N-best path the rescoring kernel is sized
/// for when no list has been measured yet (finish-time second pass;
/// utterance length is unknown at step-program build time). Once an
/// engine has served N-best lists, feed its measured
/// [`RescoreStats`] through [`HypWorkload::with_rescore_stats`] and the
/// kernel is sized from serving reality instead.
pub const RESCORE_AVG_WORDS: f64 = 12.0;

/// Per-thread cost of rescoring one N-best path under the second-pass
/// LM: fetch the path record, then per word a trigram-table probe, a
/// backoff test and an SFU score accumulate, finally the re-rank
/// insert handshake with the hypothesis unit.
pub fn rescore_thread_instrs(avg_words: f64) -> u64 {
    let fetch = 18u64; // path record header + word list base
    let per_word = 42.0; // context hash, table probe, backoff test, accumulate
    let emit = 12u64; // sorted re-insert handshake
    THREAD_FIXED + fetch + (avg_words * per_word) as u64 + emit
}

/// Peak multiply-accumulate throughput of the PE pool in GMAC/s: every
/// PE retires one `mac_vector_width`-wide vector MAC per cycle (§3.4).
/// The paper configuration (8 PEs × 8-wide @ 500 MHz) peaks at
/// 32 GMAC/s — the device-side yardstick the host kernel benches
/// (`benches/gemm_kernels.rs`) report their GMAC/s against.
pub fn peak_gmacs(accel: &AccelConfig) -> f64 {
    accel.num_pes as f64 * accel.mac_vector_width as f64 * accel.frequency_hz as f64 / 1e9
}

/// Hypothesis-expansion workload parameters, either defaults derived
/// from the synthetic lexicon or measured `PruneStats` from a real run.
#[derive(Debug, Clone, Copy)]
pub struct HypWorkload {
    /// Live hypotheses entering each expansion (threads launched).
    pub n_hyps: u64,
    /// Mean outgoing lexicon links per hypothesis.
    pub avg_children: f64,
    /// Fraction of advanced links that complete a word (LM walk).
    pub word_commit_frac: f64,
    /// Mean words per N-best path the finish-time rescore kernel is
    /// sized for ([`RESCORE_AVG_WORDS`] until measured list statistics
    /// arrive through [`Self::with_rescore_stats`]).
    pub rescore_avg_words: f64,
}

impl Default for HypWorkload {
    fn default() -> Self {
        // Paper-scale defaults: beam keeps a few hundred live hypotheses
        // (bounded by the 384-entry hypothesis memory); word-piece
        // lexicon tries have high root branching but shallow interiors.
        HypWorkload {
            n_hyps: 256,
            avg_children: 8.0,
            word_commit_frac: 0.12,
            rescore_avg_words: RESCORE_AVG_WORDS,
        }
    }
}

impl HypWorkload {
    /// Replace the nominal rescore path length with measured N-best
    /// statistics from a served engine. Unmeasured (empty) stats keep
    /// the nominal [`RESCORE_AVG_WORDS`] sizing.
    pub fn with_rescore_stats(mut self, stats: &RescoreStats) -> Self {
        if let Some(w) = stats.avg_words() {
            self.rescore_avg_words = w;
        }
        self
    }
}

/// Build the decoding-step kernel sequence by *deriving* it from the
/// shared stage description ([`PipelineDesc`]) — the same ordered stage
/// list the functional engine executes (`coordinator::Engine::pipeline`),
/// so the simulator's program and the engine's pipeline cannot drift
/// apart. Per stage: MFCC (one thread per output frame), one kernel per
/// AM layer (FC kernels split to fit model memory, §5.2), then the
/// hypothesis-expansion repetitions.
///
/// `batch` is the number of concurrent audio streams fused into the step
/// (the coordinator's lane-batched serving, `coordinator::Batcher`). Each
/// stream contributes its own threads and activation traffic, so thread
/// counts and shared-memory bytes scale ×batch — but `model_bytes` does
/// not: the staged weights are shared across lanes, which is exactly the
/// amortization the batched engine exploits. Wider kernels also raise
/// PE-pool utilization on the small layers whose thread count alone
/// cannot fill the pool.
pub fn build_step_kernels(
    pipe: &PipelineDesc,
    accel: &AccelConfig,
    hyp: &HypWorkload,
    batch: usize,
) -> Vec<KernelExec> {
    assert!(batch >= 1, "batch factor must be at least 1");
    let batch = batch as u64;
    let model = &pipe.model;
    let v = accel.mac_vector_width as u64;
    let mut kernels = Vec::new();
    // Temporal rate through the AM stages: output timesteps = frames /
    // rate_div after each strided conv.
    let mut rate_div = 1usize;
    for stage in &pipe.stages {
        match stage {
            StageDesc::Features => {
                kernels.push(KernelExec {
                    name: stage.name(),
                    class: KernelClass::FeatureExtraction,
                    threads: model.frames_per_step() as u64,
                    instr_per_thread: mfcc_thread_instrs(
                        model.win_len as u64,
                        model.win_len.next_power_of_two() as u64,
                        model.n_mels as u64,
                    ),
                    model_bytes: 0,
                    smem_bytes: (model.samples_per_step() * 4
                        + model.frames_per_step() * model.n_mels * 4)
                        as u64,
                });
            }
            StageDesc::AmLayer(layer) => {
                // Per-layer served precision: the calibration map decides
                // the width each layer's weights stream at, so weight DMA
                // is charged at `weight_bits` (3 for 2:4-sparse int4) and
                // activations at `activation_bytes`. LayerNorm parameters
                // stay f32 in every configuration (the map never touches
                // the LN arm below).
                let prec = pipe.precisions.resolve(layer.name());
                let act_bytes = prec.activation_bytes();
                let weight_bits = prec.weight_bits() as u64;
                match layer {
                    Layer::Conv { out_ch, stride, w, in_ch, kw, .. } => {
                        rate_div *= stride;
                        let t_out = (model.frames_per_step() / rate_div) as u64;
                        kernels.push(KernelExec {
                            name: layer.name().to_string(),
                            class: KernelClass::Conv,
                            threads: (out_ch * w) as u64 * t_out,
                            instr_per_thread: dot_thread_instrs(layer.dot_len() as u64, v),
                            model_bytes: layer.model_bytes(prec) as u64,
                            smem_bytes: ((in_ch * w * kw + out_ch * w) * act_bytes) as u64
                                * t_out,
                        });
                    }
                    Layer::Fc { in_dim, out_dim, .. } => {
                        let t_out = (model.frames_per_step() / rate_div) as u64;
                        let bytes = layer.model_bytes(prec) as u64;
                        // §5.2: split kernels larger than model memory into
                        // neuron subsets, each fitting.
                        let splits = bytes.div_ceil(accel.model_mem_bytes as u64).max(1);
                        let neurons_per = (*out_dim as u64).div_ceil(splits);
                        for s in 0..splits {
                            let n = neurons_per.min(*out_dim as u64 - s * neurons_per);
                            let name = if splits == 1 {
                                layer.name().to_string()
                            } else {
                                format!("{}[{}/{}]", layer.name(), s, splits)
                            };
                            kernels.push(KernelExec {
                                name,
                                class: KernelClass::Fc,
                                threads: n * t_out,
                                instr_per_thread: dot_thread_instrs(*in_dim as u64, v),
                                model_bytes: n * (*in_dim as u64 + 1) * weight_bits / 8,
                                smem_bytes: ((*in_dim + *out_dim) * act_bytes) as u64 * t_out,
                            });
                        }
                    }
                    Layer::LayerNorm { dim, .. } => {
                        let t_out = (model.frames_per_step() / rate_div) as u64;
                        kernels.push(KernelExec {
                            name: layer.name().to_string(),
                            class: KernelClass::LayerNorm,
                            threads: t_out,
                            instr_per_thread: layernorm_thread_instrs(*dim as u64),
                            model_bytes: (2 * dim * 4) as u64,
                            smem_bytes: (2 * dim * 4) as u64 * t_out,
                        });
                    }
                }
            }
            StageDesc::HypExpansion { repeats } => {
                // Once per acoustic vector (Fig. 6).
                let instr = hyp_expansion_thread_instrs(hyp.avg_children, hyp.word_commit_frac);
                for rep in 0..*repeats {
                    kernels.push(KernelExec {
                        name: format!("hyp.expand[{rep}]"),
                        class: KernelClass::HypExpansion,
                        threads: hyp.n_hyps,
                        instr_per_thread: instr,
                        model_bytes: 0,
                        smem_bytes: hyp.n_hyps * accel.hyp_record_bytes as u64 * 2,
                    });
                }
            }
            StageDesc::Rescore { nbest } => {
                // Finish-time second pass: one thread per N-best path.
                // Trigram tables stream from external memory, so no
                // model-memory staging; path records round-trip through
                // shared memory like hypothesis records do.
                kernels.push(KernelExec {
                    name: stage.name(),
                    class: KernelClass::Rescore,
                    threads: *nbest as u64,
                    instr_per_thread: rescore_thread_instrs(hyp.rescore_avg_words),
                    model_bytes: 0,
                    smem_bytes: *nbest as u64 * accel.hyp_record_bytes as u64 * 2,
                });
            }
        }
    }
    // Lane-batching: every stream runs its own threads over the same
    // staged model data.
    if batch > 1 {
        for k in &mut kernels {
            k.threads *= batch;
            k.smem_bytes *= batch;
        }
    }
    kernels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn pipe(m: &ModelConfig) -> PipelineDesc {
        PipelineDesc::for_model(m)
    }

    #[test]
    fn dot_instrs_scale_with_length_and_vector_width() {
        assert!(dot_thread_instrs(1200, 8) > dot_thread_instrs(800, 8));
        // 8-wide MAC ≈ 4× fewer iterations than 2-wide.
        let wide = dot_thread_instrs(1200, 8);
        let narrow = dot_thread_instrs(1200, 2);
        assert!((narrow as f64 / wide as f64) > 3.5);
        // 1200/8 = 150 iterations × 6 + fixed.
        assert_eq!(dot_thread_instrs(1200, 8), THREAD_FIXED + 150 * 6);
    }

    #[test]
    fn paper_step_kernel_inventory() {
        let m = ModelConfig::paper_tds();
        let a = AccelConfig::paper();
        let ks = build_step_kernels(&pipe(&m), &a, &HypWorkload::default(), 1);
        let count = |c: KernelClass| ks.iter().filter(|k| k.class == c).count();
        assert_eq!(count(KernelClass::FeatureExtraction), 1);
        assert_eq!(count(KernelClass::Conv), 18);
        assert_eq!(count(KernelClass::LayerNorm), 32);
        // Hidden FCs: g0 8×640 KB + g1 10×922 KB unsplit, g2 10×1.44 MB
        // split ×2 = 20; output FC 1200×9000 ≈ 10.8 MB → split ×11.
        // 8 + 10 + 20 + 11 = 49 FC kernel executions (§5.2 splitting).
        assert_eq!(count(KernelClass::Fc), 49);
        assert_eq!(count(KernelClass::HypExpansion), 4);
    }

    #[test]
    fn split_kernels_fit_model_memory() {
        let m = ModelConfig::paper_tds();
        let a = AccelConfig::paper();
        let ks = build_step_kernels(&pipe(&m), &a, &HypWorkload::default(), 1);
        for k in &ks {
            assert!(
                k.model_bytes <= a.model_mem_bytes as u64,
                "kernel {} needs {} bytes > model memory",
                k.name,
                k.model_bytes
            );
        }
        // Splits preserve total neurons: sum of split threads equals the
        // unsplit layer's threads.
        let out_threads: u64 = ks
            .iter()
            .filter(|k| k.name.starts_with("output.fc"))
            .map(|k| k.threads)
            .sum();
        assert_eq!(out_threads, 9000 * m.vectors_per_step() as u64);
    }

    #[test]
    fn first_fc_splits_in_two_like_paper() {
        // §5.2: "We divide each of these layers into 2 kernels, each
        // computing 600 neurons."
        let m = ModelConfig::paper_tds();
        let a = AccelConfig::paper();
        let ks = build_step_kernels(&pipe(&m), &a, &HypWorkload::default(), 1);
        let g2_fc: Vec<&KernelExec> =
            ks.iter().filter(|k| k.name.starts_with("g2.b0.fc0")).collect();
        assert_eq!(g2_fc.len(), 2, "1.44 MB FC splits into exactly 2 kernels");
        // Each handles 600 neurons × 4 timesteps.
        assert_eq!(g2_fc[0].threads, 600 * 4);
    }

    #[test]
    fn subsampling_reduces_downstream_threads() {
        let m = ModelConfig::paper_tds();
        let a = AccelConfig::paper();
        let ks = build_step_kernels(&pipe(&m), &a, &HypWorkload::default(), 1);
        let sub = ks.iter().find(|k| k.name == "g0.sub").unwrap();
        let blk = ks.iter().find(|k| k.name == "g0.b0.conv").unwrap();
        // Entry conv emits at stride 2 → 4 timesteps; so does the block.
        assert_eq!(sub.threads, (10 * 80 * 4) as u64);
        assert_eq!(blk.threads, (10 * 80 * 4) as u64);
    }

    #[test]
    fn batch_factor_scales_threads_not_model_bytes() {
        let m = ModelConfig::paper_tds();
        let a = AccelConfig::paper();
        let one = build_step_kernels(&pipe(&m), &a, &HypWorkload::default(), 1);
        let eight = build_step_kernels(&pipe(&m), &a, &HypWorkload::default(), 8);
        assert_eq!(one.len(), eight.len(), "batching adds lanes, not kernels");
        for (x, y) in one.iter().zip(&eight) {
            assert_eq!(y.threads, 8 * x.threads, "{}", x.name);
            assert_eq!(y.smem_bytes, 8 * x.smem_bytes, "{}", x.name);
            // Staged weights are shared across lanes.
            assert_eq!(y.model_bytes, x.model_bytes, "{}", x.name);
            assert_eq!(y.instr_per_thread, x.instr_per_thread, "{}", x.name);
        }
    }

    #[test]
    fn precision_knob_scales_weight_traffic_4x() {
        use crate::config::Precision;
        let m8 = ModelConfig::paper_tds();
        assert!(m8.precision.is_quantized());
        let m32 = ModelConfig { precision: Precision::F32, ..ModelConfig::paper_tds() };
        let a = AccelConfig::paper();
        let hyp = HypWorkload::default();
        let k8 = build_step_kernels(&pipe(&m8), &a, &hyp, 1);
        let k32 = build_step_kernels(&pipe(&m32), &a, &hyp, 1);
        let weight_bytes = |ks: &[KernelExec]| {
            ks.iter()
                .filter(|k| matches!(k.class, KernelClass::Conv | KernelClass::Fc))
                .map(|k| k.model_bytes)
                .sum::<u64>()
        };
        // Exactly 4× less conv/FC weight traffic at int8 (LayerNorm
        // params stay f32 in both presets).
        assert_eq!(weight_bytes(&k32), 4 * weight_bytes(&k8));
        // f32 FCs overflow model memory more often, so the §5.2 splitting
        // produces strictly more kernel executions.
        assert!(k32.len() > k8.len(), "{} !> {}", k32.len(), k8.len());
        // Same total compute either way: threads and per-thread cost are
        // precision-independent (the MAC unit is 8-bit wide regardless).
        let instrs = |ks: &[KernelExec]| ks.iter().map(|k| k.total_instrs()).sum::<u64>();
        assert_eq!(instrs(&k8), instrs(&k32));
    }

    #[test]
    fn int4_at_least_halves_conv_fc_weight_traffic_vs_int8() {
        use crate::config::{Precision, PrecisionMap};
        let m = ModelConfig::paper_tds();
        assert_eq!(m.precision, Precision::Int8);
        let a = AccelConfig::paper();
        let hyp = HypWorkload::default();
        let k8 = build_step_kernels(&pipe(&m), &a, &hyp, 1);
        let p4 = PipelineDesc::for_model_mixed(&m, PrecisionMap::uniform(Precision::Int4));
        let k4 = build_step_kernels(&p4, &a, &hyp, 1);
        // LayerNorm parameters stay f32 at every precision, so the
        // headline claim is over the layers the map actually narrows.
        let weight_bytes = |ks: &[KernelExec]| {
            ks.iter()
                .filter(|k| matches!(k.class, KernelClass::Conv | KernelClass::Fc))
                .map(|k| k.model_bytes)
                .sum::<u64>()
        };
        let (b8, b4) = (weight_bytes(&k8), weight_bytes(&k4));
        assert!(
            b8 >= 2 * b4,
            "int8 conv/FC weight DMA {b8} not ≥ 2× int4 {b4}"
        );
        // 2:4 sparse (3 bits/weight amortized) narrows further still.
        let ps =
            PipelineDesc::for_model_mixed(&m, PrecisionMap::uniform(Precision::Int4Sparse));
        let ksparse = build_step_kernels(&ps, &a, &hyp, 1);
        let bs = weight_bytes(&ksparse);
        assert!(bs < b4, "sparse weight DMA {bs} not below dense int4 {b4}");
        // Same total compute either way: threads and per-thread cost are
        // precision-independent (the MAC unit is 8-bit wide regardless).
        let instrs = |ks: &[KernelExec]| ks.iter().map(|k| k.total_instrs()).sum::<u64>();
        assert_eq!(instrs(&k8), instrs(&k4));
        assert_eq!(instrs(&k8), instrs(&ksparse));
    }

    #[test]
    fn mixed_map_charges_each_layer_at_its_resolved_width() {
        use crate::config::{Precision, PrecisionMap};
        let m = ModelConfig::paper_tds();
        let a = AccelConfig::paper();
        let mut map = PrecisionMap::uniform(Precision::Int4);
        map.set("g0.sub", Precision::F32);
        map.set("g0.b0.conv", Precision::Int8);
        let p = PipelineDesc::for_model_mixed(&m, map);
        let ks = build_step_kernels(&p, &a, &HypWorkload::default(), 1);
        let layer = |name: &str| m.layers().into_iter().find(|l| l.name() == name).unwrap();
        let exec = |name: &str| ks.iter().find(|k| k.name == name).unwrap();
        assert_eq!(
            exec("g0.sub").model_bytes,
            layer("g0.sub").model_bytes(Precision::F32) as u64
        );
        assert_eq!(
            exec("g0.b0.conv").model_bytes,
            layer("g0.b0.conv").model_bytes(Precision::Int8) as u64
        );
        // An un-overridden conv streams at the map's int4 default.
        assert_eq!(
            exec("g1.b0.conv").model_bytes,
            layer("g1.b0.conv").model_bytes(Precision::Int4) as u64
        );
    }

    #[test]
    fn rescore_kernel_is_sized_from_measured_nbest_stats() {
        use crate::decoder::NbestEntry;
        let entry = |n: usize| NbestEntry { words: vec![0; n], text: String::new(), score: 0.0 };
        let mut stats = RescoreStats::default();
        stats.record(&[entry(3), entry(5)]);
        let hyp = HypWorkload::default().with_rescore_stats(&stats);
        assert_eq!(hyp.rescore_avg_words, 4.0);
        let m = ModelConfig::paper_tds();
        let a = AccelConfig::paper();
        let mut p = pipe(&m);
        p.stages.push(StageDesc::Rescore { nbest: 8 });
        let ks = build_step_kernels(&p, &a, &hyp, 1);
        let r = ks.iter().find(|k| k.class == KernelClass::Rescore).unwrap();
        assert_eq!(r.instr_per_thread, rescore_thread_instrs(4.0));
        assert_ne!(r.instr_per_thread, rescore_thread_instrs(RESCORE_AVG_WORDS));
        // Unmeasured stats keep the nominal sizing constant.
        let idle = HypWorkload::default().with_rescore_stats(&RescoreStats::default());
        assert_eq!(idle.rescore_avg_words, RESCORE_AVG_WORDS);
    }

    #[test]
    fn rescore_stage_adds_one_kernel() {
        let m = ModelConfig::paper_tds();
        let a = AccelConfig::paper();
        let mut p = pipe(&m);
        p.stages.push(StageDesc::Rescore { nbest: 8 });
        let ks = build_step_kernels(&p, &a, &HypWorkload::default(), 1);
        let rescore: Vec<&KernelExec> =
            ks.iter().filter(|k| k.class == KernelClass::Rescore).collect();
        assert_eq!(rescore.len(), 1);
        assert_eq!(rescore[0].threads, 8);
        assert_eq!(rescore[0].model_bytes, 0, "trigram tables stream, no staging");
        assert_eq!(
            rescore[0].instr_per_thread,
            rescore_thread_instrs(RESCORE_AVG_WORDS)
        );
        // The rescore program is tiny next to expansion: it must not
        // perturb the step total materially.
        let total: u64 = ks.iter().map(|k| k.total_instrs()).sum();
        let rescore_instrs = rescore[0].total_instrs();
        assert!(rescore_instrs * 1000 < total);
    }

    #[test]
    fn hyp_expansion_cost_scales_with_branching() {
        let narrow = hyp_expansion_thread_instrs(2.0, 0.1);
        let wide = hyp_expansion_thread_instrs(20.0, 0.1);
        assert!(wide > 3 * narrow / 2);
    }

    #[test]
    fn total_step_instructions_in_expected_band() {
        // Sanity: the paper's step executes in ≈40 ms at 500 MHz on 8 PEs
        // ⇒ ≈160 M instruction slots. Our counted total must be within
        // the same order (50–160 M) for the headline claim to reproduce.
        let m = ModelConfig::paper_tds();
        let a = AccelConfig::paper();
        let ks = build_step_kernels(&pipe(&m), &a, &HypWorkload::default(), 1);
        let total: u64 = ks.iter().map(|k| k.total_instrs()).sum();
        assert!(
            (50_000_000..170_000_000).contains(&total),
            "total step instructions {total}"
        );
    }

    #[test]
    fn peak_gmacs_matches_paper_configuration() {
        // 8 PEs × 8-wide MAC @ 500 MHz = 32 GMAC/s.
        assert_eq!(peak_gmacs(&AccelConfig::paper()), 32.0);
    }
}
