//! Hypothesis-unit model (§3.5): a dedicated memory plus controller that
//! receives hypotheses from expansion threads, keeps them sorted, and
//! prunes by beam and capacity. Timing: the controller inserts one
//! hypothesis per cycle into its score-sorted memory (hardware insertion
//! sort over a small SRAM), overlapped with expansion-thread execution.

use crate::config::AccelConfig;
use crate::decoder::{ExpandStats, PruneStats};

use super::kernels::HypWorkload;

/// Timing/occupancy outcome of one expansion round through the unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HypUnitRound {
    /// Cycles the unit spends inserting + pruning.
    pub insert_cycles: u64,
    /// Candidates that arrived after the outgoing set filled (dropped by
    /// capacity, exactly like `Pruner::capacity_pruned`).
    pub overflow: u64,
    /// Live hypotheses kept for the next round.
    pub kept: u64,
}

/// The unit itself (hardware parameters only; search behaviour lives in
/// [`crate::decoder::Pruner`], which this model mirrors in time).
#[derive(Debug, Clone, Copy)]
pub struct HypUnit {
    pub capacity: u64,
}

impl HypUnit {
    pub fn new(accel: &AccelConfig) -> Self {
        HypUnit { capacity: accel.hyp_capacity() as u64 }
    }

    /// Process `candidates` arriving hypotheses of which `within_beam`
    /// survive the score beam.
    pub fn round(&self, candidates: u64, within_beam: u64) -> HypUnitRound {
        let within_beam = within_beam.min(candidates);
        let kept = within_beam.min(self.capacity);
        HypUnitRound {
            // One insertion per arriving candidate (beam-rejected ones
            // are still compared: 1 cycle each).
            insert_cycles: candidates,
            overflow: within_beam - kept,
            kept,
        }
    }

    /// The mean expansion round implied by measured decoder statistics:
    /// `generated / rounds` candidate arrivals of which everything the
    /// merge and beam did not reject is within-beam. This is how the
    /// simulator's unit is driven from a real decode's per-flush
    /// `PruneStats` instead of synthetic inputs.
    pub fn round_from_stats(&self, stats: &PruneStats) -> HypUnitRound {
        let rounds = stats.rounds.max(1);
        let candidates = stats.generated / rounds;
        let within = (stats.generated - stats.merged - stats.beam_pruned) / rounds;
        self.round(candidates, within)
    }
}

impl HypWorkload {
    /// Derive the simulator workload from measured functional-decoder
    /// statistics, coupling the timing experiments to real search
    /// behaviour (DESIGN.md: simulator and engine share one workload).
    pub fn from_stats(stats: &PruneStats, avg_children: f64, word_commit_frac: f64) -> Self {
        HypWorkload {
            n_hyps: stats.mean_live().ceil().max(1.0) as u64,
            avg_children,
            word_commit_frac,
            ..Default::default()
        }
    }

    /// Derive every workload parameter from measured decoder counters —
    /// no synthetic inputs. Branching and word-commit fractions come
    /// from the expansion-side [`ExpandStats`] (advance/commit arcs per
    /// expanded hypothesis); occupancy comes from the prune-side
    /// [`PruneStats`], exactly as [`HypWorkload::from_stats`].
    pub fn from_measured(prune: &PruneStats, expand: &ExpandStats) -> Self {
        let expanded = expand.expanded.max(1) as f64;
        let links = expand.advance + expand.commit;
        let avg_children = links as f64 / expanded;
        let word_commit_frac = if links == 0 {
            0.0
        } else {
            expand.commit as f64 / links as f64
        };
        Self::from_stats(prune, avg_children, word_commit_frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_from_table2() {
        let u = HypUnit::new(&AccelConfig::paper());
        assert_eq!(u.capacity, 384);
    }

    #[test]
    fn round_respects_capacity() {
        let u = HypUnit { capacity: 10 };
        let r = u.round(100, 40);
        assert_eq!(r.kept, 10);
        assert_eq!(r.overflow, 30);
        assert_eq!(r.insert_cycles, 100);
    }

    #[test]
    fn round_clamps_inconsistent_inputs() {
        let u = HypUnit { capacity: 10 };
        let r = u.round(5, 50); // within_beam > candidates
        assert_eq!(r.kept, 5);
        assert_eq!(r.overflow, 0);
    }

    #[test]
    fn insertion_hides_behind_expansion() {
        // 256 candidates = 256 insert cycles; a single expansion thread
        // costs hundreds of instructions, so with any pool the unit is
        // never the bottleneck — the §3.5 design point.
        let u = HypUnit::new(&AccelConfig::paper());
        let r = u.round(256 * 8, 256 * 8);
        let expansion_cycles = super::super::kernels::hyp_expansion_thread_instrs(8.0, 0.12)
            * 256
            / 8; // 256 threads on 8 PEs
        assert!(r.insert_cycles < expansion_cycles);
    }

    #[test]
    fn workload_from_stats() {
        let stats = PruneStats {
            generated: 1000,
            merged: 100,
            beam_pruned: 300,
            capacity_pruned: 200,
            peak_live: 80,
            rounds: 10,
        };
        let w = HypWorkload::from_stats(&stats, 5.0, 0.2);
        assert_eq!(w.n_hyps, 40); // survived 400 / 10 rounds
        assert_eq!(w.avg_children, 5.0);
    }

    #[test]
    fn workload_from_measured_counters() {
        let prune = PruneStats {
            generated: 1000,
            merged: 100,
            beam_pruned: 300,
            capacity_pruned: 200,
            peak_live: 80,
            rounds: 10,
        };
        let expand = ExpandStats {
            expanded: 100,
            blank: 100,
            repeat: 60,
            advance: 700,
            commit: 140,
        };
        let w = HypWorkload::from_measured(&prune, &expand);
        assert_eq!(w.n_hyps, 40);
        assert!((w.avg_children - 8.4).abs() < 1e-9, "{}", w.avg_children);
        let frac = 140.0 / 840.0;
        assert!((w.word_commit_frac - frac).abs() < 1e-9);
        // Degenerate counters must not divide by zero.
        let idle = HypWorkload::from_measured(&PruneStats::default(), &ExpandStats::default());
        assert_eq!(idle.word_commit_frac, 0.0);
        assert_eq!(idle.avg_children, 0.0);
    }

    #[test]
    fn round_from_stats_matches_explicit_round() {
        let stats = PruneStats {
            generated: 1000,
            merged: 100,
            beam_pruned: 300,
            capacity_pruned: 200,
            peak_live: 80,
            rounds: 10,
        };
        let u = HypUnit { capacity: 30 };
        let r = u.round_from_stats(&stats);
        // 100 arrivals per round, 60 within beam, capacity 30.
        assert_eq!(r, u.round(100, 60));
        assert_eq!(r.kept, 30);
        assert_eq!(r.overflow, 30);
        // Zero-round stats are clamped, not divided by zero.
        let empty = u.round_from_stats(&PruneStats::default());
        assert_eq!(empty.insert_cycles, 0);
    }
}
