//! Hypothesis-unit model (§3.5): a dedicated memory plus controller that
//! receives hypotheses from expansion threads, keeps them sorted, and
//! prunes by beam and capacity. Timing: the controller inserts one
//! hypothesis per cycle into its score-sorted memory (hardware insertion
//! sort over a small SRAM), overlapped with expansion-thread execution.

use crate::config::AccelConfig;
use crate::decoder::PruneStats;

use super::kernels::HypWorkload;

/// Timing/occupancy outcome of one expansion round through the unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HypUnitRound {
    /// Cycles the unit spends inserting + pruning.
    pub insert_cycles: u64,
    /// Candidates that arrived after the outgoing set filled (dropped by
    /// capacity, exactly like `Pruner::capacity_pruned`).
    pub overflow: u64,
    /// Live hypotheses kept for the next round.
    pub kept: u64,
}

/// The unit itself (hardware parameters only; search behaviour lives in
/// [`crate::decoder::Pruner`], which this model mirrors in time).
#[derive(Debug, Clone, Copy)]
pub struct HypUnit {
    pub capacity: u64,
}

impl HypUnit {
    pub fn new(accel: &AccelConfig) -> Self {
        HypUnit { capacity: accel.hyp_capacity() as u64 }
    }

    /// Process `candidates` arriving hypotheses of which `within_beam`
    /// survive the score beam.
    pub fn round(&self, candidates: u64, within_beam: u64) -> HypUnitRound {
        let within_beam = within_beam.min(candidates);
        let kept = within_beam.min(self.capacity);
        HypUnitRound {
            // One insertion per arriving candidate (beam-rejected ones
            // are still compared: 1 cycle each).
            insert_cycles: candidates,
            overflow: within_beam - kept,
            kept,
        }
    }
}

impl HypWorkload {
    /// Derive the simulator workload from measured functional-decoder
    /// statistics, coupling the timing experiments to real search
    /// behaviour (DESIGN.md: simulator and engine share one workload).
    pub fn from_stats(stats: &PruneStats, avg_children: f64, word_commit_frac: f64) -> Self {
        HypWorkload {
            n_hyps: stats.mean_live().ceil().max(1.0) as u64,
            avg_children,
            word_commit_frac,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_from_table2() {
        let u = HypUnit::new(&AccelConfig::paper());
        assert_eq!(u.capacity, 384);
    }

    #[test]
    fn round_respects_capacity() {
        let u = HypUnit { capacity: 10 };
        let r = u.round(100, 40);
        assert_eq!(r.kept, 10);
        assert_eq!(r.overflow, 30);
        assert_eq!(r.insert_cycles, 100);
    }

    #[test]
    fn round_clamps_inconsistent_inputs() {
        let u = HypUnit { capacity: 10 };
        let r = u.round(5, 50); // within_beam > candidates
        assert_eq!(r.kept, 5);
        assert_eq!(r.overflow, 0);
    }

    #[test]
    fn insertion_hides_behind_expansion() {
        // 256 candidates = 256 insert cycles; a single expansion thread
        // costs hundreds of instructions, so with any pool the unit is
        // never the bottleneck — the §3.5 design point.
        let u = HypUnit::new(&AccelConfig::paper());
        let r = u.round(256 * 8, 256 * 8);
        let expansion_cycles = super::super::kernels::hyp_expansion_thread_instrs(8.0, 0.12)
            * 256
            / 8; // 256 threads on 8 PEs
        assert!(r.insert_cycles < expansion_cycles);
    }

    #[test]
    fn workload_from_stats() {
        let stats = PruneStats {
            generated: 1000,
            merged: 100,
            beam_pruned: 300,
            capacity_pruned: 200,
            peak_live: 80,
            rounds: 10,
        };
        let w = HypWorkload::from_stats(&stats, 5.0, 0.2);
        assert_eq!(w.n_hyps, 40); // survived 400 / 10 rounds
        assert_eq!(w.avg_children, 5.0);
    }
}
