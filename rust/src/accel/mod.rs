//! The ASRPU accelerator simulator (§3): command decoder, ASR controller
//! with Fig. 7 setup/DMA pipelining, PE-pool scheduling, hypothesis unit
//! and the §5.1 instruction-count kernel models.

pub mod command;
pub mod controller;
pub mod hypunit;
pub mod kernels;
pub mod memory;
pub mod pool;

pub use command::{AsrpuDevice, Command};
pub use controller::{
    simulate_pipeline, simulate_step, simulate_step_batched, simulate_step_elastic,
    simulate_step_sharded, ShardedReport, SimMode, StepReport,
};
pub use hypunit::HypUnit;
pub use memory::{Cache, GraphWorkload};
pub use kernels::{build_step_kernels, HypWorkload, KernelClass, KernelExec};
