//! Memory-hierarchy models (§3.6).
//!
//! During acoustic scoring the model memory is a software-managed staging
//! buffer (DMA prefetch, modeled in the controller). During hypothesis
//! expansion it "acts as a regular LRU cache to leverage locality in the
//! access to the graph structures" — the lexicon and LM graphs are far
//! larger than on-chip SRAM and are walked with little spatial locality.
//! This module provides a set-associative LRU cache simulator and a
//! Monte-Carlo estimate of the hypothesis-expansion miss rate, which the
//! controller's Detailed mode converts into PE stall cycles.

use crate::util::rng::Rng;

/// Set-associative cache with LRU replacement.
#[derive(Debug, Clone)]
pub struct Cache {
    pub line_bytes: usize,
    pub sets: usize,
    pub ways: usize,
    /// tags[set * ways + way]; u64::MAX = invalid.
    tags: Vec<u64>,
    /// LRU stamps, monotone counter.
    stamps: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    /// `bytes` total capacity, `ways` associativity, `line_bytes` line.
    pub fn new(bytes: usize, ways: usize, line_bytes: usize) -> Self {
        assert!(line_bytes.is_power_of_two() && ways > 0);
        let lines = bytes / line_bytes;
        assert!(lines >= ways, "cache smaller than one set");
        // Round set count down to a power of two for cheap indexing.
        let raw = (lines / ways).max(1);
        let sets = if raw.is_power_of_two() {
            raw
        } else {
            raw.next_power_of_two() / 2
        };
        Cache {
            line_bytes,
            sets,
            ways,
            tags: vec![u64::MAX; sets * ways],
            stamps: vec![0; sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Access a byte address; returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        let line = addr / self.line_bytes as u64;
        let set = (line % self.sets as u64) as usize;
        let tag = line / self.sets as u64;
        let base = set * self.ways;
        // Hit?
        for w in 0..self.ways {
            if self.tags[base + w] == tag {
                self.stamps[base + w] = self.clock;
                self.hits += 1;
                return true;
            }
        }
        // Miss: evict LRU way.
        self.misses += 1;
        let mut victim = 0;
        for w in 1..self.ways {
            if self.stamps[base + w] < self.stamps[base + victim] {
                victim = w;
            }
        }
        self.tags[base + victim] = tag;
        self.stamps[base + victim] = self.clock;
        false
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

/// Hypothesis-expansion access pattern: each thread touches its
/// hypothesis record (hypothesis memory — on chip, not modeled here),
/// then walks lexicon-trie nodes (skewed toward shallow nodes: depth
/// popularity ~ Zipf) and, on word commits, an LM node (near-uniform
/// over the bigram table — the low-locality part).
pub struct GraphWorkload {
    pub lexicon_bytes: u64,
    pub lm_bytes: u64,
    /// Accesses per expanded hypothesis into each graph.
    pub lex_accesses_per_hyp: f64,
    pub lm_accesses_per_hyp: f64,
}

impl GraphWorkload {
    /// Paper-scale defaults: a word-piece lexicon trie of a few MB and a
    /// pruned n-gram LM of a few hundred MB (§3.6: "hundreds of MB").
    pub fn paper() -> Self {
        GraphWorkload {
            lexicon_bytes: 8 << 20,
            lm_bytes: 300 << 20,
            lex_accesses_per_hyp: 9.0, // node + 8 links (HypWorkload default)
            lm_accesses_per_hyp: 1.0,  // word_commit_frac ≈ 0.12 × lookup chain
        }
    }
}

/// Monte-Carlo miss-rate estimate for one decoding step of hypothesis
/// expansion: `n_hyps × vectors` threads replaying the skewed access
/// pattern through the model-memory cache. Deterministic per seed.
pub fn hyp_expansion_miss_rate(
    cache_bytes: usize,
    workload: &GraphWorkload,
    n_threads: u64,
    seed: u64,
) -> f64 {
    let mut cache = Cache::new(cache_bytes, 8, 64);
    let mut rng = Rng::new(seed);
    // Warm the cache with one round first (steady-state estimate: the
    // cache persists across decoding steps).
    for round in 0..2 {
        if round == 1 {
            cache.reset_stats();
        }
        for _ in 0..n_threads {
            let lex = workload.lex_accesses_per_hyp.round() as usize;
            for _ in 0..lex {
                // Trie walks are skewed: every thread re-touches the root
                // region (first-level nodes + link tables, ~128 KB hot
                // set), deeper nodes follow a Zipf-ish u⁴ profile.
                let addr = if rng.f64() < 0.5 {
                    rng.below((128 << 10).min(workload.lexicon_bytes))
                } else {
                    let u = rng.f64();
                    ((u * u * u * u) * workload.lexicon_bytes as f64) as u64
                };
                cache.access(addr);
            }
            let lm = workload.lm_accesses_per_hyp.round() as usize;
            for _ in 0..lm {
                let addr =
                    workload.lexicon_bytes + rng.below(workload.lm_bytes.max(1));
                cache.access(addr);
            }
        }
    }
    1.0 - cache.hit_rate()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1 << 20, 8, 64);
        assert!(!c.access(0x1000));
        assert!(c.access(0x1000));
        assert!(c.access(0x1010), "same line");
        assert!(!c.access(0x2000), "different line");
    }

    #[test]
    fn working_set_within_capacity_converges_to_hits() {
        let mut c = Cache::new(64 << 10, 8, 64);
        let mut rng = Rng::new(1);
        // 32 KB working set inside a 64 KB cache.
        for _ in 0..20_000 {
            c.access(rng.below(32 << 10));
        }
        c.reset_stats();
        for _ in 0..20_000 {
            c.access(rng.below(32 << 10));
        }
        assert!(c.hit_rate() > 0.99, "hit rate {}", c.hit_rate());
    }

    #[test]
    fn working_set_beyond_capacity_misses() {
        let mut c = Cache::new(64 << 10, 8, 64);
        let mut rng = Rng::new(2);
        for _ in 0..50_000 {
            c.access(rng.below(64 << 20)); // 64 MB uniform
        }
        assert!(c.hit_rate() < 0.05, "hit rate {}", c.hit_rate());
    }

    #[test]
    fn lru_evicts_least_recent() {
        // Direct-construct a tiny 2-way cache: 2 sets × 2 ways × 64 B.
        let mut c = Cache::new(256, 2, 64);
        assert_eq!(c.sets * c.ways * c.line_bytes, 256);
        // Three distinct tags mapping to set 0.
        let stride = (c.sets * c.line_bytes) as u64;
        assert!(!c.access(0));
        assert!(!c.access(stride));
        assert!(c.access(0)); // refresh tag0
        assert!(!c.access(2 * stride)); // evicts tag1 (LRU)
        assert!(c.access(0), "tag0 must survive");
        assert!(!c.access(stride), "tag1 was evicted");
    }

    #[test]
    fn conservation_property() {
        prop::check("cache-hit-miss-conservation", 25, |g| {
            let bytes = 1 << (12 + g.index(6));
            let ways = 1 << g.index(4);
            let mut c = Cache::new(bytes, ways, 64);
            let n = g.len(10) as u64 * 50;
            for _ in 0..n {
                c.access(g.rng.below(1 << 22));
            }
            crate::prop_assert!(c.hits + c.misses == n, "conservation");
            crate::prop_assert!((0.0..=1.0).contains(&c.hit_rate()), "rate range");
            Ok(())
        });
    }

    #[test]
    fn miss_rate_monotone_in_cache_size() {
        let w = GraphWorkload::paper();
        let small = hyp_expansion_miss_rate(64 << 10, &w, 512, 7);
        let large = hyp_expansion_miss_rate(4 << 20, &w, 512, 7);
        assert!(large < small, "bigger cache should miss less: {large} !< {small}");
    }

    #[test]
    fn paper_config_miss_rate_is_moderate() {
        // 1 MB model memory vs ~300 MB of graphs: LM lookups mostly miss,
        // lexicon walk mostly hits (Zipf skew) ⇒ miss rate between the
        // two extremes.
        let w = GraphWorkload::paper();
        let rate = hyp_expansion_miss_rate(1 << 20, &w, 1024, 9);
        assert!((0.05..0.6).contains(&rate), "miss rate {rate}");
    }
}
