//! PE-pool thread scheduler (§3.3): the ASR controller dispatches kernel
//! threads to idle PEs; every time a PE becomes idle it receives the next
//! thread, until the kernel's threads are exhausted. This is classic
//! online list scheduling, simulated exactly with a min-heap of PE
//! free times.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Outcome of scheduling one kernel on the pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolRun {
    /// Cycles from dispatch start to last thread completion.
    pub makespan: u64,
    /// Σ busy cycles across PEs (= total instructions at 1 IPC).
    pub busy_cycles: u64,
    /// busy / (makespan × PEs) — pool utilization.
    pub utilization: f64,
}

/// Schedule `threads` equal-cost threads of `cycles_per_thread` each on
/// `num_pes` PEs (the common case: one thread per neuron, §3.1) — closed
/// form.
pub fn schedule_uniform(threads: u64, cycles_per_thread: u64, num_pes: u64) -> PoolRun {
    if threads == 0 || cycles_per_thread == 0 {
        return PoolRun { makespan: 0, busy_cycles: 0, utilization: 1.0 };
    }
    let waves = threads.div_ceil(num_pes);
    let makespan = waves * cycles_per_thread;
    let busy = threads * cycles_per_thread;
    PoolRun {
        makespan,
        busy_cycles: busy,
        utilization: busy as f64 / (makespan * num_pes) as f64,
    }
}

/// Schedule threads with heterogeneous costs (hypothesis expansion with
/// per-hypothesis branching) in dispatch order.
pub fn schedule(thread_cycles: &[u64], num_pes: usize) -> PoolRun {
    assert!(num_pes > 0);
    let mut heap: BinaryHeap<Reverse<u64>> = (0..num_pes).map(|_| Reverse(0u64)).collect();
    let mut makespan = 0u64;
    let mut busy = 0u64;
    for &c in thread_cycles {
        let Reverse(free_at) = heap.pop().unwrap();
        let done = free_at + c;
        busy += c;
        makespan = makespan.max(done);
        heap.push(Reverse(done));
    }
    let util = if makespan == 0 {
        1.0
    } else {
        busy as f64 / (makespan * num_pes as u64) as f64
    };
    PoolRun { makespan, busy_cycles: busy, utilization: util }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn uniform_closed_form_matches_simulation() {
        prop::check("uniform-schedule-closed-form", 40, |g| {
            let threads = g.len(0) as u64;
            let cycles = 1 + g.index(1000) as u64;
            let pes = 1 + g.index(16);
            let fast = schedule_uniform(threads, cycles, pes as u64);
            let slow = schedule(&vec![cycles; threads as usize], pes);
            crate::prop_assert!(
                fast.makespan == slow.makespan,
                "makespan {} != {}",
                fast.makespan,
                slow.makespan
            );
            crate::prop_assert!(fast.busy_cycles == slow.busy_cycles, "busy mismatch");
            Ok(())
        });
    }

    #[test]
    fn makespan_bounds_property() {
        prop::check("schedule-bounds", 40, |g| {
            let n = g.len(1);
            let costs = g.vec_of(n, |r| 1 + r.below(500));
            let pes = 1 + g.index(12);
            let run = schedule(&costs, pes);
            let total: u64 = costs.iter().sum();
            let max = *costs.iter().max().unwrap();
            // Lower bounds: critical path and perfect balance.
            crate::prop_assert!(run.makespan >= max, "below critical path");
            crate::prop_assert!(
                run.makespan >= total.div_ceil(pes as u64),
                "below perfect balance"
            );
            // Graham bound for list scheduling: ≤ total/p + max.
            crate::prop_assert!(
                run.makespan <= total / pes as u64 + max,
                "above Graham bound: {} > {}",
                run.makespan,
                total / pes as u64 + max
            );
            crate::prop_assert!(run.busy_cycles == total, "busy != total");
            crate::prop_assert!(run.utilization <= 1.0 + 1e-9, "util > 1");
            Ok(())
        });
    }

    #[test]
    fn single_pe_serializes() {
        let run = schedule(&[5, 7, 3], 1);
        assert_eq!(run.makespan, 15);
        assert_eq!(run.utilization, 1.0);
    }

    #[test]
    fn more_pes_never_slower() {
        let costs: Vec<u64> = (0..100).map(|i| 10 + (i * 7) % 90).collect();
        let mut prev = u64::MAX;
        for pes in [1, 2, 4, 8, 16] {
            let m = schedule(&costs, pes).makespan;
            assert!(m <= prev, "{pes} PEs slower");
            prev = m;
        }
    }

    #[test]
    fn empty_kernel_is_free() {
        assert_eq!(schedule(&[], 8).makespan, 0);
        assert_eq!(schedule_uniform(0, 100, 8).makespan, 0);
    }
}
