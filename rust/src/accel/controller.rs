//! The ASR controller (§3.3) — simulates one decoding step: the acoustic
//! scoring phase (kernel sequence with setup threads and DMA prefetch
//! overlapped per Fig. 7) followed by the hypothesis expansion phase
//! (one execution per acoustic vector, Fig. 6).
//!
//! Two fidelity modes:
//! * **Ideal** — the paper's §5.4 assumptions: no network contention,
//!   model data pre-fetched; kernels run back-to-back on the pool.
//! * **Detailed** — adds the DMA engine (serial transfers at external
//!   bandwidth) and setup-thread serialization, exposing stalls the
//!   Fig. 7 pipelining is designed to hide.

use crate::config::{AccelConfig, Layer, ModelConfig, PipelineDesc};

use super::kernels::{build_step_kernels, HypWorkload, KernelClass, KernelExec, SETUP_INSTRS};
use super::memory::{hyp_expansion_miss_rate, GraphWorkload};
use super::pool::{schedule_uniform, PoolRun};

/// External-memory miss penalty in core cycles (≈100 ns DRAM at 500 MHz).
const MISS_PENALTY_CYCLES: f64 = 50.0;

/// Simulation fidelity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimMode {
    /// §5.4 assumptions (no contention, model data prefetched).
    Ideal,
    /// Model DMA transfers and setup-thread serialization explicitly.
    Detailed,
}

/// Timing of one kernel inside a decoding step.
#[derive(Debug, Clone)]
pub struct KernelTiming {
    pub name: String,
    pub class: KernelClass,
    pub threads: u64,
    pub instrs: u64,
    /// Cycle at which the kernel's threads start dispatching.
    pub start: u64,
    pub end: u64,
    /// Cycles the kernel waited on its DMA prefetch (Detailed mode).
    pub dma_stall: u64,
    /// Pool utilization while this kernel ran.
    pub utilization: f64,
}

impl KernelTiming {
    pub fn cycles(&self) -> u64 {
        self.end - self.start
    }
}

/// Result of simulating one decoding step.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub kernels: Vec<KernelTiming>,
    pub total_cycles: u64,
    pub acoustic_cycles: u64,
    pub hyp_cycles: u64,
    pub dma_bytes: u64,
    pub dma_stall_cycles: u64,
    /// Σ instructions (= Σ PE-busy cycles at 1 IPC).
    pub total_instrs: u64,
    /// Inter-step state resident in shared memory (bytes).
    pub state_bytes: u64,
}

impl StepReport {
    pub fn seconds(&self, accel: &AccelConfig) -> f64 {
        self.total_cycles as f64 * accel.cycle_s()
    }

    /// Real-time factor: audio seconds per compute second (>1 ⇒ faster
    /// than real time; the paper reports 2×).
    pub fn rtf(&self, model: &ModelConfig, accel: &AccelConfig) -> f64 {
        model.step_seconds() / self.seconds(accel)
    }

    /// Aggregate real-time factor of a `batch`-stream fused step (from
    /// [`simulate_step_batched`]): the step covers `batch × step_seconds`
    /// of audio.
    pub fn rtf_batched(&self, model: &ModelConfig, accel: &AccelConfig, batch: usize) -> f64 {
        batch as f64 * model.step_seconds() / self.seconds(accel)
    }

    /// Mean pool utilization over the step.
    pub fn utilization(&self, accel: &AccelConfig) -> f64 {
        self.total_instrs as f64 / (self.total_cycles * accel.num_pes as u64) as f64
    }

    /// Aggregate cycles per kernel class (Fig. 11 grouping).
    pub fn by_class(&self, class: KernelClass) -> u64 {
        self.kernels
            .iter()
            .filter(|k| k.class == class)
            .map(|k| k.cycles())
            .sum()
    }
}

/// Inter-step state the implementation keeps in shared memory between
/// decoding steps (§5.2 reports ≈275 KB for the case-study system):
/// per-conv input histories (the shifting convolution windows) plus the
/// in-flight activation buffers, at int8 activation width for the
/// quantized paper model.
pub fn inter_step_state_bytes(model: &ModelConfig) -> u64 {
    let elem = model.precision.activation_bytes() as u64;
    let mut bytes = 0u64;
    for layer in model.layers() {
        if let Layer::Conv { in_ch, kw, w, .. } = &layer {
            bytes += ((kw - 1) * in_ch * w) as u64 * elem;
        }
    }
    bytes
}

/// Simulate one decoding step (single stream).
pub fn simulate_step(
    model: &ModelConfig,
    accel: &AccelConfig,
    hyp: &HypWorkload,
    mode: SimMode,
) -> StepReport {
    simulate_step_batched(model, accel, hyp, mode, 1)
}

/// Simulate one decoding step fused over `batch` concurrent streams
/// (the coordinator's lane-batched serving mapped onto the device):
/// every kernel launches ×batch threads over the same staged model data,
/// so PE-pool utilization and RTF reflect multi-stream load. Compare
/// [`StepReport::rtf_batched`] against `rtf` at batch 1 to read off the
/// consolidation win.
pub fn simulate_step_batched(
    model: &ModelConfig,
    accel: &AccelConfig,
    hyp: &HypWorkload,
    mode: SimMode,
    batch: usize,
) -> StepReport {
    simulate_pipeline(&PipelineDesc::for_model(model), accel, hyp, mode, batch)
}

/// Result of simulating one fused decoding step sharded across several
/// workers (see [`simulate_step_sharded`]): each worker device runs its
/// lane slice in parallel, so the step's wall time is the widest
/// shard's, while model DMA is replicated per device (each worker
/// streams its own copy of the shared weights — the consolidation cost
/// the single-device fused step avoids).
#[derive(Debug, Clone)]
pub struct ShardedReport {
    /// Per-worker step reports, widest shard first (shards with zero
    /// lanes are omitted — they run nothing).
    pub per_shard: Vec<StepReport>,
    /// Lanes per worker, aligned with `per_shard`.
    pub lanes: Vec<usize>,
}

impl ShardedReport {
    /// Total lanes across every worker.
    pub fn total_lanes(&self) -> usize {
        self.lanes.iter().sum()
    }

    /// Wall-clock of the sharded step: workers run in parallel, so the
    /// critical path is the widest shard's device step.
    pub fn seconds(&self, accel: &AccelConfig) -> f64 {
        self.per_shard
            .iter()
            .map(|r| r.seconds(accel))
            .fold(0.0, f64::max)
    }

    /// Σ instructions across all workers (identical to the one-device
    /// fused step at the same total batch — sharding moves work, it
    /// never changes it).
    pub fn total_instrs(&self) -> u64 {
        self.per_shard.iter().map(|r| r.total_instrs).sum()
    }

    /// Σ model-DMA bytes across all workers: each device streams its
    /// own copy of the weights, so this grows with the shard count.
    pub fn total_dma_bytes(&self) -> u64 {
        self.per_shard.iter().map(|r| r.dma_bytes).sum()
    }

    /// Aggregate real-time factor: the step covers
    /// `total_lanes × step_seconds` of audio in the critical path's wall
    /// time.
    pub fn rtf_aggregate(&self, model: &ModelConfig, accel: &AccelConfig) -> f64 {
        self.total_lanes() as f64 * model.step_seconds() / self.seconds(accel)
    }
}

/// Simulate one fused decoding step of `batch` concurrent streams
/// sharded across `shards` worker devices — the device-side mirror of
/// the coordinator's [`ShardPool`](crate::coordinator::ShardPool).
/// Lanes split as evenly as the router's least-loaded assignment
/// (`⌈batch/shards⌉` on the first `batch % shards` workers), every
/// worker's kernel program is derived from the same [`PipelineDesc`] —
/// sim and engine keep deriving one program — and each worker's device
/// step is simulated independently.
pub fn simulate_step_sharded(
    model: &ModelConfig,
    accel: &AccelConfig,
    hyp: &HypWorkload,
    mode: SimMode,
    batch: usize,
    shards: usize,
) -> ShardedReport {
    assert!(batch >= 1, "need at least one lane");
    assert!(shards >= 1, "need at least one shard");
    let lanes_per_shard: Vec<usize> = (0..shards)
        .map(|i| batch / shards + usize::from(i < batch % shards))
        .collect();
    simulate_step_elastic(model, accel, hyp, mode, &lanes_per_shard)
}

/// Simulate one fused decoding step over an *explicit* lane-per-worker
/// topology — the device-side mirror of the elastic
/// [`ShardPool`](crate::coordinator::ShardPool), whose worker count and
/// per-shard session load change at runtime (`pool add` / `pool drain`).
/// Unlike [`simulate_step_sharded`]'s even split, `lanes_per_shard`
/// carries whatever shape the pool is in mid-scale: a draining shard
/// tapers through ever-smaller entries, a freshly added worker starts
/// small. Zero-lane entries are skipped (an empty worker runs nothing);
/// at least one entry must be non-zero.
pub fn simulate_step_elastic(
    model: &ModelConfig,
    accel: &AccelConfig,
    hyp: &HypWorkload,
    mode: SimMode,
    lanes_per_shard: &[usize],
) -> ShardedReport {
    assert!(
        lanes_per_shard.iter().any(|&l| l > 0),
        "need at least one lane on some shard"
    );
    let pipe = PipelineDesc::for_model(model);
    let mut per_shard = Vec::with_capacity(lanes_per_shard.len());
    let mut lanes = Vec::with_capacity(lanes_per_shard.len());
    for &lanes_i in lanes_per_shard {
        if lanes_i == 0 {
            continue;
        }
        per_shard.push(simulate_pipeline(&pipe, accel, hyp, mode, lanes_i));
        lanes.push(lanes_i);
    }
    ShardedReport { per_shard, lanes }
}

/// Simulate one decoding step of an explicit stage description — the
/// entry point the engine-visible pipeline flows through: the kernel
/// program is derived from the same [`PipelineDesc`] the functional
/// engine executes, so simulator timing always describes the program
/// actually being served.
pub fn simulate_pipeline(
    pipe: &PipelineDesc,
    accel: &AccelConfig,
    hyp: &HypWorkload,
    mode: SimMode,
    batch: usize,
) -> StepReport {
    let kernels = build_step_kernels(pipe, accel, hyp, batch);
    simulate_kernels(&kernels, &pipe.model, accel, mode)
}

/// Simulate a given kernel sequence (exposed for ablations).
pub fn simulate_kernels(
    kernels: &[KernelExec],
    model: &ModelConfig,
    accel: &AccelConfig,
    mode: SimMode,
) -> StepReport {
    let freq = accel.frequency_hz as f64;
    let dma_cycles = |bytes: u64| -> u64 {
        if bytes == 0 {
            0
        } else {
            (bytes as f64 / accel.ext_mem_bw_bytes_per_s as f64 * freq).ceil() as u64
        }
    };
    let mut timings: Vec<KernelTiming> = Vec::with_capacity(kernels.len());
    let mut now = 0u64; // time the pool becomes free
    let mut dma_free = 0u64; // time the DMA engine becomes free
    let mut dma_ready: Vec<u64> = vec![0; kernels.len()];
    if mode == SimMode::Detailed {
        // Kernel 0's model data is pre-fetched during the previous step's
        // idle time when possible (Fig. 7 step ❹/❶') — it is ready at 0,
        // matching the steady-state behaviour the paper describes. Each
        // subsequent kernel's DMA is configured by its setup thread, which
        // runs alongside the *previous* kernel — i.e. the transfer may
        // begin when the previous kernel starts.
        let mut prev_start = 0u64;
        let mut sim_now = 0u64;
        for (i, k) in kernels.iter().enumerate() {
            let issue_at = if i == 0 { 0 } else { prev_start };
            let start = issue_at.max(dma_free);
            let ready = start + dma_cycles(k.model_bytes);
            dma_free = ready;
            dma_ready[i] = ready;
            // Track provisional kernel starts to anchor the next issue
            // (refined below in the main loop; good enough for ordering).
            prev_start = sim_now.max(ready);
            sim_now = prev_start
                + schedule_uniform(k.threads, k.instr_per_thread, accel.num_pes as u64).makespan;
        }
    }
    // §3.6: during hypothesis expansion the model memory acts as an LRU
    // cache over the (off-chip) lexicon/LM graphs; in Detailed mode each
    // graph access adds an expected miss penalty to the thread cost.
    let hyp_extra_cycles: u64 = if mode == SimMode::Detailed {
        let graphs = GraphWorkload::paper();
        let n_threads: u64 = kernels
            .iter()
            .filter(|k| k.class == KernelClass::HypExpansion)
            .map(|k| k.threads)
            .sum();
        if n_threads == 0 {
            0
        } else {
            let miss = hyp_expansion_miss_rate(accel.model_mem_bytes, &graphs, n_threads, 11);
            let accesses = graphs.lex_accesses_per_hyp + graphs.lm_accesses_per_hyp;
            (accesses * miss * MISS_PENALTY_CYCLES) as u64
        }
    } else {
        0
    };
    let mut total_instrs = 0u64;
    let mut dma_bytes = 0u64;
    let mut dma_stall_cycles = 0u64;
    for (i, k) in kernels.iter().enumerate() {
        // Stall cycles (cache misses into the graph structures) extend
        // thread latency but are NOT instructions — account separately.
        let thread_cycles = if k.class == KernelClass::HypExpansion {
            k.instr_per_thread + hyp_extra_cycles
        } else {
            k.instr_per_thread
        };
        let run: PoolRun = schedule_uniform(k.threads, thread_cycles, accel.num_pes as u64);
        let instrs = k.threads * k.instr_per_thread;
        let mut start = now;
        let mut dma_stall = 0;
        match mode {
            SimMode::Ideal => {}
            SimMode::Detailed => {
                // Setup thread: hidden behind the previous kernel unless
                // this is the first kernel or the previous was shorter.
                if i == 0 {
                    start += SETUP_INSTRS;
                } else {
                    let prev = &timings[i - 1];
                    let setup_done = prev.start + SETUP_INSTRS;
                    start = start.max(setup_done);
                }
                if dma_ready[i] > start {
                    dma_stall = dma_ready[i] - start;
                    start = dma_ready[i];
                }
            }
        }
        let end = start + run.makespan;
        total_instrs += instrs;
        dma_bytes += k.model_bytes;
        dma_stall_cycles += dma_stall;
        timings.push(KernelTiming {
            name: k.name.clone(),
            class: k.class,
            threads: k.threads,
            instrs,
            start,
            end,
            dma_stall,
            utilization: run.utilization,
        });
        now = end;
    }
    let acoustic_cycles = timings
        .iter()
        .filter(|t| t.class != KernelClass::HypExpansion)
        .map(|t| t.cycles() + t.dma_stall)
        .sum();
    let hyp_cycles = timings
        .iter()
        .filter(|t| t.class == KernelClass::HypExpansion)
        .map(|t| t.cycles() + t.dma_stall)
        .sum();
    StepReport {
        total_cycles: now,
        acoustic_cycles,
        hyp_cycles,
        dma_bytes,
        dma_stall_cycles,
        total_instrs,
        state_bytes: inter_step_state_bytes(model),
        kernels: timings,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::kernels::HypWorkload;

    fn paper() -> (ModelConfig, AccelConfig) {
        (ModelConfig::paper_tds(), AccelConfig::paper())
    }

    #[test]
    fn headline_two_x_realtime() {
        // §5.4: "ASRPU takes about 40ms to perform a decoding step" of
        // 80 ms audio — 2× real time. Accept 1.5×–3× for the shape.
        let (m, a) = paper();
        let r = simulate_step(&m, &a, &HypWorkload::default(), SimMode::Ideal);
        let ms = r.seconds(&a) * 1e3;
        assert!(
            (27.0..55.0).contains(&ms),
            "decoding step took {ms:.1} ms, expected ≈40 ms"
        );
        let rtf = r.rtf(&m, &a);
        assert!((1.5..3.0).contains(&rtf), "rtf {rtf:.2}, expected ≈2×");
    }

    #[test]
    fn fc_dominates_conv_like_fig11() {
        // Fig. 11: FC kernels dominate the step time (they are plotted on
        // their own axis); convolutions are comparatively small.
        let (m, a) = paper();
        let r = simulate_step(&m, &a, &HypWorkload::default(), SimMode::Ideal);
        let fc = r.by_class(KernelClass::Fc);
        let conv = r.by_class(KernelClass::Conv);
        assert!(fc > 2 * conv, "fc {fc} !> 2×conv {conv}");
    }

    #[test]
    fn state_fits_shared_memory_like_section_5_2() {
        // §5.2: "stores about 275KB of intermediate data in between
        // decoding steps … We include 512KB of shared memory".
        let (m, a) = paper();
        let bytes = inter_step_state_bytes(&m);
        assert!(
            (200_000..450_000).contains(&bytes),
            "inter-step state = {bytes} B, paper reports ≈275 KB"
        );
        assert!(bytes < a.shared_mem_bytes as u64);
    }

    #[test]
    fn detailed_mode_mostly_hides_dma() {
        // The Fig. 7 pipelining claim: prefetching model data behind the
        // previous kernel hides (almost) all DMA latency.
        let (m, a) = paper();
        let ideal = simulate_step(&m, &a, &HypWorkload::default(), SimMode::Ideal);
        let detailed = simulate_step(&m, &a, &HypWorkload::default(), SimMode::Detailed);
        assert!(detailed.total_cycles >= ideal.total_cycles);
        let overhead =
            detailed.total_cycles as f64 / ideal.total_cycles as f64 - 1.0;
        assert!(overhead < 0.30, "DMA/setup overhead {overhead:.2} too large");
    }

    #[test]
    fn starved_bandwidth_stalls() {
        // With 100× less external bandwidth, DMA stalls must appear.
        let (m, mut a) = paper();
        a.ext_mem_bw_bytes_per_s /= 100;
        let r = simulate_step(&m, &a, &HypWorkload::default(), SimMode::Detailed);
        assert!(r.dma_stall_cycles > 0, "expected stalls at 80 MB/s");
    }

    #[test]
    fn more_pes_scale_throughput() {
        let (m, mut a) = paper();
        let base = simulate_step(&m, &a, &HypWorkload::default(), SimMode::Ideal).total_cycles;
        a.num_pes = 16;
        let doubled = simulate_step(&m, &a, &HypWorkload::default(), SimMode::Ideal).total_cycles;
        let speedup = base as f64 / doubled as f64;
        assert!(speedup > 1.7, "16 PEs speedup only {speedup:.2}");
    }

    #[test]
    fn kernel_timeline_is_contiguous_and_ordered() {
        let (m, a) = paper();
        let r = simulate_step(&m, &a, &HypWorkload::default(), SimMode::Ideal);
        let mut prev_end = 0;
        for k in &r.kernels {
            assert!(k.start >= prev_end);
            assert!(k.end >= k.start);
            prev_end = k.end;
        }
        assert_eq!(prev_end, r.total_cycles);
        // Phase split covers the whole step (ideal mode: no gaps).
        assert_eq!(r.acoustic_cycles + r.hyp_cycles, r.total_cycles);
    }

    #[test]
    fn utilization_is_high_on_wide_kernels() {
        let (m, a) = paper();
        let r = simulate_step(&m, &a, &HypWorkload::default(), SimMode::Ideal);
        assert!(r.utilization(&a) > 0.9, "util {}", r.utilization(&a));
    }

    #[test]
    fn batched_streams_amortize_the_step() {
        // Fusing B streams must cost less than B single-stream steps
        // (shared model staging + better pool packing on narrow kernels),
        // while executing exactly B× the instructions.
        let (m, a) = paper();
        let hyp = HypWorkload::default();
        let one = simulate_step_batched(&m, &a, &hyp, SimMode::Ideal, 1);
        let four = simulate_step_batched(&m, &a, &hyp, SimMode::Ideal, 4);
        assert_eq!(four.total_instrs, 4 * one.total_instrs);
        assert!(
            four.total_cycles < 4 * one.total_cycles,
            "batched step {} !< 4×{}",
            four.total_cycles,
            one.total_cycles
        );
        // Same weights stream once regardless of batch.
        assert_eq!(four.dma_bytes, one.dma_bytes);
        // Aggregate RTF grows with consolidation.
        assert!(four.rtf_batched(&m, &a, 4) > one.rtf(&m, &a));
        // Utilization can only improve when kernels get wider.
        assert!(four.utilization(&a) >= one.utilization(&a) - 1e-9);
    }

    #[test]
    fn sharding_splits_work_without_changing_it() {
        // 8 lanes on one device vs sharded across 2 and 4 workers: the
        // instruction count is conserved (sharding moves work), the
        // critical path shrinks (workers run in parallel), and weight
        // DMA is replicated per device.
        let (m, a) = paper();
        let hyp = HypWorkload::default();
        let one = simulate_step_batched(&m, &a, &hyp, SimMode::Ideal, 8);
        for shards in [2usize, 4] {
            let s = simulate_step_sharded(&m, &a, &hyp, SimMode::Ideal, 8, shards);
            assert_eq!(s.per_shard.len(), shards);
            assert_eq!(s.total_lanes(), 8);
            assert_eq!(s.total_instrs(), one.total_instrs, "shards={shards}");
            assert_eq!(s.total_dma_bytes(), shards as u64 * one.dma_bytes);
            assert!(
                s.seconds(&a) < one.seconds(&a),
                "shards={shards}: {} !< {}",
                s.seconds(&a),
                one.seconds(&a)
            );
            assert!(s.rtf_aggregate(&m, &a) > one.rtf_batched(&m, &a, 8));
        }
    }

    #[test]
    fn elastic_topology_conserves_work_at_any_shape() {
        // Mid-scale shapes (a draining shard tapering, a fresh worker
        // ramping) conserve instructions vs the fused step at the same
        // total lanes, replicate weight DMA once per *occupied* worker,
        // and reduce to the even split when the shape is even.
        let (m, a) = paper();
        let hyp = HypWorkload::default();
        let one = simulate_step_batched(&m, &a, &hyp, SimMode::Ideal, 8);
        for shape in [vec![5, 2, 1], vec![1, 0, 7], vec![8], vec![2, 2, 2, 2]] {
            let s = simulate_step_elastic(&m, &a, &hyp, SimMode::Ideal, &shape);
            let occupied = shape.iter().filter(|&&l| l > 0).count();
            assert_eq!(s.total_lanes(), 8, "{shape:?}");
            assert_eq!(s.per_shard.len(), occupied, "{shape:?}");
            assert_eq!(s.total_instrs(), one.total_instrs, "{shape:?}");
            assert_eq!(s.total_dma_bytes(), occupied as u64 * one.dma_bytes, "{shape:?}");
        }
        let even = simulate_step_sharded(&m, &a, &hyp, SimMode::Ideal, 8, 4);
        let explicit = simulate_step_elastic(&m, &a, &hyp, SimMode::Ideal, &[2, 2, 2, 2]);
        assert_eq!(even.lanes, explicit.lanes);
        assert_eq!(even.total_instrs(), explicit.total_instrs());
        assert_eq!(
            even.seconds(&a).to_bits(),
            explicit.seconds(&a).to_bits(),
            "even split must be the elastic path bit for bit"
        );
    }

    #[test]
    fn sharding_splits_lanes_like_the_router() {
        // Uneven split: ⌈/⌉ on the first batch % shards workers, and
        // empty shards are omitted entirely.
        let (m, a) = paper();
        let hyp = HypWorkload::default();
        let s = simulate_step_sharded(&m, &a, &hyp, SimMode::Ideal, 5, 2);
        assert_eq!(s.lanes, vec![3, 2]);
        // Critical path is the widest shard's own step.
        let widest = simulate_step_batched(&m, &a, &hyp, SimMode::Ideal, 3);
        assert_eq!(s.per_shard[0].total_cycles, widest.total_cycles);
        let sparse = simulate_step_sharded(&m, &a, &hyp, SimMode::Ideal, 2, 4);
        assert_eq!(sparse.lanes, vec![1, 1]);
        assert_eq!(sparse.per_shard.len(), 2);
        // One shard degenerates to the plain fused step.
        let solo = simulate_step_sharded(&m, &a, &hyp, SimMode::Ideal, 4, 1);
        let fused = simulate_step_batched(&m, &a, &hyp, SimMode::Ideal, 4);
        assert_eq!(solo.per_shard[0].total_cycles, fused.total_cycles);
        assert_eq!(solo.total_instrs(), fused.total_instrs);
    }
}
