//! The command decoder (§3.7, Table 1): the interface between ASRPU and
//! the host SoC. Commands are encoded as MMIO-style words (opcode +
//! operands) and drive a stateful device model: configuration must
//! precede decoding, `DecodingStep` runs the simulator, `CleanDecoding`
//! resets utterance state.

use anyhow::{bail, ensure, Result};

use crate::config::{AccelConfig, ModelConfig};

use super::controller::{simulate_step, SimMode, StepReport};
use super::kernels::HypWorkload;

/// Table 1 commands.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Command {
    /// Configure kernel `n` of the acoustic scoring phase: external
    /// memory addresses of its setup program and kernel program.
    ConfigureAcousticScoring { n: u16, setup_addr: u32, kernel_addr: u32 },
    /// Configure the hypothesis-expansion kernel.
    ConfigureHypExpansion { kernel_addr: u32 },
    /// Configure the hypothesis unit's score beam (fixed-point ×256).
    ConfigureBeamWidth { beam_q8: u32 },
    /// Reset hypothesis memory and internal state for a new utterance.
    CleanDecoding,
    /// Decode the signal at `signal_addr`, appending to the current
    /// utterance's hypotheses.
    DecodingStep { signal_addr: u32 },
}

const OP_CFG_AS: u64 = 0x1;
const OP_CFG_HYP: u64 = 0x2;
const OP_CFG_BEAM: u64 = 0x3;
const OP_CLEAN: u64 = 0x4;
const OP_STEP: u64 = 0x5;

impl Command {
    /// Encode as a (cmd, arg) register-write pair: opcode in the top
    /// byte of `cmd`, small operands packed below; `arg` carries the
    /// address operand.
    pub fn encode(&self) -> (u64, u64) {
        match *self {
            Command::ConfigureAcousticScoring { n, setup_addr, kernel_addr } => (
                (OP_CFG_AS << 56) | ((n as u64) << 32) | setup_addr as u64,
                kernel_addr as u64,
            ),
            Command::ConfigureHypExpansion { kernel_addr } => {
                ((OP_CFG_HYP << 56), kernel_addr as u64)
            }
            Command::ConfigureBeamWidth { beam_q8 } => ((OP_CFG_BEAM << 56), beam_q8 as u64),
            Command::CleanDecoding => ((OP_CLEAN << 56), 0),
            Command::DecodingStep { signal_addr } => ((OP_STEP << 56), signal_addr as u64),
        }
    }

    pub fn decode(cmd: u64, arg: u64) -> Result<Command> {
        Ok(match cmd >> 56 {
            OP_CFG_AS => Command::ConfigureAcousticScoring {
                n: ((cmd >> 32) & 0xFFFF) as u16,
                setup_addr: (cmd & 0xFFFF_FFFF) as u32,
                kernel_addr: arg as u32,
            },
            OP_CFG_HYP => Command::ConfigureHypExpansion { kernel_addr: arg as u32 },
            OP_CFG_BEAM => Command::ConfigureBeamWidth { beam_q8: arg as u32 },
            OP_CLEAN => Command::CleanDecoding,
            OP_STEP => Command::DecodingStep { signal_addr: arg as u32 },
            op => bail!("unknown ASRPU opcode {op:#x}"),
        })
    }
}

/// Per-utterance accumulated timing.
#[derive(Debug, Clone, Default)]
pub struct UtteranceTiming {
    pub steps: usize,
    pub total_cycles: u64,
    pub audio_seconds: f64,
}

/// The device model: command decoder + ASR controller + simulator.
#[derive(Debug)]
pub struct AsrpuDevice {
    pub accel: AccelConfig,
    pub model: ModelConfig,
    pub mode: SimMode,
    pub hyp: HypWorkload,
    /// Configured acoustic-scoring kernels (n → (setup, kernel) addrs).
    as_kernels: Vec<Option<(u32, u32)>>,
    hyp_kernel: Option<u32>,
    beam_q8: Option<u32>,
    pub utterance: UtteranceTiming,
    pub last_step: Option<StepReport>,
}

impl AsrpuDevice {
    pub fn new(accel: AccelConfig, model: ModelConfig, mode: SimMode) -> Result<Self> {
        accel.validate()?;
        let n_as = model.layers().len() + 1; // + feature extraction
        Ok(AsrpuDevice {
            accel,
            model,
            mode,
            hyp: HypWorkload::default(),
            as_kernels: vec![None; n_as],
            hyp_kernel: None,
            beam_q8: None,
            utterance: UtteranceTiming::default(),
            last_step: None,
        })
    }

    /// Expected number of acoustic-scoring kernel slots.
    pub fn num_as_kernels(&self) -> usize {
        self.as_kernels.len()
    }

    fn configured(&self) -> bool {
        self.as_kernels.iter().all(Option::is_some)
            && self.hyp_kernel.is_some()
            && self.beam_q8.is_some()
    }

    /// Issue the standard configuration sequence (all kernels + beam).
    pub fn configure_all(&mut self, beam: f32) -> Result<()> {
        for n in 0..self.num_as_kernels() {
            self.issue(Command::ConfigureAcousticScoring {
                n: n as u16,
                setup_addr: 0x1000_0000 + (n as u32) * 0x800,
                kernel_addr: 0x2000_0000 + (n as u32) * 0x800,
            })?;
        }
        self.issue(Command::ConfigureHypExpansion { kernel_addr: 0x3000_0000 })?;
        self.issue(Command::ConfigureBeamWidth { beam_q8: (beam * 256.0) as u32 })?;
        Ok(())
    }

    /// Execute one command (the §3.7 semantics).
    pub fn issue(&mut self, cmd: Command) -> Result<()> {
        match cmd {
            Command::ConfigureAcousticScoring { n, setup_addr, kernel_addr } => {
                ensure!(
                    (n as usize) < self.as_kernels.len(),
                    "acoustic-scoring kernel index {n} out of range (model has {})",
                    self.as_kernels.len()
                );
                self.as_kernels[n as usize] = Some((setup_addr, kernel_addr));
            }
            Command::ConfigureHypExpansion { kernel_addr } => {
                self.hyp_kernel = Some(kernel_addr);
            }
            Command::ConfigureBeamWidth { beam_q8 } => {
                ensure!(beam_q8 > 0, "beam width must be positive");
                self.beam_q8 = Some(beam_q8);
            }
            Command::CleanDecoding => {
                self.utterance = UtteranceTiming::default();
                self.last_step = None;
            }
            Command::DecodingStep { signal_addr: _ } => {
                ensure!(
                    self.configured(),
                    "DecodingStep before configuration is complete (Table 1: \
                     configuration commands must be used before any decoding begins)"
                );
                let report = simulate_step(&self.model, &self.accel, &self.hyp, self.mode);
                self.utterance.steps += 1;
                self.utterance.total_cycles += report.total_cycles;
                self.utterance.audio_seconds += self.model.step_seconds();
                self.last_step = Some(report);
            }
        }
        Ok(())
    }

    /// Utterance-level real-time factor so far.
    pub fn utterance_rtf(&self) -> f64 {
        if self.utterance.total_cycles == 0 {
            return f64::INFINITY;
        }
        self.utterance.audio_seconds
            / (self.utterance.total_cycles as f64 * self.accel.cycle_s())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn command_encode_decode_roundtrip() {
        let cmds = [
            Command::ConfigureAcousticScoring { n: 79, setup_addr: 0xDEAD, kernel_addr: 0xBEEF },
            Command::ConfigureHypExpansion { kernel_addr: 0x1234 },
            Command::ConfigureBeamWidth { beam_q8: 3584 },
            Command::CleanDecoding,
            Command::DecodingStep { signal_addr: 0xCAFE },
        ];
        for c in cmds {
            let (w, a) = c.encode();
            assert_eq!(Command::decode(w, a).unwrap(), c);
        }
        assert!(Command::decode(0xFF << 56, 0).is_err());
    }

    fn device() -> AsrpuDevice {
        AsrpuDevice::new(
            AccelConfig::paper(),
            ModelConfig::paper_tds(),
            SimMode::Ideal,
        )
        .unwrap()
    }

    #[test]
    fn decoding_before_configuration_is_rejected() {
        let mut d = device();
        assert!(d.issue(Command::DecodingStep { signal_addr: 0 }).is_err());
        d.configure_all(14.0).unwrap();
        assert!(d.issue(Command::DecodingStep { signal_addr: 0 }).is_ok());
    }

    #[test]
    fn paper_model_has_80_as_kernel_slots() {
        // 79 layers + feature extraction (§4.2).
        assert_eq!(device().num_as_kernels(), 80);
    }

    #[test]
    fn out_of_range_kernel_index_rejected() {
        let mut d = device();
        assert!(d
            .issue(Command::ConfigureAcousticScoring { n: 200, setup_addr: 0, kernel_addr: 0 })
            .is_err());
    }

    #[test]
    fn utterance_timing_accumulates_and_cleans() {
        let mut d = device();
        d.configure_all(14.0).unwrap();
        d.issue(Command::DecodingStep { signal_addr: 0 }).unwrap();
        d.issue(Command::DecodingStep { signal_addr: 1280 }).unwrap();
        assert_eq!(d.utterance.steps, 2);
        assert!((d.utterance.audio_seconds - 0.16).abs() < 1e-9);
        let rtf = d.utterance_rtf();
        assert!((1.5..3.0).contains(&rtf), "rtf {rtf}");
        d.issue(Command::CleanDecoding).unwrap();
        assert_eq!(d.utterance.steps, 0);
        // Configuration survives CleanDecoding (only hypothesis state is
        // cleared, §3.7).
        assert!(d.issue(Command::DecodingStep { signal_addr: 0 }).is_ok());
    }
}
