//! Sort + prune of candidate hypotheses — the software mirror of the
//! hypothesis unit (§3.5): merge duplicates (keep best), apply the score
//! beam, cap at hypothesis-memory capacity.

use super::Hyp;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher for the (already well-mixed) 64-bit state keys —
/// SipHash showed up in the §Perf profile at large beams; state keys are
/// not attacker-controlled, so a fast non-cryptographic hash is fine.
#[derive(Default)]
pub struct KeyHasher(u64);

impl Hasher for KeyHasher {
    fn finish(&self) -> u64 {
        self.0
    }

    fn write(&mut self, _bytes: &[u8]) {
        unreachable!("KeyHasher is only used with u64 keys");
    }

    fn write_u64(&mut self, k: u64) {
        // Fibonacci multiply + xor-shift: enough mixing for trie/LM ids.
        let h = k.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.0 = h ^ (h >> 29);
    }
}

/// Hash map keyed by 64-bit hypothesis state keys (fast non-crypto hash).
pub type KeyMap<V> = HashMap<u64, V, BuildHasherDefault<KeyHasher>>;

/// Pruning parameters (hardware: `ConfigureBeamWidth` + memory size).
#[derive(Debug, Clone, Copy)]
pub struct Pruner {
    pub beam: f32,
    pub max_hyps: usize,
}

/// Statistics accumulated across `prune` calls; consumed by the
/// simulator's hypothesis-unit occupancy model and the ABL2 ablation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PruneStats {
    /// Candidate hypotheses generated (pre-merge).
    pub generated: u64,
    /// Removed as duplicates of a better-scoring equal state.
    pub merged: u64,
    /// Removed by the score beam.
    pub beam_pruned: u64,
    /// Removed by the capacity cap.
    pub capacity_pruned: u64,
    /// Max simultaneous live hypotheses seen.
    pub peak_live: u64,
    /// Prune invocations (= acoustic frames).
    pub rounds: u64,
}

impl PruneStats {
    /// Survivors across all rounds.
    pub fn survived(&self) -> u64 {
        self.generated - self.merged - self.beam_pruned - self.capacity_pruned
    }

    /// Mean live hypotheses per round.
    pub fn mean_live(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.survived() as f64 / self.rounds as f64
        }
    }
}

impl Pruner {
    /// Merge → beam → capacity. Returns the surviving set sorted by
    /// descending score (the hypothesis unit keeps them sorted).
    /// Convenience wrapper over [`Self::prune_into`] that allocates its
    /// working set per call; hot loops should hold a
    /// [`super::DecodeScratch`] and go through `prune_into`.
    pub fn prune(&self, mut cands: Vec<Hyp>, stats: &mut PruneStats) -> Vec<Hyp> {
        let mut map = KeyMap::default();
        let mut out = Vec::new();
        self.prune_into(&mut cands, &mut map, &mut out, stats);
        out
    }

    /// Allocation-free merge → beam → capacity: candidates are drained
    /// from `cands`, merged through the reusable `map` (cleared, capacity
    /// kept) and the survivors written into `out`, sorted by descending
    /// score with `state_key` as the tie-break — a total order, so the
    /// result is independent of hash-map iteration order (and therefore
    /// of the map's inherited capacity).
    pub fn prune_into(
        &self,
        cands: &mut Vec<Hyp>,
        map: &mut KeyMap<Hyp>,
        out: &mut Vec<Hyp>,
        stats: &mut PruneStats,
    ) {
        stats.rounds += 1;
        stats.generated += cands.len() as u64;
        out.clear();
        if cands.is_empty() {
            return;
        }
        // Merge duplicates by state key, keeping the max score.
        map.clear();
        map.reserve(cands.len());
        let mut merged = 0u64;
        for h in cands.drain(..) {
            match map.entry(h.state_key()) {
                std::collections::hash_map::Entry::Occupied(mut e) => {
                    merged += 1;
                    if h.score > e.get().score {
                        e.insert(h);
                    }
                }
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(h);
                }
            }
        }
        stats.merged += merged;
        out.extend(map.drain().map(|(_, h)| h));
        // Score beam relative to the best candidate.
        let top = out.iter().map(|h| h.score).fold(f32::MIN, f32::max);
        let floor = top - self.beam;
        let before = out.len();
        out.retain(|h| h.score >= floor);
        stats.beam_pruned += (before - out.len()) as u64;
        // Capacity: keep the max_hyps best (deterministic total order).
        out.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap()
                .then_with(|| a.state_key().cmp(&b.state_key()))
        });
        if out.len() > self.max_hyps {
            stats.capacity_pruned += (out.len() - self.max_hyps) as u64;
            out.truncate(self.max_hyps);
        }
        stats.peak_live = stats.peak_live.max(out.len() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::LmState;
    use crate::util::prop;

    fn hyp(score: f32, node: u32, lm: u32, last: u32) -> Hyp {
        Hyp {
            score,
            node,
            lm: LmState(lm),
            last_token: last,
            back: u32::MAX,
        }
    }

    #[test]
    fn merges_equal_states_keeping_best() {
        let p = Pruner { beam: 100.0, max_hyps: 10 };
        let mut stats = PruneStats::default();
        let out = p.prune(
            vec![hyp(-1.0, 5, 2, 1), hyp(-3.0, 5, 2, 1), hyp(-2.0, 6, 2, 1)],
            &mut stats,
        );
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].score, -1.0);
        assert_eq!(stats.merged, 1);
    }

    #[test]
    fn beam_prunes_far_scores() {
        let p = Pruner { beam: 5.0, max_hyps: 10 };
        let mut stats = PruneStats::default();
        let out = p.prune(
            vec![hyp(0.0, 1, 0, 0), hyp(-4.9, 2, 0, 0), hyp(-5.1, 3, 0, 0)],
            &mut stats,
        );
        assert_eq!(out.len(), 2);
        assert_eq!(stats.beam_pruned, 1);
    }

    #[test]
    fn capacity_caps_and_sorts() {
        let p = Pruner { beam: 1000.0, max_hyps: 3 };
        let mut stats = PruneStats::default();
        let cands: Vec<Hyp> = (0..10).map(|i| hyp(-(i as f32), i, 0, 0)).collect();
        let out = p.prune(cands, &mut stats);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].score, 0.0);
        assert_eq!(out[2].score, -2.0);
        assert_eq!(stats.capacity_pruned, 7);
    }

    #[test]
    fn prune_invariants_property() {
        prop::check("prune-invariants", 60, |g| {
            let n = g.len(1);
            let cands: Vec<Hyp> = (0..n)
                .map(|_| {
                    hyp(
                        g.f32(20.0),
                        g.index(8) as u32,
                        g.index(4) as u32,
                        g.index(3) as u32,
                    )
                })
                .collect();
            let beam = 1.0 + g.rng.f32() * 10.0;
            let max_hyps = 1 + g.index(16);
            let p = Pruner { beam, max_hyps };
            let mut stats = PruneStats::default();
            let best_in = cands.iter().map(|h| h.score).fold(f32::MIN, f32::max);
            let out = p.prune(cands.clone(), &mut stats);
            // 1. Conservation: generated = survivors + all prune causes.
            crate::prop_assert!(
                stats.survived() == out.len() as u64,
                "conservation violated"
            );
            // 2. Capacity respected.
            crate::prop_assert!(out.len() <= max_hyps, "over capacity");
            // 3. Sorted descending.
            crate::prop_assert!(
                out.windows(2).all(|w| w[0].score >= w[1].score),
                "not sorted"
            );
            // 4. The best candidate always survives.
            crate::prop_assert!(
                (out[0].score - best_in).abs() < 1e-6,
                "best lost: {} vs {}",
                out[0].score,
                best_in
            );
            // 5. Everything within beam of best... that survived capacity.
            for h in &out {
                crate::prop_assert!(h.score >= best_in - beam - 1e-5, "beam violated");
            }
            // 6. No duplicate states among survivors.
            let mut keys: Vec<u64> = out.iter().map(|h| h.state_key()).collect();
            keys.sort_unstable();
            keys.dedup();
            crate::prop_assert!(keys.len() == out.len(), "duplicate states survive");
            Ok(())
        });
    }

    #[test]
    fn prune_into_reuses_buffers_and_matches_prune() {
        // Same survivors as the allocating wrapper regardless of the
        // scratch map's inherited capacity (total-order sort), and no
        // buffer regrowth once warmed.
        let p = Pruner { beam: 8.0, max_hyps: 6 };
        let mut rng = crate::util::rng::Rng::new(44);
        let mut map = KeyMap::default();
        let mut out = Vec::new();
        // Warm-up round: 40 candidates with all-distinct state keys grows
        // map and survivor buffer to their high-water mark.
        let mut warm: Vec<Hyp> =
            (0..40).map(|i| hyp(-(i as f32) * 0.01, i, 0, 0)).collect();
        p.prune_into(&mut warm, &mut map, &mut out, &mut PruneStats::default());
        let fp = (out.as_ptr() as usize, out.capacity());
        for round in 0..10 {
            // ≤ 40 candidates over ≤ 54 possible keys but at most 40
            // occupied — never exceeds the warmed capacity.
            let cands: Vec<Hyp> = (0..40)
                .map(|_| {
                    hyp(
                        rng.uniform(-10.0, 0.0),
                        rng.below(6) as u32,
                        rng.below(3) as u32,
                        rng.below(3) as u32,
                    )
                })
                .collect();
            let mut s1 = PruneStats::default();
            let mut s2 = PruneStats::default();
            let reference = p.prune(cands.clone(), &mut s1);
            let mut scratch_cands = cands;
            p.prune_into(&mut scratch_cands, &mut map, &mut out, &mut s2);
            assert_eq!(reference, out, "round {round} diverged");
            assert_eq!(s1, s2);
            assert_eq!(
                fp,
                (out.as_ptr() as usize, out.capacity()),
                "survivor buffer reallocated after warm-up (round {round})"
            );
        }
    }

    #[test]
    fn stats_accumulate_across_rounds() {
        let p = Pruner { beam: 100.0, max_hyps: 100 };
        let mut stats = PruneStats::default();
        p.prune(vec![hyp(0.0, 1, 0, 0)], &mut stats);
        p.prune(vec![hyp(0.0, 1, 0, 0), hyp(-1.0, 2, 0, 0)], &mut stats);
        assert_eq!(stats.rounds, 2);
        assert_eq!(stats.generated, 3);
        assert_eq!(stats.mean_live(), 1.5);
    }
}
