//! CTC beam-search decoding with lexicon trie and n-gram LM — the
//! functional twin of ASRPU's hypothesis-expansion kernel (§4.3).
//!
//! Per acoustic frame, every live hypothesis expands into:
//!  * a **blank** hypothesis (CTC blank symbol),
//!  * a **repeat** hypothesis (the last phonetic unit again — a valid CTC
//!    path that does not advance the lexicon),
//!  * one **advance** hypothesis per outgoing lexicon-trie link; when the
//!    reached node completes a word, the LM transitions one n-gram
//!    further and contributes `lm_weight · lnP(w|h) + word_penalty`
//!    (§4.3), forking into "commit word" and "keep extending" paths.
//!
//! Identical expansion logic drives the accelerator simulator's
//! hypothesis-expansion cost model (`accel::kernels`), so timing
//! experiments see the same search behaviour measured here.

pub mod lattice;
pub mod prune;
pub mod rescore;

use crate::config::DecoderConfig;
use crate::lexicon::{Lexicon, BLANK, ROOT};
use crate::lm::{LmState, NgramLm};
use crate::util::tensor_io::{u64_from_words, u64_words, Tensor, TensorFile};
use anyhow::{ensure, Result};
use std::borrow::Cow;
pub use lattice::{Lattice, LatticePath};
pub use prune::{KeyMap, PruneStats, Pruner};
pub use rescore::{RescoreStats, Rescored, Rescorer, TrigramLm};

/// Sentinel for "no backtrack entry".
const NO_BACK: u32 = u32::MAX;

/// Reusable buffers for hypothesis expansion + pruning: the candidate
/// list, the merge map and the survivor list live here and are recycled
/// across frames (and lanes), so a warmed scratch makes
/// [`BeamDecoder::step_with`] allocation-free apart from the per-utterance
/// backtrack arena (which grows amortized-O(log) per word committed).
#[derive(Debug, Default)]
pub struct DecodeScratch {
    cands: Vec<Hyp>,
    map: KeyMap<Hyp>,
    survivors: Vec<Hyp>,
    /// Lane-major flat candidate table for batched stepping: all lanes'
    /// candidates for one frame, concatenated in lane order — the
    /// offloadable shape of the batched exact-lattice decoder
    /// (arXiv:1910.10032).
    flat: Vec<Hyp>,
    /// Exclusive end offset of each lane's slice of `flat`.
    lane_ends: Vec<usize>,
}

impl DecodeScratch {
    /// Pointer/capacity fingerprint of the candidate buffer (scratch
    /// reuse tests; the survivor buffer intentionally swaps with
    /// `DecodeState::hyps` each frame, so it is not part of the
    /// fingerprint).
    pub fn fingerprint(&self) -> (usize, usize) {
        (self.cands.as_ptr() as usize, self.cands.capacity())
    }
}

/// One transcription hypothesis — the §3.5 record: identifying hash
/// (derived from the state tuple), score, and the programmer-defined
/// fields (lexicon node, LM state, last token, backlink).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyp {
    pub score: f32,
    /// Lexicon-trie node of the partially spelled word.
    pub node: u32,
    /// LM state (last committed word).
    pub lm: LmState,
    /// Last CTC symbol on this path (BLANK or a token id).
    pub last_token: u32,
    /// Index into the word backtrack arena (NO_BACK = no words yet).
    back: u32,
}

impl Hyp {
    /// Merge key: hypotheses with equal state are duplicates; the
    /// hypothesis unit keeps the best ("all but the best scoring are
    /// discarded", §2.3.1).
    pub fn state_key(&self) -> u64 {
        // node(24b) | lm(24b) | last_token(16b) — fits our scales.
        ((self.node as u64) << 40) ^ ((self.lm.0 as u64) << 16) ^ self.last_token as u64
    }
}

/// Expansion-side counters, one per candidate class the §4.3 kernel
/// generates — the measured inputs that drive the simulator's
/// hypothesis-expansion cost model (`accel::kernels::HypWorkload`)
/// instead of its synthetic defaults.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ExpandStats {
    /// Hypotheses that entered expansion (Σ live set sizes per frame).
    pub expanded: u64,
    /// Blank candidates generated.
    pub blank: u64,
    /// CTC-repeat candidates generated.
    pub repeat: u64,
    /// Trie-advance candidates generated (including keep-extending
    /// forks past a completed word).
    pub advance: u64,
    /// Word-commit candidates generated (LM transition + arena push).
    pub commit: u64,
}

impl ExpandStats {
    /// Total candidates generated — must equal
    /// [`PruneStats::generated`] (asserted in tests).
    pub fn generated(&self) -> u64 {
        self.blank + self.repeat + self.advance + self.commit
    }
}

/// Decoding state carried across acoustic frames (and decoding steps).
#[derive(Debug, Clone)]
pub struct DecodeState {
    pub hyps: Vec<Hyp>,
    /// Backtrack arena: (parent entry, word id).
    arena: Vec<(u32, u32)>,
    /// Acoustic frames consumed so far.
    pub frames: usize,
    /// Accumulated pruning statistics (drives ABL2 + simulator coupling).
    pub stats: PruneStats,
    /// Accumulated expansion counters (measured simulator inputs).
    pub expand: ExpandStats,
    /// Exact lattice, recorded when enabled (boxed: most lanes decode
    /// 1-best only and pay one pointer).
    lattice: Option<Box<Lattice>>,
}

impl DecodeState {
    /// Start recording an exact lattice from the current hypothesis
    /// set. Enabled at `start()` time this captures the whole
    /// utterance; enabling mid-utterance seeds from the live set (words
    /// committed earlier stay reachable through the backtrack arena).
    /// Idempotent.
    pub fn enable_lattice(&mut self) {
        if self.lattice.is_none() {
            self.lattice = Some(Box::new(Lattice::seeded(&self.hyps)));
        }
    }

    /// The recorded lattice, if recording is enabled.
    pub fn lattice(&self) -> Option<&Lattice> {
        self.lattice.as_deref()
    }
}

/// One entry of an exact N-best list: first-pass words + score (same
/// arithmetic as [`Transcript`]).
#[derive(Debug, Clone, PartialEq)]
pub struct NbestEntry {
    pub words: Vec<u32>,
    pub text: String,
    pub score: f32,
}

/// Final transcription.
#[derive(Debug, Clone, PartialEq)]
pub struct Transcript {
    pub words: Vec<u32>,
    pub text: String,
    pub score: f32,
}

/// A relocatable copy of one lane's decode state — the per-channel
/// state object of batched online decoding (Braun et al.) extracted
/// from [`DecodeState`]: the live hypothesis set (scores, lexicon
/// nodes, LM contexts, CTC last-tokens, backtrack links), the word
/// backtrack arena, the frame counter and the accumulated pruner
/// statistics. Encodes to and from [`TensorFile`] tensors
/// deterministically, so a snapshot taken on one shard restores
/// bit-identically on another (`tests/snapshot_parity.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct DecoderSnapshot {
    scores: Vec<f32>,
    nodes: Vec<u32>,
    lms: Vec<u32>,
    last_tokens: Vec<u32>,
    backs: Vec<u32>,
    /// Backtrack arena, interleaved `[parent, word]` pairs.
    arena: Vec<u32>,
    /// Frame counter + the six `PruneStats` counters + the five
    /// `ExpandStats` counters, as u64 lo/hi pairs (24 words).
    counters: Vec<u32>,
    /// Exact lattice, when the lane was recording one.
    lattice: Option<Lattice>,
}

impl DecoderSnapshot {
    /// Capture a lane's decode state (a deep copy; the live state keeps
    /// decoding).
    pub fn capture(state: &DecodeState) -> Self {
        let mut snap = DecoderSnapshot {
            scores: Vec::with_capacity(state.hyps.len()),
            nodes: Vec::with_capacity(state.hyps.len()),
            lms: Vec::with_capacity(state.hyps.len()),
            last_tokens: Vec::with_capacity(state.hyps.len()),
            backs: Vec::with_capacity(state.hyps.len()),
            arena: Vec::with_capacity(2 * state.arena.len()),
            counters: Vec::with_capacity(24),
            lattice: state.lattice.as_deref().cloned(),
        };
        for h in &state.hyps {
            snap.scores.push(h.score);
            snap.nodes.push(h.node);
            snap.lms.push(h.lm.0);
            snap.last_tokens.push(h.last_token);
            snap.backs.push(h.back);
        }
        for &(parent, word) in &state.arena {
            snap.arena.push(parent);
            snap.arena.push(word);
        }
        for v in [
            state.frames as u64,
            state.stats.generated,
            state.stats.merged,
            state.stats.beam_pruned,
            state.stats.capacity_pruned,
            state.stats.peak_live,
            state.stats.rounds,
            state.expand.expanded,
            state.expand.blank,
            state.expand.repeat,
            state.expand.advance,
            state.expand.commit,
        ] {
            snap.counters.extend_from_slice(&u64_words(v));
        }
        snap
    }

    /// Rebuild the decode state this snapshot captured.
    pub fn restore(&self) -> DecodeState {
        let hyps = self
            .scores
            .iter()
            .zip(&self.nodes)
            .zip(&self.lms)
            .zip(&self.last_tokens)
            .zip(&self.backs)
            .map(|((((&score, &node), &lm), &last_token), &back)| Hyp {
                score,
                node,
                lm: LmState(lm),
                last_token,
                back,
            })
            .collect();
        let arena = self
            .arena
            .chunks_exact(2)
            .map(|p| (p[0], p[1]))
            .collect();
        let c = |i: usize| u64_from_words(self.counters[2 * i], self.counters[2 * i + 1]);
        DecodeState {
            hyps,
            arena,
            frames: c(0) as usize,
            stats: PruneStats {
                generated: c(1),
                merged: c(2),
                beam_pruned: c(3),
                capacity_pruned: c(4),
                peak_live: c(5),
                rounds: c(6),
            },
            expand: ExpandStats {
                expanded: c(7),
                blank: c(8),
                repeat: c(9),
                advance: c(10),
                commit: c(11),
            },
            lattice: self.lattice.clone().map(Box::new),
        }
    }

    /// Write the snapshot as `dec.*` tensors (scores as f32, ids and
    /// counters as u32 — lossless both ways).
    pub fn write_tensors(&self, tf: &mut TensorFile) {
        let n = self.scores.len();
        tf.push(Tensor::f32("dec.hyp.score", vec![n], self.scores.clone()));
        tf.push(Tensor::u32("dec.hyp.node", vec![n], self.nodes.clone()));
        tf.push(Tensor::u32("dec.hyp.lm", vec![n], self.lms.clone()));
        tf.push(Tensor::u32("dec.hyp.last", vec![n], self.last_tokens.clone()));
        tf.push(Tensor::u32("dec.hyp.back", vec![n], self.backs.clone()));
        tf.push(Tensor::u32(
            "dec.arena",
            vec![self.arena.len() / 2, 2],
            self.arena.clone(),
        ));
        tf.push(Tensor::u32(
            "dec.counters",
            vec![self.counters.len()],
            self.counters.clone(),
        ));
        if let Some(lat) = &self.lattice {
            lat.write_tensors(tf);
        }
    }

    /// Read a snapshot back from `dec.*` tensors, validating shapes.
    pub fn read_tensors(tf: &TensorFile) -> Result<Self> {
        let scores = tf.require("dec.hyp.score")?.as_f32()?.to_vec();
        let nodes = tf.require("dec.hyp.node")?.as_u32()?.to_vec();
        let lms = tf.require("dec.hyp.lm")?.as_u32()?.to_vec();
        let last_tokens = tf.require("dec.hyp.last")?.as_u32()?.to_vec();
        let backs = tf.require("dec.hyp.back")?.as_u32()?.to_vec();
        let n = scores.len();
        ensure!(
            nodes.len() == n && lms.len() == n && last_tokens.len() == n && backs.len() == n,
            "decoder snapshot: ragged hypothesis columns"
        );
        let arena = tf.require("dec.arena")?.as_u32()?.to_vec();
        ensure!(arena.len() % 2 == 0, "decoder snapshot: odd arena payload");
        let counters = tf.require("dec.counters")?.as_u32()?.to_vec();
        ensure!(
            counters.len() == 24,
            "decoder snapshot: expected 24 counter words, got {}",
            counters.len()
        );
        let arena_len = arena.len() as u64 / 2;
        for (i, &b) in backs.iter().enumerate() {
            ensure!(
                b == NO_BACK || (b as u64) < arena_len,
                "decoder snapshot: hypothesis {i} backlink {b} outside arena"
            );
        }
        // Arena parents must point strictly earlier (how the live
        // decoder builds them) — this guarantees backtrack walks
        // terminate. Structural checks only; the resource-relative id
        // ranges (trie nodes, LM states, words, tokens) are validated
        // by [`Self::validate_bounds`] at restore time, where the
        // decoding resources are known.
        for (i, pair) in arena.chunks_exact(2).enumerate() {
            let parent = pair[0];
            ensure!(
                parent == NO_BACK || (parent as u64) < i as u64,
                "decoder snapshot: arena entry {i} parent {parent} not an earlier entry"
            );
        }
        // The lattice rides along only when the lane recorded one; its
        // presence is keyed on its node columns.
        let lattice = if tf.get("dec.lat.node.best").is_some() {
            Some(Lattice::read_tensors(tf, n, arena_len as usize)?)
        } else {
            None
        };
        Ok(DecoderSnapshot { scores, nodes, lms, last_tokens, backs, arena, counters, lattice })
    }

    /// Range-check every id against the decoding resources the restored
    /// state will run against, so a corrupt-but-CRC-valid snapshot can
    /// never index out of bounds inside the lexicon trie, the LM tables
    /// or the word list mid-decode. Called by `Engine::restore` with
    /// its own lexicon/LM dimensions.
    pub fn validate_bounds(
        &self,
        trie_nodes: usize,
        lm_vocab: usize,
        lexicon_words: usize,
        tokens: usize,
    ) -> Result<()> {
        for (i, &n) in self.nodes.iter().enumerate() {
            ensure!(
                (n as usize) < trie_nodes,
                "decoder snapshot: hypothesis {i} trie node {n} >= {trie_nodes}"
            );
        }
        for (i, &l) in self.lms.iter().enumerate() {
            ensure!(
                (l as usize) < lm_vocab,
                "decoder snapshot: hypothesis {i} LM state {l} >= {lm_vocab}"
            );
        }
        for (i, &t) in self.last_tokens.iter().enumerate() {
            ensure!(
                (t as usize) < tokens,
                "decoder snapshot: hypothesis {i} last token {t} >= {tokens}"
            );
        }
        for (i, pair) in self.arena.chunks_exact(2).enumerate() {
            let word = pair[1];
            ensure!(
                (word as usize) < lexicon_words,
                "decoder snapshot: arena entry {i} word {word} >= {lexicon_words}"
            );
        }
        if let Some(lat) = &self.lattice {
            lat.validate_words(lexicon_words)?;
        }
        Ok(())
    }
}

/// The beam-search decoder.
pub struct BeamDecoder<'a> {
    pub lex: &'a Lexicon,
    pub lm: &'a NgramLm,
    pub cfg: DecoderConfig,
    /// lexicon word id → LM word id (unk for OOV-in-LM). Borrowed when
    /// the caller (the engine) caches the O(vocabulary) mapping so that
    /// constructing a decoder per batch drain stays allocation-free.
    word_lm_ids: Cow<'a, [u32]>,
}

impl<'a> BeamDecoder<'a> {
    pub fn new(lex: &'a Lexicon, lm: &'a NgramLm, cfg: DecoderConfig) -> Result<Self> {
        let ids = Self::word_lm_ids(lex, lm)?;
        Self::with_word_ids(lex, lm, cfg, Cow::Owned(ids))
    }

    /// Compute the lexicon-word → LM-word mapping (O(vocabulary); cache
    /// it if you construct decoders in a hot loop).
    pub fn word_lm_ids(lex: &Lexicon, lm: &NgramLm) -> Result<Vec<u32>> {
        let unk = lm
            .word_id(crate::lm::UNK)
            .ok_or_else(|| anyhow::anyhow!("LM missing <unk>"))?;
        Ok(lex
            .words
            .iter()
            .map(|w| lm.word_id(w).unwrap_or(unk))
            .collect())
    }

    /// Build with a precomputed word-id mapping (borrowed: no allocation).
    pub fn with_word_ids(
        lex: &'a Lexicon,
        lm: &'a NgramLm,
        cfg: DecoderConfig,
        word_lm_ids: Cow<'a, [u32]>,
    ) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(
            word_lm_ids.len() == lex.words.len(),
            "word-id mapping covers {} words, lexicon has {}",
            word_lm_ids.len(),
            lex.words.len()
        );
        Ok(BeamDecoder { lex, lm, cfg, word_lm_ids })
    }

    /// Fresh state: a single empty hypothesis at the trie root.
    pub fn start(&self) -> DecodeState {
        DecodeState {
            hyps: vec![Hyp {
                score: 0.0,
                node: ROOT,
                lm: self.lm.start(),
                last_token: BLANK,
                back: NO_BACK,
            }],
            arena: Vec::new(),
            frames: 0,
            stats: PruneStats::default(),
            expand: ExpandStats::default(),
            lattice: None,
        }
    }

    /// Expand all hypotheses with one acoustic frame of token
    /// log-probabilities, then sort + prune (the hypothesis unit's job).
    /// Allocates a fresh scratch; hot loops should hold a
    /// [`DecodeScratch`] and call [`Self::step_with`].
    pub fn step(&self, state: &mut DecodeState, logp: &[f32]) {
        let mut sc = DecodeScratch::default();
        self.step_with(state, logp, &mut sc);
    }

    /// Advance `B = states.len()` independent per-lane decode states over a
    /// lane-major `[B × tokens]` logit block — the decoder half of the
    /// lane-batched execution core. Allocates a fresh scratch; hot loops
    /// should hold a [`DecodeScratch`] and call [`Self::step_batch_with`].
    pub fn step_batch(&self, states: &mut [&mut DecodeState], logps: &[f32]) {
        let mut sc = DecodeScratch::default();
        self.step_batch_with(states, logps, &mut sc);
    }

    /// Lane-major batched stepping (the offloadable shape of the batched
    /// exact-lattice decoder, arXiv:1910.10032): phase one expands every
    /// lane into one flat candidate table (`sc.flat`, lane-major — the
    /// layout a hypothesis-expansion kernel would score in a single
    /// launch); phase two prunes each lane's contiguous slice with the
    /// deterministic total-order sort. Each lane's candidate generation
    /// order, scores and prune are exactly [`Self::step_with`]'s, so
    /// batched decoding is bit-identical to B sequential scalar decodes
    /// (hypothesis sets *and* counters — asserted in tests).
    pub fn step_batch_with(
        &self,
        states: &mut [&mut DecodeState],
        logps: &[f32],
        sc: &mut DecodeScratch,
    ) {
        let tokens = self.lex.tokens.len();
        debug_assert_eq!(logps.len(), states.len() * tokens);
        self.batch_begin(sc);
        for (lane, state) in states.iter_mut().enumerate() {
            self.batch_expand(state, &logps[lane * tokens..(lane + 1) * tokens], sc);
        }
        for (lane, state) in states.iter_mut().enumerate() {
            self.batch_prune(state, lane, sc);
        }
    }

    /// Begin a lane-major batched frame: reset the flat candidate table.
    /// Exposed (with [`Self::batch_expand`] / [`Self::batch_prune`]) so
    /// callers that cannot hand over a `&mut [&mut DecodeState]` slice —
    /// the engine walks lanes embedded in larger session objects — can
    /// still drive the same lane-major path allocation-free.
    pub fn batch_begin(&self, sc: &mut DecodeScratch) {
        sc.flat.clear();
        sc.lane_ends.clear();
    }

    /// Phase one for one lane: expand its hypotheses into the shared
    /// flat candidate table. Lanes must be expanded in lane order.
    pub fn batch_expand(&self, state: &mut DecodeState, logp: &[f32], sc: &mut DecodeScratch) {
        self.expand_into(state, logp, &mut sc.flat);
        sc.lane_ends.push(sc.flat.len());
    }

    /// Phase two for one lane: prune its slice of the flat table and
    /// swap the survivors in. Callable in any lane order (slices are
    /// disjoint), but every expanded lane must be pruned exactly once
    /// before the next [`Self::batch_begin`].
    pub fn batch_prune(&self, state: &mut DecodeState, lane: usize, sc: &mut DecodeScratch) {
        let DecodeScratch { cands, map, survivors, flat, lane_ends } = sc;
        let start = if lane == 0 { 0 } else { lane_ends[lane - 1] };
        let end = lane_ends[lane];
        cands.clear();
        cands.extend_from_slice(&flat[start..end]);
        state.frames += 1;
        let pruner = Pruner {
            beam: self.cfg.beam,
            max_hyps: self.cfg.max_hyps,
        };
        pruner.prune_into(cands, map, survivors, &mut state.stats);
        if let Some(lat) = state.lattice.as_deref_mut() {
            lat.commit_frame(state.frames as u32, survivors);
        }
        std::mem::swap(&mut state.hyps, survivors);
    }

    /// One frame of hypothesis expansion + prune through a reusable
    /// scratch: candidates, the merge map and the survivor buffer all
    /// come from `sc`, so a warmed scratch makes the steady state
    /// allocation-free (the per-utterance backtrack arena is the only
    /// amortized-growth container). Identical results to [`Self::step`]:
    /// pruning is a deterministic total order.
    pub fn step_with(&self, state: &mut DecodeState, logp: &[f32], sc: &mut DecodeScratch) {
        let DecodeScratch { cands, map, survivors, .. } = sc;
        cands.clear();
        self.expand_into(state, logp, cands);
        state.frames += 1;
        let pruner = Pruner {
            beam: self.cfg.beam,
            max_hyps: self.cfg.max_hyps,
        };
        pruner.prune_into(cands, map, survivors, &mut state.stats);
        if let Some(lat) = state.lattice.as_deref_mut() {
            lat.commit_frame(state.frames as u32, survivors);
        }
        // Survivors become the live set; the old live set's buffer is
        // recycled as next frame's survivor scratch.
        std::mem::swap(&mut state.hyps, survivors);
    }

    /// Hypothesis expansion for one lane-frame, appended to `cands` —
    /// the single source of the §4.3 candidate arithmetic, shared by
    /// scalar ([`Self::step_with`]) and lane-major ([`Self::batch_expand`])
    /// stepping so the two are bit-identical by construction. When the
    /// state records a lattice, every candidate also pends an arc (in
    /// the same deterministic generation order the pruner sees).
    fn expand_into(&self, state: &mut DecodeState, logp: &[f32], cands: &mut Vec<Hyp>) {
        debug_assert_eq!(logp.len(), self.lex.tokens.len());
        cands.reserve(state.hyps.len() * 8);
        let DecodeState { hyps, arena, expand, lattice, .. } = state;
        let mut lat = lattice.as_deref_mut();
        expand.expanded += hyps.len() as u64;
        for (src, h) in hyps.iter().enumerate() {
            // (1) blank.
            let cand = Hyp {
                score: h.score + logp[BLANK as usize] + self.cfg.silence_bonus,
                last_token: BLANK,
                ..*h
            };
            if let Some(l) = lat.as_deref_mut() {
                l.pend(src, lattice::NO_WORD, &cand);
            }
            cands.push(cand);
            expand.blank += 1;
            // (2) repeat of the last unit (valid CTC path, no advance).
            if h.last_token != BLANK {
                let cand = Hyp {
                    score: h.score + logp[h.last_token as usize],
                    ..*h
                };
                if let Some(l) = lat.as_deref_mut() {
                    l.pend(src, lattice::NO_WORD, &cand);
                }
                cands.push(cand);
                expand.repeat += 1;
            }
            // (3) advance along every lexicon link.
            for (&tok, &child) in &self.lex.node(h.node).children {
                // CTC collapse rule: re-emitting the same unit without an
                // intervening blank is the 'repeat' path, not a new unit.
                if tok == h.last_token {
                    continue;
                }
                let base = h.score + logp[tok as usize];
                match self.lex.node(child).word {
                    None => {
                        let cand = Hyp {
                            score: base,
                            node: child,
                            last_token: tok,
                            ..*h
                        };
                        if let Some(l) = lat.as_deref_mut() {
                            l.pend(src, lattice::NO_WORD, &cand);
                        }
                        cands.push(cand);
                        expand.advance += 1;
                    }
                    Some(word) => {
                        // Commit the word: LM transition + word penalty,
                        // return to the trie root for the next word.
                        let lm_word = self.word_lm_ids[word as usize];
                        let (lm_lp, lm_next) = self.lm.score(h.lm, lm_word);
                        let back = arena.len() as u32;
                        arena.push((h.back, word));
                        let cand = Hyp {
                            score: base
                                + self.cfg.lm_weight * lm_lp
                                + self.cfg.word_penalty,
                            node: ROOT,
                            lm: lm_next,
                            last_token: tok,
                            back,
                        };
                        if let Some(l) = lat.as_deref_mut() {
                            l.pend(src, word, &cand);
                        }
                        cands.push(cand);
                        expand.commit += 1;
                        // Keep extending if longer words share this prefix.
                        if !self.lex.node(child).children.is_empty() {
                            let cand = Hyp {
                                score: base,
                                node: child,
                                last_token: tok,
                                ..*h
                            };
                            if let Some(l) = lat.as_deref_mut() {
                                l.pend(src, lattice::NO_WORD, &cand);
                            }
                            cands.push(cand);
                            expand.advance += 1;
                        }
                    }
                }
            }
        }
    }

    /// Complete one hypothesis at utterance end: commit any word
    /// finished at its current trie node (LM transition + word penalty),
    /// then apply the LM sentence-end score. Returns the completed score
    /// and the virtually committed final word, if any — the exact
    /// per-hypothesis arithmetic of [`Self::finish`], factored out so
    /// N-best extraction scores final hypotheses bit-identically.
    pub fn finish_hyp(&self, h: &Hyp) -> (f32, Option<u32>) {
        let mut score = h.score;
        let mut lm = h.lm;
        let mut final_word = None;
        if let Some(word) = self.lex.node(h.node).word {
            let lm_word = self.word_lm_ids[word as usize];
            let (lm_lp, lm_next) = self.lm.score(lm, lm_word);
            score += self.cfg.lm_weight * lm_lp + self.cfg.word_penalty;
            lm = lm_next;
            final_word = Some(word);
        }
        score += self.cfg.lm_weight * self.lm.score_end(lm);
        (score, final_word)
    }

    /// Extract the best transcription: commit any word completed at the
    /// current node, apply the LM sentence-end score, backtrack words.
    /// Ties keep the first (deterministic-order) hypothesis.
    pub fn finish(&self, state: &DecodeState) -> Transcript {
        let mut best: Option<(f32, Vec<u32>)> = None;
        for h in &state.hyps {
            let (score, final_word) = self.finish_hyp(h);
            if let Some((b, _)) = &best {
                if *b >= score {
                    continue;
                }
            }
            let mut words = self.backtrack(state, h.back);
            if let Some(word) = final_word {
                // Virtual arena entry (not stored; we backtrack manually).
                words.push(word);
            }
            best = Some((score, words));
        }
        let (score, words) = best.unwrap_or((f32::MIN, Vec::new()));
        let text = words
            .iter()
            .map(|&w| self.lex.word_name(w))
            .collect::<Vec<_>>()
            .join(" ");
        Transcript { words, text, score }
    }

    /// Exact N-best extraction. With a recorded lattice this enumerates
    /// paths best-first via the sidetrack decomposition
    /// ([`Lattice::nbest_paths`]): entry 0 is bit-identical to
    /// [`Self::finish`] (same score, words and tie-break), and every
    /// entry's score is the exact first-pass score of its path. Without
    /// a lattice it degrades to ranking the surviving endpoint
    /// hypotheses (still deterministic, but blind to merged-away
    /// alternatives). Distinct entries have distinct word sequences.
    pub fn nbest(&self, state: &DecodeState, n: usize) -> Vec<NbestEntry> {
        let finals: Vec<(f32, Option<u32>)> =
            state.hyps.iter().map(|h| self.finish_hyp(h)).collect();
        let mut out = Vec::new();
        match state.lattice.as_deref() {
            Some(lat) => {
                for p in lat.nbest_paths(&finals, n) {
                    let mut words = self.backtrack(state, lat.seed_back(p.seed));
                    words.extend(p.words);
                    out.push(self.entry(words, p.score));
                }
            }
            None => {
                let mut order: Vec<usize> = (0..finals.len()).collect();
                order.sort_by(|&a, &b| finals[b].0.total_cmp(&finals[a].0).then(a.cmp(&b)));
                let mut seen = std::collections::BTreeSet::new();
                for i in order {
                    let (score, final_word) = finals[i];
                    let mut words = self.backtrack(state, state.hyps[i].back);
                    if let Some(w) = final_word {
                        words.push(w);
                    }
                    if seen.insert(words.clone()) {
                        out.push(self.entry(words, score));
                        if out.len() >= n {
                            break;
                        }
                    }
                }
            }
        }
        // A dead decode (no hypotheses) still answers like `finish`.
        if out.is_empty() && n > 0 {
            out.push(self.entry(Vec::new(), f32::MIN));
        }
        out
    }

    fn entry(&self, words: Vec<u32>, score: f32) -> NbestEntry {
        let text = words
            .iter()
            .map(|&w| self.lex.word_name(w))
            .collect::<Vec<_>>()
            .join(" ");
        NbestEntry { words, text, score }
    }

    fn backtrack(&self, state: &DecodeState, mut back: u32) -> Vec<u32> {
        let mut words = Vec::new();
        while back != NO_BACK {
            let (parent, word) = state.arena[back as usize];
            words.push(word);
            back = parent;
        }
        words.reverse();
        words
    }

    /// Greedy (no-search) decode, the "simplest approach" baseline of §1:
    /// argmax per frame, CTC-collapse, then spell through the lexicon
    /// greedily. Used as the quality baseline in ABL2.
    pub fn greedy(&self, logps: &[f32]) -> Transcript {
        let tokens = self.lex.tokens.len();
        let mut path = Vec::new();
        for frame in logps.chunks(tokens) {
            let arg = frame
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as u32;
            path.push(arg);
        }
        // Collapse repeats then remove blanks.
        let mut units = Vec::new();
        let mut last = BLANK;
        for t in path {
            if t != last && t != BLANK {
                units.push(t);
            }
            last = t;
        }
        // Greedy longest-match spell through the trie.
        let mut words = Vec::new();
        let mut node = ROOT;
        let mut pending: Option<u32> = None;
        for t in units {
            node = match self.lex.node(node).children.get(&t) {
                Some(&child) => child,
                None => {
                    if let Some(w) = pending.take() {
                        words.push(w);
                    }
                    // Restart from root; drop the unit if it doesn't start
                    // a word (OOV path).
                    node = ROOT;
                    match self.lex.node(node).children.get(&t) {
                        Some(&child) => child,
                        None => continue,
                    }
                }
            };
            if let Some(w) = self.lex.node(node).word {
                pending = Some(w);
                if self.lex.node(node).children.is_empty() {
                    words.push(w);
                    pending = None;
                    node = ROOT;
                }
            }
        }
        if let Some(w) = pending {
            words.push(w);
        }
        let text = words
            .iter()
            .map(|&w| self.lex.word_name(w))
            .collect::<Vec<_>>()
            .join(" ");
        Transcript { words, text, score: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexicon::TokenSet;

    /// Lexicon: words "ab", "abc", "ba" over tokens a,b,c + LM favouring
    /// "ab ba".
    fn fixtures() -> (Lexicon, NgramLm) {
        let tokens = TokenSet::new(vec!["a".into(), "b".into(), "c".into()]);
        let a = tokens.id("a").unwrap();
        let b = tokens.id("b").unwrap();
        let c = tokens.id("c").unwrap();
        let lex = Lexicon::build(
            tokens,
            &[
                ("ab".into(), vec![a, b]),
                ("abc".into(), vec![a, b, c]),
                ("ba".into(), vec![b, a]),
            ],
        )
        .unwrap();
        let corpus: Vec<Vec<String>> = [
            "ab ba", "ab ba", "ab abc", "ba ab", "ab ba ab",
        ]
        .iter()
        .map(|s| s.split_whitespace().map(str::to_string).collect())
        .collect();
        let lm = NgramLm::estimate(&corpus, 0.4).unwrap();
        (lex, lm)
    }

    /// Build per-frame log-prob rows that strongly favour a token path.
    fn frames_for(path: &[u32], tokens: usize) -> Vec<f32> {
        let mut out = Vec::new();
        for &t in path {
            let mut row = vec![(0.01f32 / (tokens - 1) as f32).ln(); tokens];
            row[t as usize] = 0.99f32.ln();
            out.extend(row);
        }
        out
    }

    fn decode(lex: &Lexicon, lm: &NgramLm, frames: &[f32]) -> Transcript {
        let dec = BeamDecoder::new(lex, lm, DecoderConfig::default()).unwrap();
        let mut st = dec.start();
        for row in frames.chunks(lex.tokens.len()) {
            dec.step(&mut st, row);
        }
        dec.finish(&st)
    }

    #[test]
    fn decodes_clean_single_word() {
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        // a a b b (CTC repeats collapse) → "ab".
        let frames = frames_for(&[a, a, b, b], lex.tokens.len());
        assert_eq!(decode(&lex, &lm, &frames).text, "ab");
    }

    #[test]
    fn blank_separates_repeated_units() {
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        // "ab" then "ba": a b <blank> b a — blank needed between b,b.
        let frames = frames_for(&[a, b, BLANK, b, a], lex.tokens.len());
        assert_eq!(decode(&lex, &lm, &frames).text, "ab ba");
    }

    #[test]
    fn prefix_word_vs_longer_word() {
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let c = lex.tokens.id("c").unwrap();
        // Clean "abc" must decode as the longer word, not "ab"+dangling c.
        let frames = frames_for(&[a, b, c], lex.tokens.len());
        assert_eq!(decode(&lex, &lm, &frames).text, "abc");
    }

    #[test]
    fn lm_breaks_acoustic_ties() {
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        // After "ab", an ambiguous frame between starting "ba" vs "abc"
        // continuation is resolved by the LM (corpus favours "ab ba").
        let tokens = lex.tokens.len();
        let mut frames = frames_for(&[a, b, BLANK], tokens);
        // Ambiguous frame: b and c equally likely.
        let mut row = vec![0.02f32.ln(); tokens];
        row[b as usize] = 0.48f32.ln();
        row[lex.tokens.id("c").unwrap() as usize] = 0.48f32.ln();
        frames.extend(row);
        frames.extend(frames_for(&[a], tokens));
        let t = decode(&lex, &lm, &frames);
        assert_eq!(t.text, "ab ba");
    }

    #[test]
    fn step_batch_matches_sequential_lanes() {
        // Two lanes decoding different audio through one decoder: batched
        // stepping must reproduce each scalar lane exactly (hypothesis
        // sets, scores and final transcripts).
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let c = lex.tokens.id("c").unwrap();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let tokens = lex.tokens.len();
        let lane_paths: Vec<Vec<u32>> =
            vec![vec![a, b, BLANK, b, a], vec![a, b, c, BLANK, BLANK]];
        let frames: Vec<Vec<f32>> =
            lane_paths.iter().map(|p| frames_for(p, tokens)).collect();
        // Scalar reference.
        let mut scalar: Vec<DecodeState> = (0..2).map(|_| dec.start()).collect();
        for (lane, st) in scalar.iter_mut().enumerate() {
            for row in frames[lane].chunks(tokens) {
                dec.step(st, row);
            }
        }
        // Batched: interleave the same frames as [B × tokens] blocks.
        let mut batched: Vec<DecodeState> = (0..2).map(|_| dec.start()).collect();
        let n_frames = lane_paths[0].len();
        for f in 0..n_frames {
            let mut block = Vec::with_capacity(2 * tokens);
            for lane_frames in &frames {
                block.extend_from_slice(&lane_frames[f * tokens..(f + 1) * tokens]);
            }
            let mut refs: Vec<&mut DecodeState> = batched.iter_mut().collect();
            dec.step_batch(&mut refs, &block);
        }
        for lane in 0..2 {
            assert_eq!(scalar[lane].hyps, batched[lane].hyps, "lane {lane} hyps");
            assert_eq!(scalar[lane].stats, batched[lane].stats, "lane {lane} stats");
            let ts = dec.finish(&scalar[lane]);
            let tb = dec.finish(&batched[lane]);
            assert_eq!(ts.text, tb.text);
            assert_eq!(ts.score, tb.score);
        }
    }

    #[test]
    fn step_with_shared_scratch_matches_fresh_scratch_steps() {
        // One reused scratch across many frames and two interleaved lanes
        // must give exactly the per-frame results of fresh-scratch steps,
        // and the candidate buffer must stop reallocating once warmed.
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let c = lex.tokens.id("c").unwrap();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let tokens = lex.tokens.len();
        let path = [a, b, BLANK, b, a, c, BLANK, a, b, c, a, BLANK];
        let frames = frames_for(&path, tokens);
        let mut fresh = dec.start();
        let mut reused = dec.start();
        let mut sc = DecodeScratch::default();
        // Pass 1 (warm-up): shared scratch must match fresh-scratch steps.
        for (i, row) in frames.chunks(tokens).enumerate() {
            dec.step(&mut fresh, row);
            dec.step_with(&mut reused, row, &mut sc);
            assert_eq!(fresh.hyps, reused.hyps, "frame {i} diverged");
        }
        assert_eq!(dec.finish(&fresh).text, dec.finish(&reused).text);
        // Pass 2: identical frames through the warmed scratch — the
        // candidate buffer must never reallocate.
        let fp = sc.fingerprint();
        let mut second = dec.start();
        for (i, row) in frames.chunks(tokens).enumerate() {
            dec.step_with(&mut second, row, &mut sc);
            assert_eq!(fp, sc.fingerprint(), "frame {i} reallocated");
        }
        assert_eq!(second.hyps, reused.hyps);
    }

    #[test]
    fn with_word_ids_borrowed_matches_new() {
        let (lex, lm) = fixtures();
        let ids = BeamDecoder::word_lm_ids(&lex, &lm).unwrap();
        let owned = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let borrowed = BeamDecoder::with_word_ids(
            &lex,
            &lm,
            DecoderConfig::default(),
            std::borrow::Cow::Borrowed(&ids),
        )
        .unwrap();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let frames = frames_for(&[a, a, b, b], lex.tokens.len());
        let mut s1 = owned.start();
        let mut s2 = borrowed.start();
        for row in frames.chunks(lex.tokens.len()) {
            owned.step(&mut s1, row);
            borrowed.step(&mut s2, row);
        }
        assert_eq!(owned.finish(&s1).text, borrowed.finish(&s2).text);
        assert_eq!(owned.finish(&s1).score, borrowed.finish(&s2).score);
    }

    #[test]
    fn empty_input_gives_empty_transcript() {
        let (lex, lm) = fixtures();
        let t = decode(&lex, &lm, &[]);
        assert_eq!(t.text, "");
    }

    #[test]
    fn beam_width_zero_pruning_is_greedy_like() {
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let dec = BeamDecoder::new(
            &lex,
            &lm,
            DecoderConfig { beam: 0.5, max_hyps: 2, ..Default::default() },
        )
        .unwrap();
        let frames = frames_for(&[a, b], lex.tokens.len());
        let mut st = dec.start();
        for row in frames.chunks(lex.tokens.len()) {
            dec.step(&mut st, row);
            assert!(st.hyps.len() <= 2, "capacity violated");
        }
        assert_eq!(dec.finish(&st).text, "ab");
    }

    #[test]
    fn greedy_baseline_decodes_clean_path() {
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let frames = frames_for(&[a, a, b, BLANK, b, a], lex.tokens.len());
        assert_eq!(dec.greedy(&frames).text, "ab ba");
    }

    #[test]
    fn scores_are_monotone_decreasing() {
        // Adding frames can only lower the (log-prob) score of the best
        // path when every frame's best log-prob is ≤ 0 and no word bonus
        // exceeds it — with word_penalty ≤ 0 and lm_weight ≥ 0 this holds.
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let mut st = dec.start();
        let mut prev_best = 0.0f32;
        for &t in &[a, b, BLANK, b, a, BLANK] {
            let frames = frames_for(&[t], lex.tokens.len());
            dec.step(&mut st, &frames);
            let best = st.hyps.iter().map(|h| h.score).fold(f32::MIN, f32::max);
            assert!(best <= prev_best + 1e-5);
            prev_best = best;
        }
    }

    #[test]
    fn snapshot_mid_decode_restores_bit_identically() {
        // Snapshot after a prefix of frames, round-trip through tensors,
        // and continue both the original and the restored state: every
        // hypothesis, the stats and the final transcript must be equal.
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let c = lex.tokens.id("c").unwrap();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let tokens = lex.tokens.len();
        let path = [a, b, BLANK, b, a, c, BLANK, a, b, c];
        let frames = frames_for(&path, tokens);
        for cut in [1usize, 4, 7] {
            let mut live = dec.start();
            for row in frames[..cut * tokens].chunks(tokens) {
                dec.step(&mut live, row);
            }
            let mut tf = TensorFile::new();
            DecoderSnapshot::capture(&live).write_tensors(&mut tf);
            // Serialize the container itself too: the snapshot must
            // survive the byte round-trip shards actually ship.
            let tf = TensorFile::from_bytes(&tf.to_bytes().unwrap()).unwrap();
            let mut restored = DecoderSnapshot::read_tensors(&tf).unwrap().restore();
            assert_eq!(live.hyps, restored.hyps, "cut {cut}");
            assert_eq!(live.arena, restored.arena, "cut {cut}");
            assert_eq!(live.stats, restored.stats, "cut {cut}");
            assert_eq!(live.frames, restored.frames, "cut {cut}");
            for row in frames[cut * tokens..].chunks(tokens) {
                dec.step(&mut live, row);
                dec.step(&mut restored, row);
            }
            let t_live = dec.finish(&live);
            let t_rest = dec.finish(&restored);
            assert_eq!(t_live.text, t_rest.text, "cut {cut}");
            assert_eq!(t_live.score, t_rest.score, "cut {cut}");
        }
    }

    /// Frames with genuine ambiguity (merges, beam prunes, LM
    /// tie-breaks) so lattice tests exercise sidetracks, not just a
    /// single chain.
    fn ambiguous_frames(lex: &Lexicon) -> Vec<f32> {
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let c = lex.tokens.id("c").unwrap();
        let tokens = lex.tokens.len();
        let mut frames = frames_for(&[a, b, BLANK], tokens);
        let mut row = vec![0.02f32.ln(); tokens];
        row[b as usize] = 0.48f32.ln();
        row[c as usize] = 0.48f32.ln();
        frames.extend(row);
        frames.extend(frames_for(&[a, BLANK, a, b, c], tokens));
        frames
    }

    #[test]
    fn expand_stats_partition_generated() {
        let (lex, lm) = fixtures();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let mut st = dec.start();
        for row in ambiguous_frames(&lex).chunks(lex.tokens.len()) {
            dec.step(&mut st, row);
            assert_eq!(st.expand.generated(), st.stats.generated);
        }
        assert!(st.expand.expanded > 0);
        assert!(st.expand.commit > 0, "test input commits words");
    }

    #[test]
    fn lattice_best_path_is_bit_identical_to_finish() {
        let (lex, lm) = fixtures();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let mut st = dec.start();
        st.enable_lattice();
        for row in ambiguous_frames(&lex).chunks(lex.tokens.len()) {
            dec.step(&mut st, row);
        }
        let lat = st.lattice().expect("recording enabled");
        assert!(lat.num_arcs() > lat.num_nodes(), "ambiguity must leave sidetracks");
        let t = dec.finish(&st);
        let nb = dec.nbest(&st, 5);
        assert!(nb.len() > 1, "ambiguous input must yield alternatives");
        assert_eq!(nb[0].words, t.words);
        assert_eq!(nb[0].text, t.text);
        assert_eq!(nb[0].score, t.score, "lattice best must be bit-identical");
        for w in nb.windows(2) {
            assert!(w[0].score >= w[1].score, "N-best must be sorted");
            assert_ne!(w[0].words, w[1].words, "entries must be distinct");
        }
        assert_eq!(nb, dec.nbest(&st, 5), "N-best must be deterministic");
    }

    #[test]
    fn nbest_without_lattice_degrades_to_endpoint_ranking() {
        let (lex, lm) = fixtures();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let mut st = dec.start();
        for row in ambiguous_frames(&lex).chunks(lex.tokens.len()) {
            dec.step(&mut st, row);
        }
        assert!(st.lattice().is_none());
        let t = dec.finish(&st);
        let nb = dec.nbest(&st, 3);
        assert_eq!(nb[0].words, t.words);
        assert_eq!(nb[0].score, t.score);
    }

    #[test]
    fn batched_lattices_match_scalar_lattices() {
        // Lane-major batched stepping with recording enabled: per-lane
        // lattices, hypothesis sets, counters and N-best lists must all
        // equal the scalar decodes'.
        let (lex, lm) = fixtures();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let c = lex.tokens.id("c").unwrap();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let tokens = lex.tokens.len();
        let lane_paths: Vec<Vec<u32>> = vec![
            vec![a, b, BLANK, b, a],
            vec![a, b, c, BLANK, BLANK],
            vec![b, a, BLANK, a, b],
        ];
        let frames: Vec<Vec<f32>> =
            lane_paths.iter().map(|p| frames_for(p, tokens)).collect();
        let lanes = lane_paths.len();
        let mut scalar: Vec<DecodeState> = (0..lanes).map(|_| dec.start()).collect();
        for st in &mut scalar {
            st.enable_lattice();
        }
        for (lane, st) in scalar.iter_mut().enumerate() {
            for row in frames[lane].chunks(tokens) {
                dec.step(st, row);
            }
        }
        let mut batched: Vec<DecodeState> = (0..lanes).map(|_| dec.start()).collect();
        for st in &mut batched {
            st.enable_lattice();
        }
        let mut sc = DecodeScratch::default();
        let n_frames = lane_paths[0].len();
        for f in 0..n_frames {
            let mut block = Vec::with_capacity(lanes * tokens);
            for lane_frames in &frames {
                block.extend_from_slice(&lane_frames[f * tokens..(f + 1) * tokens]);
            }
            let mut refs: Vec<&mut DecodeState> = batched.iter_mut().collect();
            dec.step_batch_with(&mut refs, &block, &mut sc);
        }
        for lane in 0..lanes {
            assert_eq!(scalar[lane].hyps, batched[lane].hyps, "lane {lane} hyps");
            assert_eq!(scalar[lane].stats, batched[lane].stats, "lane {lane} stats");
            assert_eq!(scalar[lane].expand, batched[lane].expand, "lane {lane} expand");
            assert_eq!(
                scalar[lane].lattice(),
                batched[lane].lattice(),
                "lane {lane} lattice"
            );
            assert_eq!(
                dec.nbest(&scalar[lane], 4),
                dec.nbest(&batched[lane], 4),
                "lane {lane} nbest"
            );
        }
    }

    #[test]
    fn lattice_snapshot_round_trip_preserves_nbest() {
        let (lex, lm) = fixtures();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let tokens = lex.tokens.len();
        let frames = ambiguous_frames(&lex);
        let n_frames = frames.len() / tokens;
        for cut in [2usize, 5] {
            let mut live = dec.start();
            live.enable_lattice();
            for row in frames[..cut * tokens].chunks(tokens) {
                dec.step(&mut live, row);
            }
            assert!(live.lattice().unwrap().num_arcs() > 0, "cut {cut}: lattice non-empty");
            let mut tf = TensorFile::new();
            DecoderSnapshot::capture(&live).write_tensors(&mut tf);
            let tf = TensorFile::from_bytes(&tf.to_bytes().unwrap()).unwrap();
            let snap = DecoderSnapshot::read_tensors(&tf).unwrap();
            snap.validate_bounds(
                lex.num_nodes(),
                lm.vocab_len(),
                lex.words.len(),
                lex.tokens.len(),
            )
            .unwrap();
            let mut restored = snap.restore();
            assert_eq!(live.lattice(), restored.lattice(), "cut {cut}");
            assert_eq!(live.expand, restored.expand, "cut {cut}");
            for row in frames[cut * tokens..n_frames * tokens].chunks(tokens) {
                dec.step(&mut live, row);
                dec.step(&mut restored, row);
            }
            assert_eq!(live.lattice(), restored.lattice(), "cut {cut} after continue");
            let t_live = dec.finish(&live);
            let t_rest = dec.finish(&restored);
            assert_eq!(t_live.score, t_rest.score, "cut {cut}");
            assert_eq!(dec.nbest(&live, 4), dec.nbest(&restored, 4), "cut {cut}");
        }
    }

    #[test]
    fn snapshot_rejects_corrupt_tensors() {
        let (lex, lm) = fixtures();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let st = dec.start();
        let mut tf = TensorFile::new();
        DecoderSnapshot::capture(&st).write_tensors(&mut tf);
        assert!(DecoderSnapshot::read_tensors(&tf).is_ok());
        // Missing column.
        let mut partial = TensorFile::new();
        for t in tf.tensors.iter().filter(|t| t.name != "dec.hyp.lm") {
            partial.push(t.clone());
        }
        assert!(DecoderSnapshot::read_tensors(&partial).is_err());
        // Out-of-range backlink.
        let mut bad = TensorFile::new();
        for t in &tf.tensors {
            if t.name == "dec.hyp.back" {
                bad.push(Tensor::u32("dec.hyp.back", t.dims.clone(), vec![5]));
            } else {
                bad.push(t.clone());
            }
        }
        assert!(DecoderSnapshot::read_tensors(&bad).is_err());
        // Arena parent that is not an earlier entry (would loop or
        // index out of bounds during backtracking).
        let mut bad = TensorFile::new();
        for t in &tf.tensors {
            if t.name == "dec.arena" {
                bad.push(Tensor::u32("dec.arena", vec![1, 2], vec![5, 0]));
            } else {
                bad.push(t.clone());
            }
        }
        assert!(DecoderSnapshot::read_tensors(&bad).is_err());
    }

    #[test]
    fn snapshot_bounds_validation_catches_out_of_range_ids() {
        let (lex, lm) = fixtures();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let mut st = dec.start();
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        for row in frames_for(&[a, b], lex.tokens.len()).chunks(lex.tokens.len()) {
            dec.step(&mut st, row);
        }
        let snap = DecoderSnapshot::capture(&st);
        let (nodes, vocab, words, tokens) = (
            lex.num_nodes(),
            lm.vocab_len(),
            lex.words.len(),
            lex.tokens.len(),
        );
        snap.validate_bounds(nodes, vocab, words, tokens).unwrap();
        // Shrinking any resource below a used id must fail — the same
        // check that rejects a snapshot with out-of-range ids.
        assert!(snap.validate_bounds(1, vocab, words, tokens).is_err());
        assert!(snap.validate_bounds(nodes, 1, words, tokens).is_err());
        assert!(snap.validate_bounds(nodes, vocab, words, 1).is_err());
    }
}
