//! Second-pass LM rescoring of exact N-best lists (§4.3's programmable
//! follow-on stage): the first pass decodes with the cheap bigram
//! [`NgramLm`] baked into the search, the lattice yields an exact
//! N-best list, and this module re-ranks it under a higher-order
//! (trigram) LM — the classic two-pass recipe the exact-lattice
//! decoder of Braun et al. (arXiv:1910.10032) exists to enable.
//!
//! The second-pass score is an exact swap of the LM component:
//! `second = first − lm_weight·lnP_bigram(words) + weight·lnP_trigram(words)`
//! where `lnP_bigram(words)` is the full-sentence score of the
//! first-pass LM. Acoustic scores and word penalties carry over
//! unchanged. Re-ranking is deterministic: ties in the second-pass
//! score keep first-pass (rank) order.

use super::NbestEntry;
use crate::lexicon::Lexicon;
use crate::lm::{LmState, NgramLm, SENT_END, UNK};
use anyhow::Result;
use std::collections::BTreeMap;

/// Backoff trigram LM built over the bigram [`NgramLm`]: seen trigrams
/// carry absolutely discounted probabilities; unseen trigrams back off
/// (Katz-style, with the same simplified backoff mass normalization as
/// the bigram estimator) to the bigram score.
#[derive(Debug, Clone)]
pub struct TrigramLm {
    backoff: NgramLm,
    /// (u, v, w) → ln p(w | u, v) for seen trigrams.
    tri_logp: BTreeMap<(u32, u32, u32), f32>,
    /// (u, v) → ln backoff weight for contexts with seen trigrams.
    ctx_backoff: BTreeMap<(u32, u32), f32>,
}

impl TrigramLm {
    /// Estimate from a corpus of sentences with absolute discounting,
    /// sharing the vocabulary (and the backoff distribution) with a
    /// bigram estimated from the same corpus.
    pub fn estimate(corpus: &[Vec<String>], discount: f64) -> Result<Self> {
        let backoff = NgramLm::estimate(corpus, discount)?;
        let start = backoff.start().0;
        let mut tri_count: BTreeMap<(u32, u32, u32), u64> = BTreeMap::new();
        let mut ctx_count: BTreeMap<(u32, u32), u64> = BTreeMap::new();
        for sent in corpus {
            // Context starts as (<s>, <s>); the sentence end transition
            // is part of the model, as in the bigram.
            let (mut u, mut v) = (start, start);
            for w in sent
                .iter()
                .map(String::as_str)
                .chain(std::iter::once(SENT_END))
            {
                let id = backoff
                    .word_id(w)
                    .expect("bigram estimator interned every corpus word");
                *tri_count.entry((u, v, id)).or_default() += 1;
                *ctx_count.entry((u, v)).or_default() += 1;
                u = v;
                v = id;
            }
        }
        let mut tri_logp = BTreeMap::new();
        let mut ctx_backoff = BTreeMap::new();
        for (&(u, v), &ct) in &ctx_count {
            let seen: Vec<(u32, u64)> = tri_count
                .range((u, v, 0)..=(u, v, u32::MAX))
                .map(|(&(_, _, w), &c)| (w, c))
                .collect();
            for &(w, c) in &seen {
                let p = (c as f64 - discount).max(1e-10) / ct as f64;
                tri_logp.insert((u, v, w), p.ln() as f32);
            }
            let bo = (discount * seen.len() as f64 / ct as f64).max(1e-10);
            ctx_backoff.insert((u, v), bo.ln() as f32);
        }
        Ok(TrigramLm { backoff, tri_logp, ctx_backoff })
    }

    /// The shared vocabulary's bigram backoff model.
    pub fn bigram(&self) -> &NgramLm {
        &self.backoff
    }

    /// Number of seen trigrams (reporting).
    pub fn num_trigrams(&self) -> usize {
        self.tri_logp.len()
    }

    /// `ln p(w | u, v)` with backoff to the bigram.
    pub fn logp(&self, u: u32, v: u32, w: u32) -> f32 {
        match self.tri_logp.get(&(u, v, w)) {
            Some(&lp) => lp,
            None => {
                self.ctx_backoff.get(&(u, v)).copied().unwrap_or(0.0)
                    + self.backoff.score(LmState(v), w).0
            }
        }
    }

    /// Log-prob of a whole sentence, `<s> <s> … </s>`, unknown words
    /// mapped to `<unk>` — the second-pass counterpart of
    /// [`NgramLm::sentence_logp`].
    pub fn sentence_logp(&self, sentence: &[&str]) -> f32 {
        let unk = self.backoff.word_id(UNK).expect("LM missing <unk>");
        let end = self
            .backoff
            .word_id(SENT_END)
            .expect("LM missing </s>");
        let start = self.backoff.start().0;
        let (mut u, mut v) = (start, start);
        let mut total = 0.0f32;
        for w in sentence {
            let id = self.backoff.word_id(w).unwrap_or(unk);
            total += self.logp(u, v, id);
            u = v;
            v = id;
        }
        total + self.logp(u, v, end)
    }

    /// Estimated external-memory footprint of the trigram tables
    /// (simulator reporting; the bigram's graph is counted separately).
    pub fn graph_bytes(&self) -> usize {
        self.tri_logp.len() * 16 + self.ctx_backoff.len() * 12
    }
}

/// Running statistics over served N-best lists — the measured
/// counterpart of the simulator's nominal rescore-path length
/// (`accel::kernels::RESCORE_AVG_WORDS`). The engine folds every list
/// it serves into these counters; the simulator sizes its finish-time
/// rescore kernel from `avg_words` via
/// `HypWorkload::with_rescore_stats`, so the simulated second-pass cost
/// tracks real utterance lengths instead of a fixed constant.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RescoreStats {
    /// N-best lists measured so far.
    pub lists: u64,
    /// Total entries across those lists.
    pub entries: u64,
    /// Total words across those entries.
    pub words: u64,
}

impl RescoreStats {
    /// Fold one served N-best list into the running totals.
    pub fn record(&mut self, entries: &[NbestEntry]) {
        self.lists += 1;
        self.entries += entries.len() as u64;
        self.words += entries.iter().map(|e| e.words.len() as u64).sum::<u64>();
    }

    /// Mean words per N-best path, `None` until at least one non-empty
    /// list was measured (callers keep their nominal sizing constant).
    pub fn avg_words(&self) -> Option<f64> {
        (self.entries > 0).then(|| self.words as f64 / self.entries as f64)
    }

    /// Mean entries per measured list (reporting).
    pub fn avg_entries(&self) -> Option<f64> {
        (self.lists > 0).then(|| self.entries as f64 / self.lists as f64)
    }
}

/// One N-best entry after the second pass.
#[derive(Debug, Clone, PartialEq)]
pub struct Rescored {
    pub words: Vec<u32>,
    pub text: String,
    /// Exact first-pass (search) score of this path.
    pub first_pass: f32,
    /// Score after swapping the LM component for the second-pass LM.
    pub second_pass: f32,
}

/// A configured second pass: the higher-order LM plus its weight.
#[derive(Debug, Clone)]
pub struct Rescorer {
    pub lm: TrigramLm,
    /// Weight on the second-pass LM log-prob (replaces the first pass's
    /// `lm_weight · lnP_bigram` share).
    pub weight: f32,
}

impl Rescorer {
    /// Re-rank an N-best list: swap each entry's first-pass LM
    /// component (`lm_weight · lnP_bigram`) for
    /// `weight · lnP_trigram`, then sort by second-pass score
    /// descending with ties keeping first-pass order. Deterministic for
    /// a fixed entry order.
    pub fn rescore(
        &self,
        entries: &[NbestEntry],
        lex: &Lexicon,
        first_lm: &NgramLm,
        lm_weight: f32,
    ) -> Vec<Rescored> {
        let mut ranked: Vec<(usize, Rescored)> = entries
            .iter()
            .enumerate()
            .map(|(rank, e)| {
                let names: Vec<&str> =
                    e.words.iter().map(|&w| lex.word_name(w)).collect();
                let second = e.score - lm_weight * first_lm.sentence_logp(&names)
                    + self.weight * self.lm.sentence_logp(&names);
                (
                    rank,
                    Rescored {
                        words: e.words.clone(),
                        text: e.text.clone(),
                        first_pass: e.score,
                        second_pass: second,
                    },
                )
            })
            .collect();
        ranked.sort_by(|a, b| {
            b.1.second_pass
                .total_cmp(&a.1.second_pass)
                .then(a.0.cmp(&b.0))
        });
        ranked.into_iter().map(|(_, r)| r).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Vec<Vec<String>> {
        // Trigram-distinguishable: after "b", the bigram sees c and d
        // equally often; only the (·, b) two-word context tells them
        // apart.
        let sents = [
            "a b c", "a b c", "a b c", "x b d", "x b d", "x b d", "a b c", "x b d",
        ];
        sents
            .iter()
            .map(|s| s.split_whitespace().map(str::to_string).collect())
            .collect()
    }

    #[test]
    fn trigram_separates_contexts_the_bigram_conflates() {
        let tri = TrigramLm::estimate(&corpus(), 0.4).unwrap();
        let bi = tri.bigram();
        // Bigram: p(c|b) == p(d|b) — the histories are identical.
        let b = bi.word_id("b").unwrap();
        let c = bi.word_id("c").unwrap();
        let d = bi.word_id("d").unwrap();
        let (p_c, _) = bi.score(LmState(b), c);
        let (p_d, _) = bi.score(LmState(b), d);
        assert!((p_c - p_d).abs() < 1e-6, "{p_c} vs {p_d}");
        // Trigram: "a b" predicts c, not d.
        let tri_margin =
            tri.sentence_logp(&["a", "b", "c"]) - tri.sentence_logp(&["a", "b", "d"]);
        let bi_margin = bi.sentence_logp(&["a", "b", "c"]) - bi.sentence_logp(&["a", "b", "d"]);
        assert!(
            tri_margin > bi_margin + 0.5,
            "trigram margin {tri_margin} not above bigram margin {bi_margin}"
        );
    }

    #[test]
    fn unknown_words_score_finitely() {
        let tri = TrigramLm::estimate(&corpus(), 0.4).unwrap();
        assert!(tri.sentence_logp(&["zebra", "b", "c"]).is_finite());
        assert!(tri.sentence_logp(&[]).is_finite());
    }

    #[test]
    fn seen_trigrams_are_recorded() {
        let tri = TrigramLm::estimate(&corpus(), 0.4).unwrap();
        assert!(tri.num_trigrams() > 0);
        assert!(tri.graph_bytes() > 0);
    }

    #[test]
    fn rescoring_reranks_and_keeps_first_pass_scores() {
        use crate::lexicon::{Lexicon, TokenSet};
        // Lexicon over the corpus words so word ids resolve to names.
        let tokens =
            TokenSet::new(vec!["a".into(), "b".into(), "c".into(), "d".into(), "x".into()]);
        let spell = |s: &str| s.chars().map(|c| tokens.id(&c.to_string()).unwrap()).collect();
        let entries_words: Vec<(String, Vec<u32>)> = ["a", "b", "c", "d", "x"]
            .iter()
            .map(|w| (w.to_string(), spell(w)))
            .collect();
        let lex = Lexicon::build(tokens, &entries_words).unwrap();
        let wid = |w: &str| lex.words.iter().position(|x| x == w).unwrap() as u32;
        let tri = TrigramLm::estimate(&corpus(), 0.4).unwrap();
        let bi = tri.bigram().clone();
        let rescorer = Rescorer { lm: tri, weight: 1.2 };
        // First pass narrowly prefers "a b d" (which the trigram LM
        // dislikes) over "a b c" (which it strongly prefers).
        let e1 = NbestEntry {
            words: vec![wid("a"), wid("b"), wid("d")],
            text: "a b d".into(),
            score: -10.0,
        };
        let e2 = NbestEntry {
            words: vec![wid("a"), wid("b"), wid("c")],
            text: "a b c".into(),
            score: -10.1,
        };
        let out = rescorer.rescore(&[e1.clone(), e2.clone()], &lex, &bi, 1.2);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].text, "a b c", "second pass must promote the trigram-likely path");
        assert_eq!(out[0].first_pass, -10.1);
        assert_eq!(out[1].first_pass, -10.0);
        assert!(out[0].second_pass >= out[1].second_pass);
        // Deterministic: same inputs, same output.
        let again = rescorer.rescore(&[e1, e2], &lex, &bi, 1.2);
        assert_eq!(out, again);
    }

    #[test]
    fn rescore_stats_accumulate_and_average() {
        let e = |n: usize| NbestEntry { words: vec![0; n], text: String::new(), score: 0.0 };
        let mut st = RescoreStats::default();
        assert_eq!(st.avg_words(), None);
        assert_eq!(st.avg_entries(), None);
        st.record(&[e(3), e(5)]);
        st.record(&[e(4)]);
        assert_eq!(st.lists, 2);
        assert_eq!(st.entries, 3);
        assert_eq!(st.words, 12);
        assert_eq!(st.avg_words(), Some(4.0));
        assert_eq!(st.avg_entries(), Some(1.5));
        // An empty list counts as a list but leaves the word mean alone.
        st.record(&[]);
        assert_eq!(st.avg_words(), Some(4.0));
    }

    #[test]
    fn rescoring_ties_keep_first_pass_order() {
        let tri = TrigramLm::estimate(&corpus(), 0.4).unwrap();
        let bi = tri.bigram().clone();
        use crate::lexicon::{Lexicon, TokenSet};
        let tokens = TokenSet::new(vec!["a".into()]);
        let lex = Lexicon::build(tokens, &[("a".into(), vec![0])]).unwrap();
        let rescorer = Rescorer { lm: tri, weight: 1.0 };
        // Identical word sequences → identical second-pass scores; the
        // first-pass order must be preserved.
        let e = |score: f32| NbestEntry { words: vec![0], text: "a".into(), score };
        let out = rescorer.rescore(&[e(-5.0), e(-5.0)], &lex, &bi, 1.0);
        assert_eq!(out[0].first_pass, -5.0);
        assert_eq!(out.len(), 2);
    }
}
