//! Exact decode lattices — every scored hypothesis-expansion arc the
//! pruner ever saw, recorded per frame in deterministic order.
//!
//! Following the batched exact-lattice decoder of Braun et al.
//! (arXiv:1910.10032), the lattice keeps not just the surviving
//! hypotheses but the *arcs between them*: for each frame, one arc per
//! generated candidate, tagged with the candidate's full path score.
//! Nodes are the per-frame survivor sets (exactly the hypotheses
//! [`super::Pruner`] kept, in its deterministic total order), so the
//! lattice is a DAG whose best path is — by construction —
//! bit-identical to the 1-best transcript the live beam search
//! produces. Arcs whose candidate was merged away, beam-pruned or
//! capacity-pruned survive as *sidetracks*, which is what makes exact
//! N-best extraction ([`Lattice::nbest_paths`]) and second-pass LM
//! rescoring ([`super::rescore`]) possible after the fact.
//!
//! The whole structure is flat `u32`/`f32` columns (structure of
//! arrays), so it encodes to [`TensorFile`] tensors losslessly and
//! rides the CRC-framed `SessionSnapshot` across shards.

use super::prune::KeyMap;
use super::{Hyp, NO_BACK};
use crate::util::tensor_io::{Tensor, TensorFile};
use anyhow::{ensure, Result};
use std::cmp::Ordering;
use std::collections::{BTreeSet, BinaryHeap};

/// Sentinel for "no incoming winner arc" (lattice seed nodes).
pub const NO_ARC: u32 = u32::MAX;
/// Sentinel word id on arcs that do not commit a word.
pub const NO_WORD: u32 = u32::MAX;
/// Sentinel destination for arcs whose candidate did not survive the
/// frame's prune (merged away, outside the beam, or over capacity).
pub const PRUNED: u32 = u32::MAX;

/// An arc recorded during expansion, before the frame's prune has
/// decided which candidates survive (and therefore which node — if
/// any — the arc lands on).
#[derive(Debug, Clone, Copy)]
struct PendingArc {
    /// Source lattice node (a frontier node of the previous frame).
    src: u32,
    /// Word committed by this expansion, or [`NO_WORD`].
    word: u32,
    /// Merge key of the candidate — matches survivors to arcs.
    key: u64,
    /// Full path score of the candidate.
    score: f32,
}

/// A per-session exact lattice, grown one frame at a time by
/// [`Lattice::pend`] (during expansion) + [`Lattice::commit_frame`]
/// (after the prune). Column-oriented so snapshots are trivial.
#[derive(Debug, Clone, Default)]
pub struct Lattice {
    // Arc columns (one entry per candidate ever generated, in
    // generation order — frame-major, then hypothesis order, then
    // expansion order within a hypothesis).
    arc_src: Vec<u32>,
    arc_dst: Vec<u32>,
    arc_word: Vec<u32>,
    arc_score: Vec<f32>,
    arc_frame: Vec<u32>,
    // Node columns (seed nodes first, then per-frame survivor sets in
    // the pruner's deterministic order).
    node_best: Vec<u32>,
    node_score: Vec<f32>,
    /// Backtrack-arena links of the seed hypotheses (words committed
    /// before the lattice started recording); nodes `0..seed_backs.len()`
    /// are seeds.
    seed_backs: Vec<u32>,
    /// Current-frame survivor nodes, aligned with `DecodeState::hyps`.
    frontier: Vec<u32>,
    // Per-frame recording scratch (drained by `commit_frame`; never
    // serialized, excluded from equality).
    pending: Vec<PendingArc>,
    index: KeyMap<u32>,
}

impl PartialEq for Lattice {
    /// Equality over the persistent lattice only — the per-frame
    /// recording scratch (`pending`, `index`) is transient state that
    /// is empty/stale between frames and never serialized.
    fn eq(&self, other: &Self) -> bool {
        self.arc_src == other.arc_src
            && self.arc_dst == other.arc_dst
            && self.arc_word == other.arc_word
            && self.arc_score == other.arc_score
            && self.arc_frame == other.arc_frame
            && self.node_best == other.node_best
            && self.node_score == other.node_score
            && self.seed_backs == other.seed_backs
            && self.frontier == other.frontier
    }
}

/// One path enumerated from the lattice, best-first.
#[derive(Debug, Clone, PartialEq)]
pub struct LatticePath {
    /// Exact completed path score (first-pass: same arithmetic as
    /// [`super::BeamDecoder::finish`]).
    pub score: f32,
    /// Words committed while the lattice was recording, in utterance
    /// order. Words committed before the seed frame are reachable via
    /// [`Lattice::seed_back`] + the decode state's backtrack arena.
    pub words: Vec<u32>,
    /// Seed node the backward walk terminated at.
    pub seed: u32,
}

/// A heap entry in the lazy best-first path enumeration: a total path
/// score, the node the backward walk has reached, and the words
/// collected so far (reverse utterance order). `seq` makes heap order
/// a deterministic total order: ties in score pop in insertion order,
/// which matches the live decoder's first-wins tie-break.
#[derive(Debug, Clone)]
struct Walk {
    score: f32,
    seq: u64,
    cursor: u32,
    words_rev: Vec<u32>,
}

impl PartialEq for Walk {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Walk {}
impl PartialOrd for Walk {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Walk {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap: higher score first; equal scores in insertion order.
        self.score
            .total_cmp(&other.score)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl Lattice {
    /// Start recording from an existing hypothesis set: one seed node
    /// per live hypothesis (no incoming arcs), frontier aligned with
    /// `hyps`. For a fresh utterance this is the single root hypothesis.
    pub(crate) fn seeded(hyps: &[Hyp]) -> Self {
        let mut lat = Lattice::default();
        for (i, h) in hyps.iter().enumerate() {
            lat.node_best.push(NO_ARC);
            lat.node_score.push(h.score);
            lat.seed_backs.push(h.back);
            lat.frontier.push(i as u32);
        }
        lat
    }

    /// Total recorded arcs (== candidates ever generated while
    /// recording).
    pub fn num_arcs(&self) -> usize {
        self.arc_src.len()
    }

    /// Total nodes (seeds + per-frame survivors).
    pub fn num_nodes(&self) -> usize {
        self.node_best.len()
    }

    /// Current frontier size (must equal the live hypothesis count).
    pub fn frontier_len(&self) -> usize {
        self.frontier.len()
    }

    /// Backtrack-arena link of seed node `seed` (words committed before
    /// recording started).
    pub(crate) fn seed_back(&self, seed: u32) -> u32 {
        self.seed_backs[seed as usize]
    }

    /// Record one candidate arc during expansion. `src_hyp` indexes the
    /// *pre-frame* hypothesis set (== the current frontier); `cand` is
    /// the fully scored candidate; `word` is the committed word or
    /// [`NO_WORD`].
    #[inline]
    pub(crate) fn pend(&mut self, src_hyp: usize, word: u32, cand: &Hyp) {
        self.pending.push(PendingArc {
            src: self.frontier[src_hyp],
            word,
            key: cand.state_key(),
            score: cand.score,
        });
    }

    /// Seal one frame: materialize the pending arcs against the frame's
    /// survivor set. Survivors must be the pruner's output *in its
    /// deterministic order*, before they are swapped into
    /// `DecodeState::hyps`. Each survivor becomes a node; each pending
    /// arc resolves its destination by merge key ([`PRUNED`] if the
    /// candidate did not survive); the *first* pending arc whose score
    /// bit-equals the survivor's score becomes the node's winner arc —
    /// the same first-wins rule the pruner's merge uses, which is what
    /// keeps the lattice's best path bit-identical to the live search.
    pub(crate) fn commit_frame(&mut self, frame: u32, survivors: &[Hyp]) {
        let base = self.node_best.len() as u32;
        self.index.clear();
        for (i, h) in survivors.iter().enumerate() {
            self.index.insert(h.state_key(), i as u32);
            self.node_best.push(NO_ARC);
            self.node_score.push(h.score);
        }
        let pending = std::mem::take(&mut self.pending);
        for p in &pending {
            let arc = self.arc_src.len() as u32;
            let dst = match self.index.get(&p.key) {
                Some(&i) => {
                    let ni = (base + i) as usize;
                    if self.node_best[ni] == NO_ARC && p.score == self.node_score[ni] {
                        self.node_best[ni] = arc;
                    }
                    base + i
                }
                None => PRUNED,
            };
            self.arc_src.push(p.src);
            self.arc_dst.push(dst);
            self.arc_word.push(p.word);
            self.arc_score.push(p.score);
            self.arc_frame.push(frame);
        }
        // Hand the (cleared) buffer back so recording stays
        // allocation-free once warmed.
        self.pending = pending;
        self.pending.clear();
        self.frontier.clear();
        self.frontier.extend(base..base + survivors.len() as u32);
        debug_assert!(
            self.node_best[base as usize..].iter().all(|&b| b != NO_ARC),
            "every survivor must have a winning arc"
        );
    }

    /// Exact N-best path enumeration, lazy best-first (the classic
    /// sidetrack decomposition): seed the heap with one walk per final
    /// hypothesis at its completed score, then repeatedly pop the best
    /// walk, branching into every non-winner incoming arc along its
    /// remaining winner chain with the exact score delta
    /// `arc_score − node_score` (≤ 0 by construction). Every lattice
    /// path has a unique sidetrack decomposition, so each is generated
    /// at most once; emitted word sequences are deduplicated keeping
    /// the best-scoring (first-emitted) instance.
    ///
    /// `finals[i]` is the completed (`finish`-arithmetic) score of
    /// frontier hypothesis `i` plus its virtually committed final word,
    /// if any. The top returned path reproduces
    /// [`super::BeamDecoder::finish`] exactly — same score bits, same
    /// words, same tie-break.
    pub(crate) fn nbest_paths(&self, finals: &[(f32, Option<u32>)], n: usize) -> Vec<LatticePath> {
        debug_assert_eq!(finals.len(), self.frontier.len());
        if n == 0 || finals.is_empty() {
            return Vec::new();
        }
        // Non-winner incoming arcs per node (the sidetracks).
        let mut alts: Vec<Vec<u32>> = vec![Vec::new(); self.node_best.len()];
        for (a, &d) in self.arc_dst.iter().enumerate() {
            if d != PRUNED && self.node_best[d as usize] != a as u32 {
                alts[d as usize].push(a as u32);
            }
        }
        let mut heap = BinaryHeap::new();
        let mut seq = 0u64;
        for (i, &(score, word)) in finals.iter().enumerate() {
            heap.push(Walk {
                score,
                seq,
                cursor: self.frontier[i],
                words_rev: word.into_iter().collect(),
            });
            seq += 1;
        }
        let mut out: Vec<LatticePath> = Vec::new();
        let mut seen: BTreeSet<Vec<u32>> = BTreeSet::new();
        // Enumeration budget: timing-variant duplicates of the same
        // word sequence dominate dense lattices, so allow generously
        // more pops than requested paths before giving up.
        let pop_cap = n.saturating_mul(64) + 256;
        let mut pops = 0usize;
        while let Some(walk) = heap.pop() {
            pops += 1;
            let mut words = walk.words_rev;
            let mut node = walk.cursor;
            loop {
                // Branch into each sidetrack entering this node before
                // following the winner backward: the branched walk
                // shares this walk's downstream words and re-enters the
                // lattice at the sidetrack's source.
                for &a in &alts[node as usize] {
                    let mut words_rev = words.clone();
                    if self.arc_word[a as usize] != NO_WORD {
                        words_rev.push(self.arc_word[a as usize]);
                    }
                    heap.push(Walk {
                        score: walk.score
                            + (self.arc_score[a as usize] - self.node_score[node as usize]),
                        seq,
                        cursor: self.arc_src[a as usize],
                        words_rev,
                    });
                    seq += 1;
                }
                let best = self.node_best[node as usize];
                if best == NO_ARC {
                    break; // Seed reached; path complete.
                }
                if self.arc_word[best as usize] != NO_WORD {
                    words.push(self.arc_word[best as usize]);
                }
                node = self.arc_src[best as usize];
            }
            words.reverse();
            if seen.insert(words.clone()) {
                out.push(LatticePath { score: walk.score, words, seed: node });
                if out.len() >= n {
                    break;
                }
            }
            if pops >= pop_cap {
                break;
            }
        }
        out
    }

    /// Write the lattice as `dec.lat.*` tensors (deterministic order;
    /// lossless both ways).
    pub(crate) fn write_tensors(&self, tf: &mut TensorFile) {
        let a = self.arc_src.len();
        tf.push(Tensor::u32("dec.lat.arc.src", vec![a], self.arc_src.clone()));
        tf.push(Tensor::u32("dec.lat.arc.dst", vec![a], self.arc_dst.clone()));
        tf.push(Tensor::u32("dec.lat.arc.word", vec![a], self.arc_word.clone()));
        tf.push(Tensor::f32("dec.lat.arc.score", vec![a], self.arc_score.clone()));
        tf.push(Tensor::u32("dec.lat.arc.frame", vec![a], self.arc_frame.clone()));
        let m = self.node_best.len();
        tf.push(Tensor::u32("dec.lat.node.best", vec![m], self.node_best.clone()));
        tf.push(Tensor::f32("dec.lat.node.score", vec![m], self.node_score.clone()));
        tf.push(Tensor::u32(
            "dec.lat.seed.back",
            vec![self.seed_backs.len()],
            self.seed_backs.clone(),
        ));
        tf.push(Tensor::u32(
            "dec.lat.frontier",
            vec![self.frontier.len()],
            self.frontier.clone(),
        ));
    }

    /// Read a lattice back from `dec.lat.*` tensors, validating every
    /// structural invariant a backward walk relies on: column shapes,
    /// id ranges, strictly-backward arcs (walks terminate), winner-arc
    /// consistency, and frontier alignment with the hypothesis set
    /// (`hyps_len`) and seed links with the backtrack arena
    /// (`arena_len`).
    pub(crate) fn read_tensors(tf: &TensorFile, hyps_len: usize, arena_len: usize) -> Result<Self> {
        let arc_src = tf.require("dec.lat.arc.src")?.as_u32()?.to_vec();
        let arc_dst = tf.require("dec.lat.arc.dst")?.as_u32()?.to_vec();
        let arc_word = tf.require("dec.lat.arc.word")?.as_u32()?.to_vec();
        let arc_score = tf.require("dec.lat.arc.score")?.as_f32()?.to_vec();
        let arc_frame = tf.require("dec.lat.arc.frame")?.as_u32()?.to_vec();
        let a = arc_src.len();
        ensure!(
            arc_dst.len() == a && arc_word.len() == a && arc_score.len() == a
                && arc_frame.len() == a,
            "lattice snapshot: ragged arc columns"
        );
        let node_best = tf.require("dec.lat.node.best")?.as_u32()?.to_vec();
        let node_score = tf.require("dec.lat.node.score")?.as_f32()?.to_vec();
        let m = node_best.len();
        ensure!(node_score.len() == m, "lattice snapshot: ragged node columns");
        let seed_backs = tf.require("dec.lat.seed.back")?.as_u32()?.to_vec();
        ensure!(
            seed_backs.len() <= m,
            "lattice snapshot: more seeds than nodes"
        );
        let frontier = tf.require("dec.lat.frontier")?.as_u32()?.to_vec();
        ensure!(
            frontier.len() == hyps_len,
            "lattice snapshot: frontier covers {} nodes, state has {hyps_len} hypotheses",
            frontier.len()
        );
        for (i, &b) in seed_backs.iter().enumerate() {
            ensure!(
                b == NO_BACK || (b as usize) < arena_len,
                "lattice snapshot: seed {i} backlink {b} outside arena"
            );
        }
        for (i, &f) in frontier.iter().enumerate() {
            ensure!(
                (f as usize) < m,
                "lattice snapshot: frontier {i} node {f} out of range"
            );
        }
        for i in 0..a {
            ensure!(
                (arc_src[i] as usize) < m,
                "lattice snapshot: arc {i} source {} out of range",
                arc_src[i]
            );
            ensure!(
                arc_dst[i] == PRUNED
                    || ((arc_dst[i] as usize) < m && arc_src[i] < arc_dst[i]),
                "lattice snapshot: arc {i} destination {} not strictly after source",
                arc_dst[i]
            );
        }
        for (i, &b) in node_best.iter().enumerate() {
            if i < seed_backs.len() {
                ensure!(
                    b == NO_ARC,
                    "lattice snapshot: seed node {i} has a winner arc"
                );
            } else {
                ensure!(
                    b != NO_ARC && (b as usize) < a && arc_dst[b as usize] == i as u32,
                    "lattice snapshot: node {i} winner arc {b} inconsistent"
                );
            }
        }
        Ok(Lattice {
            arc_src,
            arc_dst,
            arc_word,
            arc_score,
            arc_frame,
            node_best,
            node_score,
            seed_backs,
            frontier,
            pending: Vec::new(),
            index: KeyMap::default(),
        })
    }

    /// Range-check recorded word ids against the lexicon (the lattice
    /// leg of [`super::DecoderSnapshot::validate_bounds`]).
    pub(crate) fn validate_words(&self, lexicon_words: usize) -> Result<()> {
        for (i, &w) in self.arc_word.iter().enumerate() {
            ensure!(
                w == NO_WORD || (w as usize) < lexicon_words,
                "lattice snapshot: arc {i} word {w} >= {lexicon_words}"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::LmState;

    fn hyp(score: f32, node: u32) -> Hyp {
        Hyp { score, node, lm: LmState(0), last_token: 0, back: NO_BACK }
    }

    /// Hand-drive two frames: seed → {A, B} → {C}, with a sidetrack
    /// into C from B and one pruned candidate per frame.
    fn two_frame_lattice() -> Lattice {
        let seed = [hyp(0.0, 0)];
        let mut lat = Lattice::seeded(&seed);
        // Frame 1: candidates A(-1, survives), B(-2, survives),
        // X(-9, pruned).
        let (a, b, x) = (hyp(-1.0, 1), hyp(-2.0, 2), hyp(-9.0, 3));
        lat.pend(0, NO_WORD, &a);
        lat.pend(0, 7, &b);
        lat.pend(0, NO_WORD, &x);
        lat.commit_frame(1, &[a, b]);
        // Frame 2: A→C wins (-3), B→C sidetrack (-4, same state key),
        // B→Y pruned.
        let c_from_a = hyp(-3.0, 4);
        let c_from_b = Hyp { score: -4.0, ..c_from_a };
        let y = hyp(-8.0, 5);
        lat.pend(0, 9, &c_from_a);
        lat.pend(1, NO_WORD, &c_from_b);
        lat.pend(1, NO_WORD, &y);
        lat.commit_frame(2, &[c_from_a]);
        lat
    }

    #[test]
    fn commit_resolves_winners_and_pruned_arcs() {
        let lat = two_frame_lattice();
        assert_eq!(lat.num_nodes(), 4); // seed + {A,B} + {C}
        assert_eq!(lat.num_arcs(), 6);
        assert_eq!(lat.frontier_len(), 1);
        // Node ids: 0 seed, 1 = A, 2 = B, 3 = C.
        assert_eq!(lat.node_best[1], 0); // A's winner is arc 0
        assert_eq!(lat.node_best[2], 1); // B's winner is arc 1
        assert_eq!(lat.node_best[3], 3); // C's winner is A→C (arc 3)
        assert_eq!(lat.arc_dst[2], PRUNED);
        assert_eq!(lat.arc_dst[5], PRUNED);
        assert_eq!(lat.arc_dst[4], 3); // sidetrack B→C survives as an arc
    }

    #[test]
    fn nbest_enumerates_exact_scores_best_first() {
        let lat = two_frame_lattice();
        // Final completion adds nothing: finish score == node score.
        let paths = lat.nbest_paths(&[(-3.0, None)], 4);
        // Best path: seed→A→C, words [9]. Second: seed→B→C via the
        // sidetrack, delta = −4 − (−3) = −1 → score −4, words [7].
        assert_eq!(paths.len(), 2);
        assert_eq!(paths[0].score, -3.0);
        assert_eq!(paths[0].words, vec![9]);
        assert_eq!(paths[0].seed, 0);
        assert_eq!(paths[1].score, -4.0);
        assert_eq!(paths[1].words, vec![7]);
    }

    #[test]
    fn nbest_includes_virtual_final_word() {
        let lat = two_frame_lattice();
        let paths = lat.nbest_paths(&[(-3.5, Some(11))], 2);
        assert_eq!(paths[0].words, vec![9, 11]);
        assert_eq!(paths[0].score, -3.5);
    }

    #[test]
    fn tensor_round_trip_is_lossless() {
        let lat = two_frame_lattice();
        let mut tf = TensorFile::new();
        lat.write_tensors(&mut tf);
        let tf = TensorFile::from_bytes(&tf.to_bytes().unwrap()).unwrap();
        let back = Lattice::read_tensors(&tf, lat.frontier_len(), 0).unwrap();
        assert_eq!(lat, back);
    }

    #[test]
    fn read_rejects_structural_corruption() {
        let lat = two_frame_lattice();
        let mut tf = TensorFile::new();
        lat.write_tensors(&mut tf);
        // Frontier / hypothesis mismatch.
        assert!(Lattice::read_tensors(&tf, 2, 0).is_err());
        // Forward-pointing arc (would make backward walks loop).
        let mut bad = TensorFile::new();
        for t in &tf.tensors {
            if t.name == "dec.lat.arc.src" {
                let mut src = lat.arc_src.clone();
                src[3] = 3; // arc 3 is C's winner; C is node 3
                bad.push(Tensor::u32("dec.lat.arc.src", t.dims.clone(), src));
            } else {
                bad.push(t.clone());
            }
        }
        assert!(Lattice::read_tensors(&bad, 1, 0).is_err());
        // Winner arc pointing at the wrong node.
        let mut bad = TensorFile::new();
        for t in &tf.tensors {
            if t.name == "dec.lat.node.best" {
                let mut best = lat.node_best.clone();
                best[3] = 0; // arc 0 lands on node 1, not node 3
                bad.push(Tensor::u32("dec.lat.node.best", t.dims.clone(), best));
            } else {
                bad.push(t.clone());
            }
        }
        assert!(Lattice::read_tensors(&bad, 1, 0).is_err());
        // Seed backlink outside the arena.
        assert!(Lattice::read_tensors(&tf, 1, 0).is_ok());
        let mut bad = TensorFile::new();
        for t in &tf.tensors {
            if t.name == "dec.lat.seed.back" {
                bad.push(Tensor::u32("dec.lat.seed.back", t.dims.clone(), vec![4]));
            } else {
                bad.push(t.clone());
            }
        }
        assert!(Lattice::read_tensors(&bad, 1, 0).is_err());
    }

    #[test]
    fn word_bounds_are_validated() {
        let lat = two_frame_lattice();
        assert!(lat.validate_words(10).is_ok());
        assert!(lat.validate_words(8).is_err()); // arc word 9 out of range
    }
}
