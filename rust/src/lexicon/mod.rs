//! Lexicon: acoustic-token inventory and the pronunciation trie the
//! decoder walks (§2.3.2: "the lexicon can be efficiently represented
//! with a tree structure of phonetic units; the path from the root to a
//! leaf contains a sequence of phonetic units that form a complete
//! word").

use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// The acoustic-token inventory. Token 0 is the CTC blank; the rest are
/// the phonetic units the acoustic model scores.
#[derive(Debug, Clone, PartialEq)]
pub struct TokenSet {
    names: Vec<String>,
}

pub const BLANK: u32 = 0;

impl TokenSet {
    /// `names` excludes the blank; token ids are `1 + index`.
    pub fn new(names: Vec<String>) -> Self {
        let mut all = vec!["<blank>".to_string()];
        all.extend(names);
        TokenSet { names: all }
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn name(&self, id: u32) -> &str {
        &self.names[id as usize]
    }

    pub fn id(&self, name: &str) -> Option<u32> {
        self.names.iter().position(|n| n == name).map(|i| i as u32)
    }
}

/// One node of the lexicon trie.
#[derive(Debug, Clone, Default)]
pub struct TrieNode {
    /// Outgoing links: token id → child node index. BTreeMap keeps
    /// expansion order deterministic.
    pub children: BTreeMap<u32, u32>,
    /// Word completed at this node, if any.
    pub word: Option<u32>,
    /// Depth (tokens from root) — used by the hypothesis-expansion cost
    /// model and for invariant checks.
    pub depth: u32,
}

/// The lexicon: a token inventory, a word list, and the trie.
#[derive(Debug, Clone)]
pub struct Lexicon {
    pub tokens: TokenSet,
    pub words: Vec<String>,
    nodes: Vec<TrieNode>,
}

pub const ROOT: u32 = 0;

impl Lexicon {
    /// Build from `(word, pronunciation)` pairs.
    pub fn build(tokens: TokenSet, entries: &[(String, Vec<u32>)]) -> Result<Self> {
        let mut lex = Lexicon {
            tokens,
            words: Vec::new(),
            nodes: vec![TrieNode::default()],
        };
        for (word, pron) in entries {
            ensure!(!pron.is_empty(), "word '{word}' has empty pronunciation");
            for &t in pron {
                ensure!(
                    t != BLANK && (t as usize) < lex.tokens.len(),
                    "word '{word}': token {t} out of range"
                );
            }
            let word_id = lex.words.len() as u32;
            let mut node = ROOT;
            for &t in pron {
                node = match lex.nodes[node as usize].children.get(&t) {
                    Some(&child) => child,
                    None => {
                        let child = lex.nodes.len() as u32;
                        let depth = lex.nodes[node as usize].depth + 1;
                        lex.nodes.push(TrieNode { depth, ..Default::default() });
                        lex.nodes[node as usize].children.insert(t, child);
                        child
                    }
                };
            }
            if let Some(prev) = lex.nodes[node as usize].word {
                bail!(
                    "homophone: '{}' and '{}' share a pronunciation",
                    lex.words[prev as usize],
                    word
                );
            }
            lex.nodes[node as usize].word = Some(word_id);
            lex.words.push(word.clone());
        }
        Ok(lex)
    }

    pub fn node(&self, id: u32) -> &TrieNode {
        &self.nodes[id as usize]
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn word_name(&self, id: u32) -> &str {
        &self.words[id as usize]
    }

    /// Estimated bytes of the trie as laid out in ASRPU external memory
    /// (node header + links) — feeds the simulator's hypothesis-expansion
    /// memory model.
    pub fn graph_bytes(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| 12 + 8 * n.children.len())
            .sum()
    }

    /// Parse the `lexicon.txt` artifact format: `word<TAB>tok tok tok`,
    /// first line `#tokens: a b c ...` (names excluding blank).
    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let header = lines.next().context("empty lexicon file")?;
        let names = header
            .strip_prefix("#tokens:")
            .context("lexicon missing '#tokens:' header")?
            .split_whitespace()
            .map(str::to_string)
            .collect();
        let tokens = TokenSet::new(names);
        let mut entries = Vec::new();
        for (lno, line) in lines.enumerate() {
            if line.trim().is_empty() || line.starts_with('#') {
                continue;
            }
            let (word, pron) = line
                .split_once('\t')
                .with_context(|| format!("lexicon line {}: missing tab", lno + 2))?;
            let ids = pron
                .split_whitespace()
                .map(|t| {
                    tokens
                        .id(t)
                        .with_context(|| format!("lexicon line {}: unknown token '{t}'", lno + 2))
                })
                .collect::<Result<Vec<u32>>>()?;
            entries.push((word.to_string(), ids));
        }
        Self::build(tokens, &entries)
    }

    /// Serialize in the artifact format.
    pub fn serialize(&self) -> String {
        let mut out = String::from("#tokens:");
        for i in 1..self.tokens.len() {
            out.push(' ');
            out.push_str(self.tokens.name(i as u32));
        }
        out.push('\n');
        // Reconstruct pronunciations by DFS.
        let mut prons: Vec<(u32, Vec<u32>)> = Vec::new();
        let mut stack: Vec<(u32, Vec<u32>)> = vec![(ROOT, Vec::new())];
        while let Some((node, path)) = stack.pop() {
            let n = self.node(node);
            if let Some(w) = n.word {
                prons.push((w, path.clone()));
            }
            for (&tok, &child) in n.children.iter().rev() {
                let mut p = path.clone();
                p.push(tok);
                stack.push((child, p));
            }
        }
        prons.sort_by_key(|(w, _)| *w);
        for (w, path) in prons {
            out.push_str(&self.words[w as usize]);
            out.push('\t');
            let toks: Vec<&str> = path.iter().map(|&t| self.tokens.name(t)).collect();
            out.push_str(&toks.join(" "));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Lexicon {
        let tokens = TokenSet::new(vec!["a".into(), "b".into(), "c".into()]);
        let a = tokens.id("a").unwrap();
        let b = tokens.id("b").unwrap();
        let c = tokens.id("c").unwrap();
        Lexicon::build(
            tokens,
            &[
                ("ab".into(), vec![a, b]),
                ("abc".into(), vec![a, b, c]),
                ("ba".into(), vec![b, a]),
            ],
        )
        .unwrap()
    }

    #[test]
    fn trie_shares_prefixes() {
        let lex = toy();
        // root + a + ab + abc + b + ba = 6 nodes.
        assert_eq!(lex.num_nodes(), 6);
        let a = lex.tokens.id("a").unwrap();
        let b = lex.tokens.id("b").unwrap();
        let n_a = *lex.node(ROOT).children.get(&a).unwrap();
        let n_ab = *lex.node(n_a).children.get(&b).unwrap();
        assert_eq!(lex.node(n_ab).word, Some(0));
        assert_eq!(lex.node(n_ab).depth, 2);
        // 'abc' extends the same path.
        assert_eq!(lex.node(n_ab).children.len(), 1);
    }

    #[test]
    fn rejects_homophones_and_bad_tokens() {
        let tokens = TokenSet::new(vec!["a".into()]);
        let a = tokens.id("a").unwrap();
        assert!(Lexicon::build(
            tokens.clone(),
            &[("x".into(), vec![a]), ("y".into(), vec![a])]
        )
        .is_err());
        assert!(Lexicon::build(tokens.clone(), &[("x".into(), vec![BLANK])]).is_err());
        assert!(Lexicon::build(tokens.clone(), &[("x".into(), vec![99])]).is_err());
        assert!(Lexicon::build(tokens, &[("x".into(), vec![])]).is_err());
    }

    #[test]
    fn parse_serialize_roundtrip() {
        let lex = toy();
        let text = lex.serialize();
        let re = Lexicon::parse(&text).unwrap();
        assert_eq!(re.words, lex.words);
        assert_eq!(re.num_nodes(), lex.num_nodes());
        assert_eq!(re.serialize(), text);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Lexicon::parse("").is_err());
        assert!(Lexicon::parse("no header\n").is_err());
        assert!(Lexicon::parse("#tokens: a\nword without tab\n").is_err());
        assert!(Lexicon::parse("#tokens: a\nw\tz\n").is_err());
    }

    #[test]
    fn graph_bytes_scales_with_nodes() {
        let lex = toy();
        assert!(lex.graph_bytes() >= lex.num_nodes() * 12);
    }
}
