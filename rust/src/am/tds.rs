//! Native TDS acoustic model with exact streaming execution.
//!
//! Mirrors `python/compile/model.py` layer for layer (same weight names,
//! same causal-conv semantics), so the engine can run either through the
//! AOT-compiled XLA artifact ([`crate::runtime`]) or natively here, with
//! tests asserting the two paths agree. The streaming step consumes the
//! feature frames of one decoding step and carries conv history across
//! steps — reproducing the offline full-sequence output exactly (causal
//! convolutions, §Hardware-Adaptation in DESIGN.md).

use crate::config::{Layer, ModelConfig};
use crate::util::rng::Rng;
use crate::util::tensor_io::{Tensor, TensorFile};
use anyhow::{ensure, Context, Result};

use super::ops;

const LN_EPS: f32 = 1e-5;

/// Weights for one layer, resolved from the tensor file.
#[derive(Debug, Clone)]
enum LayerWeights {
    Conv { w: Vec<f32>, b: Vec<f32> },
    Fc { w: Vec<f32>, b: Vec<f32> },
    LayerNorm { g: Vec<f32>, b: Vec<f32> },
}

/// The model: topology + weights.
#[derive(Debug, Clone)]
pub struct TdsModel {
    pub cfg: ModelConfig,
    layers: Vec<(Layer, LayerWeights)>,
}

/// Streaming state: per conv layer, the last `kw-1` input timesteps.
#[derive(Debug, Clone)]
pub struct TdsState {
    conv_hist: Vec<Vec<Vec<f32>>>,
}

impl TdsModel {
    /// Load weights (naming convention: `{layer}.w`/`{layer}.b` for conv
    /// and fc, `{layer}.g`/`{layer}.b` for layer norm).
    pub fn from_weights(cfg: ModelConfig, weights: &TensorFile) -> Result<Self> {
        let mut layers = Vec::new();
        for layer in cfg.layers() {
            let name = layer.name().to_string();
            let lw = match &layer {
                Layer::Conv { in_ch, out_ch, kw, .. } => {
                    let w = weights.require(&format!("{name}.w"))?;
                    ensure!(
                        w.dims == vec![*out_ch, *in_ch, *kw],
                        "conv '{name}': dims {:?}, expected [{out_ch},{in_ch},{kw}]",
                        w.dims
                    );
                    let b = weights.require(&format!("{name}.b"))?;
                    ensure!(b.dims == vec![*out_ch], "conv '{name}' bias dims {:?}", b.dims);
                    LayerWeights::Conv {
                        w: w.as_f32()?.to_vec(),
                        b: b.as_f32()?.to_vec(),
                    }
                }
                Layer::Fc { in_dim, out_dim, .. } => {
                    let w = weights.require(&format!("{name}.w"))?;
                    ensure!(
                        w.dims == vec![*out_dim, *in_dim],
                        "fc '{name}': dims {:?}, expected [{out_dim},{in_dim}]",
                        w.dims
                    );
                    let b = weights.require(&format!("{name}.b"))?;
                    LayerWeights::Fc {
                        w: w.as_f32()?.to_vec(),
                        b: b.as_f32()?.to_vec(),
                    }
                }
                Layer::LayerNorm { dim, .. } => {
                    let g = weights.require(&format!("{name}.g"))?;
                    ensure!(g.dims == vec![*dim], "ln '{name}' gain dims {:?}", g.dims);
                    let b = weights.require(&format!("{name}.b"))?;
                    LayerWeights::LayerNorm {
                        g: g.as_f32()?.to_vec(),
                        b: b.as_f32()?.to_vec(),
                    }
                }
            };
            layers.push((layer, lw));
        }
        Ok(TdsModel { cfg, layers })
    }

    /// Load from `artifacts/weights.bin`.
    pub fn from_artifacts(cfg: ModelConfig, dir: &std::path::Path) -> Result<Self> {
        let tf = TensorFile::load(&dir.join("weights.bin"))
            .context("loading weights.bin (run `make artifacts` first)")?;
        Self::from_weights(cfg, &tf)
    }

    /// Random (He-initialized) weights — used by benches and simulator
    /// workloads where the numerics don't matter, only the shapes.
    pub fn random(cfg: ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut tf = TensorFile::new();
        for layer in cfg.layers() {
            let name = layer.name().to_string();
            match &layer {
                Layer::Conv { in_ch, out_ch, kw, .. } => {
                    let fan_in = (in_ch * kw) as f32;
                    let std = (2.0 / fan_in).sqrt();
                    let n = out_ch * in_ch * kw;
                    tf.push(Tensor::f32(
                        format!("{name}.w"),
                        vec![*out_ch, *in_ch, *kw],
                        (0..n).map(|_| rng.normal() * std).collect(),
                    ));
                    tf.push(Tensor::f32(format!("{name}.b"), vec![*out_ch], vec![0.0; *out_ch]));
                }
                Layer::Fc { in_dim, out_dim, .. } => {
                    let std = (2.0 / *in_dim as f32).sqrt();
                    let n = in_dim * out_dim;
                    tf.push(Tensor::f32(
                        format!("{name}.w"),
                        vec![*out_dim, *in_dim],
                        (0..n).map(|_| rng.normal() * std).collect(),
                    ));
                    tf.push(Tensor::f32(format!("{name}.b"), vec![*out_dim], vec![0.0; *out_dim]));
                }
                Layer::LayerNorm { dim, .. } => {
                    tf.push(Tensor::f32(format!("{name}.g"), vec![*dim], vec![1.0; *dim]));
                    tf.push(Tensor::f32(format!("{name}.b"), vec![*dim], vec![0.0; *dim]));
                }
            }
        }
        Self::from_weights(cfg, &tf).expect("random weights must validate")
    }

    /// Fresh streaming state (conv histories zeroed — equivalent to the
    /// left zero-padding of the offline causal model).
    pub fn state(&self) -> TdsState {
        let mut conv_hist = Vec::new();
        for (layer, _) in &self.layers {
            if let Layer::Conv { in_ch, kw, w, .. } = layer {
                conv_hist.push(vec![vec![0.0f32; in_ch * w]; kw - 1]);
            }
        }
        TdsState { conv_hist }
    }

    /// Process one decoding step: `feats` is `frames × n_mels` row-major;
    /// returns `vectors_per_step × tokens` log-probabilities.
    pub fn step(&self, state: &mut TdsState, feats: &[f32]) -> Vec<f32> {
        let n_mels = self.cfg.n_mels;
        assert_eq!(feats.len() % n_mels, 0, "feats not a whole number of frames");
        let n_frames = feats.len() / n_mels;
        // Current activations: one Vec per timestep.
        let mut acts: Vec<Vec<f32>> = (0..n_frames)
            .map(|f| feats[f * n_mels..(f + 1) * n_mels].to_vec())
            .collect();
        let mut conv_idx = 0;
        for (layer, lw) in &self.layers {
            match (layer, lw) {
                (
                    Layer::Conv { in_ch, out_ch, kw, stride, w, residual, .. },
                    LayerWeights::Conv { w: cw, b: cb },
                ) => {
                    let hist = &mut state.conv_hist[conv_idx];
                    conv_idx += 1;
                    // ext = hist ++ acts, length (kw-1) + T.
                    let mut ext: Vec<&[f32]> = Vec::with_capacity(kw - 1 + acts.len());
                    for h in hist.iter() {
                        ext.push(h);
                    }
                    for a in acts.iter() {
                        ext.push(a);
                    }
                    assert_eq!(
                        acts.len() % stride,
                        0,
                        "chunk length {} not divisible by stride {stride}",
                        acts.len()
                    );
                    let t_out = acts.len() / stride;
                    let mut outs: Vec<Vec<f32>> = Vec::with_capacity(t_out);
                    let mut buf = Vec::new();
                    for o in 0..t_out {
                        let win = &ext[o * stride..o * stride + kw];
                        ops::conv_step(cw, cb, win, *in_ch, *out_ch, *kw, *w, &mut buf);
                        ops::relu_inplace(&mut buf);
                        if *residual {
                            // Residual aligns with the newest input of the
                            // window (stride 1 inside TDS blocks).
                            debug_assert_eq!(*stride, 1);
                            for (v, x) in buf.iter_mut().zip(win[kw - 1].iter()) {
                                *v += x;
                            }
                        }
                        outs.push(buf.clone());
                    }
                    // Update history: last kw-1 ext entries.
                    let total = ext.len();
                    let new_hist: Vec<Vec<f32>> =
                        ext[total - (kw - 1)..].iter().map(|s| s.to_vec()).collect();
                    *hist = new_hist;
                    acts = outs;
                }
                (
                    Layer::Fc { residual, relu, .. },
                    LayerWeights::Fc { w: fw, b: fb },
                ) => {
                    let mut buf = Vec::new();
                    for t in acts.iter_mut() {
                        ops::fc(fw, fb, t, &mut buf);
                        if *relu {
                            ops::relu_inplace(&mut buf);
                        }
                        if *residual {
                            for (v, x) in buf.iter_mut().zip(t.iter()) {
                                *v += x;
                            }
                        }
                        std::mem::swap(t, &mut buf);
                    }
                }
                (Layer::LayerNorm { .. }, LayerWeights::LayerNorm { g, b }) => {
                    for t in acts.iter_mut() {
                        ops::layer_norm(g, b, t, LN_EPS);
                    }
                }
                _ => unreachable!("layer/weights mismatch"),
            }
        }
        // Log-softmax over tokens, flatten.
        let tokens = self.cfg.tokens;
        let mut out = Vec::with_capacity(acts.len() * tokens);
        for t in acts.iter_mut() {
            ops::log_softmax(t);
            out.extend_from_slice(t);
        }
        out
    }

    /// Lane-batched streaming step: advance `B = states.len()` independent
    /// streams through one fused forward pass.
    ///
    /// `feats` is lane-major `[B × (frames × n_mels)]` (lane `l`'s chunk at
    /// `feats[l*F .. (l+1)*F]`); the return value is lane-major
    /// `[B × (vectors_per_step × tokens)]`. Internally activations are kept
    /// as per-timestep `[B × D]` blocks so each weight row is streamed once
    /// for all lanes (see `am::ops`). Per-lane results are **bit-identical**
    /// to calling [`Self::step`] on each lane separately — the batched ops
    /// replay the scalar op order exactly — which is what lets the serving
    /// path batch opportunistically without changing transcripts.
    pub fn step_batch(&self, states: &mut [&mut TdsState], feats: &[f32]) -> Vec<f32> {
        let batch = states.len();
        assert!(batch > 0, "step_batch needs at least one lane");
        let n_mels = self.cfg.n_mels;
        assert_eq!(
            feats.len() % (batch * n_mels),
            0,
            "feats not whole frames across {batch} lanes"
        );
        let n_frames = feats.len() / (batch * n_mels);
        let lane_feats = n_frames * n_mels;
        // Per-timestep activations as [B × D] lane-major blocks.
        let mut acts: Vec<Vec<f32>> = (0..n_frames)
            .map(|f| {
                let mut block = Vec::with_capacity(batch * n_mels);
                for lane in 0..batch {
                    let base = lane * lane_feats + f * n_mels;
                    block.extend_from_slice(&feats[base..base + n_mels]);
                }
                block
            })
            .collect();
        let mut conv_idx = 0;
        for (layer, lw) in &self.layers {
            match (layer, lw) {
                (
                    Layer::Conv { in_ch, out_ch, kw, stride, w, residual, .. },
                    LayerWeights::Conv { w: cw, b: cb },
                ) => {
                    let d_in = in_ch * w;
                    // Gather each lane's conv history into [B × D] blocks.
                    let hist_blocks: Vec<Vec<f32>> = (0..kw - 1)
                        .map(|h| {
                            let mut block = Vec::with_capacity(batch * d_in);
                            for st in states.iter() {
                                block.extend_from_slice(&st.conv_hist[conv_idx][h]);
                            }
                            block
                        })
                        .collect();
                    let mut ext: Vec<&[f32]> = Vec::with_capacity(kw - 1 + acts.len());
                    for h in hist_blocks.iter() {
                        ext.push(h);
                    }
                    for a in acts.iter() {
                        ext.push(a);
                    }
                    assert_eq!(
                        acts.len() % stride,
                        0,
                        "chunk length {} not divisible by stride {stride}",
                        acts.len()
                    );
                    let t_out = acts.len() / stride;
                    let mut outs: Vec<Vec<f32>> = Vec::with_capacity(t_out);
                    let mut buf = Vec::new();
                    for o in 0..t_out {
                        let win = &ext[o * stride..o * stride + kw];
                        ops::conv_step_batch(
                            cw, cb, win, batch, *in_ch, *out_ch, *kw, *w, &mut buf,
                        );
                        ops::relu_inplace(&mut buf);
                        if *residual {
                            debug_assert_eq!(*stride, 1);
                            for (v, x) in buf.iter_mut().zip(win[kw - 1].iter()) {
                                *v += x;
                            }
                        }
                        outs.push(buf.clone());
                    }
                    // Scatter the last kw-1 ext blocks back into per-lane
                    // histories.
                    let total = ext.len();
                    let tail: Vec<Vec<f32>> =
                        ext[total - (kw - 1)..].iter().map(|s| s.to_vec()).collect();
                    drop(ext);
                    for (lane, st) in states.iter_mut().enumerate() {
                        let hist = &mut st.conv_hist[conv_idx];
                        for (h, block) in tail.iter().enumerate() {
                            hist[h].clear();
                            hist[h].extend_from_slice(&block[lane * d_in..(lane + 1) * d_in]);
                        }
                    }
                    conv_idx += 1;
                    acts = outs;
                }
                (
                    Layer::Fc { residual, relu, .. },
                    LayerWeights::Fc { w: fw, b: fb },
                ) => {
                    let mut buf = Vec::new();
                    for t in acts.iter_mut() {
                        ops::fc_batch(fw, fb, t, batch, &mut buf);
                        if *relu {
                            ops::relu_inplace(&mut buf);
                        }
                        if *residual {
                            for (v, x) in buf.iter_mut().zip(t.iter()) {
                                *v += x;
                            }
                        }
                        std::mem::swap(t, &mut buf);
                    }
                }
                (Layer::LayerNorm { .. }, LayerWeights::LayerNorm { g, b }) => {
                    for t in acts.iter_mut() {
                        ops::layer_norm_batch(g, b, t, batch, LN_EPS);
                    }
                }
                _ => unreachable!("layer/weights mismatch"),
            }
        }
        // Log-softmax over tokens, de-interleave to lane-major output.
        let tokens = self.cfg.tokens;
        let vps = acts.len();
        let mut out = vec![0.0f32; batch * vps * tokens];
        for (t_idx, t) in acts.iter_mut().enumerate() {
            ops::log_softmax_batch(t, batch);
            for lane in 0..batch {
                let src = &t[lane * tokens..(lane + 1) * tokens];
                let dst = (lane * vps + t_idx) * tokens;
                out[dst..dst + tokens].copy_from_slice(src);
            }
        }
        out
    }

    /// Offline full-sequence forward: chunk the features into decoding
    /// steps and stream through a fresh state (drops a ragged tail).
    pub fn forward_full(&self, feats: &[f32]) -> Vec<f32> {
        let n_mels = self.cfg.n_mels;
        let fps = self.cfg.frames_per_step();
        let n_frames = feats.len() / n_mels;
        let mut state = self.state();
        let mut out = Vec::new();
        let mut f = 0;
        while f + fps <= n_frames {
            out.extend(self.step(&mut state, &feats[f * n_mels..(f + fps) * n_mels]));
            f += fps;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn tiny() -> TdsModel {
        TdsModel::random(ModelConfig::tiny_tds(), 42)
    }

    #[test]
    fn step_output_shape() {
        let m = tiny();
        let mut st = m.state();
        let feats = vec![0.1f32; m.cfg.frames_per_step() * m.cfg.n_mels];
        let out = m.step(&mut st, &feats);
        assert_eq!(out.len(), m.cfg.vectors_per_step() * m.cfg.tokens);
    }

    #[test]
    fn outputs_are_log_probs() {
        let m = tiny();
        let mut st = m.state();
        let feats = vec![0.3f32; m.cfg.frames_per_step() * m.cfg.n_mels];
        let out = m.step(&mut st, &feats);
        for v in out.chunks(m.cfg.tokens) {
            let total: f32 = v.iter().map(|x| x.exp()).sum();
            assert!((total - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn streaming_equals_offline() {
        // Two chunks through one state == both chunks through forward_full.
        let m = tiny();
        let n = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(7);
        let feats: Vec<f32> = (0..3 * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let full = m.forward_full(&feats);
        let mut st = m.state();
        let mut streamed = Vec::new();
        for c in 0..3 {
            streamed.extend(m.step(&mut st, &feats[c * n..(c + 1) * n]));
        }
        assert_eq!(full.len(), streamed.len());
        for (a, b) in full.iter().zip(&streamed) {
            assert!((a - b).abs() < 1e-5, "streaming != offline: {a} vs {b}");
        }
    }

    #[test]
    fn state_carries_context() {
        // Same second chunk gives different output if the first chunk
        // differed — i.e. conv history actually crosses step boundaries.
        let m = tiny();
        let n = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(9);
        let a: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let c: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut st1 = m.state();
        m.step(&mut st1, &a);
        let out1 = m.step(&mut st1, &c);
        let mut st2 = m.state();
        m.step(&mut st2, &b);
        let out2 = m.step(&mut st2, &c);
        let diff: f32 = out1.iter().zip(&out2).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-3, "conv state had no effect");
    }

    #[test]
    fn step_batch_is_bit_identical_to_scalar_lanes() {
        // Three lanes with different histories and inputs, stepped twice:
        // the fused pass must reproduce each scalar lane exactly (==, not
        // approx — the batched ops replay the scalar op order).
        let m = tiny();
        let batch = 3;
        let f = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(21);
        let mut scalar_states: Vec<TdsState> = (0..batch).map(|_| m.state()).collect();
        let mut batch_states: Vec<TdsState> = (0..batch).map(|_| m.state()).collect();
        for _ in 0..2 {
            let feats: Vec<f32> = (0..batch * f).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut refs: Vec<&mut TdsState> = batch_states.iter_mut().collect();
            let fused = m.step_batch(&mut refs, &feats);
            let lane_out = fused.len() / batch;
            for (lane, st) in scalar_states.iter_mut().enumerate() {
                let out = m.step(st, &feats[lane * f..(lane + 1) * f]);
                assert_eq!(out.len(), lane_out);
                assert_eq!(
                    out,
                    fused[lane * lane_out..(lane + 1) * lane_out],
                    "lane {lane} diverged"
                );
            }
        }
        // Streaming states must match exactly too.
        for (a, b) in scalar_states.iter().zip(&batch_states) {
            assert_eq!(a.conv_hist, b.conv_hist);
        }
    }

    #[test]
    fn step_batch_single_lane_equals_step() {
        let m = tiny();
        let f = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(23);
        let feats: Vec<f32> = (0..f).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut s1 = m.state();
        let out1 = m.step(&mut s1, &feats);
        let mut s2 = m.state();
        let mut refs = vec![&mut s2];
        let out2 = m.step_batch(&mut refs, &feats);
        assert_eq!(out1, out2);
    }

    #[test]
    fn from_weights_rejects_bad_dims() {
        let cfg = ModelConfig::tiny_tds();
        let good = TdsModel::random(cfg.clone(), 1);
        // Rebuild the tensor file but corrupt one tensor's dims.
        let mut tf = TensorFile::new();
        for (layer, _) in &good.layers {
            let name = layer.name();
            match layer {
                Layer::Conv { in_ch, out_ch, kw, .. } => {
                    tf.push(Tensor::f32(
                        format!("{name}.w"),
                        vec![*out_ch, *in_ch, *kw + 1], // wrong kw
                        vec![0.0; out_ch * in_ch * (kw + 1)],
                    ));
                    tf.push(Tensor::f32(format!("{name}.b"), vec![*out_ch], vec![0.0; *out_ch]));
                }
                _ => break,
            }
        }
        assert!(TdsModel::from_weights(cfg, &tf).is_err());
    }

    #[test]
    fn paper_scale_shapes_run() {
        // One (expensive-ish) smoke test that the 79-layer paper topology
        // actually executes. Random weights; just shape/finiteness.
        let cfg = ModelConfig::paper_tds();
        let cfg = ModelConfig { quantized: false, ..cfg };
        let m = TdsModel::random(cfg, 3);
        let mut st = m.state();
        let feats = vec![0.05f32; m.cfg.frames_per_step() * m.cfg.n_mels];
        let out = m.step(&mut st, &feats);
        assert_eq!(out.len(), m.cfg.vectors_per_step() * 9000);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
