//! Native TDS acoustic model with exact streaming execution.
//!
//! Mirrors `python/compile/model.py` layer for layer (same weight names,
//! same causal-conv semantics), so the engine can run either through the
//! AOT-compiled XLA artifact ([`crate::runtime`]) or natively here, with
//! tests asserting the two paths agree. The streaming step consumes the
//! feature frames of one decoding step and carries conv history across
//! steps — reproducing the offline full-sequence output exactly (causal
//! convolutions, §Hardware-Adaptation in DESIGN.md).
//!
//! ## Execution core
//!
//! All four entry points (`step`, `step_batch`, and the int8 pair on
//! [`super::QuantizedTdsModel`]) funnel into one [`step_batch_driver`]:
//! activations live in contiguous lane-major `[T × B × D]` buffers owned
//! by a reusable [`Scratch`] arena, conv layers see history + current
//! timesteps as one contiguous `ext` buffer (windows become slices, not
//! vectors of pointers), and the dense math runs through the
//! register-blocked, runtime-ISA-dispatched kernels in [`super::gemm`]
//! (AVX2/NEON when the host supports them — bit-identical to the scalar
//! path, see [`super::gemm::dispatch`]). A scalar step is the
//! B = 1 case of the same driver, so batched-vs-scalar parity is
//! structural, not merely tested. With a caller-provided `Scratch`
//! (`step_batch_into`) the steady-state loop performs **zero heap
//! allocations** after warm-up — asserted by `tests/alloc_free.rs`.

use crate::config::{Layer, ModelConfig};
use crate::util::rng::Rng;
use crate::util::tensor_io::{Tensor, TensorFile};
use anyhow::{ensure, Context, Result};

use super::gemm;
use super::ops;
use super::quant::{Int4Weights, SparseInt4Weights};

const LN_EPS: f32 = 1e-5;

/// Weights for one layer, resolved from the tensor file.
#[derive(Debug, Clone)]
enum LayerWeights {
    Conv { w: Vec<f32>, b: Vec<f32> },
    Fc { w: Vec<f32>, b: Vec<f32> },
    LayerNorm { g: Vec<f32>, b: Vec<f32> },
}

/// Borrowed view of one layer's weights, dispatching the step driver to
/// the matching [`super::gemm`] kernel. The quantized variants (int8,
/// packed int4, 2:4 sparse int4) are produced by
/// [`super::QuantizedTdsModel`], possibly mixed per layer.
pub(crate) enum KernelWeights<'a> {
    ConvF32 { w: &'a [f32], b: &'a [f32] },
    ConvI8 { q: &'a [i8], scale: &'a [f32], zp: &'a [f32], b: &'a [f32] },
    ConvI4 { qw: &'a Int4Weights, b: &'a [f32] },
    ConvI4S { qw: &'a SparseInt4Weights, b: &'a [f32] },
    FcF32 { w: &'a [f32], b: &'a [f32] },
    FcI8 { q: &'a [i8], scale: &'a [f32], zp: &'a [f32], b: &'a [f32] },
    FcI4 { qw: &'a Int4Weights, b: &'a [f32] },
    FcI4S { qw: &'a SparseInt4Weights, b: &'a [f32] },
    Ln { g: &'a [f32], b: &'a [f32] },
}

/// Anything that can present its per-layer weights as [`KernelWeights`].
pub(crate) trait AsKernel {
    fn kernel(&self) -> KernelWeights<'_>;
}

impl AsKernel for LayerWeights {
    fn kernel(&self) -> KernelWeights<'_> {
        match self {
            LayerWeights::Conv { w, b } => KernelWeights::ConvF32 { w, b },
            LayerWeights::Fc { w, b } => KernelWeights::FcF32 { w, b },
            LayerWeights::LayerNorm { g, b } => KernelWeights::Ln { g, b },
        }
    }
}

/// Reusable buffers for the step driver. One `Scratch` serves any model
/// shape: buffers grow to the high-water mark of whatever they are used
/// for and are then recycled in place (`resize()` keeps capacity; every
/// element is overwritten each step), so a warmed scratch makes every
/// subsequent step allocation-free.
///
/// Ownership model: the arena owns all *transient* per-step data —
/// activations (`acts`/`next` ping-pong), the conv `ext` gather buffer
/// and the int8 partial-sum buffer. *Persistent* streaming data (conv
/// histories) stays in [`TdsState`], per session; model weights stay in
/// the model. Nothing in `Scratch` outlives a step, so one arena can be
/// shared across sessions, batches and even models.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Current activations, `[T × B × D]` timestep-major.
    acts: Vec<f32>,
    /// Next layer's activations (ping-pong partner of `acts`).
    next: Vec<f32>,
    /// Conv input: `(kw-1)` history blocks + `T` activation blocks.
    ext: Vec<f32>,
    /// Int8 kernels' reusable partial sums (Σx per lane / window sums).
    tmp: Vec<f32>,
}

impl Scratch {
    /// Capacity fingerprint (pointer + capacity per buffer) — lets tests
    /// assert steady-state reuse without a counting allocator.
    pub fn fingerprint(&self) -> [(usize, usize); 4] {
        [
            (self.acts.as_ptr() as usize, self.acts.capacity()),
            (self.next.as_ptr() as usize, self.next.capacity()),
            (self.ext.as_ptr() as usize, self.ext.capacity()),
            (self.tmp.as_ptr() as usize, self.tmp.capacity()),
        ]
    }
}

/// Per-lane streaming-state access for the batched driver. Implemented
/// by plain `[&mut TdsState]` slices and by the engine's session adapter,
/// so the serving loop never has to materialize a `Vec` of references.
pub trait LaneStates {
    fn lane_count(&self) -> usize;
    fn state(&mut self, lane: usize) -> &mut TdsState;
}

impl<'a> LaneStates for [&'a mut TdsState] {
    fn lane_count(&self) -> usize {
        self.len()
    }

    fn state(&mut self, lane: usize) -> &mut TdsState {
        &mut *self[lane]
    }
}

/// The model: topology + weights.
#[derive(Debug, Clone)]
pub struct TdsModel {
    pub cfg: ModelConfig,
    layers: Vec<(Layer, LayerWeights)>,
}

/// Streaming state: per conv layer, the last `kw-1` input timesteps.
#[derive(Debug, Clone, PartialEq)]
pub struct TdsState {
    conv_hist: Vec<Vec<Vec<f32>>>,
}

impl TdsState {
    /// Zeroed conv histories for a layer sequence (the streaming
    /// equivalent of the offline causal left zero-padding).
    pub(crate) fn for_layers<'a>(layers: impl Iterator<Item = &'a Layer>) -> TdsState {
        let mut conv_hist = Vec::new();
        for layer in layers {
            if let Layer::Conv { in_ch, kw, w, .. } = layer {
                conv_hist.push(vec![vec![0.0f32; in_ch * w]; kw - 1]);
            }
        }
        TdsState { conv_hist }
    }

    /// Serialize the conv histories as one `conv{i}` tensor per conv
    /// layer, shaped `[kw-1, in_ch·w]` — the native half of a session
    /// snapshot. Deterministic and lossless (f32 payloads are copied
    /// bit-for-bit), so a restored state streams bit-identically.
    pub fn write_tensors(&self, tf: &mut TensorFile) {
        for (i, hist) in self.conv_hist.iter().enumerate() {
            let rows = hist.len();
            let d = hist.first().map_or(0, Vec::len);
            let mut data = Vec::with_capacity(rows * d);
            for row in hist {
                data.extend_from_slice(row);
            }
            tf.push(Tensor::f32(format!("conv{i}"), vec![rows, d], data));
        }
    }

    /// Overwrite this state's conv histories from `conv{i}` tensors,
    /// validating every shape against the model geometry this state was
    /// opened with.
    pub fn read_tensors(&mut self, tf: &TensorFile) -> Result<()> {
        for (i, hist) in self.conv_hist.iter_mut().enumerate() {
            let rows = hist.len();
            let d = hist.first().map_or(0, Vec::len);
            let t = tf.require(&format!("conv{i}"))?;
            ensure!(
                t.dims == vec![rows, d],
                "state tensor 'conv{i}': dims {:?}, expected [{rows},{d}]",
                t.dims
            );
            let data = t.as_f32()?;
            for (h, row) in hist.iter_mut().enumerate() {
                row.copy_from_slice(&data[h * d..(h + 1) * d]);
            }
        }
        Ok(())
    }
}

/// One fused decoding step over `B = states.lane_count()` lanes — THE
/// compute path shared by every model variant and batch size.
///
/// `feats` is lane-major `[B × (T × n_mels)]`; `out` becomes lane-major
/// `[B × (vectors_per_step × tokens)]` log-probabilities. Per-lane f32
/// results are bit-identical to a single-lane call: the register-blocked
/// kernels preserve each output's scalar reduction order exactly (see
/// `am::gemm`).
pub(crate) fn step_batch_driver<S, W>(
    cfg: &ModelConfig,
    layers: &[(Layer, W)],
    states: &mut S,
    feats: &[f32],
    sc: &mut Scratch,
    out: &mut Vec<f32>,
) where
    S: LaneStates + ?Sized,
    W: AsKernel,
{
    let batch = states.lane_count();
    assert!(batch > 0, "step needs at least one lane");
    let n_mels = cfg.n_mels;
    assert_eq!(
        feats.len() % (batch * n_mels),
        0,
        "feats not whole frames across {batch} lanes"
    );
    let Scratch { acts, next, ext, tmp } = sc;
    let mut cur_t = feats.len() / (batch * n_mels);
    let mut cur_d = n_mels;
    let lane_feats = cur_t * n_mels;
    // De-interleave lane-major feats into [T × B × n_mels] blocks.
    // (Transient buffers are resized without clear(): every element is
    // overwritten below, so only newly grown capacity pays a memset.)
    acts.resize(cur_t * batch * n_mels, 0.0);
    for f in 0..cur_t {
        for lane in 0..batch {
            let src = lane * lane_feats + f * n_mels;
            let dst = (f * batch + lane) * n_mels;
            acts[dst..dst + n_mels].copy_from_slice(&feats[src..src + n_mels]);
        }
    }
    let mut conv_idx = 0;
    for (layer, lw) in layers {
        match (layer, lw.kernel()) {
            (Layer::Conv { in_ch, out_ch, kw, stride, w: width, residual, .. }, kern) => {
                let d_in = in_ch * width;
                debug_assert_eq!(cur_d, d_in, "conv {} input dim", layer.name());
                let in_block = batch * d_in;
                let ext_t = kw - 1 + cur_t;
                // Gather: history blocks first, then the current
                // activations as one contiguous run (fully overwritten).
                ext.resize(ext_t * in_block, 0.0);
                for h in 0..kw - 1 {
                    for lane in 0..batch {
                        let hist = &states.state(lane).conv_hist[conv_idx][h];
                        let dst = h * in_block + lane * d_in;
                        ext[dst..dst + d_in].copy_from_slice(hist);
                    }
                }
                ext[(kw - 1) * in_block..].copy_from_slice(&acts[..cur_t * in_block]);
                assert_eq!(
                    cur_t % stride,
                    0,
                    "chunk length {cur_t} not divisible by stride {stride}"
                );
                let t_out = cur_t / stride;
                let d_out = out_ch * width;
                let out_block = batch * d_out;
                next.resize(t_out * out_block, 0.0);
                match kern {
                    KernelWeights::ConvF32 { w, b } => gemm::conv_steps_into(
                        w, b, ext, t_out, *stride, batch, *in_ch, *out_ch, *kw, *width, next,
                    ),
                    KernelWeights::ConvI8 { q, scale, zp, b } => gemm::conv_steps_int8_into(
                        q, scale, zp, b, ext, t_out, *stride, batch, *in_ch, *out_ch, *kw,
                        *width, tmp, next,
                    ),
                    KernelWeights::ConvI4 { qw, b } => gemm::conv_steps_int4_into(
                        &qw.packed, &qw.scale, &qw.zp, b, ext, t_out, *stride, batch, *in_ch,
                        *out_ch, *kw, *width, tmp, next,
                    ),
                    KernelWeights::ConvI4S { qw, b } => gemm::conv_steps_int4_sparse_into(
                        &qw.vals, &qw.idxs, &qw.scale, b, ext, t_out, *stride, batch, *in_ch,
                        *out_ch, *kw, *width, next,
                    ),
                    _ => unreachable!("conv layer/weights mismatch"),
                }
                ops::relu_inplace(next);
                if *residual {
                    // Residual aligns with the newest input of each
                    // window (stride 1 inside TDS blocks, d_out == d_in).
                    debug_assert_eq!(*stride, 1);
                    debug_assert_eq!(d_out, d_in);
                    for t in 0..t_out {
                        let x = &ext[(t * stride + kw - 1) * in_block..][..in_block];
                        let dst = &mut next[t * out_block..][..out_block];
                        for (v, xi) in dst.iter_mut().zip(x) {
                            *v += xi;
                        }
                    }
                }
                // Scatter the last kw-1 ext blocks back into per-lane
                // histories.
                for h in 0..kw - 1 {
                    let src_block = (ext_t - (kw - 1) + h) * in_block;
                    for lane in 0..batch {
                        let src = &ext[src_block + lane * d_in..][..d_in];
                        let hist = &mut states.state(lane).conv_hist[conv_idx][h];
                        hist.clear();
                        hist.extend_from_slice(src);
                    }
                }
                conv_idx += 1;
                std::mem::swap(acts, next);
                cur_t = t_out;
                cur_d = d_out;
            }
            (Layer::Fc { in_dim, out_dim, relu, residual, .. }, kern) => {
                debug_assert_eq!(cur_d, *in_dim, "fc {} input dim", layer.name());
                let in_block = batch * in_dim;
                let out_block = batch * out_dim;
                next.resize(cur_t * out_block, 0.0);
                for t in 0..cur_t {
                    let xs = &acts[t * in_block..][..in_block];
                    let dst = &mut next[t * out_block..][..out_block];
                    match &kern {
                        KernelWeights::FcF32 { w, b } => gemm::fc_batch_into(w, b, xs, batch, dst),
                        KernelWeights::FcI8 { q, scale, zp, b } => {
                            gemm::fc_batch_int8_into(q, scale, zp, b, xs, batch, tmp, dst)
                        }
                        KernelWeights::FcI4 { qw, b } => gemm::fc_batch_int4_into(
                            &qw.packed, &qw.scale, &qw.zp, b, xs, batch, tmp, dst,
                        ),
                        KernelWeights::FcI4S { qw, b } => gemm::fc_batch_int4_sparse_into(
                            &qw.vals, &qw.idxs, &qw.scale, b, xs, batch, dst,
                        ),
                        _ => unreachable!("fc layer/weights mismatch"),
                    }
                }
                if *relu {
                    ops::relu_inplace(next);
                }
                if *residual {
                    debug_assert_eq!(in_dim, out_dim);
                    for (v, x) in next.iter_mut().zip(acts.iter()) {
                        *v += x;
                    }
                }
                std::mem::swap(acts, next);
                cur_d = *out_dim;
            }
            (Layer::LayerNorm { dim, .. }, KernelWeights::Ln { g, b }) => {
                debug_assert_eq!(cur_d, *dim, "ln {} input dim", layer.name());
                let block = batch * dim;
                for t in 0..cur_t {
                    ops::layer_norm_batch(g, b, &mut acts[t * block..][..block], batch, LN_EPS);
                }
            }
            _ => unreachable!("layer/weights mismatch"),
        }
    }
    // Log-softmax over tokens, de-interleave to lane-major output.
    let tokens = cfg.tokens;
    debug_assert_eq!(cur_d, tokens);
    let vps = cur_t;
    out.resize(batch * vps * tokens, 0.0);
    for t in 0..vps {
        let block = &mut acts[t * batch * tokens..][..batch * tokens];
        ops::log_softmax_batch(block, batch);
        for lane in 0..batch {
            let src = &block[lane * tokens..(lane + 1) * tokens];
            let dst = (lane * vps + t) * tokens;
            out[dst..dst + tokens].copy_from_slice(src);
        }
    }
}

impl TdsModel {
    /// Load weights (naming convention: `{layer}.w`/`{layer}.b` for conv
    /// and fc, `{layer}.g`/`{layer}.b` for layer norm).
    pub fn from_weights(cfg: ModelConfig, weights: &TensorFile) -> Result<Self> {
        let mut layers = Vec::new();
        for layer in cfg.layers() {
            let name = layer.name().to_string();
            let lw = match &layer {
                Layer::Conv { in_ch, out_ch, kw, .. } => {
                    let w = weights.require(&format!("{name}.w"))?;
                    ensure!(
                        w.dims == vec![*out_ch, *in_ch, *kw],
                        "conv '{name}': dims {:?}, expected [{out_ch},{in_ch},{kw}]",
                        w.dims
                    );
                    let b = weights.require(&format!("{name}.b"))?;
                    ensure!(b.dims == vec![*out_ch], "conv '{name}' bias dims {:?}", b.dims);
                    LayerWeights::Conv {
                        w: w.as_f32()?.to_vec(),
                        b: b.as_f32()?.to_vec(),
                    }
                }
                Layer::Fc { in_dim, out_dim, .. } => {
                    let w = weights.require(&format!("{name}.w"))?;
                    ensure!(
                        w.dims == vec![*out_dim, *in_dim],
                        "fc '{name}': dims {:?}, expected [{out_dim},{in_dim}]",
                        w.dims
                    );
                    let b = weights.require(&format!("{name}.b"))?;
                    LayerWeights::Fc {
                        w: w.as_f32()?.to_vec(),
                        b: b.as_f32()?.to_vec(),
                    }
                }
                Layer::LayerNorm { dim, .. } => {
                    let g = weights.require(&format!("{name}.g"))?;
                    ensure!(g.dims == vec![*dim], "ln '{name}' gain dims {:?}", g.dims);
                    let b = weights.require(&format!("{name}.b"))?;
                    LayerWeights::LayerNorm {
                        g: g.as_f32()?.to_vec(),
                        b: b.as_f32()?.to_vec(),
                    }
                }
            };
            layers.push((layer, lw));
        }
        Ok(TdsModel { cfg, layers })
    }

    /// Load from `artifacts/weights.bin`.
    pub fn from_artifacts(cfg: ModelConfig, dir: &std::path::Path) -> Result<Self> {
        let tf = TensorFile::load(&dir.join("weights.bin"))
            .context("loading weights.bin (run `make artifacts` first)")?;
        Self::from_weights(cfg, &tf)
    }

    /// Random (He-initialized) weights — used by benches and simulator
    /// workloads where the numerics don't matter, only the shapes.
    pub fn random(cfg: ModelConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut tf = TensorFile::new();
        for layer in cfg.layers() {
            let name = layer.name().to_string();
            match &layer {
                Layer::Conv { in_ch, out_ch, kw, .. } => {
                    let fan_in = (in_ch * kw) as f32;
                    let std = (2.0 / fan_in).sqrt();
                    let n = out_ch * in_ch * kw;
                    tf.push(Tensor::f32(
                        format!("{name}.w"),
                        vec![*out_ch, *in_ch, *kw],
                        (0..n).map(|_| rng.normal() * std).collect(),
                    ));
                    tf.push(Tensor::f32(format!("{name}.b"), vec![*out_ch], vec![0.0; *out_ch]));
                }
                Layer::Fc { in_dim, out_dim, .. } => {
                    let std = (2.0 / *in_dim as f32).sqrt();
                    let n = in_dim * out_dim;
                    tf.push(Tensor::f32(
                        format!("{name}.w"),
                        vec![*out_dim, *in_dim],
                        (0..n).map(|_| rng.normal() * std).collect(),
                    ));
                    tf.push(Tensor::f32(format!("{name}.b"), vec![*out_dim], vec![0.0; *out_dim]));
                }
                Layer::LayerNorm { dim, .. } => {
                    tf.push(Tensor::f32(format!("{name}.g"), vec![*dim], vec![1.0; *dim]));
                    tf.push(Tensor::f32(format!("{name}.b"), vec![*dim], vec![0.0; *dim]));
                }
            }
        }
        Self::from_weights(cfg, &tf).expect("random weights must validate")
    }

    /// Fresh streaming state (conv histories zeroed — equivalent to the
    /// left zero-padding of the offline causal model).
    pub fn state(&self) -> TdsState {
        TdsState::for_layers(self.layers.iter().map(|(l, _)| l))
    }

    /// Number of layers (for weight-view iteration by the quantizer).
    pub(crate) fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// Borrowed weight view of one layer (for the quantizer).
    pub(crate) fn layer_kernel(&self, idx: usize) -> (&Layer, KernelWeights<'_>) {
        let (layer, lw) = &self.layers[idx];
        (layer, lw.kernel())
    }

    /// Process one decoding step: `feats` is `frames × n_mels` row-major;
    /// returns `vectors_per_step × tokens` log-probabilities. One-lane
    /// case of [`Self::step_batch`].
    pub fn step(&self, state: &mut TdsState, feats: &[f32]) -> Vec<f32> {
        let mut lanes = [state];
        self.step_batch(&mut lanes, feats)
    }

    /// Lane-batched streaming step: advance `B = states.len()` independent
    /// streams through one fused forward pass.
    ///
    /// `feats` is lane-major `[B × (frames × n_mels)]` (lane `l`'s chunk at
    /// `feats[l*F .. (l+1)*F]`); the return value is lane-major
    /// `[B × (vectors_per_step × tokens)]`. Per-lane results are
    /// **bit-identical** to calling [`Self::step`] on each lane separately
    /// — scalar and batched execution are the same driver — which is what
    /// lets the serving path batch opportunistically without changing
    /// transcripts. Allocates a fresh scratch; hot loops should hold a
    /// [`Scratch`] and call [`Self::step_batch_into`].
    pub fn step_batch(&self, states: &mut [&mut TdsState], feats: &[f32]) -> Vec<f32> {
        let mut sc = Scratch::default();
        let mut out = Vec::new();
        self.step_batch_into(states, feats, &mut sc, &mut out);
        out
    }

    /// Allocation-free batched step: all transient buffers come from `sc`
    /// and the result is written into `out` (resized and fully
    /// overwritten). After one warm-up call with the same shapes, this
    /// performs zero heap allocations (`tests/alloc_free.rs`).
    pub fn step_batch_into<S: LaneStates + ?Sized>(
        &self,
        states: &mut S,
        feats: &[f32],
        sc: &mut Scratch,
        out: &mut Vec<f32>,
    ) {
        step_batch_driver(&self.cfg, &self.layers, states, feats, sc, out);
    }

    /// Offline full-sequence forward: chunk the features into decoding
    /// steps and stream through a fresh state (drops a ragged tail).
    pub fn forward_full(&self, feats: &[f32]) -> Vec<f32> {
        let n_mels = self.cfg.n_mels;
        let fps = self.cfg.frames_per_step();
        let n_frames = feats.len() / n_mels;
        let mut state = self.state();
        let mut sc = Scratch::default();
        let mut step_out = Vec::new();
        let mut out = Vec::new();
        let mut f = 0;
        while f + fps <= n_frames {
            let mut lanes = [&mut state];
            self.step_batch_into(
                &mut lanes[..],
                &feats[f * n_mels..(f + fps) * n_mels],
                &mut sc,
                &mut step_out,
            );
            out.extend_from_slice(&step_out);
            f += fps;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn tiny() -> TdsModel {
        TdsModel::random(ModelConfig::tiny_tds(), 42)
    }

    #[test]
    fn step_output_shape() {
        let m = tiny();
        let mut st = m.state();
        let feats = vec![0.1f32; m.cfg.frames_per_step() * m.cfg.n_mels];
        let out = m.step(&mut st, &feats);
        assert_eq!(out.len(), m.cfg.vectors_per_step() * m.cfg.tokens);
    }

    #[test]
    fn outputs_are_log_probs() {
        let m = tiny();
        let mut st = m.state();
        let feats = vec![0.3f32; m.cfg.frames_per_step() * m.cfg.n_mels];
        let out = m.step(&mut st, &feats);
        for v in out.chunks(m.cfg.tokens) {
            let total: f32 = v.iter().map(|x| x.exp()).sum();
            assert!((total - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn streaming_equals_offline() {
        // Two chunks through one state == both chunks through forward_full.
        let m = tiny();
        let n = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(7);
        let feats: Vec<f32> = (0..3 * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let full = m.forward_full(&feats);
        let mut st = m.state();
        let mut streamed = Vec::new();
        for c in 0..3 {
            streamed.extend(m.step(&mut st, &feats[c * n..(c + 1) * n]));
        }
        assert_eq!(full.len(), streamed.len());
        for (a, b) in full.iter().zip(&streamed) {
            assert!((a - b).abs() < 1e-5, "streaming != offline: {a} vs {b}");
        }
    }

    #[test]
    fn state_carries_context() {
        // Same second chunk gives different output if the first chunk
        // differed — i.e. conv history actually crosses step boundaries.
        let m = tiny();
        let n = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(9);
        let a: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let c: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut st1 = m.state();
        m.step(&mut st1, &a);
        let out1 = m.step(&mut st1, &c);
        let mut st2 = m.state();
        m.step(&mut st2, &b);
        let out2 = m.step(&mut st2, &c);
        let diff: f32 = out1.iter().zip(&out2).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-3, "conv state had no effect");
    }

    #[test]
    fn step_batch_is_bit_identical_to_scalar_lanes() {
        // Three lanes with different histories and inputs, stepped twice:
        // the fused pass must reproduce each scalar lane exactly (==, not
        // approx — scalar and batched execution share one driver and the
        // tiled kernels preserve per-output reduction order).
        let m = tiny();
        let batch = 3;
        let f = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(21);
        let mut scalar_states: Vec<TdsState> = (0..batch).map(|_| m.state()).collect();
        let mut batch_states: Vec<TdsState> = (0..batch).map(|_| m.state()).collect();
        for _ in 0..2 {
            let feats: Vec<f32> = (0..batch * f).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut refs: Vec<&mut TdsState> = batch_states.iter_mut().collect();
            let fused = m.step_batch(&mut refs, &feats);
            let lane_out = fused.len() / batch;
            for (lane, st) in scalar_states.iter_mut().enumerate() {
                let out = m.step(st, &feats[lane * f..(lane + 1) * f]);
                assert_eq!(out.len(), lane_out);
                assert_eq!(
                    out,
                    fused[lane * lane_out..(lane + 1) * lane_out],
                    "lane {lane} diverged"
                );
            }
        }
        // Streaming states must match exactly too.
        for (a, b) in scalar_states.iter().zip(&batch_states) {
            assert_eq!(a.conv_hist, b.conv_hist);
        }
    }

    #[test]
    fn step_batch_single_lane_equals_step() {
        let m = tiny();
        let f = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(23);
        let feats: Vec<f32> = (0..f).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut s1 = m.state();
        let out1 = m.step(&mut s1, &feats);
        let mut s2 = m.state();
        let mut refs = vec![&mut s2];
        let out2 = m.step_batch(&mut refs, &feats);
        assert_eq!(out1, out2);
    }

    #[test]
    fn scratch_reuse_is_stable_across_steps() {
        // After one warm-up step, repeated steps with the same shapes must
        // not move or regrow any scratch buffer — the pointer/capacity
        // fingerprint stays fixed (the scratch-arena reuse contract).
        let m = tiny();
        let batch = 4;
        let f = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(31);
        let mut states: Vec<TdsState> = (0..batch).map(|_| m.state()).collect();
        let mut sc = Scratch::default();
        let mut out = Vec::new();
        let feats: Vec<f32> = (0..batch * f).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut refs: Vec<&mut TdsState> = states.iter_mut().collect();
        m.step_batch_into(&mut refs[..], &feats, &mut sc, &mut out);
        let fp = sc.fingerprint();
        let out_fp = (out.as_ptr() as usize, out.capacity());
        for _ in 0..5 {
            m.step_batch_into(&mut refs[..], &feats, &mut sc, &mut out);
            assert_eq!(sc.fingerprint(), fp, "scratch buffer reallocated");
            assert_eq!(
                (out.as_ptr() as usize, out.capacity()),
                out_fp,
                "output buffer reallocated"
            );
        }
    }

    #[test]
    fn state_tensor_roundtrip_streams_bit_identically() {
        // Step a state, snapshot it through tensors (and the byte
        // container), restore into a fresh state, then continue both:
        // outputs and histories must be bit-equal at every step.
        let m = tiny();
        let n = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = crate::util::rng::Rng::new(17);
        let warm: Vec<f32> = (0..2 * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut live = m.state();
        m.step(&mut live, &warm[..n]);
        m.step(&mut live, &warm[n..]);
        let mut tf = TensorFile::new();
        live.write_tensors(&mut tf);
        let tf = TensorFile::from_bytes(&tf.to_bytes().unwrap()).unwrap();
        let mut restored = m.state();
        restored.read_tensors(&tf).unwrap();
        assert_eq!(live, restored);
        let next: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        assert_eq!(m.step(&mut live, &next), m.step(&mut restored, &next));
        // Shape mismatches are rejected (state from a different model).
        let other = TdsModel::random(
            crate::config::ModelConfig {
                n_mels: m.cfg.n_mels + 2,
                ..m.cfg.clone()
            },
            1,
        );
        let mut wrong = other.state();
        assert!(wrong.read_tensors(&tf).is_err());
    }

    #[test]
    fn from_weights_rejects_bad_dims() {
        let cfg = ModelConfig::tiny_tds();
        let good = TdsModel::random(cfg.clone(), 1);
        // Rebuild the tensor file but corrupt one tensor's dims.
        let mut tf = TensorFile::new();
        for (layer, _) in &good.layers {
            let name = layer.name();
            match layer {
                Layer::Conv { in_ch, out_ch, kw, .. } => {
                    tf.push(Tensor::f32(
                        format!("{name}.w"),
                        vec![*out_ch, *in_ch, *kw + 1], // wrong kw
                        vec![0.0; out_ch * in_ch * (kw + 1)],
                    ));
                    tf.push(Tensor::f32(format!("{name}.b"), vec![*out_ch], vec![0.0; *out_ch]));
                }
                _ => break,
            }
        }
        assert!(TdsModel::from_weights(cfg, &tf).is_err());
    }

    #[test]
    fn paper_scale_shapes_run() {
        // One (expensive-ish) smoke test that the 79-layer paper topology
        // actually executes. Random weights; just shape/finiteness.
        let cfg = ModelConfig::paper_tds();
        let cfg = ModelConfig { precision: crate::config::Precision::F32, ..cfg };
        let m = TdsModel::random(cfg, 3);
        let mut st = m.state();
        let feats = vec![0.05f32; m.cfg.frames_per_step() * m.cfg.n_mels];
        let out = m.step(&mut st, &feats);
        assert_eq!(out.len(), m.cfg.vectors_per_step() * 9000);
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
