//! Int8 weight quantization for the TDS acoustic model — the functional
//! counterpart of the paper's 8-bit MAC-unit assumption (§3.4): weights
//! are stored as `i8` with **per-output-row** affine parameters, and the
//! kernels accumulate in f32 ([`super::gemm`]). Because accumulation is
//! f32, the SIMD variants of the int8 kernels vectorize across
//! independent outputs (never the reduction), so every
//! [`super::gemm::dispatch::KernelIsa`] produces bit-identical int8
//! results too.
//!
//! Scheme, per weight row `w` (an FC output neuron's inputs, or a conv
//! output channel's `[in_ch × kw]` taps):
//!
//! ```text
//!   lo = min(w)∧0,  hi = max(w)∨0          (0 always representable)
//!   scale = (hi − lo) / 255                (or 1 for a constant-0 row)
//!   zp    = round(−128 − lo/scale)         (lo ↦ −128, hi ↦ ≈127)
//!   q_i   = clamp(round(w_i/scale) + zp, −128, 127)
//!   deq_i = (q_i − zp) · scale
//! ```
//!
//! **Error bound:** rounding is to the nearest of 256 levels spanning
//! `[lo, hi]`, so `|deq_i − w_i| ≤ scale/2 = (hi−lo)/510`, i.e. at most
//! `max|w|/255` of the row's largest-magnitude weight —
//! [`INT8_MAX_ROW_REL_ERR`], asserted by `tests/quant_parity.rs`.
//! Activations, biases, layer-norm parameters and all accumulations stay
//! f32, matching the hardware's f32 special-function path.
//!
//! # Below int8
//!
//! Two further weight formats halve (or better) the int8 footprint; both
//! keep f32 accumulation and the bit-exact-across-ISAs property:
//!
//! **Packed int4** ([`Int4Weights`]): two weights per byte, affine
//! parameters per *group* of [`INT4_GROUP`] consecutive columns instead
//! of per row (16 levels need a tighter range to stay accurate):
//!
//! ```text
//!   per (row, group): lo = min∧0, hi = max∨0, scale = (hi−lo)/15
//!   zp  = round(−8 − lo/scale)
//!   q_i = clamp(round(w_i/scale) + zp, −8, 7), stored as (q_i+8) nibble
//! ```
//!
//! Even columns sit in the **low** nibble. Error ≤ `scale/2`, i.e. at
//! most [`INT4_MAX_GROUP_REL_ERR`] (= 1/15) of the *group's*
//! largest-magnitude weight.
//!
//! **2:4 structured-sparse int4** ([`SparseInt4Weights`]): per 4-column
//! block the 2 largest-magnitude weights survive (magnitude pruning,
//! ties to the lower index); each block stores one byte of two 4-bit
//! values and one byte of two 2-bit in-block indices — 12 bits per 4
//! weights, with a fixed 2 MACs/block the kernels execute without any
//! per-element branching. Values are *symmetric* per row
//! (`scale = max|kept|/7`, `q = clamp(round(w/scale), −7, 7)`, stored as
//! `q+8`), so pruned weights dequantize to exactly 0.0 and kept weights
//! err by at most [`SPARSE4_MAX_ROW_REL_ERR`] (= 1/14) of the row's
//! largest kept magnitude. The pruning error itself (dropping the 2
//! smallest of each 4) is unbounded pointwise and is what the
//! compile-side calibration pass budgets against measured WER.

use crate::config::{Layer, ModelConfig, Precision, PrecisionMap};
use anyhow::Result;

use super::tds::{KernelWeights, LaneStates, Scratch, TdsModel, TdsState};

/// Documented per-row relative quantization error bound: for every weight
/// `|dequant(quant(w)) − w| ≤ INT8_MAX_ROW_REL_ERR · max|row|` (with a
/// hair of slack for f32 rounding in the quantizer itself).
pub const INT8_MAX_ROW_REL_ERR: f32 = 1.0 / 255.0;

/// One int8-quantized weight matrix: `[rows × cols]` i8 data plus
/// per-row affine parameters. `zp` is integral-valued but stored as f32
/// because the kernels consume it in f32 accumulation.
#[derive(Debug, Clone)]
pub struct QuantizedWeights {
    pub q: Vec<i8>,
    pub scale: Vec<f32>,
    pub zp: Vec<f32>,
}

/// Quantize a row-major `[rows × cols]` f32 matrix, one affine pair per
/// row.
pub fn quantize_rows(w: &[f32], rows: usize, cols: usize) -> QuantizedWeights {
    assert_eq!(w.len(), rows * cols, "quantize_rows: shape mismatch");
    let mut q = Vec::with_capacity(rows * cols);
    let mut scale = Vec::with_capacity(rows);
    let mut zp = Vec::with_capacity(rows);
    for row in w.chunks_exact(cols.max(1)) {
        let lo = row.iter().cloned().fold(0.0f32, f32::min);
        let hi = row.iter().cloned().fold(0.0f32, f32::max);
        let s = if hi > lo { (hi - lo) / 255.0 } else { 1.0 };
        let z = (-128.0 - lo / s).round();
        scale.push(s);
        zp.push(z);
        for &x in row {
            let v = (x / s).round() + z;
            q.push(v.clamp(-128.0, 127.0) as i8);
        }
    }
    QuantizedWeights { q, scale, zp }
}

/// Dequantize one element of a row (test/diagnostic helper).
pub fn dequantize(qw: &QuantizedWeights, row: usize, cols: usize, col: usize) -> f32 {
    (qw.q[row * cols + col] as f32 - qw.zp[row]) * qw.scale[row]
}

/// Columns per int4 quantization group: each group of this many
/// consecutive columns in a row shares one scale/zero-point pair.
pub const INT4_GROUP: usize = 32;

/// Documented per-group relative error bound for packed int4: for every
/// weight `|dequant(quant(w)) − w| ≤ INT4_MAX_GROUP_REL_ERR · max|group|`
/// (16 levels spanning `[lo, hi] ∋ 0` ⇒ half-step error `≤ (hi−lo)/30
/// ≤ max|group|·2/30`... conservatively stated as `max|group|/15`, with
/// a hair of slack for f32 rounding in the quantizer itself).
pub const INT4_MAX_GROUP_REL_ERR: f32 = 1.0 / 15.0;

/// Documented per-row relative error bound for the *kept* weights of the
/// 2:4 sparse format: `|dequant(q) − w| ≤ SPARSE4_MAX_ROW_REL_ERR ·
/// max|kept in row|` (symmetric 15-level grid, half-step = scale/2 =
/// max|kept|/14). Pruned weights dequantize to exactly 0.0.
pub const SPARSE4_MAX_ROW_REL_ERR: f32 = 1.0 / 14.0;

/// One packed-int4 weight matrix: `[rows × cols]` 4-bit codes, two per
/// byte (even column in the low nibble), with affine parameters per
/// `(row, group-of-[`INT4_GROUP`]-columns)`. `zp` is integral-valued but
/// stored as f32 because the kernels consume it in f32 accumulation.
#[derive(Debug, Clone)]
pub struct Int4Weights {
    /// Packed codes, row-major, `row_stride()` bytes per row. Code
    /// `(q+8) ∈ [0, 15]` for signed `q ∈ [−8, 7]`.
    pub packed: Vec<u8>,
    /// Per-(row, group) scale, `[rows × groups()]` row-major.
    pub scale: Vec<f32>,
    /// Per-(row, group) zero-point, same layout as `scale`.
    pub zp: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
}

impl Int4Weights {
    /// Bytes per packed row.
    pub fn row_stride(&self) -> usize {
        self.cols.div_ceil(2)
    }

    /// Quantization groups per row.
    pub fn groups(&self) -> usize {
        self.cols.div_ceil(INT4_GROUP)
    }

    /// The signed 4-bit code at `(row, col)` (test/diagnostic helper).
    pub fn code(&self, row: usize, col: usize) -> i32 {
        let byte = self.packed[row * self.row_stride() + col / 2];
        let nib = if col % 2 == 0 { byte & 0x0f } else { byte >> 4 };
        nib as i32 - 8
    }
}

/// Quantize a row-major `[rows × cols]` f32 matrix to packed int4, one
/// affine pair per `(row, group)`.
pub fn quantize_rows_int4(w: &[f32], rows: usize, cols: usize) -> Int4Weights {
    assert_eq!(w.len(), rows * cols, "quantize_rows_int4: shape mismatch");
    let groups = cols.div_ceil(INT4_GROUP).max(1);
    let stride = cols.div_ceil(2);
    let mut packed = vec![0u8; rows * stride];
    let mut scale = Vec::with_capacity(rows * groups);
    let mut zp = Vec::with_capacity(rows * groups);
    for (r, row) in w.chunks_exact(cols.max(1)).enumerate() {
        for g in 0..groups {
            let seg = &row[g * INT4_GROUP..((g + 1) * INT4_GROUP).min(cols)];
            let lo = seg.iter().cloned().fold(0.0f32, f32::min);
            let hi = seg.iter().cloned().fold(0.0f32, f32::max);
            let s = if hi > lo { (hi - lo) / 15.0 } else { 1.0 };
            let z = (-8.0 - lo / s).round();
            scale.push(s);
            zp.push(z);
            for (j, &x) in seg.iter().enumerate() {
                let col = g * INT4_GROUP + j;
                let q = ((x / s).round() + z).clamp(-8.0, 7.0) as i32;
                let nib = (q + 8) as u8;
                let slot = &mut packed[r * stride + col / 2];
                if col % 2 == 0 {
                    *slot = (*slot & 0xf0) | nib;
                } else {
                    *slot = (*slot & 0x0f) | (nib << 4);
                }
            }
        }
    }
    Int4Weights { packed, scale, zp, rows, cols }
}

/// Dequantize one element of a packed-int4 matrix (test/diagnostic
/// helper).
pub fn dequantize_int4(qw: &Int4Weights, row: usize, col: usize) -> f32 {
    let g = col / INT4_GROUP;
    let gi = row * qw.groups() + g;
    (qw.code(row, col) as f32 - qw.zp[gi]) * qw.scale[gi]
}

/// One 2:4 structured-sparse int4 weight matrix: per 4-column block the
/// 2 largest-magnitude weights survive as 4-bit symmetric codes plus
/// 2-bit in-block column indices. Kernels execute a fixed 2 MACs per
/// block with no per-element branching.
#[derive(Debug, Clone)]
pub struct SparseInt4Weights {
    /// One byte per block: slot-0 code in the low nibble, slot-1 in the
    /// high nibble. Code `(q+8) ∈ [1, 15]` for signed `q ∈ [−7, 7]`;
    /// padding slots store code 8 (q = 0).
    pub vals: Vec<u8>,
    /// One byte per block: slot-0 in-block column index in bits 0–1,
    /// slot-1 in bits 2–3. Indices are strictly ascending within a block
    /// except padding slots, which point at in-block column 0 (always in
    /// bounds) with a zero value.
    pub idxs: Vec<u8>,
    /// Per-row symmetric scale (no zero-point: pruned weights are
    /// exactly 0.0).
    pub scale: Vec<f32>,
    pub rows: usize,
    pub cols: usize,
}

impl SparseInt4Weights {
    /// 4-column blocks per row.
    pub fn blocks(&self) -> usize {
        self.cols.div_ceil(4)
    }

    /// The two `(in-block index, signed code)` slots of block `b` of
    /// `row` (test/diagnostic helper).
    pub fn block(&self, row: usize, b: usize) -> [(usize, i32); 2] {
        let at = row * self.blocks() + b;
        let v = self.vals[at];
        let ix = self.idxs[at];
        [
            ((ix & 0x03) as usize, (v & 0x0f) as i32 - 8),
            (((ix >> 2) & 0x03) as usize, (v >> 4) as i32 - 8),
        ]
    }
}

/// Magnitude-prune a row-major `[rows × cols]` f32 matrix to 2:4 blocks
/// and quantize the survivors to symmetric int4, one scale per row.
pub fn prune_quantize_rows_2of4(w: &[f32], rows: usize, cols: usize) -> SparseInt4Weights {
    assert_eq!(w.len(), rows * cols, "prune_quantize_rows_2of4: shape mismatch");
    let blocks = cols.div_ceil(4).max(1);
    let mut vals = Vec::with_capacity(rows * blocks);
    let mut idxs = Vec::with_capacity(rows * blocks);
    let mut scale = Vec::with_capacity(rows);
    for row in w.chunks_exact(cols.max(1)) {
        // Survivor set first (the scale depends on it): per block, the 2
        // largest magnitudes, ties to the lower index.
        let mut kept: Vec<(usize, usize)> = Vec::with_capacity(blocks); // (i0, i1) per block
        let mut amax = 0.0f32;
        for b in 0..blocks {
            let base = b * 4;
            let len = (cols - base).min(4);
            let mut order: Vec<usize> = (0..len).collect();
            order.sort_by(|&a, &c| {
                row[base + c]
                    .abs()
                    .partial_cmp(&row[base + a].abs())
                    .unwrap()
                    .then(a.cmp(&c))
            });
            let mut pair: Vec<usize> = order.into_iter().take(2).collect();
            pair.sort_unstable();
            for &i in &pair {
                amax = amax.max(row[base + i].abs());
            }
            let i0 = pair[0]; // every block covers ≥ 1 real column
            let i1 = pair.get(1).copied().unwrap_or(0);
            kept.push((i0, i1));
        }
        let s = if amax > 0.0 { amax / 7.0 } else { 1.0 };
        scale.push(s);
        for (b, &(i0, i1)) in kept.iter().enumerate() {
            let base = b * 4;
            let len = (cols - base).min(4);
            let code = |i: usize, present: bool| -> u8 {
                if !present {
                    return 8; // padding: q = 0 at in-block column 0
                }
                let q = (row[base + i] / s).round().clamp(-7.0, 7.0) as i32;
                (q + 8) as u8
            };
            let has1 = len >= 2;
            vals.push(code(i0, true) | (code(i1, has1) << 4));
            let ix1 = if has1 { i1 } else { 0 };
            idxs.push((i0 as u8) | ((ix1 as u8) << 2));
        }
    }
    SparseInt4Weights { vals, idxs, scale, rows, cols }
}

/// Dequantize one element of a sparse matrix: the kept value at
/// `(row, col)`, or exactly 0.0 if pruned (test/diagnostic helper).
pub fn dequantize_sparse(qw: &SparseInt4Weights, row: usize, col: usize) -> f32 {
    let b = col / 4;
    let want = col % 4;
    for (i, q) in qw.block(row, b) {
        if i == want && q != 0 {
            return q as f32 * qw.scale[row];
        }
    }
    0.0
}

/// Weights for one layer of the (possibly mixed-precision) quantized
/// model. Conv/FC weights are stored at the layer's resolved precision;
/// biases and LayerNorm parameters stay f32 (they are a vanishing
/// fraction of the model bytes and feed the f32 accumulate directly).
#[derive(Debug, Clone)]
enum QLayerWeights {
    ConvF32 { w: Vec<f32>, b: Vec<f32> },
    FcF32 { w: Vec<f32>, b: Vec<f32> },
    Conv { qw: QuantizedWeights, b: Vec<f32> },
    Fc { qw: QuantizedWeights, b: Vec<f32> },
    ConvI4 { qw: Int4Weights, b: Vec<f32> },
    FcI4 { qw: Int4Weights, b: Vec<f32> },
    ConvI4S { qw: SparseInt4Weights, b: Vec<f32> },
    FcI4S { qw: SparseInt4Weights, b: Vec<f32> },
    LayerNorm { g: Vec<f32>, b: Vec<f32> },
}

impl super::tds::AsKernel for QLayerWeights {
    fn kernel(&self) -> KernelWeights<'_> {
        match self {
            QLayerWeights::ConvF32 { w, b } => KernelWeights::ConvF32 { w, b },
            QLayerWeights::FcF32 { w, b } => KernelWeights::FcF32 { w, b },
            QLayerWeights::Conv { qw, b } => KernelWeights::ConvI8 {
                q: &qw.q,
                scale: &qw.scale,
                zp: &qw.zp,
                b,
            },
            QLayerWeights::Fc { qw, b } => KernelWeights::FcI8 {
                q: &qw.q,
                scale: &qw.scale,
                zp: &qw.zp,
                b,
            },
            QLayerWeights::ConvI4 { qw, b } => KernelWeights::ConvI4 { qw, b },
            QLayerWeights::FcI4 { qw, b } => KernelWeights::FcI4 { qw, b },
            QLayerWeights::ConvI4S { qw, b } => KernelWeights::ConvI4S { qw, b },
            QLayerWeights::FcI4S { qw, b } => KernelWeights::FcI4S { qw, b },
            QLayerWeights::LayerNorm { g, b } => KernelWeights::Ln { g, b },
        }
    }
}

/// The quantized TDS acoustic model — uniform int8 (the classic path) or
/// a calibrated per-layer mix of {f32, int8, int4, int4+sparse}. Drop-in
/// for [`TdsModel`] on the serving path: same streaming [`TdsState`]
/// (activations and conv history stay f32), same step entry points,
/// 4–10× smaller weight footprint and sub-byte weight streams in the hot
/// kernels.
#[derive(Debug, Clone)]
pub struct QuantizedTdsModel {
    pub cfg: ModelConfig,
    layers: Vec<(Layer, QLayerWeights)>,
    precisions: PrecisionMap,
}

impl QuantizedTdsModel {
    /// Quantize an f32 model uniformly to int8. The config is stamped
    /// [`Precision::Int8`] so downstream cost models (accel/power) see
    /// int8 weight traffic.
    pub fn from_model(model: &TdsModel) -> Result<Self> {
        Self::from_model_mixed(model, &PrecisionMap::uniform(Precision::Int8))
    }

    /// Quantize an f32 model with a per-layer precision map (the output
    /// of the compile-side calibration pass). LayerNorm layers always
    /// stay f32; conv/FC layers store weights at their resolved
    /// precision. The config is stamped with the map's default precision
    /// so scalar consumers see the dominant format.
    pub fn from_model_mixed(model: &TdsModel, map: &PrecisionMap) -> Result<Self> {
        map.validate(&model.cfg).map_err(anyhow::Error::msg)?;
        let mut layers = Vec::with_capacity(model.layer_count());
        for idx in 0..model.layer_count() {
            let (layer, view) = model.layer_kernel(idx);
            let p = map.resolve(layer.name());
            let qlw = match view {
                KernelWeights::ConvF32 { w, b } => {
                    let Layer::Conv { in_ch, out_ch, kw, .. } = layer else {
                        unreachable!("conv weights on non-conv layer")
                    };
                    let (rows, cols) = (*out_ch, in_ch * kw);
                    match p {
                        Precision::F32 => {
                            QLayerWeights::ConvF32 { w: w.to_vec(), b: b.to_vec() }
                        }
                        Precision::Int8 => QLayerWeights::Conv {
                            qw: quantize_rows(w, rows, cols),
                            b: b.to_vec(),
                        },
                        Precision::Int4 => QLayerWeights::ConvI4 {
                            qw: quantize_rows_int4(w, rows, cols),
                            b: b.to_vec(),
                        },
                        Precision::Int4Sparse => QLayerWeights::ConvI4S {
                            qw: prune_quantize_rows_2of4(w, rows, cols),
                            b: b.to_vec(),
                        },
                    }
                }
                KernelWeights::FcF32 { w, b } => {
                    let Layer::Fc { in_dim, out_dim, .. } = layer else {
                        unreachable!("fc weights on non-fc layer")
                    };
                    let (rows, cols) = (*out_dim, *in_dim);
                    match p {
                        Precision::F32 => {
                            QLayerWeights::FcF32 { w: w.to_vec(), b: b.to_vec() }
                        }
                        Precision::Int8 => QLayerWeights::Fc {
                            qw: quantize_rows(w, rows, cols),
                            b: b.to_vec(),
                        },
                        Precision::Int4 => QLayerWeights::FcI4 {
                            qw: quantize_rows_int4(w, rows, cols),
                            b: b.to_vec(),
                        },
                        Precision::Int4Sparse => QLayerWeights::FcI4S {
                            qw: prune_quantize_rows_2of4(w, rows, cols),
                            b: b.to_vec(),
                        },
                    }
                }
                KernelWeights::Ln { g, b } => QLayerWeights::LayerNorm {
                    g: g.to_vec(),
                    b: b.to_vec(),
                },
                _ => unreachable!("TdsModel only yields f32 kernels"),
            };
            layers.push((layer.clone(), qlw));
        }
        let cfg = ModelConfig { precision: map.default, ..model.cfg.clone() };
        Ok(QuantizedTdsModel { cfg, layers, precisions: map.clone() })
    }

    /// The per-layer precision map this model was quantized with.
    pub fn precision_map(&self) -> &PrecisionMap {
        &self.precisions
    }

    /// Fresh streaming state — identical layout to [`TdsModel::state`].
    pub fn state(&self) -> TdsState {
        TdsState::for_layers(self.layers.iter().map(|(l, _)| l))
    }

    /// Scratch-arena batched step; see [`TdsModel::step_batch_into`].
    pub fn step_batch_into<S: LaneStates + ?Sized>(
        &self,
        states: &mut S,
        feats: &[f32],
        sc: &mut Scratch,
        out: &mut Vec<f32>,
    ) {
        super::tds::step_batch_driver(&self.cfg, &self.layers, states, feats, sc, out);
    }

    /// Convenience batched step (allocates a fresh scratch per call).
    pub fn step_batch(&self, states: &mut [&mut TdsState], feats: &[f32]) -> Vec<f32> {
        let mut sc = Scratch::default();
        let mut out = Vec::new();
        self.step_batch_into(states, feats, &mut sc, &mut out);
        out
    }

    /// Convenience scalar step (one lane through the batched driver).
    pub fn step(&self, state: &mut TdsState, feats: &[f32]) -> Vec<f32> {
        let mut lanes = [state];
        self.step_batch(&mut lanes, feats)
    }

    /// Total stored model-data bytes (quantized weights at their packed
    /// width, plus f32 biases and quantization parameters).
    pub fn weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|(_, lw)| match lw {
                QLayerWeights::ConvF32 { w, b } | QLayerWeights::FcF32 { w, b } => {
                    4 * (w.len() + b.len())
                }
                QLayerWeights::Conv { qw, b } | QLayerWeights::Fc { qw, b } => {
                    qw.q.len() + 4 * (b.len() + qw.scale.len() + qw.zp.len())
                }
                QLayerWeights::ConvI4 { qw, b } | QLayerWeights::FcI4 { qw, b } => {
                    qw.packed.len() + 4 * (b.len() + qw.scale.len() + qw.zp.len())
                }
                QLayerWeights::ConvI4S { qw, b } | QLayerWeights::FcI4S { qw, b } => {
                    qw.vals.len() + qw.idxs.len() + 4 * (b.len() + qw.scale.len())
                }
                QLayerWeights::LayerNorm { g, b } => 4 * (g.len() + b.len()),
            })
            .sum()
    }
}

/// Greedy CTC argmax over a `[frames × tokens]` log-prob matrix —
/// convenience for parity diagnostics.
pub fn argmax_path(logps: &[f32], tokens: usize) -> Vec<usize> {
    logps
        .chunks_exact(tokens)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn quantize_dequantize_within_documented_bound() {
        prop::check("quant-row-rel-err", 50, |g| {
            let rows = 1 + g.index(8);
            let cols = 1 + g.index(64);
            let mag = 0.01 + g.rng.uniform(0.0, 4.0);
            let w = g.vec_of(rows * cols, |r| r.uniform(-mag, mag));
            let qw = quantize_rows(&w, rows, cols);
            for r in 0..rows {
                let row = &w[r * cols..(r + 1) * cols];
                let amax = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
                let bound = INT8_MAX_ROW_REL_ERR * amax.max(f32::EPSILON) + 1e-7;
                for c in 0..cols {
                    let deq = dequantize(&qw, r, cols, c);
                    crate::prop_assert!(
                        (deq - row[c]).abs() <= bound,
                        "row {r} col {c}: |{deq} - {}| > {bound}",
                        row[c]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_and_constant_rows_are_handled() {
        let qw = quantize_rows(&[0.0; 8], 1, 8);
        for c in 0..8 {
            assert_eq!(dequantize(&qw, 0, 8, c), 0.0);
        }
        // All-positive constant row: lo clamps to 0, hi = c.
        let qw = quantize_rows(&[3.0; 4], 1, 4);
        for c in 0..4 {
            assert!((dequantize(&qw, 0, 4, c) - 3.0).abs() < 3.0 * INT8_MAX_ROW_REL_ERR + 1e-6);
        }
    }

    #[test]
    fn quantized_model_step_shape_and_finiteness() {
        let m = TdsModel::random(ModelConfig::tiny_tds(), 42);
        let qm = QuantizedTdsModel::from_model(&m).unwrap();
        assert_eq!(qm.cfg.precision, Precision::Int8);
        let mut st = qm.state();
        let feats = vec![0.1f32; qm.cfg.frames_per_step() * qm.cfg.n_mels];
        let out = qm.step(&mut st, &feats);
        assert_eq!(out.len(), qm.cfg.vectors_per_step() * qm.cfg.tokens);
        assert!(out.iter().all(|v| v.is_finite()));
        // Log-softmax rows must still normalize.
        for row in out.chunks(qm.cfg.tokens) {
            let total: f32 = row.iter().map(|v| v.exp()).sum();
            assert!((total - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn quantized_logits_track_f32_logits() {
        // Multi-step streaming: int8 log-probs must stay close to f32
        // ones (loose bound — the tight transcript-level guarantee lives
        // in tests/quant_parity.rs).
        let m = TdsModel::random(ModelConfig::tiny_tds(), 7);
        let qm = QuantizedTdsModel::from_model(&m).unwrap();
        let f = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = Rng::new(5);
        let mut st_f = m.state();
        let mut st_q = qm.state();
        for _ in 0..3 {
            let feats: Vec<f32> = (0..f).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let a = m.step(&mut st_f, &feats);
            let b = qm.step(&mut st_q, &feats);
            assert_eq!(a.len(), b.len());
            let max_diff = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 0.5, "int8 logits drifted {max_diff} from f32");
        }
    }

    #[test]
    fn quantized_weight_bytes_are_roughly_quarter() {
        let m = TdsModel::random(ModelConfig::tiny_tds(), 11);
        let qm = QuantizedTdsModel::from_model(&m).unwrap();
        let f32_bytes: usize = m.cfg.layers().iter().map(|l| l.params() * 4).sum();
        let q_bytes = qm.weight_bytes();
        assert!(
            (q_bytes as f64) < 0.5 * f32_bytes as f64,
            "int8 model {q_bytes} B not ≪ f32 {f32_bytes} B"
        );
    }

    #[test]
    fn int4_roundtrip_within_documented_bound() {
        prop::check("int4-group-rel-err", 50, |g| {
            let rows = 1 + g.index(6);
            let cols = 1 + g.index(80); // crosses group boundaries + odd widths
            let mag = 0.01 + g.rng.uniform(0.0, 4.0);
            let w = g.vec_of(rows * cols, |r| r.uniform(-mag, mag));
            let qw = quantize_rows_int4(&w, rows, cols);
            for r in 0..rows {
                let row = &w[r * cols..(r + 1) * cols];
                for gi in 0..qw.groups() {
                    let seg = &row[gi * INT4_GROUP..((gi + 1) * INT4_GROUP).min(cols)];
                    let gmax = seg.iter().fold(0.0f32, |a, v| a.max(v.abs()));
                    let bound = INT4_MAX_GROUP_REL_ERR * gmax.max(f32::EPSILON) + 1e-7;
                    for (j, &x) in seg.iter().enumerate() {
                        let deq = dequantize_int4(&qw, r, gi * INT4_GROUP + j);
                        crate::prop_assert!(
                            (deq - x).abs() <= bound,
                            "row {r} group {gi} col {j}: |{deq} - {x}| > {bound}"
                        );
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn int4_packing_is_two_nibbles_per_byte() {
        let w: Vec<f32> = (0..2 * 7).map(|i| i as f32 / 7.0 - 1.0).collect();
        let qw = quantize_rows_int4(&w, 2, 7);
        assert_eq!(qw.row_stride(), 4, "7 cols pack into 4 bytes");
        assert_eq!(qw.packed.len(), 2 * 4);
        assert_eq!(qw.groups(), 1);
        assert_eq!(qw.scale.len(), 2);
        // Codes stay in the signed nibble range.
        for r in 0..2 {
            for c in 0..7 {
                let q = qw.code(r, c);
                assert!((-8..=7).contains(&q), "code {q} out of nibble range");
            }
        }
        // Zero rows dequantize to exactly zero.
        let z = quantize_rows_int4(&[0.0; 5], 1, 5);
        for c in 0..5 {
            assert_eq!(dequantize_int4(&z, 0, c), 0.0);
        }
    }

    #[test]
    fn sparse_2of4_keeps_two_largest_and_zeroes_rest() {
        prop::check("sparse-2of4", 50, |g| {
            let rows = 1 + g.index(6);
            let cols = 1 + g.index(40); // includes ragged tails
            let mag = 0.01 + g.rng.uniform(0.0, 4.0);
            let w = g.vec_of(rows * cols, |r| r.uniform(-mag, mag));
            let qw = prune_quantize_rows_2of4(&w, rows, cols);
            for r in 0..rows {
                let row = &w[r * cols..(r + 1) * cols];
                // Kept magnitude bound over the whole row.
                let mut kept_max = 0.0f32;
                for b in 0..qw.blocks() {
                    for (i, q) in qw.block(r, b) {
                        let col = b * 4 + i;
                        crate::prop_assert!(col < cols, "slot index {col} out of bounds");
                        if q != 0 {
                            kept_max = kept_max.max(row[col].abs());
                        }
                    }
                }
                let bound = SPARSE4_MAX_ROW_REL_ERR * kept_max.max(f32::EPSILON) + 1e-7;
                for b in 0..qw.blocks() {
                    let base = b * 4;
                    let len = (cols - base).min(4);
                    // The pruned (non-kept) columns dequantize to exactly 0,
                    // and no block keeps more than 2 columns.
                    let slots = qw.block(r, b);
                    let kept: Vec<usize> =
                        slots.iter().filter(|(_, q)| *q != 0).map(|(i, _)| base + i).collect();
                    crate::prop_assert!(kept.len() <= 2, "block {b} kept {}", kept.len());
                    for c in base..base + len {
                        let deq = dequantize_sparse(&qw, r, c);
                        if kept.contains(&c) {
                            crate::prop_assert!(
                                (deq - row[c]).abs() <= bound,
                                "kept row {r} col {c}: |{deq} - {}| > {bound}",
                                row[c]
                            );
                        } else {
                            crate::prop_assert!(deq == 0.0, "pruned col {c} deq {deq} != 0");
                            // Magnitude pruning: nothing pruned may exceed a
                            // block survivor (kept codes can round to 0, so
                            // compare against the block's true top-2 only
                            // when both survivors are nonzero codes).
                            if slots.iter().all(|(_, q)| *q != 0) && len == 4 {
                                let min_kept = slots
                                    .iter()
                                    .map(|(i, _)| row[base + i].abs())
                                    .fold(f32::INFINITY, f32::min);
                                crate::prop_assert!(
                                    row[c].abs() <= min_kept + 1e-7,
                                    "pruned |{}| beats kept {min_kept}",
                                    row[c]
                                );
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn mixed_precision_model_tracks_f32() {
        let m = TdsModel::random(ModelConfig::tiny_tds(), 13);
        let mut map = PrecisionMap::uniform(Precision::Int4);
        map.set("g0.sub", Precision::F32);
        map.set("output.fc", Precision::Int8);
        map.set("g1.b0.fc0", Precision::Int4Sparse);
        let qm = QuantizedTdsModel::from_model_mixed(&m, &map).unwrap();
        assert_eq!(qm.cfg.precision, Precision::Int4);
        assert_eq!(qm.precision_map(), &map);
        let f = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = Rng::new(3);
        let mut st_f = m.state();
        let mut st_q = qm.state();
        for _ in 0..3 {
            let feats: Vec<f32> = (0..f).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let a = m.step(&mut st_f, &feats);
            let b = qm.step(&mut st_q, &feats);
            assert_eq!(a.len(), b.len());
            assert!(b.iter().all(|v| v.is_finite()));
            // Looser than int8 (4-bit grid + pruning), still recognisably
            // the same model.
            let max_diff =
                a.iter().zip(&b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max);
            assert!(max_diff < 2.0, "mixed logits drifted {max_diff} from f32");
        }
        // The F32 override really stores f32 weights: bytes sit between
        // all-int4 and all-f32.
        let uniform4 = QuantizedTdsModel::from_model_mixed(
            &m,
            &PrecisionMap::uniform(Precision::Int4),
        )
        .unwrap();
        assert!(qm.weight_bytes() > uniform4.weight_bytes());
    }

    #[test]
    fn int4_weight_bytes_are_roughly_half_of_int8() {
        let m = TdsModel::random(ModelConfig::tiny_tds(), 11);
        let q8 = QuantizedTdsModel::from_model(&m).unwrap();
        let q4 = QuantizedTdsModel::from_model_mixed(
            &m,
            &PrecisionMap::uniform(Precision::Int4),
        )
        .unwrap();
        let qs = QuantizedTdsModel::from_model_mixed(
            &m,
            &PrecisionMap::uniform(Precision::Int4Sparse),
        )
        .unwrap();
        // Not exactly half (per-group params, f32 biases, LN stays f32),
        // but well below.
        assert!(
            (q4.weight_bytes() as f64) < 0.8 * q8.weight_bytes() as f64,
            "int4 {} B not ≪ int8 {} B",
            q4.weight_bytes(),
            q8.weight_bytes()
        );
        assert!(qs.weight_bytes() < q4.weight_bytes());
    }

    #[test]
    fn from_model_mixed_rejects_unknown_layer_overrides() {
        let m = TdsModel::random(ModelConfig::tiny_tds(), 2);
        let mut map = PrecisionMap::uniform(Precision::Int8);
        map.set("not.a.layer", Precision::Int4);
        assert!(QuantizedTdsModel::from_model_mixed(&m, &map).is_err());
    }

    #[test]
    fn batched_quantized_step_matches_scalar_lanes() {
        let m = TdsModel::random(ModelConfig::tiny_tds(), 21);
        let qm = QuantizedTdsModel::from_model(&m).unwrap();
        let f = qm.cfg.frames_per_step() * qm.cfg.n_mels;
        let batch = 3;
        let mut rng = Rng::new(17);
        let mut scalar_states: Vec<TdsState> = (0..batch).map(|_| qm.state()).collect();
        let mut batch_states: Vec<TdsState> = (0..batch).map(|_| qm.state()).collect();
        for _ in 0..2 {
            let feats: Vec<f32> = (0..batch * f).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut refs: Vec<&mut TdsState> = batch_states.iter_mut().collect();
            let fused = qm.step_batch(&mut refs, &feats);
            let lane_out = fused.len() / batch;
            for (l, st) in scalar_states.iter_mut().enumerate() {
                let out = qm.step(st, &feats[l * f..(l + 1) * f]);
                assert_eq!(
                    out,
                    fused[l * lane_out..(l + 1) * lane_out],
                    "int8 lane {l} diverged"
                );
            }
        }
    }
}
