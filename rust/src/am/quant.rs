//! Int8 weight quantization for the TDS acoustic model — the functional
//! counterpart of the paper's 8-bit MAC-unit assumption (§3.4): weights
//! are stored as `i8` with **per-output-row** affine parameters, and the
//! kernels accumulate in f32 ([`super::gemm`]). Because accumulation is
//! f32, the SIMD variants of the int8 kernels vectorize across
//! independent outputs (never the reduction), so every
//! [`super::gemm::dispatch::KernelIsa`] produces bit-identical int8
//! results too.
//!
//! Scheme, per weight row `w` (an FC output neuron's inputs, or a conv
//! output channel's `[in_ch × kw]` taps):
//!
//! ```text
//!   lo = min(w)∧0,  hi = max(w)∨0          (0 always representable)
//!   scale = (hi − lo) / 255                (or 1 for a constant-0 row)
//!   zp    = round(−128 − lo/scale)         (lo ↦ −128, hi ↦ ≈127)
//!   q_i   = clamp(round(w_i/scale) + zp, −128, 127)
//!   deq_i = (q_i − zp) · scale
//! ```
//!
//! **Error bound:** rounding is to the nearest of 256 levels spanning
//! `[lo, hi]`, so `|deq_i − w_i| ≤ scale/2 = (hi−lo)/510`, i.e. at most
//! `max|w|/255` of the row's largest-magnitude weight —
//! [`INT8_MAX_ROW_REL_ERR`], asserted by `tests/quant_parity.rs`.
//! Activations, biases, layer-norm parameters and all accumulations stay
//! f32, matching the hardware's f32 special-function path.

use crate::config::{Layer, ModelConfig, Precision};
use anyhow::Result;

use super::tds::{KernelWeights, LaneStates, Scratch, TdsModel, TdsState};

/// Documented per-row relative quantization error bound: for every weight
/// `|dequant(quant(w)) − w| ≤ INT8_MAX_ROW_REL_ERR · max|row|` (with a
/// hair of slack for f32 rounding in the quantizer itself).
pub const INT8_MAX_ROW_REL_ERR: f32 = 1.0 / 255.0;

/// One int8-quantized weight matrix: `[rows × cols]` i8 data plus
/// per-row affine parameters. `zp` is integral-valued but stored as f32
/// because the kernels consume it in f32 accumulation.
#[derive(Debug, Clone)]
pub struct QuantizedWeights {
    pub q: Vec<i8>,
    pub scale: Vec<f32>,
    pub zp: Vec<f32>,
}

/// Quantize a row-major `[rows × cols]` f32 matrix, one affine pair per
/// row.
pub fn quantize_rows(w: &[f32], rows: usize, cols: usize) -> QuantizedWeights {
    assert_eq!(w.len(), rows * cols, "quantize_rows: shape mismatch");
    let mut q = Vec::with_capacity(rows * cols);
    let mut scale = Vec::with_capacity(rows);
    let mut zp = Vec::with_capacity(rows);
    for row in w.chunks_exact(cols.max(1)) {
        let lo = row.iter().cloned().fold(0.0f32, f32::min);
        let hi = row.iter().cloned().fold(0.0f32, f32::max);
        let s = if hi > lo { (hi - lo) / 255.0 } else { 1.0 };
        let z = (-128.0 - lo / s).round();
        scale.push(s);
        zp.push(z);
        for &x in row {
            let v = (x / s).round() + z;
            q.push(v.clamp(-128.0, 127.0) as i8);
        }
    }
    QuantizedWeights { q, scale, zp }
}

/// Dequantize one element of a row (test/diagnostic helper).
pub fn dequantize(qw: &QuantizedWeights, row: usize, cols: usize, col: usize) -> f32 {
    (qw.q[row * cols + col] as f32 - qw.zp[row]) * qw.scale[row]
}

/// Weights for one layer of the quantized model. Conv/FC weights are
/// int8; biases and LayerNorm parameters stay f32 (they are a vanishing
/// fraction of the model bytes and feed the f32 accumulate directly).
#[derive(Debug, Clone)]
enum QLayerWeights {
    Conv { qw: QuantizedWeights, b: Vec<f32> },
    Fc { qw: QuantizedWeights, b: Vec<f32> },
    LayerNorm { g: Vec<f32>, b: Vec<f32> },
}

impl super::tds::AsKernel for QLayerWeights {
    fn kernel(&self) -> KernelWeights<'_> {
        match self {
            QLayerWeights::Conv { qw, b } => KernelWeights::ConvI8 {
                q: &qw.q,
                scale: &qw.scale,
                zp: &qw.zp,
                b,
            },
            QLayerWeights::Fc { qw, b } => KernelWeights::FcI8 {
                q: &qw.q,
                scale: &qw.scale,
                zp: &qw.zp,
                b,
            },
            QLayerWeights::LayerNorm { g, b } => KernelWeights::Ln { g, b },
        }
    }
}

/// The int8-quantized TDS acoustic model. Drop-in for [`TdsModel`] on the
/// serving path: same streaming [`TdsState`] (activations and conv
/// history stay f32), same step entry points, ~4× smaller weight
/// footprint and one-byte-per-MAC weight streams in the hot kernels.
#[derive(Debug, Clone)]
pub struct QuantizedTdsModel {
    pub cfg: ModelConfig,
    layers: Vec<(Layer, QLayerWeights)>,
}

impl QuantizedTdsModel {
    /// Quantize an f32 model. The config is stamped [`Precision::Int8`]
    /// so downstream cost models (accel/power) see int8 weight traffic.
    pub fn from_model(model: &TdsModel) -> Result<Self> {
        let mut layers = Vec::with_capacity(model.layer_count());
        for idx in 0..model.layer_count() {
            let (layer, view) = model.layer_kernel(idx);
            let qlw = match view {
                KernelWeights::ConvF32 { w, b } => {
                    let Layer::Conv { in_ch, out_ch, kw, .. } = layer else {
                        unreachable!("conv weights on non-conv layer")
                    };
                    QLayerWeights::Conv {
                        qw: quantize_rows(w, *out_ch, in_ch * kw),
                        b: b.to_vec(),
                    }
                }
                KernelWeights::FcF32 { w, b } => {
                    let Layer::Fc { in_dim, out_dim, .. } = layer else {
                        unreachable!("fc weights on non-fc layer")
                    };
                    QLayerWeights::Fc {
                        qw: quantize_rows(w, *out_dim, *in_dim),
                        b: b.to_vec(),
                    }
                }
                KernelWeights::Ln { g, b } => QLayerWeights::LayerNorm {
                    g: g.to_vec(),
                    b: b.to_vec(),
                },
                _ => unreachable!("TdsModel only yields f32 kernels"),
            };
            layers.push((layer.clone(), qlw));
        }
        let cfg = ModelConfig { precision: Precision::Int8, ..model.cfg.clone() };
        Ok(QuantizedTdsModel { cfg, layers })
    }

    /// Fresh streaming state — identical layout to [`TdsModel::state`].
    pub fn state(&self) -> TdsState {
        TdsState::for_layers(self.layers.iter().map(|(l, _)| l))
    }

    /// Scratch-arena batched step; see [`TdsModel::step_batch_into`].
    pub fn step_batch_into<S: LaneStates + ?Sized>(
        &self,
        states: &mut S,
        feats: &[f32],
        sc: &mut Scratch,
        out: &mut Vec<f32>,
    ) {
        super::tds::step_batch_driver(&self.cfg, &self.layers, states, feats, sc, out);
    }

    /// Convenience batched step (allocates a fresh scratch per call).
    pub fn step_batch(&self, states: &mut [&mut TdsState], feats: &[f32]) -> Vec<f32> {
        let mut sc = Scratch::default();
        let mut out = Vec::new();
        self.step_batch_into(states, feats, &mut sc, &mut out);
        out
    }

    /// Convenience scalar step (one lane through the batched driver).
    pub fn step(&self, state: &mut TdsState, feats: &[f32]) -> Vec<f32> {
        let mut lanes = [state];
        self.step_batch(&mut lanes, feats)
    }

    /// Total quantized model-data bytes (int8 weights + f32 biases).
    pub fn weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|(_, lw)| match lw {
                QLayerWeights::Conv { qw, b } | QLayerWeights::Fc { qw, b } => {
                    qw.q.len() + 4 * (b.len() + qw.scale.len() + qw.zp.len())
                }
                QLayerWeights::LayerNorm { g, b } => 4 * (g.len() + b.len()),
            })
            .sum()
    }
}

/// Greedy CTC argmax over a `[frames × tokens]` log-prob matrix —
/// convenience for parity diagnostics.
pub fn argmax_path(logps: &[f32], tokens: usize) -> Vec<usize> {
    logps
        .chunks_exact(tokens)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn quantize_dequantize_within_documented_bound() {
        prop::check("quant-row-rel-err", 50, |g| {
            let rows = 1 + g.index(8);
            let cols = 1 + g.index(64);
            let mag = 0.01 + g.rng.uniform(0.0, 4.0);
            let w = g.vec_of(rows * cols, |r| r.uniform(-mag, mag));
            let qw = quantize_rows(&w, rows, cols);
            for r in 0..rows {
                let row = &w[r * cols..(r + 1) * cols];
                let amax = row.iter().fold(0.0f32, |a, v| a.max(v.abs()));
                let bound = INT8_MAX_ROW_REL_ERR * amax.max(f32::EPSILON) + 1e-7;
                for c in 0..cols {
                    let deq = dequantize(&qw, r, cols, c);
                    crate::prop_assert!(
                        (deq - row[c]).abs() <= bound,
                        "row {r} col {c}: |{deq} - {}| > {bound}",
                        row[c]
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_and_constant_rows_are_handled() {
        let qw = quantize_rows(&[0.0; 8], 1, 8);
        for c in 0..8 {
            assert_eq!(dequantize(&qw, 0, 8, c), 0.0);
        }
        // All-positive constant row: lo clamps to 0, hi = c.
        let qw = quantize_rows(&[3.0; 4], 1, 4);
        for c in 0..4 {
            assert!((dequantize(&qw, 0, 4, c) - 3.0).abs() < 3.0 * INT8_MAX_ROW_REL_ERR + 1e-6);
        }
    }

    #[test]
    fn quantized_model_step_shape_and_finiteness() {
        let m = TdsModel::random(ModelConfig::tiny_tds(), 42);
        let qm = QuantizedTdsModel::from_model(&m).unwrap();
        assert_eq!(qm.cfg.precision, Precision::Int8);
        let mut st = qm.state();
        let feats = vec![0.1f32; qm.cfg.frames_per_step() * qm.cfg.n_mels];
        let out = qm.step(&mut st, &feats);
        assert_eq!(out.len(), qm.cfg.vectors_per_step() * qm.cfg.tokens);
        assert!(out.iter().all(|v| v.is_finite()));
        // Log-softmax rows must still normalize.
        for row in out.chunks(qm.cfg.tokens) {
            let total: f32 = row.iter().map(|v| v.exp()).sum();
            assert!((total - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn quantized_logits_track_f32_logits() {
        // Multi-step streaming: int8 log-probs must stay close to f32
        // ones (loose bound — the tight transcript-level guarantee lives
        // in tests/quant_parity.rs).
        let m = TdsModel::random(ModelConfig::tiny_tds(), 7);
        let qm = QuantizedTdsModel::from_model(&m).unwrap();
        let f = m.cfg.frames_per_step() * m.cfg.n_mels;
        let mut rng = Rng::new(5);
        let mut st_f = m.state();
        let mut st_q = qm.state();
        for _ in 0..3 {
            let feats: Vec<f32> = (0..f).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let a = m.step(&mut st_f, &feats);
            let b = qm.step(&mut st_q, &feats);
            assert_eq!(a.len(), b.len());
            let max_diff = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 0.5, "int8 logits drifted {max_diff} from f32");
        }
    }

    #[test]
    fn quantized_weight_bytes_are_roughly_quarter() {
        let m = TdsModel::random(ModelConfig::tiny_tds(), 11);
        let qm = QuantizedTdsModel::from_model(&m).unwrap();
        let f32_bytes: usize = m.cfg.layers().iter().map(|l| l.params() * 4).sum();
        let q_bytes = qm.weight_bytes();
        assert!(
            (q_bytes as f64) < 0.5 * f32_bytes as f64,
            "int8 model {q_bytes} B not ≪ f32 {f32_bytes} B"
        );
    }

    #[test]
    fn batched_quantized_step_matches_scalar_lanes() {
        let m = TdsModel::random(ModelConfig::tiny_tds(), 21);
        let qm = QuantizedTdsModel::from_model(&m).unwrap();
        let f = qm.cfg.frames_per_step() * qm.cfg.n_mels;
        let batch = 3;
        let mut rng = Rng::new(17);
        let mut scalar_states: Vec<TdsState> = (0..batch).map(|_| qm.state()).collect();
        let mut batch_states: Vec<TdsState> = (0..batch).map(|_| qm.state()).collect();
        for _ in 0..2 {
            let feats: Vec<f32> = (0..batch * f).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut refs: Vec<&mut TdsState> = batch_states.iter_mut().collect();
            let fused = qm.step_batch(&mut refs, &feats);
            let lane_out = fused.len() / batch;
            for (l, st) in scalar_states.iter_mut().enumerate() {
                let out = qm.step(st, &feats[l * f..(l + 1) * f]);
                assert_eq!(
                    out,
                    fused[l * lane_out..(l + 1) * lane_out],
                    "int8 lane {l} diverged"
                );
            }
        }
    }
}
