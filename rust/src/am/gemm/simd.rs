//! Explicit `std::arch` SIMD implementations of the AM micro-kernels —
//! AVX2 (8×f32) on x86_64, NEON (4×f32) on aarch64 — selected at runtime
//! by [`super::dispatch`].
//!
//! **Bit-exactness strategy** (the whole point of this module's shape):
//! vectors span *independent outputs only* — batch lanes for the FC
//! kernels, mel-row positions for the conv kernels — never the reduction
//! (`k`) dimension. Every SIMD lane therefore executes the exact scalar
//! op sequence for its output element (bias seed, one mul + one add per
//! `k`, ascending), using separate multiply and add instructions — FMA
//! would contract the intermediate rounding step and break `==` parity
//! with the scalar kernels, so `_mm256_fmadd_ps`/`vfmaq_f32` are banned
//! here. Remainders (batch or width not a multiple of the vector width)
//! fall back to the scalar edge helpers in [`super`], which share the
//! same per-element order.
#![allow(clippy::too_many_arguments)]

#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    //! AVX2 kernels. Every function requires the `avx2` target feature at
    //! runtime; [`super::super::dispatch`] only routes here after
    //! `is_x86_feature_detected!("avx2")` succeeded.

    use std::arch::x86_64::*;

    /// f32 lanes per 256-bit vector.
    const LANES: usize = 8;
    /// Weight rows per FC register tile (matches the scalar kernel).
    const ROWS: usize = super::super::TILE_ROWS;

    /// Strided gather of `LANES` consecutive batch lanes' activation `k`:
    /// `[xs[base], xs[base+stride], …]`. Plain indexed loads into a stack
    /// array, then one vector load — AVX2's hardware gather is slower for
    /// this stride pattern and complicates bounds reasoning.
    #[target_feature(enable = "avx2")]
    unsafe fn gather(xs: &[f32], base: usize, stride: usize) -> __m256 {
        let mut g = [0.0f32; LANES];
        for (c, v) in g.iter_mut().enumerate() {
            *v = xs[base + c * stride];
        }
        _mm256_loadu_ps(g.as_ptr())
    }

    /// `dst[m] += a * src[m]` — one mul + one add per element, the scalar
    /// width-loop op order, 8 elements per instruction, scalar tail.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len();
        let av = _mm256_set1_ps(a);
        let mut m = 0;
        while m + LANES <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(m));
            let s = _mm256_loadu_ps(src.as_ptr().add(m));
            _mm256_storeu_ps(dst.as_mut_ptr().add(m), _mm256_add_ps(d, _mm256_mul_ps(av, s)));
            m += LANES;
        }
        while m < n {
            dst[m] += a * src[m];
            m += 1;
        }
    }

    /// `dst[m] += src[m]` (the int8 conv's window-sum accumulation).
    #[target_feature(enable = "avx2")]
    unsafe fn add_assign(dst: &mut [f32], src: &[f32]) {
        let n = dst.len();
        let mut m = 0;
        while m + LANES <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(m));
            let s = _mm256_loadu_ps(src.as_ptr().add(m));
            _mm256_storeu_ps(dst.as_mut_ptr().add(m), _mm256_add_ps(d, s));
            m += LANES;
        }
        while m < n {
            dst[m] += src[m];
            m += 1;
        }
    }

    /// `dst[m] = bias + scale * (dst[m] - zp * ws[m])` — the int8 conv
    /// finalize; per element the same mul, sub, mul, add sequence as the
    /// scalar kernel.
    #[target_feature(enable = "avx2")]
    unsafe fn affine(dst: &mut [f32], ws: &[f32], bias: f32, scale: f32, zp: f32) {
        let n = dst.len();
        let bv = _mm256_set1_ps(bias);
        let sv = _mm256_set1_ps(scale);
        let zv = _mm256_set1_ps(zp);
        let mut m = 0;
        while m + LANES <= n {
            let v = _mm256_loadu_ps(dst.as_ptr().add(m));
            let s = _mm256_loadu_ps(ws.as_ptr().add(m));
            let t = _mm256_sub_ps(v, _mm256_mul_ps(zv, s));
            _mm256_storeu_ps(dst.as_mut_ptr().add(m), _mm256_add_ps(bv, _mm256_mul_ps(sv, t)));
            m += LANES;
        }
        while m < n {
            dst[m] = bias + scale * (dst[m] - zp * ws[m]);
            m += 1;
        }
    }

    /// Full 4×8 FC register tile: 4 weight rows × 8 batch lanes, one
    /// accumulator vector per row, shared `k` loop.
    #[target_feature(enable = "avx2")]
    unsafe fn fc_tile(
        w: &[f32],
        bias: &[f32],
        xs: &[f32],
        in_dim: usize,
        out_dim: usize,
        o: usize,
        l: usize,
        out: &mut [f32],
    ) {
        let r0 = &w[o * in_dim..][..in_dim];
        let r1 = &w[(o + 1) * in_dim..][..in_dim];
        let r2 = &w[(o + 2) * in_dim..][..in_dim];
        let r3 = &w[(o + 3) * in_dim..][..in_dim];
        let mut acc0 = _mm256_set1_ps(bias[o]);
        let mut acc1 = _mm256_set1_ps(bias[o + 1]);
        let mut acc2 = _mm256_set1_ps(bias[o + 2]);
        let mut acc3 = _mm256_set1_ps(bias[o + 3]);
        for k in 0..in_dim {
            let xg = gather(xs, l * in_dim + k, in_dim);
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(r0[k]), xg));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(r1[k]), xg));
            acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(r2[k]), xg));
            acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(r3[k]), xg));
        }
        let mut buf = [0.0f32; LANES];
        for (r, acc) in [acc0, acc1, acc2, acc3].into_iter().enumerate() {
            _mm256_storeu_ps(buf.as_mut_ptr(), acc);
            for (c, v) in buf.iter().enumerate() {
                out[(l + c) * out_dim + o + r] = *v;
            }
        }
    }

    /// AVX2 [`super::super::fc_batch_into`] body. Shapes must already be
    /// validated by the dispatcher.
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fc_batch(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        let mut o = 0;
        while o < out_dim {
            let rows = ROWS.min(out_dim - o);
            let mut l = 0;
            if rows == ROWS {
                while l + LANES <= batch {
                    fc_tile(w, bias, xs, in_dim, out_dim, o, l, out);
                    l += LANES;
                }
            }
            if l < batch {
                let rem = batch - l;
                super::super::fc_tile_edge(w, bias, xs, in_dim, out_dim, o, l, rows, rem, out);
            }
            o += rows;
        }
    }

    /// AVX2 [`super::super::fc_batch_int8_into`] body: per output row,
    /// 8-lane accumulator blocks over the shared `k` loop; the per-lane
    /// `Σx` pre-pass and the affine finalize stay scalar (identical
    /// expressions to the scalar kernel).
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fc_batch_int8(
        q: &[i8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        xs: &[f32],
        batch: usize,
        xsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        xsum.clear();
        xsum.resize(batch, 0.0);
        for (lane, s) in xsum.iter_mut().enumerate() {
            *s = xs[lane * in_dim..(lane + 1) * in_dim].iter().sum();
        }
        for o in 0..out_dim {
            let row = &q[o * in_dim..][..in_dim];
            let mut l = 0;
            while l + LANES <= batch {
                let mut acc = _mm256_setzero_ps();
                for (k, &qk) in row.iter().enumerate() {
                    let wq = _mm256_set1_ps(qk as f32);
                    let xg = gather(xs, l * in_dim + k, in_dim);
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(wq, xg));
                }
                let mut buf = [0.0f32; LANES];
                _mm256_storeu_ps(buf.as_mut_ptr(), acc);
                for (c, a) in buf.iter().enumerate() {
                    out[(l + c) * out_dim + o] = bias[o] + scale[o] * (a - zp[o] * xsum[l + c]);
                }
                l += LANES;
            }
            if l < batch {
                super::super::fc_int8_lane_edge(
                    row,
                    scale[o],
                    zp[o],
                    bias[o],
                    xs,
                    xsum,
                    in_dim,
                    out_dim,
                    o,
                    l,
                    batch - l,
                    out,
                );
            }
        }
    }

    /// AVX2 [`super::super::conv_steps_into`] body: identical loop nest to
    /// the scalar kernel (including the zero-weight skip), with the
    /// innermost width sweep replaced by [`axpy`].
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn conv_steps(
        w: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(bias[o]);
                }
                for i in 0..in_ch {
                    for k in 0..kw {
                        let wk = w[(o * in_ch + i) * kw + k];
                        if wk == 0.0 {
                            continue;
                        }
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wk,
                            );
                        }
                    }
                }
            }
        }
    }

    /// AVX2 [`super::super::conv_steps_int8_into`] body: window sums,
    /// accumulation and affine finalize all width-vectorized, preserving
    /// the scalar kernel's per-element op sequence.
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn conv_steps_int8(
        q: &[i8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        wsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            wsum.clear();
            wsum.resize(batch * width, 0.0);
            for i in 0..in_ch {
                for k in 0..kw {
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    for (ws, lane_in) in wsum.chunks_exact_mut(width).zip(xblk.chunks_exact(d_in))
                    {
                        add_assign(ws, &lane_in[i * width..(i + 1) * width]);
                    }
                }
            }
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(0.0);
                }
                for i in 0..in_ch {
                    for k in 0..kw {
                        let qk = q[(o * in_ch + i) * kw + k];
                        if qk == 0 {
                            continue;
                        }
                        let wq = qk as f32;
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wq,
                            );
                        }
                    }
                }
                for (lane_out, ws) in out_t.chunks_exact_mut(d_out).zip(wsum.chunks_exact(width))
                {
                    affine(&mut lane_out[o * width..(o + 1) * width], ws, bias[o], scale[o], zp[o]);
                }
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub mod neon {
    //! NEON kernels — the 4-lane mirror of the AVX2 module; same
    //! bit-exactness strategy (independent outputs only, separate
    //! mul + add, scalar tails).

    use std::arch::aarch64::*;

    /// f32 lanes per 128-bit vector.
    const LANES: usize = 4;
    /// Weight rows per FC register tile (matches the scalar kernel).
    const ROWS: usize = super::super::TILE_ROWS;

    /// Strided gather of `LANES` consecutive batch lanes' activation `k`.
    #[target_feature(enable = "neon")]
    unsafe fn gather(xs: &[f32], base: usize, stride: usize) -> float32x4_t {
        let g = [
            xs[base],
            xs[base + stride],
            xs[base + 2 * stride],
            xs[base + 3 * stride],
        ];
        vld1q_f32(g.as_ptr())
    }

    /// `dst[m] += a * src[m]` — scalar op order, 4 elements per
    /// instruction, scalar tail.
    #[target_feature(enable = "neon")]
    unsafe fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len();
        let av = vdupq_n_f32(a);
        let mut m = 0;
        while m + LANES <= n {
            let d = vld1q_f32(dst.as_ptr().add(m));
            let s = vld1q_f32(src.as_ptr().add(m));
            vst1q_f32(dst.as_mut_ptr().add(m), vaddq_f32(d, vmulq_f32(av, s)));
            m += LANES;
        }
        while m < n {
            dst[m] += a * src[m];
            m += 1;
        }
    }

    /// `dst[m] += src[m]` (the int8 conv's window-sum accumulation).
    #[target_feature(enable = "neon")]
    unsafe fn add_assign(dst: &mut [f32], src: &[f32]) {
        let n = dst.len();
        let mut m = 0;
        while m + LANES <= n {
            let d = vld1q_f32(dst.as_ptr().add(m));
            let s = vld1q_f32(src.as_ptr().add(m));
            vst1q_f32(dst.as_mut_ptr().add(m), vaddq_f32(d, s));
            m += LANES;
        }
        while m < n {
            dst[m] += src[m];
            m += 1;
        }
    }

    /// `dst[m] = bias + scale * (dst[m] - zp * ws[m])` — the int8 conv
    /// finalize, scalar mul/sub/mul/add order per element.
    #[target_feature(enable = "neon")]
    unsafe fn affine(dst: &mut [f32], ws: &[f32], bias: f32, scale: f32, zp: f32) {
        let n = dst.len();
        let bv = vdupq_n_f32(bias);
        let sv = vdupq_n_f32(scale);
        let zv = vdupq_n_f32(zp);
        let mut m = 0;
        while m + LANES <= n {
            let v = vld1q_f32(dst.as_ptr().add(m));
            let s = vld1q_f32(ws.as_ptr().add(m));
            let t = vsubq_f32(v, vmulq_f32(zv, s));
            vst1q_f32(dst.as_mut_ptr().add(m), vaddq_f32(bv, vmulq_f32(sv, t)));
            m += LANES;
        }
        while m < n {
            dst[m] = bias + scale * (dst[m] - zp * ws[m]);
            m += 1;
        }
    }

    /// Full 4×4 FC register tile: 4 weight rows × 4 batch lanes.
    #[target_feature(enable = "neon")]
    unsafe fn fc_tile(
        w: &[f32],
        bias: &[f32],
        xs: &[f32],
        in_dim: usize,
        out_dim: usize,
        o: usize,
        l: usize,
        out: &mut [f32],
    ) {
        let r0 = &w[o * in_dim..][..in_dim];
        let r1 = &w[(o + 1) * in_dim..][..in_dim];
        let r2 = &w[(o + 2) * in_dim..][..in_dim];
        let r3 = &w[(o + 3) * in_dim..][..in_dim];
        let mut acc0 = vdupq_n_f32(bias[o]);
        let mut acc1 = vdupq_n_f32(bias[o + 1]);
        let mut acc2 = vdupq_n_f32(bias[o + 2]);
        let mut acc3 = vdupq_n_f32(bias[o + 3]);
        for k in 0..in_dim {
            let xg = gather(xs, l * in_dim + k, in_dim);
            acc0 = vaddq_f32(acc0, vmulq_f32(vdupq_n_f32(r0[k]), xg));
            acc1 = vaddq_f32(acc1, vmulq_f32(vdupq_n_f32(r1[k]), xg));
            acc2 = vaddq_f32(acc2, vmulq_f32(vdupq_n_f32(r2[k]), xg));
            acc3 = vaddq_f32(acc3, vmulq_f32(vdupq_n_f32(r3[k]), xg));
        }
        let mut buf = [0.0f32; LANES];
        for (r, acc) in [acc0, acc1, acc2, acc3].into_iter().enumerate() {
            vst1q_f32(buf.as_mut_ptr(), acc);
            for (c, v) in buf.iter().enumerate() {
                out[(l + c) * out_dim + o + r] = *v;
            }
        }
    }

    /// NEON [`super::super::fc_batch_into`] body.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn fc_batch(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        let mut o = 0;
        while o < out_dim {
            let rows = ROWS.min(out_dim - o);
            let mut l = 0;
            if rows == ROWS {
                while l + LANES <= batch {
                    fc_tile(w, bias, xs, in_dim, out_dim, o, l, out);
                    l += LANES;
                }
            }
            if l < batch {
                let rem = batch - l;
                super::super::fc_tile_edge(w, bias, xs, in_dim, out_dim, o, l, rows, rem, out);
            }
            o += rows;
        }
    }

    /// NEON [`super::super::fc_batch_int8_into`] body.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn fc_batch_int8(
        q: &[i8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        xs: &[f32],
        batch: usize,
        xsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        xsum.clear();
        xsum.resize(batch, 0.0);
        for (lane, s) in xsum.iter_mut().enumerate() {
            *s = xs[lane * in_dim..(lane + 1) * in_dim].iter().sum();
        }
        for o in 0..out_dim {
            let row = &q[o * in_dim..][..in_dim];
            let mut l = 0;
            while l + LANES <= batch {
                let mut acc = vdupq_n_f32(0.0);
                for (k, &qk) in row.iter().enumerate() {
                    let wq = vdupq_n_f32(qk as f32);
                    let xg = gather(xs, l * in_dim + k, in_dim);
                    acc = vaddq_f32(acc, vmulq_f32(wq, xg));
                }
                let mut buf = [0.0f32; LANES];
                vst1q_f32(buf.as_mut_ptr(), acc);
                for (c, a) in buf.iter().enumerate() {
                    out[(l + c) * out_dim + o] = bias[o] + scale[o] * (a - zp[o] * xsum[l + c]);
                }
                l += LANES;
            }
            if l < batch {
                super::super::fc_int8_lane_edge(
                    row,
                    scale[o],
                    zp[o],
                    bias[o],
                    xs,
                    xsum,
                    in_dim,
                    out_dim,
                    o,
                    l,
                    batch - l,
                    out,
                );
            }
        }
    }

    /// NEON [`super::super::conv_steps_into`] body.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn conv_steps(
        w: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(bias[o]);
                }
                for i in 0..in_ch {
                    for k in 0..kw {
                        let wk = w[(o * in_ch + i) * kw + k];
                        if wk == 0.0 {
                            continue;
                        }
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wk,
                            );
                        }
                    }
                }
            }
        }
    }

    /// NEON [`super::super::conv_steps_int8_into`] body.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn conv_steps_int8(
        q: &[i8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        wsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            wsum.clear();
            wsum.resize(batch * width, 0.0);
            for i in 0..in_ch {
                for k in 0..kw {
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    for (ws, lane_in) in wsum.chunks_exact_mut(width).zip(xblk.chunks_exact(d_in))
                    {
                        add_assign(ws, &lane_in[i * width..(i + 1) * width]);
                    }
                }
            }
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(0.0);
                }
                for i in 0..in_ch {
                    for k in 0..kw {
                        let qk = q[(o * in_ch + i) * kw + k];
                        if qk == 0 {
                            continue;
                        }
                        let wq = qk as f32;
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wq,
                            );
                        }
                    }
                }
                for (lane_out, ws) in out_t.chunks_exact_mut(d_out).zip(wsum.chunks_exact(width))
                {
                    affine(&mut lane_out[o * width..(o + 1) * width], ws, bias[o], scale[o], zp[o]);
                }
            }
        }
    }
}
