//! Explicit `std::arch` SIMD implementations of the AM micro-kernels —
//! AVX2 (8×f32) on x86_64, NEON (4×f32) on aarch64 — selected at runtime
//! by [`super::dispatch`].
//!
//! **Bit-exactness strategy** (the whole point of this module's shape):
//! vectors span *independent outputs only* — batch lanes for the FC
//! kernels, mel-row positions for the conv kernels — never the reduction
//! (`k`) dimension. Every SIMD lane therefore executes the exact scalar
//! op sequence for its output element (bias seed, one mul + one add per
//! `k`, ascending), using separate multiply and add instructions — FMA
//! would contract the intermediate rounding step and break `==` parity
//! with the scalar kernels, so `_mm256_fmadd_ps`/`vfmaq_f32` are banned
//! here. Remainders (batch or width not a multiple of the vector width)
//! fall back to the scalar edge helpers in [`super`], which share the
//! same per-element order.
#![allow(clippy::too_many_arguments)]

#[cfg(target_arch = "x86_64")]
pub mod avx2 {
    //! AVX2 kernels. Every function requires the `avx2` target feature at
    //! runtime; [`super::super::dispatch`] only routes here after
    //! `is_x86_feature_detected!("avx2")` succeeded.

    use crate::am::quant::INT4_GROUP;
    use std::arch::x86_64::*;

    /// f32 lanes per 256-bit vector.
    const LANES: usize = 8;
    /// Weight rows per FC register tile (matches the scalar kernel).
    const ROWS: usize = super::super::TILE_ROWS;

    /// Strided gather of `LANES` consecutive batch lanes' activation `k`:
    /// `[xs[base], xs[base+stride], …]`. Plain indexed loads into a stack
    /// array, then one vector load — AVX2's hardware gather is slower for
    /// this stride pattern and complicates bounds reasoning.
    #[target_feature(enable = "avx2")]
    unsafe fn gather(xs: &[f32], base: usize, stride: usize) -> __m256 {
        let mut g = [0.0f32; LANES];
        for (c, v) in g.iter_mut().enumerate() {
            *v = xs[base + c * stride];
        }
        _mm256_loadu_ps(g.as_ptr())
    }

    /// `dst[m] += a * src[m]` — one mul + one add per element, the scalar
    /// width-loop op order, 8 elements per instruction, scalar tail.
    #[target_feature(enable = "avx2")]
    unsafe fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len();
        let av = _mm256_set1_ps(a);
        let mut m = 0;
        while m + LANES <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(m));
            let s = _mm256_loadu_ps(src.as_ptr().add(m));
            _mm256_storeu_ps(dst.as_mut_ptr().add(m), _mm256_add_ps(d, _mm256_mul_ps(av, s)));
            m += LANES;
        }
        while m < n {
            dst[m] += a * src[m];
            m += 1;
        }
    }

    /// `dst[m] += src[m]` (the int8 conv's window-sum accumulation).
    #[target_feature(enable = "avx2")]
    unsafe fn add_assign(dst: &mut [f32], src: &[f32]) {
        let n = dst.len();
        let mut m = 0;
        while m + LANES <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(m));
            let s = _mm256_loadu_ps(src.as_ptr().add(m));
            _mm256_storeu_ps(dst.as_mut_ptr().add(m), _mm256_add_ps(d, s));
            m += LANES;
        }
        while m < n {
            dst[m] += src[m];
            m += 1;
        }
    }

    /// `dst[m] = bias + scale * (dst[m] - zp * ws[m])` — the int8 conv
    /// finalize; per element the same mul, sub, mul, add sequence as the
    /// scalar kernel.
    #[target_feature(enable = "avx2")]
    unsafe fn affine(dst: &mut [f32], ws: &[f32], bias: f32, scale: f32, zp: f32) {
        let n = dst.len();
        let bv = _mm256_set1_ps(bias);
        let sv = _mm256_set1_ps(scale);
        let zv = _mm256_set1_ps(zp);
        let mut m = 0;
        while m + LANES <= n {
            let v = _mm256_loadu_ps(dst.as_ptr().add(m));
            let s = _mm256_loadu_ps(ws.as_ptr().add(m));
            let t = _mm256_sub_ps(v, _mm256_mul_ps(zv, s));
            _mm256_storeu_ps(dst.as_mut_ptr().add(m), _mm256_add_ps(bv, _mm256_mul_ps(sv, t)));
            m += LANES;
        }
        while m < n {
            dst[m] = bias + scale * (dst[m] - zp * ws[m]);
            m += 1;
        }
    }

    /// Full 4×8 FC register tile: 4 weight rows × 8 batch lanes, one
    /// accumulator vector per row, shared `k` loop.
    #[target_feature(enable = "avx2")]
    unsafe fn fc_tile(
        w: &[f32],
        bias: &[f32],
        xs: &[f32],
        in_dim: usize,
        out_dim: usize,
        o: usize,
        l: usize,
        out: &mut [f32],
    ) {
        let r0 = &w[o * in_dim..][..in_dim];
        let r1 = &w[(o + 1) * in_dim..][..in_dim];
        let r2 = &w[(o + 2) * in_dim..][..in_dim];
        let r3 = &w[(o + 3) * in_dim..][..in_dim];
        let mut acc0 = _mm256_set1_ps(bias[o]);
        let mut acc1 = _mm256_set1_ps(bias[o + 1]);
        let mut acc2 = _mm256_set1_ps(bias[o + 2]);
        let mut acc3 = _mm256_set1_ps(bias[o + 3]);
        for k in 0..in_dim {
            let xg = gather(xs, l * in_dim + k, in_dim);
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(r0[k]), xg));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(r1[k]), xg));
            acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(r2[k]), xg));
            acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(r3[k]), xg));
        }
        let mut buf = [0.0f32; LANES];
        for (r, acc) in [acc0, acc1, acc2, acc3].into_iter().enumerate() {
            _mm256_storeu_ps(buf.as_mut_ptr(), acc);
            for (c, v) in buf.iter().enumerate() {
                out[(l + c) * out_dim + o + r] = *v;
            }
        }
    }

    /// AVX2 [`super::super::fc_batch_into`] body. Shapes must already be
    /// validated by the dispatcher.
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fc_batch(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        let mut o = 0;
        while o < out_dim {
            let rows = ROWS.min(out_dim - o);
            let mut l = 0;
            if rows == ROWS {
                while l + LANES <= batch {
                    fc_tile(w, bias, xs, in_dim, out_dim, o, l, out);
                    l += LANES;
                }
            }
            if l < batch {
                let rem = batch - l;
                super::super::fc_tile_edge(w, bias, xs, in_dim, out_dim, o, l, rows, rem, out);
            }
            o += rows;
        }
    }

    /// AVX2 [`super::super::fc_batch_int8_into`] body: per output row,
    /// 8-lane accumulator blocks over the shared `k` loop; the per-lane
    /// `Σx` pre-pass and the affine finalize stay scalar (identical
    /// expressions to the scalar kernel).
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fc_batch_int8(
        q: &[i8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        xs: &[f32],
        batch: usize,
        xsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        xsum.clear();
        xsum.resize(batch, 0.0);
        for (lane, s) in xsum.iter_mut().enumerate() {
            *s = xs[lane * in_dim..(lane + 1) * in_dim].iter().sum();
        }
        for o in 0..out_dim {
            let row = &q[o * in_dim..][..in_dim];
            let mut l = 0;
            while l + LANES <= batch {
                let mut acc = _mm256_setzero_ps();
                for (k, &qk) in row.iter().enumerate() {
                    let wq = _mm256_set1_ps(qk as f32);
                    let xg = gather(xs, l * in_dim + k, in_dim);
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(wq, xg));
                }
                let mut buf = [0.0f32; LANES];
                _mm256_storeu_ps(buf.as_mut_ptr(), acc);
                for (c, a) in buf.iter().enumerate() {
                    out[(l + c) * out_dim + o] = bias[o] + scale[o] * (a - zp[o] * xsum[l + c]);
                }
                l += LANES;
            }
            if l < batch {
                super::super::fc_int8_lane_edge(
                    row,
                    scale[o],
                    zp[o],
                    bias[o],
                    xs,
                    xsum,
                    in_dim,
                    out_dim,
                    o,
                    l,
                    batch - l,
                    out,
                );
            }
        }
    }

    /// AVX2 [`super::super::conv_steps_into`] body: identical loop nest to
    /// the scalar kernel (including the zero-weight skip), with the
    /// innermost width sweep replaced by [`axpy`].
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn conv_steps(
        w: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(bias[o]);
                }
                for i in 0..in_ch {
                    for k in 0..kw {
                        let wk = w[(o * in_ch + i) * kw + k];
                        if wk == 0.0 {
                            continue;
                        }
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wk,
                            );
                        }
                    }
                }
            }
        }
    }

    /// AVX2 [`super::super::conv_steps_int8_into`] body: window sums,
    /// accumulation and affine finalize all width-vectorized, preserving
    /// the scalar kernel's per-element op sequence.
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn conv_steps_int8(
        q: &[i8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        wsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            wsum.clear();
            wsum.resize(batch * width, 0.0);
            for i in 0..in_ch {
                for k in 0..kw {
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    for (ws, lane_in) in wsum.chunks_exact_mut(width).zip(xblk.chunks_exact(d_in))
                    {
                        add_assign(ws, &lane_in[i * width..(i + 1) * width]);
                    }
                }
            }
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(0.0);
                }
                for i in 0..in_ch {
                    for k in 0..kw {
                        let qk = q[(o * in_ch + i) * kw + k];
                        if qk == 0 {
                            continue;
                        }
                        let wq = qk as f32;
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wq,
                            );
                        }
                    }
                }
                for (lane_out, ws) in out_t.chunks_exact_mut(d_out).zip(wsum.chunks_exact(width))
                {
                    affine(&mut lane_out[o * width..(o + 1) * width], ws, bias[o], scale[o], zp[o]);
                }
            }
        }
    }

    /// `dst[m] += scale * (part[m] - zp * ws[m])` — the int4 conv's
    /// per-group affine fold; per element the same mul, sub, mul, add
    /// sequence as the scalar kernel.
    #[target_feature(enable = "avx2")]
    unsafe fn group_fold(dst: &mut [f32], part: &[f32], ws: &[f32], scale: f32, zp: f32) {
        let n = dst.len();
        let sv = _mm256_set1_ps(scale);
        let zv = _mm256_set1_ps(zp);
        let mut m = 0;
        while m + LANES <= n {
            let v = _mm256_loadu_ps(dst.as_ptr().add(m));
            let p = _mm256_loadu_ps(part.as_ptr().add(m));
            let s = _mm256_loadu_ps(ws.as_ptr().add(m));
            let t = _mm256_sub_ps(p, _mm256_mul_ps(zv, s));
            _mm256_storeu_ps(dst.as_mut_ptr().add(m), _mm256_add_ps(v, _mm256_mul_ps(sv, t)));
            m += LANES;
        }
        while m < n {
            dst[m] += scale * (part[m] - zp * ws[m]);
            m += 1;
        }
    }

    /// `dst[m] = bias + scale * dst[m]` — the sparse conv finalize; per
    /// element the scalar kernel's mul-then-add sequence.
    #[target_feature(enable = "avx2")]
    unsafe fn scale_bias(dst: &mut [f32], bias: f32, scale: f32) {
        let n = dst.len();
        let bv = _mm256_set1_ps(bias);
        let sv = _mm256_set1_ps(scale);
        let mut m = 0;
        while m + LANES <= n {
            let v = _mm256_loadu_ps(dst.as_ptr().add(m));
            _mm256_storeu_ps(dst.as_mut_ptr().add(m), _mm256_add_ps(bv, _mm256_mul_ps(sv, v)));
            m += LANES;
        }
        while m < n {
            dst[m] = bias + scale * dst[m];
            m += 1;
        }
    }

    /// AVX2 [`super::super::fc_batch_int4_into`] body: per output row,
    /// 8-lane accumulator blocks over the grouped `k` loop with the
    /// per-group affine fold vectorized across lanes; the per-(lane,
    /// group) `Σx` pre-pass is the shared scalar helper.
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fc_batch_int4(
        packed: &[u8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        xs: &[f32],
        batch: usize,
        gsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        let ng = in_dim.div_ceil(INT4_GROUP);
        let stride = in_dim.div_ceil(2);
        super::super::fc_int4_gsums(xs, batch, in_dim, ng, gsum);
        for o in 0..out_dim {
            let row = &packed[o * stride..][..stride];
            let scale_o = &scale[o * ng..][..ng];
            let zp_o = &zp[o * ng..][..ng];
            let mut l = 0;
            while l + LANES <= batch {
                let mut acc = _mm256_setzero_ps();
                for g in 0..ng {
                    let k_end = ((g + 1) * INT4_GROUP).min(in_dim);
                    let mut gacc = _mm256_setzero_ps();
                    for k in g * INT4_GROUP..k_end {
                        let q = super::super::int4_code_at(row, k);
                        if q == 0 {
                            continue;
                        }
                        let wq = _mm256_set1_ps(q as f32);
                        let xg = gather(xs, l * in_dim + k, in_dim);
                        gacc = _mm256_add_ps(gacc, _mm256_mul_ps(wq, xg));
                    }
                    let gs = gather(gsum, l * ng + g, ng);
                    let t = _mm256_sub_ps(gacc, _mm256_mul_ps(_mm256_set1_ps(zp_o[g]), gs));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(scale_o[g]), t));
                }
                let mut buf = [0.0f32; LANES];
                _mm256_storeu_ps(buf.as_mut_ptr(), _mm256_add_ps(_mm256_set1_ps(bias[o]), acc));
                for (c, v) in buf.iter().enumerate() {
                    out[(l + c) * out_dim + o] = *v;
                }
                l += LANES;
            }
            if l < batch {
                super::super::fc_int4_lane_edge(
                    row,
                    scale_o,
                    zp_o,
                    bias[o],
                    xs,
                    gsum,
                    in_dim,
                    out_dim,
                    ng,
                    o,
                    l,
                    batch - l,
                    out,
                );
            }
        }
    }

    /// AVX2 [`super::super::fc_batch_int4_sparse_into`] body: 8-lane
    /// accumulator blocks over the fixed 2-MACs-per-block stream, branch
    /// free like the scalar kernel.
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fc_batch_int4_sparse(
        vals: &[u8],
        idxs: &[u8],
        scale: &[f32],
        bias: &[f32],
        xs: &[f32],
        batch: usize,
        out: &mut [f32],
    ) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        let nb = in_dim.div_ceil(4);
        for o in 0..out_dim {
            let row_v = &vals[o * nb..][..nb];
            let row_i = &idxs[o * nb..][..nb];
            let mut l = 0;
            while l + LANES <= batch {
                let mut acc = _mm256_setzero_ps();
                for (b, (&v, &ix)) in row_v.iter().zip(row_i).enumerate() {
                    let ((i0, q0), (i1, q1)) = super::super::sparse4_slots(v, ix);
                    let base = b * 4;
                    let x0 = gather(xs, l * in_dim + base + i0, in_dim);
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(q0), x0));
                    let x1 = gather(xs, l * in_dim + base + i1, in_dim);
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(q1), x1));
                }
                let bv = _mm256_set1_ps(bias[o]);
                let sv = _mm256_set1_ps(scale[o]);
                let mut buf = [0.0f32; LANES];
                _mm256_storeu_ps(buf.as_mut_ptr(), _mm256_add_ps(bv, _mm256_mul_ps(sv, acc)));
                for (c, v) in buf.iter().enumerate() {
                    out[(l + c) * out_dim + o] = *v;
                }
                l += LANES;
            }
            if l < batch {
                super::super::fc_sparse_lane_edge(
                    row_v, row_i, scale[o], bias[o], xs, in_dim, out_dim, o, l, batch - l, out,
                );
            }
        }
    }

    /// AVX2 [`super::super::conv_steps_int4_into`] body: identical loop
    /// nest to the scalar kernel (group window sums, per-group partial,
    /// affine fold), with every width sweep vectorized.
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn conv_steps_int4(
        packed: &[u8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        tmp: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        let row_len = in_ch * kw;
        let ng = row_len.div_ceil(INT4_GROUP);
        let stride_b = row_len.div_ceil(2);
        let pos = batch * width;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            tmp.clear();
            tmp.resize((ng + 1) * pos, 0.0);
            let (gsum, part) = tmp.split_at_mut(ng * pos);
            for i in 0..in_ch {
                for k in 0..kw {
                    let g = (i * kw + k) / INT4_GROUP;
                    let gs = &mut gsum[g * pos..][..pos];
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    for (ws, lane_in) in gs.chunks_exact_mut(width).zip(xblk.chunks_exact(d_in)) {
                        add_assign(ws, &lane_in[i * width..(i + 1) * width]);
                    }
                }
            }
            for o in 0..out_ch {
                let row = &packed[o * stride_b..][..stride_b];
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(bias[o]);
                }
                for g in 0..ng {
                    part.fill(0.0);
                    for j in g * INT4_GROUP..((g + 1) * INT4_GROUP).min(row_len) {
                        let q = super::super::int4_code_at(row, j);
                        if q == 0 {
                            continue;
                        }
                        let wq = q as f32;
                        let (i, k) = (j / kw, j % kw);
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        let lanes_in = xblk.chunks_exact(d_in);
                        for (ps, lane_in) in part.chunks_exact_mut(width).zip(lanes_in) {
                            axpy(ps, &lane_in[i * width..(i + 1) * width], wq);
                        }
                    }
                    let (s_g, z_g) = (scale[o * ng + g], zp[o * ng + g]);
                    let gs = &gsum[g * pos..][..pos];
                    for ((lane_out, ps), ws) in out_t
                        .chunks_exact_mut(d_out)
                        .zip(part.chunks_exact(width))
                        .zip(gs.chunks_exact(width))
                    {
                        group_fold(&mut lane_out[o * width..(o + 1) * width], ps, ws, s_g, z_g);
                    }
                }
            }
        }
    }

    /// AVX2 [`super::super::conv_steps_int4_sparse_into`] body: identical
    /// branch-free block loop to the scalar kernel, width sweeps
    /// vectorized.
    ///
    /// # Safety
    /// AVX2 must be available on the executing CPU.
    #[target_feature(enable = "avx2")]
    pub unsafe fn conv_steps_int4_sparse(
        vals: &[u8],
        idxs: &[u8],
        scale: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        let nb = (in_ch * kw).div_ceil(4);
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(0.0);
                }
                for b in 0..nb {
                    let ((i0, q0), (i1, q1)) =
                        super::super::sparse4_slots(vals[o * nb + b], idxs[o * nb + b]);
                    for (slot_j, wq) in [(b * 4 + i0, q0), (b * 4 + i1, q1)] {
                        let (i, k) = (slot_j / kw, slot_j % kw);
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wq,
                            );
                        }
                    }
                }
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    scale_bias(&mut lane_out[o * width..(o + 1) * width], bias[o], scale[o]);
                }
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub mod neon {
    //! NEON kernels — the 4-lane mirror of the AVX2 module; same
    //! bit-exactness strategy (independent outputs only, separate
    //! mul + add, scalar tails).

    use crate::am::quant::INT4_GROUP;
    use std::arch::aarch64::*;

    /// f32 lanes per 128-bit vector.
    const LANES: usize = 4;
    /// Weight rows per FC register tile (matches the scalar kernel).
    const ROWS: usize = super::super::TILE_ROWS;

    /// Strided gather of `LANES` consecutive batch lanes' activation `k`.
    #[target_feature(enable = "neon")]
    unsafe fn gather(xs: &[f32], base: usize, stride: usize) -> float32x4_t {
        let g = [
            xs[base],
            xs[base + stride],
            xs[base + 2 * stride],
            xs[base + 3 * stride],
        ];
        vld1q_f32(g.as_ptr())
    }

    /// `dst[m] += a * src[m]` — scalar op order, 4 elements per
    /// instruction, scalar tail.
    #[target_feature(enable = "neon")]
    unsafe fn axpy(dst: &mut [f32], src: &[f32], a: f32) {
        let n = dst.len();
        let av = vdupq_n_f32(a);
        let mut m = 0;
        while m + LANES <= n {
            let d = vld1q_f32(dst.as_ptr().add(m));
            let s = vld1q_f32(src.as_ptr().add(m));
            vst1q_f32(dst.as_mut_ptr().add(m), vaddq_f32(d, vmulq_f32(av, s)));
            m += LANES;
        }
        while m < n {
            dst[m] += a * src[m];
            m += 1;
        }
    }

    /// `dst[m] += src[m]` (the int8 conv's window-sum accumulation).
    #[target_feature(enable = "neon")]
    unsafe fn add_assign(dst: &mut [f32], src: &[f32]) {
        let n = dst.len();
        let mut m = 0;
        while m + LANES <= n {
            let d = vld1q_f32(dst.as_ptr().add(m));
            let s = vld1q_f32(src.as_ptr().add(m));
            vst1q_f32(dst.as_mut_ptr().add(m), vaddq_f32(d, s));
            m += LANES;
        }
        while m < n {
            dst[m] += src[m];
            m += 1;
        }
    }

    /// `dst[m] = bias + scale * (dst[m] - zp * ws[m])` — the int8 conv
    /// finalize, scalar mul/sub/mul/add order per element.
    #[target_feature(enable = "neon")]
    unsafe fn affine(dst: &mut [f32], ws: &[f32], bias: f32, scale: f32, zp: f32) {
        let n = dst.len();
        let bv = vdupq_n_f32(bias);
        let sv = vdupq_n_f32(scale);
        let zv = vdupq_n_f32(zp);
        let mut m = 0;
        while m + LANES <= n {
            let v = vld1q_f32(dst.as_ptr().add(m));
            let s = vld1q_f32(ws.as_ptr().add(m));
            let t = vsubq_f32(v, vmulq_f32(zv, s));
            vst1q_f32(dst.as_mut_ptr().add(m), vaddq_f32(bv, vmulq_f32(sv, t)));
            m += LANES;
        }
        while m < n {
            dst[m] = bias + scale * (dst[m] - zp * ws[m]);
            m += 1;
        }
    }

    /// Full 4×4 FC register tile: 4 weight rows × 4 batch lanes.
    #[target_feature(enable = "neon")]
    unsafe fn fc_tile(
        w: &[f32],
        bias: &[f32],
        xs: &[f32],
        in_dim: usize,
        out_dim: usize,
        o: usize,
        l: usize,
        out: &mut [f32],
    ) {
        let r0 = &w[o * in_dim..][..in_dim];
        let r1 = &w[(o + 1) * in_dim..][..in_dim];
        let r2 = &w[(o + 2) * in_dim..][..in_dim];
        let r3 = &w[(o + 3) * in_dim..][..in_dim];
        let mut acc0 = vdupq_n_f32(bias[o]);
        let mut acc1 = vdupq_n_f32(bias[o + 1]);
        let mut acc2 = vdupq_n_f32(bias[o + 2]);
        let mut acc3 = vdupq_n_f32(bias[o + 3]);
        for k in 0..in_dim {
            let xg = gather(xs, l * in_dim + k, in_dim);
            acc0 = vaddq_f32(acc0, vmulq_f32(vdupq_n_f32(r0[k]), xg));
            acc1 = vaddq_f32(acc1, vmulq_f32(vdupq_n_f32(r1[k]), xg));
            acc2 = vaddq_f32(acc2, vmulq_f32(vdupq_n_f32(r2[k]), xg));
            acc3 = vaddq_f32(acc3, vmulq_f32(vdupq_n_f32(r3[k]), xg));
        }
        let mut buf = [0.0f32; LANES];
        for (r, acc) in [acc0, acc1, acc2, acc3].into_iter().enumerate() {
            vst1q_f32(buf.as_mut_ptr(), acc);
            for (c, v) in buf.iter().enumerate() {
                out[(l + c) * out_dim + o + r] = *v;
            }
        }
    }

    /// NEON [`super::super::fc_batch_into`] body.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn fc_batch(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        let mut o = 0;
        while o < out_dim {
            let rows = ROWS.min(out_dim - o);
            let mut l = 0;
            if rows == ROWS {
                while l + LANES <= batch {
                    fc_tile(w, bias, xs, in_dim, out_dim, o, l, out);
                    l += LANES;
                }
            }
            if l < batch {
                let rem = batch - l;
                super::super::fc_tile_edge(w, bias, xs, in_dim, out_dim, o, l, rows, rem, out);
            }
            o += rows;
        }
    }

    /// NEON [`super::super::fc_batch_int8_into`] body.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn fc_batch_int8(
        q: &[i8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        xs: &[f32],
        batch: usize,
        xsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        xsum.clear();
        xsum.resize(batch, 0.0);
        for (lane, s) in xsum.iter_mut().enumerate() {
            *s = xs[lane * in_dim..(lane + 1) * in_dim].iter().sum();
        }
        for o in 0..out_dim {
            let row = &q[o * in_dim..][..in_dim];
            let mut l = 0;
            while l + LANES <= batch {
                let mut acc = vdupq_n_f32(0.0);
                for (k, &qk) in row.iter().enumerate() {
                    let wq = vdupq_n_f32(qk as f32);
                    let xg = gather(xs, l * in_dim + k, in_dim);
                    acc = vaddq_f32(acc, vmulq_f32(wq, xg));
                }
                let mut buf = [0.0f32; LANES];
                vst1q_f32(buf.as_mut_ptr(), acc);
                for (c, a) in buf.iter().enumerate() {
                    out[(l + c) * out_dim + o] = bias[o] + scale[o] * (a - zp[o] * xsum[l + c]);
                }
                l += LANES;
            }
            if l < batch {
                super::super::fc_int8_lane_edge(
                    row,
                    scale[o],
                    zp[o],
                    bias[o],
                    xs,
                    xsum,
                    in_dim,
                    out_dim,
                    o,
                    l,
                    batch - l,
                    out,
                );
            }
        }
    }

    /// NEON [`super::super::conv_steps_into`] body.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn conv_steps(
        w: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(bias[o]);
                }
                for i in 0..in_ch {
                    for k in 0..kw {
                        let wk = w[(o * in_ch + i) * kw + k];
                        if wk == 0.0 {
                            continue;
                        }
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wk,
                            );
                        }
                    }
                }
            }
        }
    }

    /// NEON [`super::super::conv_steps_int8_into`] body.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn conv_steps_int8(
        q: &[i8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        wsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            wsum.clear();
            wsum.resize(batch * width, 0.0);
            for i in 0..in_ch {
                for k in 0..kw {
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    for (ws, lane_in) in wsum.chunks_exact_mut(width).zip(xblk.chunks_exact(d_in))
                    {
                        add_assign(ws, &lane_in[i * width..(i + 1) * width]);
                    }
                }
            }
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(0.0);
                }
                for i in 0..in_ch {
                    for k in 0..kw {
                        let qk = q[(o * in_ch + i) * kw + k];
                        if qk == 0 {
                            continue;
                        }
                        let wq = qk as f32;
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wq,
                            );
                        }
                    }
                }
                for (lane_out, ws) in out_t.chunks_exact_mut(d_out).zip(wsum.chunks_exact(width))
                {
                    affine(&mut lane_out[o * width..(o + 1) * width], ws, bias[o], scale[o], zp[o]);
                }
            }
        }
    }

    /// `dst[m] += scale * (part[m] - zp * ws[m])` — the int4 conv's
    /// per-group affine fold, scalar mul/sub/mul/add order per element.
    #[target_feature(enable = "neon")]
    unsafe fn group_fold(dst: &mut [f32], part: &[f32], ws: &[f32], scale: f32, zp: f32) {
        let n = dst.len();
        let sv = vdupq_n_f32(scale);
        let zv = vdupq_n_f32(zp);
        let mut m = 0;
        while m + LANES <= n {
            let v = vld1q_f32(dst.as_ptr().add(m));
            let p = vld1q_f32(part.as_ptr().add(m));
            let s = vld1q_f32(ws.as_ptr().add(m));
            let t = vsubq_f32(p, vmulq_f32(zv, s));
            vst1q_f32(dst.as_mut_ptr().add(m), vaddq_f32(v, vmulq_f32(sv, t)));
            m += LANES;
        }
        while m < n {
            dst[m] += scale * (part[m] - zp * ws[m]);
            m += 1;
        }
    }

    /// `dst[m] = bias + scale * dst[m]` — the sparse conv finalize,
    /// scalar mul-then-add order per element.
    #[target_feature(enable = "neon")]
    unsafe fn scale_bias(dst: &mut [f32], bias: f32, scale: f32) {
        let n = dst.len();
        let bv = vdupq_n_f32(bias);
        let sv = vdupq_n_f32(scale);
        let mut m = 0;
        while m + LANES <= n {
            let v = vld1q_f32(dst.as_ptr().add(m));
            vst1q_f32(dst.as_mut_ptr().add(m), vaddq_f32(bv, vmulq_f32(sv, v)));
            m += LANES;
        }
        while m < n {
            dst[m] = bias + scale * dst[m];
            m += 1;
        }
    }

    /// NEON [`super::super::fc_batch_int4_into`] body — the 4-lane
    /// mirror of the AVX2 kernel.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn fc_batch_int4(
        packed: &[u8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        xs: &[f32],
        batch: usize,
        gsum: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        let ng = in_dim.div_ceil(INT4_GROUP);
        let stride = in_dim.div_ceil(2);
        super::super::fc_int4_gsums(xs, batch, in_dim, ng, gsum);
        for o in 0..out_dim {
            let row = &packed[o * stride..][..stride];
            let scale_o = &scale[o * ng..][..ng];
            let zp_o = &zp[o * ng..][..ng];
            let mut l = 0;
            while l + LANES <= batch {
                let mut acc = vdupq_n_f32(0.0);
                for g in 0..ng {
                    let k_end = ((g + 1) * INT4_GROUP).min(in_dim);
                    let mut gacc = vdupq_n_f32(0.0);
                    for k in g * INT4_GROUP..k_end {
                        let q = super::super::int4_code_at(row, k);
                        if q == 0 {
                            continue;
                        }
                        let wq = vdupq_n_f32(q as f32);
                        let xg = gather(xs, l * in_dim + k, in_dim);
                        gacc = vaddq_f32(gacc, vmulq_f32(wq, xg));
                    }
                    let gs = gather(gsum, l * ng + g, ng);
                    let t = vsubq_f32(gacc, vmulq_f32(vdupq_n_f32(zp_o[g]), gs));
                    acc = vaddq_f32(acc, vmulq_f32(vdupq_n_f32(scale_o[g]), t));
                }
                let mut buf = [0.0f32; LANES];
                vst1q_f32(buf.as_mut_ptr(), vaddq_f32(vdupq_n_f32(bias[o]), acc));
                for (c, v) in buf.iter().enumerate() {
                    out[(l + c) * out_dim + o] = *v;
                }
                l += LANES;
            }
            if l < batch {
                super::super::fc_int4_lane_edge(
                    row,
                    scale_o,
                    zp_o,
                    bias[o],
                    xs,
                    gsum,
                    in_dim,
                    out_dim,
                    ng,
                    o,
                    l,
                    batch - l,
                    out,
                );
            }
        }
    }

    /// NEON [`super::super::fc_batch_int4_sparse_into`] body — the
    /// 4-lane mirror of the AVX2 kernel.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn fc_batch_int4_sparse(
        vals: &[u8],
        idxs: &[u8],
        scale: &[f32],
        bias: &[f32],
        xs: &[f32],
        batch: usize,
        out: &mut [f32],
    ) {
        let out_dim = bias.len();
        let in_dim = xs.len() / batch;
        let nb = in_dim.div_ceil(4);
        for o in 0..out_dim {
            let row_v = &vals[o * nb..][..nb];
            let row_i = &idxs[o * nb..][..nb];
            let mut l = 0;
            while l + LANES <= batch {
                let mut acc = vdupq_n_f32(0.0);
                for (b, (&v, &ix)) in row_v.iter().zip(row_i).enumerate() {
                    let ((i0, q0), (i1, q1)) = super::super::sparse4_slots(v, ix);
                    let base = b * 4;
                    let x0 = gather(xs, l * in_dim + base + i0, in_dim);
                    acc = vaddq_f32(acc, vmulq_f32(vdupq_n_f32(q0), x0));
                    let x1 = gather(xs, l * in_dim + base + i1, in_dim);
                    acc = vaddq_f32(acc, vmulq_f32(vdupq_n_f32(q1), x1));
                }
                let bv = vdupq_n_f32(bias[o]);
                let sv = vdupq_n_f32(scale[o]);
                let mut buf = [0.0f32; LANES];
                vst1q_f32(buf.as_mut_ptr(), vaddq_f32(bv, vmulq_f32(sv, acc)));
                for (c, v) in buf.iter().enumerate() {
                    out[(l + c) * out_dim + o] = *v;
                }
                l += LANES;
            }
            if l < batch {
                super::super::fc_sparse_lane_edge(
                    row_v, row_i, scale[o], bias[o], xs, in_dim, out_dim, o, l, batch - l, out,
                );
            }
        }
    }

    /// NEON [`super::super::conv_steps_int4_into`] body — the 4-lane
    /// mirror of the AVX2 kernel.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn conv_steps_int4(
        packed: &[u8],
        scale: &[f32],
        zp: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        tmp: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        let row_len = in_ch * kw;
        let ng = row_len.div_ceil(INT4_GROUP);
        let stride_b = row_len.div_ceil(2);
        let pos = batch * width;
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            tmp.clear();
            tmp.resize((ng + 1) * pos, 0.0);
            let (gsum, part) = tmp.split_at_mut(ng * pos);
            for i in 0..in_ch {
                for k in 0..kw {
                    let g = (i * kw + k) / INT4_GROUP;
                    let gs = &mut gsum[g * pos..][..pos];
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    for (ws, lane_in) in gs.chunks_exact_mut(width).zip(xblk.chunks_exact(d_in)) {
                        add_assign(ws, &lane_in[i * width..(i + 1) * width]);
                    }
                }
            }
            for o in 0..out_ch {
                let row = &packed[o * stride_b..][..stride_b];
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(bias[o]);
                }
                for g in 0..ng {
                    part.fill(0.0);
                    for j in g * INT4_GROUP..((g + 1) * INT4_GROUP).min(row_len) {
                        let q = super::super::int4_code_at(row, j);
                        if q == 0 {
                            continue;
                        }
                        let wq = q as f32;
                        let (i, k) = (j / kw, j % kw);
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        let lanes_in = xblk.chunks_exact(d_in);
                        for (ps, lane_in) in part.chunks_exact_mut(width).zip(lanes_in) {
                            axpy(ps, &lane_in[i * width..(i + 1) * width], wq);
                        }
                    }
                    let (s_g, z_g) = (scale[o * ng + g], zp[o * ng + g]);
                    let gs = &gsum[g * pos..][..pos];
                    for ((lane_out, ps), ws) in out_t
                        .chunks_exact_mut(d_out)
                        .zip(part.chunks_exact(width))
                        .zip(gs.chunks_exact(width))
                    {
                        group_fold(&mut lane_out[o * width..(o + 1) * width], ps, ws, s_g, z_g);
                    }
                }
            }
        }
    }

    /// NEON [`super::super::conv_steps_int4_sparse_into`] body — the
    /// 4-lane mirror of the AVX2 kernel.
    ///
    /// # Safety
    /// NEON must be available on the executing CPU.
    #[target_feature(enable = "neon")]
    pub unsafe fn conv_steps_int4_sparse(
        vals: &[u8],
        idxs: &[u8],
        scale: &[f32],
        bias: &[f32],
        ext: &[f32],
        t_out: usize,
        stride: usize,
        batch: usize,
        in_ch: usize,
        out_ch: usize,
        kw: usize,
        width: usize,
        out: &mut [f32],
    ) {
        let d_in = in_ch * width;
        let d_out = out_ch * width;
        let in_block = batch * d_in;
        let out_block = batch * d_out;
        let nb = (in_ch * kw).div_ceil(4);
        for t in 0..t_out {
            let out_t = &mut out[t * out_block..][..out_block];
            let base = t * stride;
            for o in 0..out_ch {
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    lane_out[o * width..(o + 1) * width].fill(0.0);
                }
                for b in 0..nb {
                    let ((i0, q0), (i1, q1)) =
                        super::super::sparse4_slots(vals[o * nb + b], idxs[o * nb + b]);
                    for (slot_j, wq) in [(b * 4 + i0, q0), (b * 4 + i1, q1)] {
                        let (i, k) = (slot_j / kw, slot_j % kw);
                        let xblk = &ext[(base + k) * in_block..][..in_block];
                        for (lane_out, lane_in) in
                            out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                        {
                            axpy(
                                &mut lane_out[o * width..(o + 1) * width],
                                &lane_in[i * width..(i + 1) * width],
                                wq,
                            );
                        }
                    }
                }
                for lane_out in out_t.chunks_exact_mut(d_out) {
                    scale_bias(&mut lane_out[o * width..(o + 1) * width], bias[o], scale[o]);
                }
            }
        }
    }
}
