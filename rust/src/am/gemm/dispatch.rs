//! Runtime ISA dispatch for the AM micro-kernels.
//!
//! The host ISA is detected **once** per process (`is_x86_feature_detected!`
//! on x86_64, `is_aarch64_feature_detected!` on aarch64) and every public
//! kernel in [`super`] (the `am::gemm` module) routes through
//! [`active`] to either the explicit SIMD implementation in `gemm::simd`
//! or the scalar register-blocked kernel. Because the SIMD kernels
//! vectorize only across *independent* outputs (never the reduction
//! dimension — see the parity contract in `am::gemm`), the ISA choice is
//! purely a throughput knob: results are bit-identical under every ISA,
//! which `tests/simd_parity.rs` asserts.
//!
//! Two override mechanisms exist, in precedence order:
//!
//! 1. a **thread-local** forced ISA installed by [`with_forced_isa`] —
//!    used by the parity tests and the A/B legs of
//!    `benches/gemm_kernels.rs`;
//! 2. the **`ASRPU_KERNEL_ISA`** environment variable
//!    (`scalar` | `avx2` | `neon`), read once and cached — used by the
//!    forced-scalar CI matrix leg. An unknown or unsupported-on-this-host
//!    value falls back to the detected ISA (`scalar` is always honored).

use std::cell::Cell;
use std::sync::OnceLock;

/// Instruction set the AM kernels dispatch to at runtime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelIsa {
    /// Portable register-blocked Rust (the PR 2 kernels) — the oracle
    /// every SIMD path must match bit-for-bit.
    Scalar,
    /// x86_64 AVX2: 256-bit vectors, 8 f32 lanes.
    Avx2,
    /// aarch64 NEON: 128-bit vectors, 4 f32 lanes.
    Neon,
}

impl KernelIsa {
    /// Stable lower-case name (the `ASRPU_KERNEL_ISA` vocabulary and the
    /// `kernel_isa` value in serving `config` / bench JSON rows).
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelIsa::Scalar => "scalar",
            KernelIsa::Avx2 => "avx2",
            KernelIsa::Neon => "neon",
        }
    }

    /// Parse [`Self::as_str`] output (case-insensitive). `None` for
    /// anything outside the vocabulary.
    pub fn parse(s: &str) -> Option<KernelIsa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelIsa::Scalar),
            "avx2" => Some(KernelIsa::Avx2),
            "neon" => Some(KernelIsa::Neon),
            _ => None,
        }
    }

    /// f32 lanes per vector register (1 for scalar).
    pub fn simd_lanes(&self) -> usize {
        match self {
            KernelIsa::Scalar => 1,
            KernelIsa::Avx2 => 8,
            KernelIsa::Neon => 4,
        }
    }

    /// The ISA the kernels will use on this thread right now —
    /// convenience alias for [`active`].
    pub fn active() -> KernelIsa {
        active()
    }
}

impl std::fmt::Display for KernelIsa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The best ISA this host supports (ignores overrides).
pub fn detect() -> KernelIsa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return KernelIsa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return KernelIsa::Neon;
        }
    }
    KernelIsa::Scalar
}

/// Whether `isa`'s kernels can actually run on this host. `Scalar` is
/// always supported; a SIMD ISA only when it is the detected one.
pub fn supported(isa: KernelIsa) -> bool {
    isa == KernelIsa::Scalar || isa == detect()
}

/// Process-wide configured ISA: `ASRPU_KERNEL_ISA` when set, valid and
/// supported, else [`detect`]. Read once, cached.
fn configured() -> KernelIsa {
    static CONFIGURED: OnceLock<KernelIsa> = OnceLock::new();
    *CONFIGURED.get_or_init(|| match std::env::var("ASRPU_KERNEL_ISA") {
        Ok(v) if !v.trim().is_empty() => match KernelIsa::parse(&v) {
            Some(isa) if supported(isa) => isa,
            _ => detect(),
        },
        _ => detect(),
    })
}

thread_local! {
    /// Thread-local override installed by [`with_forced_isa`]. Thread-local
    /// (not process-wide) so parity tests and bench A/B legs cannot race
    /// the shard workers, which keep dispatching on their own threads.
    static FORCED: Cell<Option<KernelIsa>> = const { Cell::new(None) };
}

/// The ISA the kernels dispatch to on this thread: the
/// [`with_forced_isa`] override if one is installed, else the
/// process-wide configured ISA.
pub fn active() -> KernelIsa {
    FORCED.with(|f| f.get()).unwrap_or_else(configured)
}

/// Run `f` with the kernels forced to `isa` on this thread, restoring the
/// previous override afterwards (also on panic/unwind). An ISA this host
/// cannot execute is clamped to `Scalar` rather than faulting.
pub fn with_forced_isa<T>(isa: KernelIsa, f: impl FnOnce() -> T) -> T {
    let clamped = if supported(isa) { isa } else { KernelIsa::Scalar };
    struct Restore(Option<KernelIsa>);
    impl Drop for Restore {
        fn drop(&mut self) {
            FORCED.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(FORCED.with(|c| c.replace(Some(clamped))));
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for isa in [KernelIsa::Scalar, KernelIsa::Avx2, KernelIsa::Neon] {
            assert_eq!(KernelIsa::parse(isa.as_str()), Some(isa));
            assert_eq!(KernelIsa::parse(&isa.as_str().to_uppercase()), Some(isa));
        }
        assert_eq!(KernelIsa::parse("avx512"), None);
        assert_eq!(KernelIsa::parse(""), None);
    }

    #[test]
    fn lane_widths_match_register_sizes() {
        assert_eq!(KernelIsa::Scalar.simd_lanes(), 1);
        assert_eq!(KernelIsa::Avx2.simd_lanes(), 8);
        assert_eq!(KernelIsa::Neon.simd_lanes(), 4);
    }

    #[test]
    fn scalar_is_always_supported_and_detect_is_self_consistent() {
        assert!(supported(KernelIsa::Scalar));
        assert!(supported(detect()));
    }

    #[test]
    fn forced_isa_applies_and_restores() {
        let outer = active();
        with_forced_isa(KernelIsa::Scalar, || {
            assert_eq!(active(), KernelIsa::Scalar);
            // Nesting: the inner override wins, then unwinds.
            with_forced_isa(detect(), || assert_eq!(active(), detect()));
            assert_eq!(active(), KernelIsa::Scalar);
        });
        assert_eq!(active(), outer);
    }

    #[test]
    fn forced_isa_restores_on_panic() {
        let outer = active();
        let r = std::panic::catch_unwind(|| {
            with_forced_isa(KernelIsa::Scalar, || panic!("boom"));
        });
        assert!(r.is_err());
        assert_eq!(active(), outer);
    }

    #[test]
    fn unsupported_force_clamps_to_scalar() {
        // At most one of AVX2/NEON is the detected ISA, so the other is
        // unsupported on every host and must clamp.
        let foreign = match detect() {
            KernelIsa::Avx2 => KernelIsa::Neon,
            _ => KernelIsa::Avx2,
        };
        if !supported(foreign) {
            with_forced_isa(foreign, || assert_eq!(active(), KernelIsa::Scalar));
        }
    }
}
