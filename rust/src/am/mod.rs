//! Acoustic model: native TDS inference (streaming + offline), weight
//! loading, int8 quantization and the compute kernels it is built from
//! (§2.2, §3.4, §4.2).
//!
//! Layering: [`gemm`] holds the register-blocked micro-kernels (f32 and
//! int8) and their runtime-dispatched AVX2/NEON SIMD variants
//! ([`gemm::dispatch`] picks the ISA once per process; every ISA is
//! bit-identical), [`tds`] the streaming step driver and scratch arena
//! shared by [`TdsModel`] (f32) and [`quant::QuantizedTdsModel`] (int8
//! weights), and [`ops`] the naive reference primitives the tiled
//! kernels are verified bit-exact against.

pub mod gemm;
pub mod ops;
pub mod quant;
pub mod tds;

pub use gemm::dispatch::KernelIsa;
pub use quant::QuantizedTdsModel;
pub use tds::{LaneStates, Scratch, TdsModel, TdsState};
