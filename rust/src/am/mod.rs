//! Acoustic model: native TDS inference (streaming + offline), weight
//! loading, sub-f32 weight quantization (int8, packed int4, 2:4
//! structured-sparse int4) and the compute kernels it is built from
//! (§2.2, §3.4, §4.2).
//!
//! Layering: [`gemm`] holds the register-blocked micro-kernels (f32 and
//! every quantized format) and their runtime-dispatched AVX2/NEON SIMD
//! variants ([`gemm::dispatch`] picks the ISA once per process; every
//! ISA is bit-identical), [`tds`] the streaming step driver and scratch
//! arena shared by [`TdsModel`] (f32) and [`quant::QuantizedTdsModel`]
//! (quantized weights, uniform or mixed per layer), and [`ops`] the
//! naive reference primitives the tiled kernels are verified bit-exact
//! against.

pub mod gemm;
pub mod ops;
pub mod quant;
pub mod tds;

pub use gemm::dispatch::KernelIsa;
pub use quant::{Int4Weights, QuantizedTdsModel, SparseInt4Weights};
pub use tds::{LaneStates, Scratch, TdsModel, TdsState};
