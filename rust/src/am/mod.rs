//! Acoustic model: native TDS inference (streaming + offline), weight
//! loading and the dense primitives it is built from (§2.2, §4.2).

pub mod ops;
pub mod tds;

pub use tds::{TdsModel, TdsState};
