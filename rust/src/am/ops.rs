//! Dense primitives for the native TDS acoustic model: causal temporal
//! convolution over (channels × mel-width) timesteps, fully-connected
//! layers, layer normalization and log-softmax.
//!
//! Timestep layout: a timestep is a flat `[channels × width]` vector,
//! channel-major (`v[ch * width + mel]`) — the "view a spectrogram as
//! channels over mel bands" convention of the TDS paper, mirrored by
//! `python/compile/model.py`.
//!
//! Every primitive also has a **lane-batched** variant operating on
//! `[B × D]` row-major blocks (lane-major: lane `l`'s timestep is
//! `x[l*D .. (l+1)*D]`). Batched variants perform the exact same
//! floating-point operations in the exact same order per lane as the
//! scalar functions — B-lane output is bit-identical to B independent
//! scalar calls (asserted by `tests/batch_parity.rs`) — while streaming
//! each weight row once across all lanes, which is where the batched
//! serving path gets its memory-bandwidth amortization.
//!
//! These are the **reference (naive) kernels**: simple, obviously
//! correct, and the bit-exactness oracle for the register-blocked tiled
//! kernels in [`super::gemm`] that the serving path actually executes
//! (`benches/gemm_kernels.rs` measures the gap). `layer_norm`,
//! `log_softmax` and `relu` remain the production implementations.

/// `y = W·x + b` where `w` is row-major `[out_dim × in_dim]`.
pub fn fc(w: &[f32], b: &[f32], x: &[f32], out: &mut Vec<f32>) {
    let in_dim = x.len();
    let out_dim = b.len();
    debug_assert_eq!(w.len(), in_dim * out_dim);
    out.clear();
    for o in 0..out_dim {
        let row = &w[o * in_dim..(o + 1) * in_dim];
        let mut acc = b[o];
        // Plain loop: rustc autovectorizes this; profiled in §Perf.
        for (wi, xi) in row.iter().zip(x) {
            acc += wi * xi;
        }
        out.push(acc);
    }
}

/// Lane-batched [`fc`]: `xs` is `[batch × in_dim]` row-major, `out`
/// becomes `[batch × out_dim]`. Each weight row is loaded once and
/// applied to every lane, so at B lanes the weight matrix is streamed
/// from memory once instead of B times.
pub fn fc_batch(w: &[f32], b: &[f32], xs: &[f32], batch: usize, out: &mut Vec<f32>) {
    assert!(batch > 0, "fc_batch needs at least one lane");
    debug_assert_eq!(xs.len() % batch, 0);
    let in_dim = xs.len() / batch;
    let out_dim = b.len();
    debug_assert_eq!(w.len(), in_dim * out_dim);
    out.clear();
    out.resize(batch * out_dim, 0.0);
    for o in 0..out_dim {
        let row = &w[o * in_dim..(o + 1) * in_dim];
        for lane in 0..batch {
            let x = &xs[lane * in_dim..(lane + 1) * in_dim];
            let mut acc = b[o];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out[lane * out_dim + o] = acc;
        }
    }
}

pub fn relu_inplace(x: &mut [f32]) {
    for v in x {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Layer norm over the whole timestep vector with learned gain/bias.
pub fn layer_norm(gain: &[f32], bias: &[f32], x: &mut [f32], eps: f32) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + eps).sqrt();
    for (i, v) in x.iter_mut().enumerate() {
        *v = (*v - mean) * inv * gain[i] + bias[i];
    }
}

/// Lane-batched [`layer_norm`]: `x` is `[batch × dim]` row-major; each
/// lane is normalized independently (identical op order per lane).
pub fn layer_norm_batch(gain: &[f32], bias: &[f32], x: &mut [f32], batch: usize, eps: f32) {
    assert!(batch > 0, "layer_norm_batch needs at least one lane");
    debug_assert_eq!(x.len() % batch, 0);
    let dim = x.len() / batch;
    for lane in x.chunks_mut(dim) {
        layer_norm(gain, bias, lane, eps);
    }
}

/// Numerically-stable log-softmax.
///
/// The max fold is seeded with `NEG_INFINITY` (not `f32::MIN`) so rows
/// containing `-inf` logits are handled exactly: finite entries dominate
/// the max and `-inf` entries keep zero probability. An all-`-inf` row
/// has no mass anywhere; it normalizes to the uniform distribution
/// (`-ln n`), the only output that preserves the `Σ exp = 1` contract
/// (the old `f32::MIN` seed produced a row of NaNs).
pub fn log_softmax(x: &mut [f32]) {
    let max = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if max == f32::NEG_INFINITY {
        let uniform = -(x.len().max(1) as f32).ln();
        for v in x.iter_mut() {
            *v = uniform;
        }
        return;
    }
    let mut sum = 0.0f32;
    for v in x.iter() {
        sum += (v - max).exp();
    }
    let lse = max + sum.ln();
    for v in x.iter_mut() {
        *v -= lse;
    }
}

/// Lane-batched [`log_softmax`]: `x` is `[batch × dim]` row-major.
pub fn log_softmax_batch(x: &mut [f32], batch: usize) {
    assert!(batch > 0, "log_softmax_batch needs at least one lane");
    debug_assert_eq!(x.len() % batch, 0);
    let dim = x.len() / batch;
    for lane in x.chunks_mut(dim) {
        log_softmax(lane);
    }
}

/// Causal temporal conv at one output position.
///
/// `window` holds `kw` timesteps (oldest first), each `[in_ch × width]`;
/// `w` is `[out_ch × in_ch × kw]`; output is `[out_ch × width]`.
#[allow(clippy::too_many_arguments)]
pub fn conv_step(
    w: &[f32],
    b: &[f32],
    window: &[&[f32]],
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(window.len(), kw);
    debug_assert_eq!(w.len(), out_ch * in_ch * kw);
    out.clear();
    out.resize(out_ch * width, 0.0);
    for o in 0..out_ch {
        let out_row = &mut out[o * width..(o + 1) * width];
        for v in out_row.iter_mut() {
            *v = b[o];
        }
        for i in 0..in_ch {
            for k in 0..kw {
                let wk = w[(o * in_ch + i) * kw + k];
                if wk == 0.0 {
                    continue;
                }
                let x_row = &window[k][i * width..(i + 1) * width];
                for (v, x) in out_row.iter_mut().zip(x_row) {
                    *v += wk * x;
                }
            }
        }
    }
}

/// Lane-batched [`conv_step`]: each `window` entry is `[batch × in_ch ×
/// width]` row-major (lane-major), `out` becomes `[batch × out_ch ×
/// width]`. Each weight scalar is loaded once per (o, i, k) and swept
/// across every lane's mel row.
#[allow(clippy::too_many_arguments)]
pub fn conv_step_batch(
    w: &[f32],
    b: &[f32],
    window: &[&[f32]],
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut Vec<f32>,
) {
    assert!(batch > 0, "conv_step_batch needs at least one lane");
    debug_assert_eq!(window.len(), kw);
    debug_assert_eq!(w.len(), out_ch * in_ch * kw);
    let lane_in = in_ch * width;
    let lane_out = out_ch * width;
    out.clear();
    out.resize(batch * lane_out, 0.0);
    for o in 0..out_ch {
        for lane in 0..batch {
            let base = lane * lane_out + o * width;
            for v in out[base..base + width].iter_mut() {
                *v = b[o];
            }
        }
        for i in 0..in_ch {
            for k in 0..kw {
                let wk = w[(o * in_ch + i) * kw + k];
                if wk == 0.0 {
                    continue;
                }
                for lane in 0..batch {
                    let x_start = lane * lane_in + i * width;
                    let x_row = &window[k][x_start..x_start + width];
                    let base = lane * lane_out + o * width;
                    for (v, x) in out[base..base + width].iter_mut().zip(x_row) {
                        *v += wk * x;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn fc_identity() {
        // 2x2 identity matrix.
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![0.5, -0.5];
        let mut out = Vec::new();
        fc(&w, &b, &[3.0, 4.0], &mut out);
        assert_eq!(out, vec![3.5, 3.5]);
    }

    #[test]
    fn relu_clamps() {
        let mut x = vec![-1.0, 0.0, 2.0];
        relu_inplace(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 2.0]);
    }

    #[test]
    fn layer_norm_standardizes() {
        let gain = vec![1.0; 8];
        let bias = vec![0.0; 8];
        let mut x: Vec<f32> = (0..8).map(|i| i as f32 * 3.0 + 1.0).collect();
        layer_norm(&gain, &bias, &mut x, 1e-5);
        let mean: f32 = x.iter().sum::<f32>() / 8.0;
        let var: f32 = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 8.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn log_softmax_normalizes() {
        prop::check("log-softmax-normalizes", 30, |g| {
            let n = g.len(2).max(2);
            let mut x = g.vec_of(n, |r| r.uniform(-20.0, 20.0));
            log_softmax(&mut x);
            let total: f32 = x.iter().map(|v| v.exp()).sum();
            crate::prop_assert!((total - 1.0).abs() < 1e-4, "sum(exp) = {total}");
            crate::prop_assert!(x.iter().all(|v| *v <= 1e-6), "log-prob above 0");
            Ok(())
        });
    }

    #[test]
    fn log_softmax_handles_neg_infinity_rows() {
        // All-(-inf): normalizes to uniform (old f32::MIN seed gave NaN).
        let mut x = vec![f32::NEG_INFINITY; 4];
        log_softmax(&mut x);
        for v in &x {
            assert!((v - (-(4.0f32).ln())).abs() < 1e-6, "got {v}");
        }
        let total: f32 = x.iter().map(|v| v.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        // Mixed row: -inf entries keep zero probability, finite ones
        // normalize among themselves.
        let mut x = vec![f32::NEG_INFINITY, 0.0, 0.0, f32::NEG_INFINITY];
        log_softmax(&mut x);
        assert_eq!(x[0], f32::NEG_INFINITY);
        assert_eq!(x[3], f32::NEG_INFINITY);
        assert!((x[1] - (-(2.0f32).ln())).abs() < 1e-6);
        // Extreme-negative finite rows stay finite and normalized.
        let mut x = vec![-3.0e38, -3.0e38];
        log_softmax(&mut x);
        let total: f32 = x.iter().map(|v| v.exp()).sum();
        assert!(x.iter().all(|v| v.is_finite()));
        assert!((total - 1.0).abs() < 1e-4);
    }

    #[test]
    fn log_softmax_preserves_argmax() {
        let mut x = vec![0.1, 5.0, -3.0, 4.9];
        log_softmax(&mut x);
        let arg = x
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(arg, 1);
    }

    #[test]
    fn conv_step_impulse_weight_selects_timestep() {
        // kw=3, single channel, width=4; weight only on k=0 (oldest).
        let w = vec![1.0, 0.0, 0.0];
        let b = vec![0.0];
        let t0 = vec![1.0, 2.0, 3.0, 4.0];
        let t1 = vec![9.0; 4];
        let t2 = vec![7.0; 4];
        let window: Vec<&[f32]> = vec![&t0, &t1, &t2];
        let mut out = Vec::new();
        conv_step(&w, &b, &window, 1, 1, 3, 4, &mut out);
        assert_eq!(out, t0);
    }

    #[test]
    fn fc_batch_matches_scalar_lanes() {
        prop::check("fc-batch-parity", 30, |g| {
            let in_dim = g.len(1).min(24).max(1);
            let out_dim = g.len(1).min(16).max(1);
            let batch = 1 + g.index(5);
            let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-1.0, 1.0));
            let b = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-2.0, 2.0));
            let mut batched = Vec::new();
            fc_batch(&w, &b, &xs, batch, &mut batched);
            let mut lane_out = Vec::new();
            for lane in 0..batch {
                fc(&w, &b, &xs[lane * in_dim..(lane + 1) * in_dim], &mut lane_out);
                crate::prop_assert!(
                    lane_out == batched[lane * out_dim..(lane + 1) * out_dim],
                    "lane {lane} diverged"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn layer_norm_and_log_softmax_batch_match_scalar() {
        prop::check("ln-lsm-batch-parity", 30, |g| {
            let dim = g.len(2).min(32).max(2);
            let batch = 1 + g.index(5);
            let gain = g.vec_of(dim, |r| r.uniform(0.5, 1.5));
            let bias = g.vec_of(dim, |r| r.uniform(-0.5, 0.5));
            let xs = g.vec_of(batch * dim, |r| r.uniform(-4.0, 4.0));
            let mut a = xs.clone();
            layer_norm_batch(&gain, &bias, &mut a, batch, 1e-5);
            log_softmax_batch(&mut a, batch);
            let mut b = xs;
            for lane in b.chunks_mut(dim) {
                layer_norm(&gain, &bias, lane, 1e-5);
                log_softmax(lane);
            }
            crate::prop_assert!(a == b, "batched LN/log-softmax diverged");
            Ok(())
        });
    }

    #[test]
    fn conv_step_batch_matches_scalar_lanes() {
        prop::check("conv-batch-parity", 20, |g| {
            let in_ch = 1 + g.index(3);
            let out_ch = 1 + g.index(3);
            let kw = 1 + g.index(3);
            let width = 1 + g.index(6);
            let batch = 1 + g.index(4);
            let w = g.vec_of(out_ch * in_ch * kw, |r| r.uniform(-1.0, 1.0));
            let b = g.vec_of(out_ch, |r| r.uniform(-0.5, 0.5));
            // Batched window: kw blocks of [batch × in_ch × width].
            let blocks: Vec<Vec<f32>> = (0..kw)
                .map(|_| g.vec_of(batch * in_ch * width, |r| r.uniform(-2.0, 2.0)))
                .collect();
            let window: Vec<&[f32]> = blocks.iter().map(|v| v.as_slice()).collect();
            let mut batched = Vec::new();
            conv_step_batch(&w, &b, &window, batch, in_ch, out_ch, kw, width, &mut batched);
            let lane_in = in_ch * width;
            let lane_out = out_ch * width;
            let mut scalar = Vec::new();
            for lane in 0..batch {
                let lane_win: Vec<&[f32]> = blocks
                    .iter()
                    .map(|blk| &blk[lane * lane_in..(lane + 1) * lane_in])
                    .collect();
                conv_step(&w, &b, &lane_win, in_ch, out_ch, kw, width, &mut scalar);
                crate::prop_assert!(
                    scalar == batched[lane * lane_out..(lane + 1) * lane_out],
                    "lane {lane} diverged"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn conv_step_channel_mixing() {
        // 2 in-ch → 1 out-ch, kw=1, width=2: out = 2*chan0 + 3*chan1 + b.
        let w = vec![2.0, 3.0];
        let b = vec![1.0];
        let t = vec![1.0, 2.0, 10.0, 20.0]; // ch0=[1,2], ch1=[10,20]
        let window: Vec<&[f32]> = vec![&t];
        let mut out = Vec::new();
        conv_step(&w, &b, &window, 2, 1, 1, 2, &mut out);
        assert_eq!(out, vec![2.0 + 30.0 + 1.0, 4.0 + 60.0 + 1.0]);
    }
}
