//! Register-blocked micro-kernels for the AM hot path — the compute core
//! behind [`super::TdsModel`] and [`super::QuantizedTdsModel`].
//!
//! Layout contract (shared with `am::tds`):
//!  * activations are lane-major `[batch × dim]` blocks, one block per
//!    timestep; conv layers see all timesteps of a decoding step as one
//!    contiguous `ext` buffer of `(kw-1) + T` such blocks (history first),
//!    so a window is a contiguous slice — no per-position pointer chasing;
//!  * weights are row-major `[out × in]` (f32) or `[out × in]` int8 with
//!    per-output-row affine parameters (see [`super::quant`]);
//!  * every kernel writes into a caller-sized `&mut [f32]`, so the caller
//!    (the scratch-arena step driver) fully controls allocation.
//!
//! Blocking: the f32 FC kernel tiles `TILE_ROWS` weight rows ×
//! `TILE_LANES` lanes and keeps the 4×4 accumulator block in registers
//! through the shared `k` loop — each weight load feeds 4 lanes and each
//! activation load feeds 4 rows, which is what lets rustc autovectorize
//! the body to FMA-shaped code without losing IEEE semantics. Convolution
//! kernels hoist each weight scalar once per `(out_ch, in_ch, k)` and
//! sweep it across every lane's mel row (width-vectorized).
//!
//! **Parity contract:** for every f32 output element the floating-point
//! reduction order is IDENTICAL to the naive scalar kernels in
//! [`super::ops`] — one accumulator per output, seeded with the bias,
//! `k` ascending. Register blocking only interleaves *independent*
//! reductions, so results are bit-exact (`==`), not approximately equal;
//! `tests` below and `tests/batch_parity.rs` assert this. (rustc does not
//! contract `a*b + c` to fma without explicit opt-in, so the comparison
//! is stable across optimization levels.)
//!
//! **Runtime SIMD dispatch:** each public entry point routes through
//! [`dispatch::active`] to either the scalar kernel (`*_scalar_into`,
//! always available, the oracle) or an explicit `std::arch`
//! implementation in `simd` — AVX2 on x86_64, NEON on aarch64. The SIMD
//! kernels vectorize across independent outputs only (batch lanes for
//! FC, mel-row positions for conv) and use separate mul + add
//! instructions (never FMA), so they inherit the same parity contract:
//! every ISA produces bit-identical results, asserted by
//! `tests/simd_parity.rs`. Force an ISA with `ASRPU_KERNEL_ISA=scalar`
//! (process-wide) or [`dispatch::with_forced_isa`] (per thread).

pub mod dispatch;
mod simd;

/// Weight rows per register tile.
pub const TILE_ROWS: usize = 4;
/// Lanes (batch columns) per register tile.
pub const TILE_LANES: usize = 4;

/// Batched `[batch × out] = [batch × in] · Wᵀ + b`, dispatched to the
/// active ISA (see [`dispatch`]). `xs` is lane-major `[batch × in_dim]`,
/// `out` must be `batch * bias.len()` long. Bit-identical to
/// [`fc_batch_scalar_into`] under every ISA.
pub fn fc_batch_into(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_fc_shapes(w, bias, xs, batch, out);
            unsafe { simd::avx2::fc_batch(w, bias, xs, batch, out) }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_fc_shapes(w, bias, xs, batch, out);
            unsafe { simd::neon::fc_batch(w, bias, xs, batch, out) }
        }
        _ => fc_batch_scalar_into(w, bias, xs, batch, out),
    }
}

/// Shared shape validation for the FC dispatchers (the SIMD bodies trust
/// their caller).
fn check_fc_shapes(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &[f32]) {
    assert!(batch > 0, "fc kernels need at least one lane");
    debug_assert_eq!(xs.len() % batch, 0);
    debug_assert_eq!(w.len(), (xs.len() / batch) * bias.len());
    debug_assert_eq!(out.len(), batch * bias.len());
}

/// Tiled scalar `[batch × out] = [batch × in] · Wᵀ + b` — the
/// register-blocked reference path every SIMD kernel must match
/// bit-for-bit.
pub fn fc_batch_scalar_into(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
    assert!(batch > 0, "fc_batch_scalar_into needs at least one lane");
    let out_dim = bias.len();
    debug_assert_eq!(xs.len() % batch, 0);
    let in_dim = xs.len() / batch;
    debug_assert_eq!(w.len(), in_dim * out_dim);
    debug_assert_eq!(out.len(), batch * out_dim);
    let mut o = 0;
    while o < out_dim {
        let rows = TILE_ROWS.min(out_dim - o);
        let mut l = 0;
        while l < batch {
            let lanes = TILE_LANES.min(batch - l);
            if rows == TILE_ROWS && lanes == TILE_LANES {
                fc_tile_4x4(w, bias, xs, in_dim, out_dim, o, l, out);
            } else {
                fc_tile_edge(w, bias, xs, in_dim, out_dim, o, l, rows, lanes, out);
            }
            l += lanes;
        }
        o += rows;
    }
}

/// Full 4×4 register tile: 16 accumulators, shared `k` loop.
#[allow(clippy::too_many_arguments)]
#[inline]
fn fc_tile_4x4(
    w: &[f32],
    bias: &[f32],
    xs: &[f32],
    in_dim: usize,
    out_dim: usize,
    o: usize,
    l: usize,
    out: &mut [f32],
) {
    let r0 = &w[o * in_dim..][..in_dim];
    let r1 = &w[(o + 1) * in_dim..][..in_dim];
    let r2 = &w[(o + 2) * in_dim..][..in_dim];
    let r3 = &w[(o + 3) * in_dim..][..in_dim];
    let x0 = &xs[l * in_dim..][..in_dim];
    let x1 = &xs[(l + 1) * in_dim..][..in_dim];
    let x2 = &xs[(l + 2) * in_dim..][..in_dim];
    let x3 = &xs[(l + 3) * in_dim..][..in_dim];
    let mut acc = [[0.0f32; TILE_LANES]; TILE_ROWS];
    for (r, acc_row) in acc.iter_mut().enumerate() {
        *acc_row = [bias[o + r]; TILE_LANES];
    }
    for k in 0..in_dim {
        let wv = [r0[k], r1[k], r2[k], r3[k]];
        let xv = [x0[k], x1[k], x2[k], x3[k]];
        for (acc_row, wr) in acc.iter_mut().zip(wv) {
            for (a, xc) in acc_row.iter_mut().zip(xv) {
                *a += wr * xc;
            }
        }
    }
    for (r, acc_row) in acc.iter().enumerate() {
        for (c, a) in acc_row.iter().enumerate() {
            out[(l + c) * out_dim + o + r] = *a;
        }
    }
}

/// Ragged edge tile (rows < 4 or lanes < 4): same per-output reduction
/// order, plain loops.
#[allow(clippy::too_many_arguments)]
#[inline]
fn fc_tile_edge(
    w: &[f32],
    bias: &[f32],
    xs: &[f32],
    in_dim: usize,
    out_dim: usize,
    o: usize,
    l: usize,
    rows: usize,
    lanes: usize,
    out: &mut [f32],
) {
    for r in 0..rows {
        let row = &w[(o + r) * in_dim..][..in_dim];
        for c in 0..lanes {
            let x = &xs[(l + c) * in_dim..][..in_dim];
            let mut acc = bias[o + r];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out[(l + c) * out_dim + o + r] = acc;
        }
    }
}

/// Reference (naive) batched FC — one output at a time, weight matrix
/// re-streamed per lane. Kept for the `benches/gemm_kernels.rs` sweep and
/// as the bit-exactness oracle for the tiled kernel.
pub fn fc_batch_naive_into(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
    assert!(batch > 0);
    let out_dim = bias.len();
    let in_dim = xs.len() / batch;
    debug_assert_eq!(out.len(), batch * out_dim);
    for lane in 0..batch {
        let x = &xs[lane * in_dim..(lane + 1) * in_dim];
        for o in 0..out_dim {
            let row = &w[o * in_dim..(o + 1) * in_dim];
            let mut acc = bias[o];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out[lane * out_dim + o] = acc;
        }
    }
}

/// Int8-weight FC with per-output-row affine parameters and f32
/// accumulation:
///
/// `y[l][o] = bias[o] + scale[o] · (Σₖ q[o][k]·x[l][k] − zp[o] · Σₖ x[l][k])`
///
/// which is algebraically `Σ dequant(q)·x + bias` with the per-row
/// constants factored out of the inner loop — the weight stream is one
/// byte per MAC. `xsum` is a reusable per-lane Σx scratch buffer.
/// Dispatched to the active ISA; because accumulation is f32 (not i32),
/// the SIMD paths vectorize across batch lanes — independent outputs —
/// exactly like the f32 kernel, so results stay bit-identical (`==`) to
/// [`fc_batch_int8_scalar_into`] under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int8_into(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    xsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_fc_int8_shapes(q, scale, zp, bias, xs, batch, out);
            unsafe { simd::avx2::fc_batch_int8(q, scale, zp, bias, xs, batch, xsum, out) }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_fc_int8_shapes(q, scale, zp, bias, xs, batch, out);
            unsafe { simd::neon::fc_batch_int8(q, scale, zp, bias, xs, batch, xsum, out) }
        }
        _ => fc_batch_int8_scalar_into(q, scale, zp, bias, xs, batch, xsum, out),
    }
}

/// Shared shape validation for the int8 FC dispatcher.
fn check_fc_int8_shapes(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    out: &[f32],
) {
    assert!(batch > 0, "fc_batch_int8_into needs at least one lane");
    debug_assert_eq!(xs.len() % batch, 0);
    debug_assert_eq!(q.len(), (xs.len() / batch) * bias.len());
    debug_assert_eq!(scale.len(), bias.len());
    debug_assert_eq!(zp.len(), bias.len());
    debug_assert_eq!(out.len(), batch * bias.len());
}

/// Ragged lane block of the int8 FC — the lanes beyond the last full
/// SIMD block. Per-lane scalar accumulation with the same per-element op
/// order as the blocked paths (zero seed, `k` ascending, affine
/// finalize), shared by the scalar and SIMD kernels.
#[allow(clippy::too_many_arguments)]
#[inline]
fn fc_int8_lane_edge(
    row: &[i8],
    scale_o: f32,
    zp_o: f32,
    bias_o: f32,
    xs: &[f32],
    xsum: &[f32],
    in_dim: usize,
    out_dim: usize,
    o: usize,
    l: usize,
    lanes: usize,
    out: &mut [f32],
) {
    for c in 0..lanes {
        let x = &xs[(l + c) * in_dim..][..in_dim];
        let mut acc = 0.0f32;
        for (&qk, &xk) in row.iter().zip(x) {
            acc += qk as f32 * xk;
        }
        out[(l + c) * out_dim + o] = bias_o + scale_o * (acc - zp_o * xsum[l + c]);
    }
}

/// Scalar (lane-blocked) int8 FC — the reference path for
/// [`fc_batch_int8_into`].
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int8_scalar_into(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    xsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert!(batch > 0, "fc_batch_int8_scalar_into needs at least one lane");
    let out_dim = bias.len();
    debug_assert_eq!(xs.len() % batch, 0);
    let in_dim = xs.len() / batch;
    debug_assert_eq!(q.len(), in_dim * out_dim);
    debug_assert_eq!(scale.len(), out_dim);
    debug_assert_eq!(zp.len(), out_dim);
    debug_assert_eq!(out.len(), batch * out_dim);
    xsum.clear();
    xsum.resize(batch, 0.0);
    for (lane, s) in xsum.iter_mut().enumerate() {
        *s = xs[lane * in_dim..(lane + 1) * in_dim].iter().sum();
    }
    // Lane-blocked only: each weight byte is widened to f32 once and
    // feeds up to TILE_LANES lanes (row blocking buys nothing here — the
    // i8→f32 convert, not weight bandwidth, bounds the inner loop).
    for o in 0..out_dim {
        let row = &q[o * in_dim..][..in_dim];
        let mut l = 0;
        while l < batch {
            let lanes = TILE_LANES.min(batch - l);
            let mut acc = [0.0f32; TILE_LANES];
            for (k, &qk) in row.iter().enumerate() {
                let wq = qk as f32;
                for (c, a) in acc.iter_mut().take(lanes).enumerate() {
                    *a += wq * xs[(l + c) * in_dim + k];
                }
            }
            for (c, a) in acc.iter().take(lanes).enumerate() {
                out[(l + c) * out_dim + o] =
                    bias[o] + scale[o] * (a - zp[o] * xsum[l + c]);
            }
            l += lanes;
        }
    }
}

/// All `t_out` output positions of a causal temporal convolution over a
/// contiguous `ext` buffer of `(kw-1) + t_out·stride` lane-major
/// `[batch × in_ch·width]` timestep blocks (conv history first). Output
/// is `t_out` blocks of `[batch × out_ch·width]`.
///
/// Per output element the reduction order matches [`super::ops::conv_step`]
/// exactly: bias seed, then `(in_ch, k)` ascending, zero weights skipped.
/// Dispatched to the active ISA (the SIMD paths vectorize the width
/// sweep — independent output positions — and keep the same loop nest),
/// bit-identical to [`conv_steps_scalar_into`] under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_into(
    w: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_conv_shapes(
                w.len(),
                bias,
                ext,
                t_out,
                stride,
                batch,
                in_ch,
                out_ch,
                kw,
                width,
                out,
            );
            unsafe {
                simd::avx2::conv_steps(
                    w, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, out,
                )
            }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_conv_shapes(
                w.len(),
                bias,
                ext,
                t_out,
                stride,
                batch,
                in_ch,
                out_ch,
                kw,
                width,
                out,
            );
            unsafe {
                simd::neon::conv_steps(
                    w, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, out,
                )
            }
        }
        _ => conv_steps_scalar_into(
            w, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, out,
        ),
    }
}

/// Shared shape validation for the conv dispatchers (`w_len` is the
/// weight element count, so one helper serves the f32 and int8 forms).
#[allow(clippy::too_many_arguments)]
fn check_conv_shapes(
    w_len: usize,
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &[f32],
) {
    assert!(batch > 0, "conv kernels need at least one lane");
    debug_assert_eq!(bias.len(), out_ch);
    debug_assert_eq!(w_len, out_ch * in_ch * kw);
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * batch * in_ch * width);
    debug_assert_eq!(out.len(), t_out * batch * out_ch * width);
}

/// Scalar causal temporal convolution — the reference path for
/// [`conv_steps_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_scalar_into(
    w: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut [f32],
) {
    assert!(batch > 0, "conv_steps_scalar_into needs at least one lane");
    let d_in = in_ch * width;
    let d_out = out_ch * width;
    let in_block = batch * d_in;
    let out_block = batch * d_out;
    debug_assert_eq!(w.len(), out_ch * in_ch * kw);
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * in_block);
    debug_assert_eq!(out.len(), t_out * out_block);
    for t in 0..t_out {
        let out_t = &mut out[t * out_block..][..out_block];
        let base = t * stride;
        for o in 0..out_ch {
            for lane_out in out_t.chunks_exact_mut(d_out) {
                lane_out[o * width..(o + 1) * width].fill(bias[o]);
            }
            for i in 0..in_ch {
                for k in 0..kw {
                    let wk = w[(o * in_ch + i) * kw + k];
                    if wk == 0.0 {
                        continue;
                    }
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    // wk stays in a register while it sweeps every lane's
                    // mel row (the width loop autovectorizes).
                    for (lane_out, lane_in) in
                        out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                    {
                        let dst = &mut lane_out[o * width..(o + 1) * width];
                        let src = &lane_in[i * width..(i + 1) * width];
                        for (v, x) in dst.iter_mut().zip(src) {
                            *v += wk * x;
                        }
                    }
                }
            }
        }
    }
}

/// Int8-weight causal temporal convolution, per-output-channel affine
/// parameters, f32 accumulate:
///
/// `y[o][m] = bias[o] + scale[o] · (Σᵢₖ q[o][i][k]·x[i][k][m] − zp[o]·W[m])`
///
/// where `W[m] = Σᵢₖ x[i][k][m]` is the per-position window sum, computed
/// once per timestep into the reusable `wsum` buffer (`batch × width`)
/// and shared by every output channel. Dispatched to the active ISA;
/// accumulation is f32, so the SIMD paths vectorize the width sweep like
/// the f32 conv and stay bit-identical (`==`) to
/// [`conv_steps_int8_scalar_into`] under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int8_into(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    wsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_conv_shapes(
                q.len(),
                bias,
                ext,
                t_out,
                stride,
                batch,
                in_ch,
                out_ch,
                kw,
                width,
                out,
            );
            unsafe {
                simd::avx2::conv_steps_int8(
                    q, scale, zp, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width,
                    wsum, out,
                )
            }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_conv_shapes(
                q.len(),
                bias,
                ext,
                t_out,
                stride,
                batch,
                in_ch,
                out_ch,
                kw,
                width,
                out,
            );
            unsafe {
                simd::neon::conv_steps_int8(
                    q, scale, zp, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width,
                    wsum, out,
                )
            }
        }
        _ => conv_steps_int8_scalar_into(
            q, scale, zp, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, wsum, out,
        ),
    }
}

/// Scalar int8 causal temporal convolution — the reference path for
/// [`conv_steps_int8_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int8_scalar_into(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    wsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert!(batch > 0, "conv_steps_int8_scalar_into needs at least one lane");
    let d_in = in_ch * width;
    let d_out = out_ch * width;
    let in_block = batch * d_in;
    let out_block = batch * d_out;
    debug_assert_eq!(q.len(), out_ch * in_ch * kw);
    debug_assert_eq!(scale.len(), out_ch);
    debug_assert_eq!(zp.len(), out_ch);
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * in_block);
    debug_assert_eq!(out.len(), t_out * out_block);
    for t in 0..t_out {
        let out_t = &mut out[t * out_block..][..out_block];
        let base = t * stride;
        // Window sums, shared across output channels.
        wsum.clear();
        wsum.resize(batch * width, 0.0);
        for i in 0..in_ch {
            for k in 0..kw {
                let xblk = &ext[(base + k) * in_block..][..in_block];
                for (ws, lane_in) in wsum.chunks_exact_mut(width).zip(xblk.chunks_exact(d_in)) {
                    let src = &lane_in[i * width..(i + 1) * width];
                    for (s, x) in ws.iter_mut().zip(src) {
                        *s += x;
                    }
                }
            }
        }
        for o in 0..out_ch {
            for lane_out in out_t.chunks_exact_mut(d_out) {
                lane_out[o * width..(o + 1) * width].fill(0.0);
            }
            for i in 0..in_ch {
                for k in 0..kw {
                    let qk = q[(o * in_ch + i) * kw + k];
                    if qk == 0 {
                        continue;
                    }
                    let wq = qk as f32;
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    for (lane_out, lane_in) in
                        out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                    {
                        let dst = &mut lane_out[o * width..(o + 1) * width];
                        let src = &lane_in[i * width..(i + 1) * width];
                        for (v, x) in dst.iter_mut().zip(src) {
                            *v += wq * x;
                        }
                    }
                }
            }
            // Finalize: apply the affine transform.
            for (lane_out, ws) in out_t.chunks_exact_mut(d_out).zip(wsum.chunks_exact(width)) {
                let dst = &mut lane_out[o * width..(o + 1) * width];
                for (v, s) in dst.iter_mut().zip(ws) {
                    *v = bias[o] + scale[o] * (*v - zp[o] * s);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::ops;
    use crate::util::prop;

    #[test]
    fn tiled_fc_is_bit_exact_vs_naive() {
        // All edge-tile shapes: dims and batches around the 4×4 tile.
        prop::check("gemm-fc-tiled-vs-naive", 60, |g| {
            let in_dim = 1 + g.index(40);
            let out_dim = 1 + g.index(24);
            let batch = 1 + g.index(10);
            let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-1.5, 1.5));
            let b = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-3.0, 3.0));
            let mut tiled = vec![0.0; batch * out_dim];
            let mut naive = vec![0.0; batch * out_dim];
            fc_batch_into(&w, &b, &xs, batch, &mut tiled);
            fc_batch_naive_into(&w, &b, &xs, batch, &mut naive);
            crate::prop_assert!(tiled == naive, "tiled FC diverged from naive");
            Ok(())
        });
    }

    #[test]
    fn tiled_fc_matches_scalar_ops_fc() {
        prop::check("gemm-fc-vs-ops-fc", 40, |g| {
            let in_dim = 1 + g.index(32);
            let out_dim = 1 + g.index(16);
            let batch = 1 + g.index(6);
            let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-1.0, 1.0));
            let b = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-2.0, 2.0));
            let mut tiled = vec![0.0; batch * out_dim];
            fc_batch_into(&w, &b, &xs, batch, &mut tiled);
            let mut lane = Vec::new();
            for l in 0..batch {
                ops::fc(&w, &b, &xs[l * in_dim..(l + 1) * in_dim], &mut lane);
                crate::prop_assert!(
                    lane == tiled[l * out_dim..(l + 1) * out_dim],
                    "lane {l} diverged from scalar fc"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn conv_steps_matches_per_position_ops_conv() {
        prop::check("gemm-conv-vs-ops-conv", 30, |g| {
            let in_ch = 1 + g.index(3);
            let out_ch = 1 + g.index(3);
            let kw = 1 + g.index(4);
            let width = 1 + g.index(8);
            let batch = 1 + g.index(5);
            let stride = 1 + g.index(2);
            let t_out = 1 + g.index(3);
            let t_in = t_out * stride;
            let d_in = in_ch * width;
            let in_block = batch * d_in;
            let w = g.vec_of(out_ch * in_ch * kw, |r| r.uniform(-1.0, 1.0));
            let b = g.vec_of(out_ch, |r| r.uniform(-0.5, 0.5));
            let ext = g.vec_of((kw - 1 + t_in) * in_block, |r| r.uniform(-2.0, 2.0));
            let out_block = batch * out_ch * width;
            let mut fused = vec![0.0; t_out * out_block];
            conv_steps_into(
                &w, &b, &ext, t_out, stride, batch, in_ch, out_ch, kw, width, &mut fused,
            );
            // Oracle: per-position per-lane scalar conv_step over slices.
            let mut scalar = Vec::new();
            for t in 0..t_out {
                for lane in 0..batch {
                    let win: Vec<&[f32]> = (0..kw)
                        .map(|k| {
                            let blk = (t * stride + k) * in_block + lane * d_in;
                            &ext[blk..blk + d_in]
                        })
                        .collect();
                    ops::conv_step(&w, &b, &win, in_ch, out_ch, kw, width, &mut scalar);
                    let got =
                        &fused[t * out_block + lane * out_ch * width..][..out_ch * width];
                    crate::prop_assert!(
                        scalar == got,
                        "t={t} lane={lane} diverged from scalar conv_step"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn int8_fc_factored_form_matches_dequantized_naive() {
        // The factored affine accumulation must agree with explicit
        // per-element dequantization up to f32 reassociation noise.
        prop::check("gemm-int8-fc-vs-dequant", 40, |g| {
            let in_dim = 1 + g.index(64);
            let out_dim = 1 + g.index(16);
            let batch = 1 + g.index(6);
            let q = g.vec_of(in_dim * out_dim, |r| r.range_i64(-128, 127) as i8);
            let scale = g.vec_of(out_dim, |r| r.uniform(0.001, 0.05));
            let zp = g.vec_of(out_dim, |r| r.range_i64(-20, 20) as f32);
            let bias = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-2.0, 2.0));
            let mut xsum = Vec::new();
            let mut fused = vec![0.0; batch * out_dim];
            fc_batch_int8_into(&q, &scale, &zp, &bias, &xs, batch, &mut xsum, &mut fused);
            // Dequantize and run the f32 reference.
            let deq: Vec<f32> = q
                .iter()
                .enumerate()
                .map(|(idx, &v)| (v as f32 - zp[idx / in_dim]) * scale[idx / in_dim])
                .collect();
            let mut reference = vec![0.0; batch * out_dim];
            fc_batch_naive_into(&deq, &bias, &xs, batch, &mut reference);
            for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
                let tol = 1e-3 * (1.0 + a.abs().max(b.abs()));
                crate::prop_assert!(
                    (a - b).abs() <= tol,
                    "int8 fc elem {i}: {a} vs {b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn int8_conv_factored_form_matches_dequantized_reference() {
        prop::check("gemm-int8-conv-vs-dequant", 25, |g| {
            let in_ch = 1 + g.index(3);
            let out_ch = 1 + g.index(3);
            let kw = 1 + g.index(3);
            let width = 1 + g.index(6);
            let batch = 1 + g.index(4);
            let t_out = 1 + g.index(2);
            let d_in = in_ch * width;
            let in_block = batch * d_in;
            let q = g.vec_of(out_ch * in_ch * kw, |r| r.range_i64(-128, 127) as i8);
            let scale = g.vec_of(out_ch, |r| r.uniform(0.001, 0.05));
            let zp = g.vec_of(out_ch, |r| r.range_i64(-20, 20) as f32);
            let bias = g.vec_of(out_ch, |r| r.uniform(-0.5, 0.5));
            let ext = g.vec_of((kw - 1 + t_out) * in_block, |r| r.uniform(-2.0, 2.0));
            let out_block = batch * out_ch * width;
            let mut wsum = Vec::new();
            let mut fused = vec![0.0; t_out * out_block];
            conv_steps_int8_into(
                &q, &scale, &zp, &bias, &ext, t_out, 1, batch, in_ch, out_ch, kw, width,
                &mut wsum, &mut fused,
            );
            let deq: Vec<f32> = q
                .iter()
                .enumerate()
                .map(|(idx, &v)| (v as f32 - zp[idx / (in_ch * kw)]) * scale[idx / (in_ch * kw)])
                .collect();
            let mut reference = vec![0.0; t_out * out_block];
            conv_steps_into(
                &deq, &bias, &ext, t_out, 1, batch, in_ch, out_ch, kw, width, &mut reference,
            );
            for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
                let tol = 1e-3 * (1.0 + a.abs().max(b.abs()));
                crate::prop_assert!(
                    (a - b).abs() <= tol,
                    "int8 conv elem {i}: {a} vs {b}"
                );
            }
            Ok(())
        });
    }
}
