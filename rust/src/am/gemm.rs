//! Register-blocked micro-kernels for the AM hot path — the compute core
//! behind [`super::TdsModel`] and [`super::QuantizedTdsModel`].
//!
//! Layout contract (shared with `am::tds`):
//!  * activations are lane-major `[batch × dim]` blocks, one block per
//!    timestep; conv layers see all timesteps of a decoding step as one
//!    contiguous `ext` buffer of `(kw-1) + T` such blocks (history first),
//!    so a window is a contiguous slice — no per-position pointer chasing;
//!  * weights are row-major `[out × in]` (f32) or `[out × in]` int8 with
//!    per-output-row affine parameters (see [`super::quant`]);
//!  * every kernel writes into a caller-sized `&mut [f32]`, so the caller
//!    (the scratch-arena step driver) fully controls allocation.
//!
//! Blocking: the f32 FC kernel tiles `TILE_ROWS` weight rows ×
//! `TILE_LANES` lanes and keeps the 4×4 accumulator block in registers
//! through the shared `k` loop — each weight load feeds 4 lanes and each
//! activation load feeds 4 rows, which is what lets rustc autovectorize
//! the body to FMA-shaped code without losing IEEE semantics. Convolution
//! kernels hoist each weight scalar once per `(out_ch, in_ch, k)` and
//! sweep it across every lane's mel row (width-vectorized).
//!
//! **Parity contract:** for every f32 output element the floating-point
//! reduction order is IDENTICAL to the naive scalar kernels in
//! [`super::ops`] — one accumulator per output, seeded with the bias,
//! `k` ascending. Register blocking only interleaves *independent*
//! reductions, so results are bit-exact (`==`), not approximately equal;
//! `tests` below and `tests/batch_parity.rs` assert this. (rustc does not
//! contract `a*b + c` to fma without explicit opt-in, so the comparison
//! is stable across optimization levels.)
//!
//! **Runtime SIMD dispatch:** each public entry point routes through
//! [`dispatch::active`] to either the scalar kernel (`*_scalar_into`,
//! always available, the oracle) or an explicit `std::arch`
//! implementation in `simd` — AVX2 on x86_64, NEON on aarch64. The SIMD
//! kernels vectorize across independent outputs only (batch lanes for
//! FC, mel-row positions for conv) and use separate mul + add
//! instructions (never FMA), so they inherit the same parity contract:
//! every ISA produces bit-identical results, asserted by
//! `tests/simd_parity.rs`. Force an ISA with `ASRPU_KERNEL_ISA=scalar`
//! (process-wide) or [`dispatch::with_forced_isa`] (per thread).

pub mod dispatch;
mod simd;

/// Weight rows per register tile.
pub const TILE_ROWS: usize = 4;
/// Lanes (batch columns) per register tile.
pub const TILE_LANES: usize = 4;

/// Batched `[batch × out] = [batch × in] · Wᵀ + b`, dispatched to the
/// active ISA (see [`dispatch`]). `xs` is lane-major `[batch × in_dim]`,
/// `out` must be `batch * bias.len()` long. Bit-identical to
/// [`fc_batch_scalar_into`] under every ISA.
pub fn fc_batch_into(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_fc_shapes(w, bias, xs, batch, out);
            unsafe { simd::avx2::fc_batch(w, bias, xs, batch, out) }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_fc_shapes(w, bias, xs, batch, out);
            unsafe { simd::neon::fc_batch(w, bias, xs, batch, out) }
        }
        _ => fc_batch_scalar_into(w, bias, xs, batch, out),
    }
}

/// Shared shape validation for the FC dispatchers (the SIMD bodies trust
/// their caller).
fn check_fc_shapes(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &[f32]) {
    assert!(batch > 0, "fc kernels need at least one lane");
    debug_assert_eq!(xs.len() % batch, 0);
    debug_assert_eq!(w.len(), (xs.len() / batch) * bias.len());
    debug_assert_eq!(out.len(), batch * bias.len());
}

/// Tiled scalar `[batch × out] = [batch × in] · Wᵀ + b` — the
/// register-blocked reference path every SIMD kernel must match
/// bit-for-bit.
pub fn fc_batch_scalar_into(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
    assert!(batch > 0, "fc_batch_scalar_into needs at least one lane");
    let out_dim = bias.len();
    debug_assert_eq!(xs.len() % batch, 0);
    let in_dim = xs.len() / batch;
    debug_assert_eq!(w.len(), in_dim * out_dim);
    debug_assert_eq!(out.len(), batch * out_dim);
    let mut o = 0;
    while o < out_dim {
        let rows = TILE_ROWS.min(out_dim - o);
        let mut l = 0;
        while l < batch {
            let lanes = TILE_LANES.min(batch - l);
            if rows == TILE_ROWS && lanes == TILE_LANES {
                fc_tile_4x4(w, bias, xs, in_dim, out_dim, o, l, out);
            } else {
                fc_tile_edge(w, bias, xs, in_dim, out_dim, o, l, rows, lanes, out);
            }
            l += lanes;
        }
        o += rows;
    }
}

/// Full 4×4 register tile: 16 accumulators, shared `k` loop.
#[allow(clippy::too_many_arguments)]
#[inline]
fn fc_tile_4x4(
    w: &[f32],
    bias: &[f32],
    xs: &[f32],
    in_dim: usize,
    out_dim: usize,
    o: usize,
    l: usize,
    out: &mut [f32],
) {
    let r0 = &w[o * in_dim..][..in_dim];
    let r1 = &w[(o + 1) * in_dim..][..in_dim];
    let r2 = &w[(o + 2) * in_dim..][..in_dim];
    let r3 = &w[(o + 3) * in_dim..][..in_dim];
    let x0 = &xs[l * in_dim..][..in_dim];
    let x1 = &xs[(l + 1) * in_dim..][..in_dim];
    let x2 = &xs[(l + 2) * in_dim..][..in_dim];
    let x3 = &xs[(l + 3) * in_dim..][..in_dim];
    let mut acc = [[0.0f32; TILE_LANES]; TILE_ROWS];
    for (r, acc_row) in acc.iter_mut().enumerate() {
        *acc_row = [bias[o + r]; TILE_LANES];
    }
    for k in 0..in_dim {
        let wv = [r0[k], r1[k], r2[k], r3[k]];
        let xv = [x0[k], x1[k], x2[k], x3[k]];
        for (acc_row, wr) in acc.iter_mut().zip(wv) {
            for (a, xc) in acc_row.iter_mut().zip(xv) {
                *a += wr * xc;
            }
        }
    }
    for (r, acc_row) in acc.iter().enumerate() {
        for (c, a) in acc_row.iter().enumerate() {
            out[(l + c) * out_dim + o + r] = *a;
        }
    }
}

/// Ragged edge tile (rows < 4 or lanes < 4): same per-output reduction
/// order, plain loops.
#[allow(clippy::too_many_arguments)]
#[inline]
fn fc_tile_edge(
    w: &[f32],
    bias: &[f32],
    xs: &[f32],
    in_dim: usize,
    out_dim: usize,
    o: usize,
    l: usize,
    rows: usize,
    lanes: usize,
    out: &mut [f32],
) {
    for r in 0..rows {
        let row = &w[(o + r) * in_dim..][..in_dim];
        for c in 0..lanes {
            let x = &xs[(l + c) * in_dim..][..in_dim];
            let mut acc = bias[o + r];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out[(l + c) * out_dim + o + r] = acc;
        }
    }
}

/// Reference (naive) batched FC — one output at a time, weight matrix
/// re-streamed per lane. Kept for the `benches/gemm_kernels.rs` sweep and
/// as the bit-exactness oracle for the tiled kernel.
pub fn fc_batch_naive_into(w: &[f32], bias: &[f32], xs: &[f32], batch: usize, out: &mut [f32]) {
    assert!(batch > 0);
    let out_dim = bias.len();
    let in_dim = xs.len() / batch;
    debug_assert_eq!(out.len(), batch * out_dim);
    for lane in 0..batch {
        let x = &xs[lane * in_dim..(lane + 1) * in_dim];
        for o in 0..out_dim {
            let row = &w[o * in_dim..(o + 1) * in_dim];
            let mut acc = bias[o];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            out[lane * out_dim + o] = acc;
        }
    }
}

/// Int8-weight FC with per-output-row affine parameters and f32
/// accumulation:
///
/// `y[l][o] = bias[o] + scale[o] · (Σₖ q[o][k]·x[l][k] − zp[o] · Σₖ x[l][k])`
///
/// which is algebraically `Σ dequant(q)·x + bias` with the per-row
/// constants factored out of the inner loop — the weight stream is one
/// byte per MAC. `xsum` is a reusable per-lane Σx scratch buffer.
/// Dispatched to the active ISA; because accumulation is f32 (not i32),
/// the SIMD paths vectorize across batch lanes — independent outputs —
/// exactly like the f32 kernel, so results stay bit-identical (`==`) to
/// [`fc_batch_int8_scalar_into`] under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int8_into(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    xsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_fc_int8_shapes(q, scale, zp, bias, xs, batch, out);
            unsafe { simd::avx2::fc_batch_int8(q, scale, zp, bias, xs, batch, xsum, out) }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_fc_int8_shapes(q, scale, zp, bias, xs, batch, out);
            unsafe { simd::neon::fc_batch_int8(q, scale, zp, bias, xs, batch, xsum, out) }
        }
        _ => fc_batch_int8_scalar_into(q, scale, zp, bias, xs, batch, xsum, out),
    }
}

/// Shared shape validation for the int8 FC dispatcher.
fn check_fc_int8_shapes(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    out: &[f32],
) {
    assert!(batch > 0, "fc_batch_int8_into needs at least one lane");
    debug_assert_eq!(xs.len() % batch, 0);
    debug_assert_eq!(q.len(), (xs.len() / batch) * bias.len());
    debug_assert_eq!(scale.len(), bias.len());
    debug_assert_eq!(zp.len(), bias.len());
    debug_assert_eq!(out.len(), batch * bias.len());
}

/// Ragged lane block of the int8 FC — the lanes beyond the last full
/// SIMD block. Per-lane scalar accumulation with the same per-element op
/// order as the blocked paths (zero seed, `k` ascending, affine
/// finalize), shared by the scalar and SIMD kernels.
#[allow(clippy::too_many_arguments)]
#[inline]
fn fc_int8_lane_edge(
    row: &[i8],
    scale_o: f32,
    zp_o: f32,
    bias_o: f32,
    xs: &[f32],
    xsum: &[f32],
    in_dim: usize,
    out_dim: usize,
    o: usize,
    l: usize,
    lanes: usize,
    out: &mut [f32],
) {
    for c in 0..lanes {
        let x = &xs[(l + c) * in_dim..][..in_dim];
        let mut acc = 0.0f32;
        for (&qk, &xk) in row.iter().zip(x) {
            acc += qk as f32 * xk;
        }
        out[(l + c) * out_dim + o] = bias_o + scale_o * (acc - zp_o * xsum[l + c]);
    }
}

/// Scalar (lane-blocked) int8 FC — the reference path for
/// [`fc_batch_int8_into`].
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int8_scalar_into(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    xsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert!(batch > 0, "fc_batch_int8_scalar_into needs at least one lane");
    let out_dim = bias.len();
    debug_assert_eq!(xs.len() % batch, 0);
    let in_dim = xs.len() / batch;
    debug_assert_eq!(q.len(), in_dim * out_dim);
    debug_assert_eq!(scale.len(), out_dim);
    debug_assert_eq!(zp.len(), out_dim);
    debug_assert_eq!(out.len(), batch * out_dim);
    xsum.clear();
    xsum.resize(batch, 0.0);
    for (lane, s) in xsum.iter_mut().enumerate() {
        *s = xs[lane * in_dim..(lane + 1) * in_dim].iter().sum();
    }
    // Lane-blocked only: each weight byte is widened to f32 once and
    // feeds up to TILE_LANES lanes (row blocking buys nothing here — the
    // i8→f32 convert, not weight bandwidth, bounds the inner loop).
    for o in 0..out_dim {
        let row = &q[o * in_dim..][..in_dim];
        let mut l = 0;
        while l < batch {
            let lanes = TILE_LANES.min(batch - l);
            let mut acc = [0.0f32; TILE_LANES];
            for (k, &qk) in row.iter().enumerate() {
                let wq = qk as f32;
                for (c, a) in acc.iter_mut().take(lanes).enumerate() {
                    *a += wq * xs[(l + c) * in_dim + k];
                }
            }
            for (c, a) in acc.iter().take(lanes).enumerate() {
                out[(l + c) * out_dim + o] =
                    bias[o] + scale[o] * (a - zp[o] * xsum[l + c]);
            }
            l += lanes;
        }
    }
}

/// All `t_out` output positions of a causal temporal convolution over a
/// contiguous `ext` buffer of `(kw-1) + t_out·stride` lane-major
/// `[batch × in_ch·width]` timestep blocks (conv history first). Output
/// is `t_out` blocks of `[batch × out_ch·width]`.
///
/// Per output element the reduction order matches [`super::ops::conv_step`]
/// exactly: bias seed, then `(in_ch, k)` ascending, zero weights skipped.
/// Dispatched to the active ISA (the SIMD paths vectorize the width
/// sweep — independent output positions — and keep the same loop nest),
/// bit-identical to [`conv_steps_scalar_into`] under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_into(
    w: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_conv_shapes(
                w.len(),
                bias,
                ext,
                t_out,
                stride,
                batch,
                in_ch,
                out_ch,
                kw,
                width,
                out,
            );
            unsafe {
                simd::avx2::conv_steps(
                    w, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, out,
                )
            }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_conv_shapes(
                w.len(),
                bias,
                ext,
                t_out,
                stride,
                batch,
                in_ch,
                out_ch,
                kw,
                width,
                out,
            );
            unsafe {
                simd::neon::conv_steps(
                    w, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, out,
                )
            }
        }
        _ => conv_steps_scalar_into(
            w, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, out,
        ),
    }
}

/// Shared shape validation for the conv dispatchers (`w_len` is the
/// weight element count, so one helper serves the f32 and int8 forms).
#[allow(clippy::too_many_arguments)]
fn check_conv_shapes(
    w_len: usize,
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &[f32],
) {
    assert!(batch > 0, "conv kernels need at least one lane");
    debug_assert_eq!(bias.len(), out_ch);
    debug_assert_eq!(w_len, out_ch * in_ch * kw);
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * batch * in_ch * width);
    debug_assert_eq!(out.len(), t_out * batch * out_ch * width);
}

/// Scalar causal temporal convolution — the reference path for
/// [`conv_steps_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_scalar_into(
    w: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut [f32],
) {
    assert!(batch > 0, "conv_steps_scalar_into needs at least one lane");
    let d_in = in_ch * width;
    let d_out = out_ch * width;
    let in_block = batch * d_in;
    let out_block = batch * d_out;
    debug_assert_eq!(w.len(), out_ch * in_ch * kw);
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * in_block);
    debug_assert_eq!(out.len(), t_out * out_block);
    for t in 0..t_out {
        let out_t = &mut out[t * out_block..][..out_block];
        let base = t * stride;
        for o in 0..out_ch {
            for lane_out in out_t.chunks_exact_mut(d_out) {
                lane_out[o * width..(o + 1) * width].fill(bias[o]);
            }
            for i in 0..in_ch {
                for k in 0..kw {
                    let wk = w[(o * in_ch + i) * kw + k];
                    if wk == 0.0 {
                        continue;
                    }
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    // wk stays in a register while it sweeps every lane's
                    // mel row (the width loop autovectorizes).
                    for (lane_out, lane_in) in
                        out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                    {
                        let dst = &mut lane_out[o * width..(o + 1) * width];
                        let src = &lane_in[i * width..(i + 1) * width];
                        for (v, x) in dst.iter_mut().zip(src) {
                            *v += wk * x;
                        }
                    }
                }
            }
        }
    }
}

/// Int8-weight causal temporal convolution, per-output-channel affine
/// parameters, f32 accumulate:
///
/// `y[o][m] = bias[o] + scale[o] · (Σᵢₖ q[o][i][k]·x[i][k][m] − zp[o]·W[m])`
///
/// where `W[m] = Σᵢₖ x[i][k][m]` is the per-position window sum, computed
/// once per timestep into the reusable `wsum` buffer (`batch × width`)
/// and shared by every output channel. Dispatched to the active ISA;
/// accumulation is f32, so the SIMD paths vectorize the width sweep like
/// the f32 conv and stay bit-identical (`==`) to
/// [`conv_steps_int8_scalar_into`] under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int8_into(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    wsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_conv_shapes(
                q.len(),
                bias,
                ext,
                t_out,
                stride,
                batch,
                in_ch,
                out_ch,
                kw,
                width,
                out,
            );
            unsafe {
                simd::avx2::conv_steps_int8(
                    q, scale, zp, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width,
                    wsum, out,
                )
            }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_conv_shapes(
                q.len(),
                bias,
                ext,
                t_out,
                stride,
                batch,
                in_ch,
                out_ch,
                kw,
                width,
                out,
            );
            unsafe {
                simd::neon::conv_steps_int8(
                    q, scale, zp, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width,
                    wsum, out,
                )
            }
        }
        _ => conv_steps_int8_scalar_into(
            q, scale, zp, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, wsum, out,
        ),
    }
}

/// Scalar int8 causal temporal convolution — the reference path for
/// [`conv_steps_int8_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int8_scalar_into(
    q: &[i8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    wsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert!(batch > 0, "conv_steps_int8_scalar_into needs at least one lane");
    let d_in = in_ch * width;
    let d_out = out_ch * width;
    let in_block = batch * d_in;
    let out_block = batch * d_out;
    debug_assert_eq!(q.len(), out_ch * in_ch * kw);
    debug_assert_eq!(scale.len(), out_ch);
    debug_assert_eq!(zp.len(), out_ch);
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * in_block);
    debug_assert_eq!(out.len(), t_out * out_block);
    for t in 0..t_out {
        let out_t = &mut out[t * out_block..][..out_block];
        let base = t * stride;
        // Window sums, shared across output channels.
        wsum.clear();
        wsum.resize(batch * width, 0.0);
        for i in 0..in_ch {
            for k in 0..kw {
                let xblk = &ext[(base + k) * in_block..][..in_block];
                for (ws, lane_in) in wsum.chunks_exact_mut(width).zip(xblk.chunks_exact(d_in)) {
                    let src = &lane_in[i * width..(i + 1) * width];
                    for (s, x) in ws.iter_mut().zip(src) {
                        *s += x;
                    }
                }
            }
        }
        for o in 0..out_ch {
            for lane_out in out_t.chunks_exact_mut(d_out) {
                lane_out[o * width..(o + 1) * width].fill(0.0);
            }
            for i in 0..in_ch {
                for k in 0..kw {
                    let qk = q[(o * in_ch + i) * kw + k];
                    if qk == 0 {
                        continue;
                    }
                    let wq = qk as f32;
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    for (lane_out, lane_in) in
                        out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                    {
                        let dst = &mut lane_out[o * width..(o + 1) * width];
                        let src = &lane_in[i * width..(i + 1) * width];
                        for (v, x) in dst.iter_mut().zip(src) {
                            *v += wq * x;
                        }
                    }
                }
            }
            // Finalize: apply the affine transform.
            for (lane_out, ws) in out_t.chunks_exact_mut(d_out).zip(wsum.chunks_exact(width)) {
                let dst = &mut lane_out[o * width..(o + 1) * width];
                for (v, s) in dst.iter_mut().zip(ws) {
                    *v = bias[o] + scale[o] * (*v - zp[o] * s);
                }
            }
        }
    }
}

use super::quant::INT4_GROUP;

/// The signed 4-bit code at column `k` of a packed row (even columns in
/// the low nibble — see [`super::quant::Int4Weights`]).
#[inline(always)]
pub(crate) fn int4_code_at(row: &[u8], k: usize) -> i32 {
    let byte = row[k / 2];
    let nib = if k % 2 == 0 { byte & 0x0f } else { byte >> 4 };
    nib as i32 - 8
}

/// Decode one 2:4 sparse block byte pair into its two
/// `(in-block index, signed value)` slots (see
/// [`super::quant::SparseInt4Weights`]).
#[inline(always)]
pub(crate) fn sparse4_slots(v: u8, ix: u8) -> ((usize, f32), (usize, f32)) {
    (
        ((ix & 0x03) as usize, ((v & 0x0f) as i32 - 8) as f32),
        (((ix >> 2) & 0x03) as usize, ((v >> 4) as i32 - 8) as f32),
    )
}

/// Per-(lane, group) activation sums for the int4 FC kernels:
/// `gsum[l·ng + g] = Σ xs[l][k] over group g`, every lane's groups summed
/// `k` ascending. Shared by the scalar kernel, the SIMD kernels and the
/// naive oracle so the affine correction is bit-identical everywhere.
pub(crate) fn fc_int4_gsums(
    xs: &[f32],
    batch: usize,
    in_dim: usize,
    ng: usize,
    gsum: &mut Vec<f32>,
) {
    gsum.clear();
    gsum.resize(batch * ng, 0.0);
    for l in 0..batch {
        let x = &xs[l * in_dim..][..in_dim];
        for g in 0..ng {
            let seg = &x[g * INT4_GROUP..((g + 1) * INT4_GROUP).min(in_dim)];
            let mut s = 0.0f32;
            for &v in seg {
                s += v;
            }
            gsum[l * ng + g] = s;
        }
    }
}

/// Packed-int4 FC with per-(row, group) affine parameters and f32
/// accumulation:
///
/// `y[l][o] = bias[o] + Σ_g scale[o][g] · (Σ_{k∈g} q[o][k]·x[l][k] − zp[o][g] · Σ_{k∈g} x[l][k])`
///
/// — the int8 factored form applied per group of [`INT4_GROUP`] columns,
/// with the weight stream at half a byte per MAC. `gsum` is a reusable
/// per-(lane, group) Σx scratch buffer (`batch × groups`). Dispatched to
/// the active ISA; the SIMD paths vectorize across batch lanes only, so
/// results stay bit-identical (`==`) to [`fc_batch_int4_scalar_into`]
/// under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int4_into(
    packed: &[u8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    gsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_fc_int4_shapes(packed, scale, zp, bias, xs, batch, out);
            unsafe { simd::avx2::fc_batch_int4(packed, scale, zp, bias, xs, batch, gsum, out) }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_fc_int4_shapes(packed, scale, zp, bias, xs, batch, out);
            unsafe { simd::neon::fc_batch_int4(packed, scale, zp, bias, xs, batch, gsum, out) }
        }
        _ => fc_batch_int4_scalar_into(packed, scale, zp, bias, xs, batch, gsum, out),
    }
}

/// Shared shape validation for the int4 FC dispatcher.
fn check_fc_int4_shapes(
    packed: &[u8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    out: &[f32],
) {
    assert!(batch > 0, "fc_batch_int4_into needs at least one lane");
    debug_assert_eq!(xs.len() % batch, 0);
    let in_dim = xs.len() / batch;
    let ng = in_dim.div_ceil(INT4_GROUP);
    debug_assert_eq!(packed.len(), bias.len() * in_dim.div_ceil(2));
    debug_assert_eq!(scale.len(), bias.len() * ng);
    debug_assert_eq!(zp.len(), bias.len() * ng);
    debug_assert_eq!(out.len(), batch * bias.len());
}

/// Ragged lane block of the int4 FC — the lanes beyond the last full
/// SIMD block. Per-lane scalar accumulation with the same per-element op
/// order as the blocked paths (zero group seed, `k` ascending with zero
/// codes skipped, per-group affine fold, bias finalize), shared by the
/// scalar and SIMD kernels.
#[allow(clippy::too_many_arguments)]
#[inline]
fn fc_int4_lane_edge(
    row: &[u8],
    scale_o: &[f32],
    zp_o: &[f32],
    bias_o: f32,
    xs: &[f32],
    gsum: &[f32],
    in_dim: usize,
    out_dim: usize,
    ng: usize,
    o: usize,
    l: usize,
    lanes: usize,
    out: &mut [f32],
) {
    for c in 0..lanes {
        let x = &xs[(l + c) * in_dim..][..in_dim];
        let mut acc = 0.0f32;
        for g in 0..ng {
            let k_end = ((g + 1) * INT4_GROUP).min(in_dim);
            let mut gacc = 0.0f32;
            for k in g * INT4_GROUP..k_end {
                let q = int4_code_at(row, k);
                if q == 0 {
                    continue;
                }
                gacc += q as f32 * x[k];
            }
            acc += scale_o[g] * (gacc - zp_o[g] * gsum[(l + c) * ng + g]);
        }
        out[(l + c) * out_dim + o] = bias_o + acc;
    }
}

/// Scalar (lane-blocked) packed-int4 FC — the reference path for
/// [`fc_batch_int4_into`].
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int4_scalar_into(
    packed: &[u8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    gsum: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert!(batch > 0, "fc_batch_int4_scalar_into needs at least one lane");
    let out_dim = bias.len();
    debug_assert_eq!(xs.len() % batch, 0);
    let in_dim = xs.len() / batch;
    let ng = in_dim.div_ceil(INT4_GROUP);
    let stride = in_dim.div_ceil(2);
    debug_assert_eq!(packed.len(), out_dim * stride);
    debug_assert_eq!(scale.len(), out_dim * ng);
    debug_assert_eq!(zp.len(), out_dim * ng);
    debug_assert_eq!(out.len(), batch * out_dim);
    fc_int4_gsums(xs, batch, in_dim, ng, gsum);
    for o in 0..out_dim {
        let row = &packed[o * stride..][..stride];
        let scale_o = &scale[o * ng..][..ng];
        let zp_o = &zp[o * ng..][..ng];
        let mut l = 0;
        while l < batch {
            let lanes = TILE_LANES.min(batch - l);
            fc_int4_lane_edge(
                row, scale_o, zp_o, bias[o], xs, gsum, in_dim, out_dim, ng, o, l, lanes, out,
            );
            l += lanes;
        }
    }
}

/// Reference (naive unpacked) int4 FC — decodes every nibble one output
/// at a time with the same per-element op order as the blocked kernels.
/// The bit-exactness oracle for [`fc_batch_int4_into`] on every ISA.
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int4_naive_into(
    packed: &[u8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    out: &mut [f32],
) {
    assert!(batch > 0);
    let out_dim = bias.len();
    let in_dim = xs.len() / batch;
    let ng = in_dim.div_ceil(INT4_GROUP);
    let stride = in_dim.div_ceil(2);
    debug_assert_eq!(out.len(), batch * out_dim);
    let mut gsum = Vec::new();
    fc_int4_gsums(xs, batch, in_dim, ng, &mut gsum);
    for lane in 0..batch {
        let x = &xs[lane * in_dim..(lane + 1) * in_dim];
        for o in 0..out_dim {
            let row = &packed[o * stride..][..stride];
            let mut acc = 0.0f32;
            for g in 0..ng {
                let k_end = ((g + 1) * INT4_GROUP).min(in_dim);
                let mut gacc = 0.0f32;
                for k in g * INT4_GROUP..k_end {
                    let q = int4_code_at(row, k);
                    if q == 0 {
                        continue;
                    }
                    gacc += q as f32 * x[k];
                }
                acc += scale[o * ng + g] * (gacc - zp[o * ng + g] * gsum[lane * ng + g]);
            }
            out[lane * out_dim + o] = bias[o] + acc;
        }
    }
}

/// 2:4 structured-sparse int4 FC with per-row symmetric scale:
///
/// `y[l][o] = bias[o] + scale[o] · Σ_b (q₀·x[l][4b+i₀] + q₁·x[l][4b+i₁])`
///
/// — a fixed 2 MACs per 4-column block with **no per-element branching**
/// (padding slots carry `q = 0` and an always-in-bounds index, so tail
/// blocks cost the same two adds). Dispatched to the active ISA; the
/// SIMD paths vectorize across batch lanes only, bit-identical (`==`) to
/// [`fc_batch_int4_sparse_scalar_into`] under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int4_sparse_into(
    vals: &[u8],
    idxs: &[u8],
    scale: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_fc_sparse_shapes(vals, idxs, scale, bias, xs, batch, out);
            unsafe { simd::avx2::fc_batch_int4_sparse(vals, idxs, scale, bias, xs, batch, out) }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_fc_sparse_shapes(vals, idxs, scale, bias, xs, batch, out);
            unsafe { simd::neon::fc_batch_int4_sparse(vals, idxs, scale, bias, xs, batch, out) }
        }
        _ => fc_batch_int4_sparse_scalar_into(vals, idxs, scale, bias, xs, batch, out),
    }
}

/// Shared shape validation for the sparse FC dispatcher.
fn check_fc_sparse_shapes(
    vals: &[u8],
    idxs: &[u8],
    scale: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    out: &[f32],
) {
    assert!(batch > 0, "fc_batch_int4_sparse_into needs at least one lane");
    debug_assert_eq!(xs.len() % batch, 0);
    let nb = (xs.len() / batch).div_ceil(4);
    debug_assert_eq!(vals.len(), bias.len() * nb);
    debug_assert_eq!(idxs.len(), bias.len() * nb);
    debug_assert_eq!(scale.len(), bias.len());
    debug_assert_eq!(out.len(), batch * bias.len());
}

/// Ragged lane block of the sparse FC — per-lane scalar accumulation
/// with the same per-element op order as the blocked paths (zero seed,
/// blocks ascending, slot 0 then slot 1, affine finalize), shared by the
/// scalar and SIMD kernels.
#[allow(clippy::too_many_arguments)]
#[inline]
fn fc_sparse_lane_edge(
    row_v: &[u8],
    row_i: &[u8],
    scale_o: f32,
    bias_o: f32,
    xs: &[f32],
    in_dim: usize,
    out_dim: usize,
    o: usize,
    l: usize,
    lanes: usize,
    out: &mut [f32],
) {
    for c in 0..lanes {
        let x = &xs[(l + c) * in_dim..][..in_dim];
        let mut acc = 0.0f32;
        for (b, (&v, &ix)) in row_v.iter().zip(row_i).enumerate() {
            let ((i0, q0), (i1, q1)) = sparse4_slots(v, ix);
            let base = b * 4;
            acc += q0 * x[base + i0];
            acc += q1 * x[base + i1];
        }
        out[(l + c) * out_dim + o] = bias_o + scale_o * acc;
    }
}

/// Scalar (lane-blocked) 2:4 sparse FC — the reference path for
/// [`fc_batch_int4_sparse_into`].
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int4_sparse_scalar_into(
    vals: &[u8],
    idxs: &[u8],
    scale: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    out: &mut [f32],
) {
    assert!(batch > 0, "fc_batch_int4_sparse_scalar_into needs at least one lane");
    let out_dim = bias.len();
    debug_assert_eq!(xs.len() % batch, 0);
    let in_dim = xs.len() / batch;
    let nb = in_dim.div_ceil(4);
    debug_assert_eq!(vals.len(), out_dim * nb);
    debug_assert_eq!(idxs.len(), out_dim * nb);
    debug_assert_eq!(scale.len(), out_dim);
    debug_assert_eq!(out.len(), batch * out_dim);
    for o in 0..out_dim {
        let row_v = &vals[o * nb..][..nb];
        let row_i = &idxs[o * nb..][..nb];
        let mut l = 0;
        while l < batch {
            let lanes = TILE_LANES.min(batch - l);
            fc_sparse_lane_edge(
                row_v, row_i, scale[o], bias[o], xs, in_dim, out_dim, o, l, lanes, out,
            );
            l += lanes;
        }
    }
}

/// Reference (naive unpacked) sparse FC — the bit-exactness oracle for
/// [`fc_batch_int4_sparse_into`] on every ISA.
#[allow(clippy::too_many_arguments)]
pub fn fc_batch_int4_sparse_naive_into(
    vals: &[u8],
    idxs: &[u8],
    scale: &[f32],
    bias: &[f32],
    xs: &[f32],
    batch: usize,
    out: &mut [f32],
) {
    assert!(batch > 0);
    let out_dim = bias.len();
    let in_dim = xs.len() / batch;
    let nb = in_dim.div_ceil(4);
    debug_assert_eq!(out.len(), batch * out_dim);
    for lane in 0..batch {
        let x = &xs[lane * in_dim..(lane + 1) * in_dim];
        for o in 0..out_dim {
            let mut acc = 0.0f32;
            for b in 0..nb {
                let ((i0, q0), (i1, q1)) = sparse4_slots(vals[o * nb + b], idxs[o * nb + b]);
                acc += q0 * x[b * 4 + i0];
                acc += q1 * x[b * 4 + i1];
            }
            out[lane * out_dim + o] = bias[o] + scale[o] * acc;
        }
    }
}

/// Packed-int4 causal temporal convolution, per-(channel, group) affine
/// parameters over the flattened `[in_ch × kw]` tap axis, f32
/// accumulate:
///
/// `y[o][m] = bias[o] + Σ_g scale[o][g] · (Σ_{j∈g} q[o][j]·x[j][m] − zp[o][g]·G[g][m])`
///
/// where `j = i·kw + k` is the flat tap index and `G[g][m]` the
/// per-position per-group window sum, computed once per timestep and
/// shared by every output channel. `tmp` holds both scratch regions:
/// `groups × batch × width` of `G` followed by `batch × width` of the
/// current group partial. Dispatched to the active ISA (the SIMD paths
/// vectorize the width sweep), bit-identical (`==`) to
/// [`conv_steps_int4_scalar_into`] under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int4_into(
    packed: &[u8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    tmp: &mut Vec<f32>,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_conv_int4_shapes(
                packed, scale, zp, bias, ext, t_out, stride, batch, in_ch, kw, width, out,
            );
            unsafe {
                simd::avx2::conv_steps_int4(
                    packed, scale, zp, bias, ext, t_out, stride, batch, in_ch, out_ch, kw,
                    width, tmp, out,
                )
            }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_conv_int4_shapes(
                packed, scale, zp, bias, ext, t_out, stride, batch, in_ch, kw, width, out,
            );
            unsafe {
                simd::neon::conv_steps_int4(
                    packed, scale, zp, bias, ext, t_out, stride, batch, in_ch, out_ch, kw,
                    width, tmp, out,
                )
            }
        }
        _ => conv_steps_int4_scalar_into(
            packed, scale, zp, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, tmp,
            out,
        ),
    }
}

/// Shared shape validation for the int4 conv dispatcher.
#[allow(clippy::too_many_arguments)]
fn check_conv_int4_shapes(
    packed: &[u8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    kw: usize,
    width: usize,
    out: &[f32],
) {
    assert!(batch > 0, "conv_steps_int4_into needs at least one lane");
    let row_len = in_ch * kw;
    let ng = row_len.div_ceil(INT4_GROUP);
    debug_assert_eq!(packed.len(), bias.len() * row_len.div_ceil(2));
    debug_assert_eq!(scale.len(), bias.len() * ng);
    debug_assert_eq!(zp.len(), bias.len() * ng);
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * batch * in_ch * width);
    debug_assert_eq!(out.len(), t_out * batch * bias.len() * width);
}

/// Scalar packed-int4 causal temporal convolution — the reference path
/// for [`conv_steps_int4_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int4_scalar_into(
    packed: &[u8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    tmp: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert!(batch > 0, "conv_steps_int4_scalar_into needs at least one lane");
    let d_in = in_ch * width;
    let d_out = out_ch * width;
    let in_block = batch * d_in;
    let out_block = batch * d_out;
    let row_len = in_ch * kw;
    let ng = row_len.div_ceil(INT4_GROUP);
    let stride_b = row_len.div_ceil(2);
    let pos = batch * width;
    debug_assert_eq!(packed.len(), out_ch * stride_b);
    debug_assert_eq!(scale.len(), out_ch * ng);
    debug_assert_eq!(zp.len(), out_ch * ng);
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * in_block);
    debug_assert_eq!(out.len(), t_out * out_block);
    for t in 0..t_out {
        let out_t = &mut out[t * out_block..][..out_block];
        let base = t * stride;
        // Per-group window sums (shared across output channels) followed
        // by the current group's partial accumulator.
        tmp.clear();
        tmp.resize((ng + 1) * pos, 0.0);
        let (gsum, part) = tmp.split_at_mut(ng * pos);
        for i in 0..in_ch {
            for k in 0..kw {
                let g = (i * kw + k) / INT4_GROUP;
                let gs = &mut gsum[g * pos..][..pos];
                let xblk = &ext[(base + k) * in_block..][..in_block];
                for (ws, lane_in) in gs.chunks_exact_mut(width).zip(xblk.chunks_exact(d_in)) {
                    let src = &lane_in[i * width..(i + 1) * width];
                    for (s, x) in ws.iter_mut().zip(src) {
                        *s += x;
                    }
                }
            }
        }
        for o in 0..out_ch {
            let row = &packed[o * stride_b..][..stride_b];
            for lane_out in out_t.chunks_exact_mut(d_out) {
                lane_out[o * width..(o + 1) * width].fill(bias[o]);
            }
            for g in 0..ng {
                part.fill(0.0);
                for j in g * INT4_GROUP..((g + 1) * INT4_GROUP).min(row_len) {
                    let q = int4_code_at(row, j);
                    if q == 0 {
                        continue;
                    }
                    let wq = q as f32;
                    let (i, k) = (j / kw, j % kw);
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    let lanes_in = xblk.chunks_exact(d_in);
                    for (ps, lane_in) in part.chunks_exact_mut(width).zip(lanes_in) {
                        let src = &lane_in[i * width..(i + 1) * width];
                        for (p, x) in ps.iter_mut().zip(src) {
                            *p += wq * x;
                        }
                    }
                }
                // Fold this group's affine contribution into the output.
                let (s_g, z_g) = (scale[o * ng + g], zp[o * ng + g]);
                let gs = &gsum[g * pos..][..pos];
                for ((lane_out, ps), ws) in out_t
                    .chunks_exact_mut(d_out)
                    .zip(part.chunks_exact(width))
                    .zip(gs.chunks_exact(width))
                {
                    let dst = &mut lane_out[o * width..(o + 1) * width];
                    for ((v, p), w_) in dst.iter_mut().zip(ps).zip(ws) {
                        *v += s_g * (p - z_g * w_);
                    }
                }
            }
        }
    }
}

/// Reference (naive unpacked) int4 conv — per-element decode with the
/// same op order as the fused kernels. The bit-exactness oracle for
/// [`conv_steps_int4_into`] on every ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int4_naive_into(
    packed: &[u8],
    scale: &[f32],
    zp: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut [f32],
) {
    assert!(batch > 0);
    let d_in = in_ch * width;
    let d_out = out_ch * width;
    let in_block = batch * d_in;
    let out_block = batch * d_out;
    let row_len = in_ch * kw;
    let ng = row_len.div_ceil(INT4_GROUP);
    let stride_b = row_len.div_ceil(2);
    debug_assert_eq!(out.len(), t_out * out_block);
    for t in 0..t_out {
        let base = t * stride;
        for lane in 0..batch {
            for o in 0..out_ch {
                let row = &packed[o * stride_b..][..stride_b];
                for m in 0..width {
                    let mut acc = bias[o];
                    for g in 0..ng {
                        let mut gacc = 0.0f32;
                        let mut gs = 0.0f32;
                        for j in g * INT4_GROUP..((g + 1) * INT4_GROUP).min(row_len) {
                            let (i, k) = (j / kw, j % kw);
                            let x = ext[(base + k) * in_block + lane * d_in + i * width + m];
                            gs += x;
                            let q = int4_code_at(row, j);
                            if q != 0 {
                                gacc += q as f32 * x;
                            }
                        }
                        acc += scale[o * ng + g] * (gacc - zp[o * ng + g] * gs);
                    }
                    out[t * out_block + lane * d_out + o * width + m] = acc;
                }
            }
        }
    }
}

/// 2:4 structured-sparse int4 causal temporal convolution, per-channel
/// symmetric scale over the flattened `[in_ch × kw]` tap axis:
///
/// `y[o][m] = bias[o] + scale[o] · Σ_b (q₀·x[4b+i₀][m] + q₁·x[4b+i₁][m])`
///
/// — a fixed 2 MACs per tap block with no per-element branching (padding
/// slots carry `q = 0`). Dispatched to the active ISA (the SIMD paths
/// vectorize the width sweep), bit-identical (`==`) to
/// [`conv_steps_int4_sparse_scalar_into`] under every ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int4_sparse_into(
    vals: &[u8],
    idxs: &[u8],
    scale: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut [f32],
) {
    match dispatch::active() {
        #[cfg(target_arch = "x86_64")]
        dispatch::KernelIsa::Avx2 => {
            check_conv_sparse_shapes(
                vals, idxs, scale, bias, ext, t_out, stride, batch, in_ch, kw, width, out,
            );
            unsafe {
                simd::avx2::conv_steps_int4_sparse(
                    vals, idxs, scale, bias, ext, t_out, stride, batch, in_ch, out_ch, kw,
                    width, out,
                )
            }
        }
        #[cfg(target_arch = "aarch64")]
        dispatch::KernelIsa::Neon => {
            check_conv_sparse_shapes(
                vals, idxs, scale, bias, ext, t_out, stride, batch, in_ch, kw, width, out,
            );
            unsafe {
                simd::neon::conv_steps_int4_sparse(
                    vals, idxs, scale, bias, ext, t_out, stride, batch, in_ch, out_ch, kw,
                    width, out,
                )
            }
        }
        _ => conv_steps_int4_sparse_scalar_into(
            vals, idxs, scale, bias, ext, t_out, stride, batch, in_ch, out_ch, kw, width, out,
        ),
    }
}

/// Shared shape validation for the sparse conv dispatcher.
#[allow(clippy::too_many_arguments)]
fn check_conv_sparse_shapes(
    vals: &[u8],
    idxs: &[u8],
    scale: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    kw: usize,
    width: usize,
    out: &[f32],
) {
    assert!(batch > 0, "conv_steps_int4_sparse_into needs at least one lane");
    let nb = (in_ch * kw).div_ceil(4);
    debug_assert_eq!(vals.len(), bias.len() * nb);
    debug_assert_eq!(idxs.len(), bias.len() * nb);
    debug_assert_eq!(scale.len(), bias.len());
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * batch * in_ch * width);
    debug_assert_eq!(out.len(), t_out * batch * bias.len() * width);
}

/// Scalar 2:4 sparse causal temporal convolution — the reference path
/// for [`conv_steps_int4_sparse_into`].
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int4_sparse_scalar_into(
    vals: &[u8],
    idxs: &[u8],
    scale: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut [f32],
) {
    assert!(batch > 0, "conv_steps_int4_sparse_scalar_into needs at least one lane");
    let d_in = in_ch * width;
    let d_out = out_ch * width;
    let in_block = batch * d_in;
    let out_block = batch * d_out;
    let nb = (in_ch * kw).div_ceil(4);
    debug_assert_eq!(vals.len(), out_ch * nb);
    debug_assert_eq!(idxs.len(), out_ch * nb);
    debug_assert_eq!(scale.len(), out_ch);
    debug_assert_eq!(ext.len(), (kw - 1 + t_out * stride) * in_block);
    debug_assert_eq!(out.len(), t_out * out_block);
    for t in 0..t_out {
        let out_t = &mut out[t * out_block..][..out_block];
        let base = t * stride;
        for o in 0..out_ch {
            for lane_out in out_t.chunks_exact_mut(d_out) {
                lane_out[o * width..(o + 1) * width].fill(0.0);
            }
            for b in 0..nb {
                let ((i0, q0), (i1, q1)) = sparse4_slots(vals[o * nb + b], idxs[o * nb + b]);
                for (slot_j, wq) in [(b * 4 + i0, q0), (b * 4 + i1, q1)] {
                    let (i, k) = (slot_j / kw, slot_j % kw);
                    let xblk = &ext[(base + k) * in_block..][..in_block];
                    for (lane_out, lane_in) in
                        out_t.chunks_exact_mut(d_out).zip(xblk.chunks_exact(d_in))
                    {
                        let dst = &mut lane_out[o * width..(o + 1) * width];
                        let src = &lane_in[i * width..(i + 1) * width];
                        for (v, x) in dst.iter_mut().zip(src) {
                            *v += wq * x;
                        }
                    }
                }
            }
            // Finalize: apply bias + symmetric scale.
            for lane_out in out_t.chunks_exact_mut(d_out) {
                let dst = &mut lane_out[o * width..(o + 1) * width];
                for v in dst.iter_mut() {
                    *v = bias[o] + scale[o] * *v;
                }
            }
        }
    }
}

/// Reference (naive unpacked) sparse conv — the bit-exactness oracle for
/// [`conv_steps_int4_sparse_into`] on every ISA.
#[allow(clippy::too_many_arguments)]
pub fn conv_steps_int4_sparse_naive_into(
    vals: &[u8],
    idxs: &[u8],
    scale: &[f32],
    bias: &[f32],
    ext: &[f32],
    t_out: usize,
    stride: usize,
    batch: usize,
    in_ch: usize,
    out_ch: usize,
    kw: usize,
    width: usize,
    out: &mut [f32],
) {
    assert!(batch > 0);
    let d_in = in_ch * width;
    let d_out = out_ch * width;
    let in_block = batch * d_in;
    let out_block = batch * d_out;
    let nb = (in_ch * kw).div_ceil(4);
    debug_assert_eq!(out.len(), t_out * out_block);
    for t in 0..t_out {
        let base = t * stride;
        for lane in 0..batch {
            for o in 0..out_ch {
                for m in 0..width {
                    let mut acc = 0.0f32;
                    for b in 0..nb {
                        let ((i0, q0), (i1, q1)) =
                            sparse4_slots(vals[o * nb + b], idxs[o * nb + b]);
                        for (slot_j, wq) in [(b * 4 + i0, q0), (b * 4 + i1, q1)] {
                            let (i, k) = (slot_j / kw, slot_j % kw);
                            acc += wq
                                * ext[(base + k) * in_block + lane * d_in + i * width + m];
                        }
                    }
                    out[t * out_block + lane * d_out + o * width + m] =
                        bias[o] + scale[o] * acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::ops;
    use crate::util::prop;

    #[test]
    fn tiled_fc_is_bit_exact_vs_naive() {
        // All edge-tile shapes: dims and batches around the 4×4 tile.
        prop::check("gemm-fc-tiled-vs-naive", 60, |g| {
            let in_dim = 1 + g.index(40);
            let out_dim = 1 + g.index(24);
            let batch = 1 + g.index(10);
            let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-1.5, 1.5));
            let b = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-3.0, 3.0));
            let mut tiled = vec![0.0; batch * out_dim];
            let mut naive = vec![0.0; batch * out_dim];
            fc_batch_into(&w, &b, &xs, batch, &mut tiled);
            fc_batch_naive_into(&w, &b, &xs, batch, &mut naive);
            crate::prop_assert!(tiled == naive, "tiled FC diverged from naive");
            Ok(())
        });
    }

    #[test]
    fn tiled_fc_matches_scalar_ops_fc() {
        prop::check("gemm-fc-vs-ops-fc", 40, |g| {
            let in_dim = 1 + g.index(32);
            let out_dim = 1 + g.index(16);
            let batch = 1 + g.index(6);
            let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-1.0, 1.0));
            let b = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-2.0, 2.0));
            let mut tiled = vec![0.0; batch * out_dim];
            fc_batch_into(&w, &b, &xs, batch, &mut tiled);
            let mut lane = Vec::new();
            for l in 0..batch {
                ops::fc(&w, &b, &xs[l * in_dim..(l + 1) * in_dim], &mut lane);
                crate::prop_assert!(
                    lane == tiled[l * out_dim..(l + 1) * out_dim],
                    "lane {l} diverged from scalar fc"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn conv_steps_matches_per_position_ops_conv() {
        prop::check("gemm-conv-vs-ops-conv", 30, |g| {
            let in_ch = 1 + g.index(3);
            let out_ch = 1 + g.index(3);
            let kw = 1 + g.index(4);
            let width = 1 + g.index(8);
            let batch = 1 + g.index(5);
            let stride = 1 + g.index(2);
            let t_out = 1 + g.index(3);
            let t_in = t_out * stride;
            let d_in = in_ch * width;
            let in_block = batch * d_in;
            let w = g.vec_of(out_ch * in_ch * kw, |r| r.uniform(-1.0, 1.0));
            let b = g.vec_of(out_ch, |r| r.uniform(-0.5, 0.5));
            let ext = g.vec_of((kw - 1 + t_in) * in_block, |r| r.uniform(-2.0, 2.0));
            let out_block = batch * out_ch * width;
            let mut fused = vec![0.0; t_out * out_block];
            conv_steps_into(
                &w, &b, &ext, t_out, stride, batch, in_ch, out_ch, kw, width, &mut fused,
            );
            // Oracle: per-position per-lane scalar conv_step over slices.
            let mut scalar = Vec::new();
            for t in 0..t_out {
                for lane in 0..batch {
                    let win: Vec<&[f32]> = (0..kw)
                        .map(|k| {
                            let blk = (t * stride + k) * in_block + lane * d_in;
                            &ext[blk..blk + d_in]
                        })
                        .collect();
                    ops::conv_step(&w, &b, &win, in_ch, out_ch, kw, width, &mut scalar);
                    let got =
                        &fused[t * out_block + lane * out_ch * width..][..out_ch * width];
                    crate::prop_assert!(
                        scalar == got,
                        "t={t} lane={lane} diverged from scalar conv_step"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn int8_fc_factored_form_matches_dequantized_naive() {
        // The factored affine accumulation must agree with explicit
        // per-element dequantization up to f32 reassociation noise.
        prop::check("gemm-int8-fc-vs-dequant", 40, |g| {
            let in_dim = 1 + g.index(64);
            let out_dim = 1 + g.index(16);
            let batch = 1 + g.index(6);
            let q = g.vec_of(in_dim * out_dim, |r| r.range_i64(-128, 127) as i8);
            let scale = g.vec_of(out_dim, |r| r.uniform(0.001, 0.05));
            let zp = g.vec_of(out_dim, |r| r.range_i64(-20, 20) as f32);
            let bias = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-2.0, 2.0));
            let mut xsum = Vec::new();
            let mut fused = vec![0.0; batch * out_dim];
            fc_batch_int8_into(&q, &scale, &zp, &bias, &xs, batch, &mut xsum, &mut fused);
            // Dequantize and run the f32 reference.
            let deq: Vec<f32> = q
                .iter()
                .enumerate()
                .map(|(idx, &v)| (v as f32 - zp[idx / in_dim]) * scale[idx / in_dim])
                .collect();
            let mut reference = vec![0.0; batch * out_dim];
            fc_batch_naive_into(&deq, &bias, &xs, batch, &mut reference);
            for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
                let tol = 1e-3 * (1.0 + a.abs().max(b.abs()));
                crate::prop_assert!(
                    (a - b).abs() <= tol,
                    "int8 fc elem {i}: {a} vs {b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn int4_fc_is_bit_exact_vs_naive_oracle() {
        use crate::am::quant::quantize_rows_int4;
        prop::check("gemm-int4-fc-vs-naive", 50, |g| {
            // Remainder-heavy shapes: odd widths, group-boundary crossers.
            let in_dim = 1 + g.index(80);
            let out_dim = 1 + g.index(16);
            let batch = 1 + g.index(10);
            let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-1.5, 1.5));
            let qw = quantize_rows_int4(&w, out_dim, in_dim);
            let bias = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-2.0, 2.0));
            let mut gsum = Vec::new();
            let mut fused = vec![0.0; batch * out_dim];
            let mut naive = vec![0.0; batch * out_dim];
            fc_batch_int4_into(
                &qw.packed, &qw.scale, &qw.zp, &bias, &xs, batch, &mut gsum, &mut fused,
            );
            fc_batch_int4_naive_into(&qw.packed, &qw.scale, &qw.zp, &bias, &xs, batch, &mut naive);
            crate::prop_assert!(fused == naive, "int4 FC diverged from naive oracle");
            Ok(())
        });
    }

    #[test]
    fn int4_fc_tracks_dequantized_reference() {
        use crate::am::quant::{dequantize_int4, quantize_rows_int4};
        prop::check("gemm-int4-fc-vs-dequant", 30, |g| {
            let in_dim = 1 + g.index(70);
            let out_dim = 1 + g.index(12);
            let batch = 1 + g.index(5);
            let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-1.0, 1.0));
            let qw = quantize_rows_int4(&w, out_dim, in_dim);
            let bias = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-2.0, 2.0));
            let mut gsum = Vec::new();
            let mut fused = vec![0.0; batch * out_dim];
            fc_batch_int4_into(
                &qw.packed, &qw.scale, &qw.zp, &bias, &xs, batch, &mut gsum, &mut fused,
            );
            let deq: Vec<f32> = (0..out_dim * in_dim)
                .map(|idx| dequantize_int4(&qw, idx / in_dim, idx % in_dim))
                .collect();
            let mut reference = vec![0.0; batch * out_dim];
            fc_batch_naive_into(&deq, &bias, &xs, batch, &mut reference);
            for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
                let tol = 1e-3 * (1.0 + a.abs().max(b.abs()));
                crate::prop_assert!((a - b).abs() <= tol, "int4 fc elem {i}: {a} vs {b}");
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_fc_is_bit_exact_vs_naive_oracle() {
        use crate::am::quant::prune_quantize_rows_2of4;
        prop::check("gemm-sparse-fc-vs-naive", 50, |g| {
            let in_dim = 1 + g.index(50); // includes ragged 2:4 tails
            let out_dim = 1 + g.index(16);
            let batch = 1 + g.index(10);
            let w = g.vec_of(in_dim * out_dim, |r| r.uniform(-1.5, 1.5));
            let qw = prune_quantize_rows_2of4(&w, out_dim, in_dim);
            let bias = g.vec_of(out_dim, |r| r.uniform(-1.0, 1.0));
            let xs = g.vec_of(batch * in_dim, |r| r.uniform(-2.0, 2.0));
            let mut fused = vec![0.0; batch * out_dim];
            let mut naive = vec![0.0; batch * out_dim];
            fc_batch_int4_sparse_into(&qw.vals, &qw.idxs, &qw.scale, &bias, &xs, batch, &mut fused);
            fc_batch_int4_sparse_naive_into(
                &qw.vals, &qw.idxs, &qw.scale, &bias, &xs, batch, &mut naive,
            );
            crate::prop_assert!(fused == naive, "sparse FC diverged from naive oracle");
            Ok(())
        });
    }

    #[test]
    fn int4_conv_is_bit_exact_vs_naive_oracle() {
        use crate::am::quant::quantize_rows_int4;
        prop::check("gemm-int4-conv-vs-naive", 30, |g| {
            let in_ch = 1 + g.index(4);
            let out_ch = 1 + g.index(3);
            let kw = 1 + g.index(9); // in_ch·kw crosses the 32-col group
            let width = 1 + g.index(8);
            let batch = 1 + g.index(5);
            let stride = 1 + g.index(2);
            let t_out = 1 + g.index(3);
            let d_in = in_ch * width;
            let in_block = batch * d_in;
            let w = g.vec_of(out_ch * in_ch * kw, |r| r.uniform(-1.0, 1.0));
            let qw = quantize_rows_int4(&w, out_ch, in_ch * kw);
            let bias = g.vec_of(out_ch, |r| r.uniform(-0.5, 0.5));
            let ext = g.vec_of((kw - 1 + t_out * stride) * in_block, |r| r.uniform(-2.0, 2.0));
            let out_block = batch * out_ch * width;
            let mut tmp = Vec::new();
            let mut fused = vec![0.0; t_out * out_block];
            let mut naive = vec![0.0; t_out * out_block];
            conv_steps_int4_into(
                &qw.packed, &qw.scale, &qw.zp, &bias, &ext, t_out, stride, batch, in_ch,
                out_ch, kw, width, &mut tmp, &mut fused,
            );
            conv_steps_int4_naive_into(
                &qw.packed, &qw.scale, &qw.zp, &bias, &ext, t_out, stride, batch, in_ch,
                out_ch, kw, width, &mut naive,
            );
            crate::prop_assert!(fused == naive, "int4 conv diverged from naive oracle");
            Ok(())
        });
    }

    #[test]
    fn sparse_conv_is_bit_exact_vs_naive_oracle() {
        use crate::am::quant::prune_quantize_rows_2of4;
        prop::check("gemm-sparse-conv-vs-naive", 30, |g| {
            let in_ch = 1 + g.index(4);
            let out_ch = 1 + g.index(3);
            let kw = 1 + g.index(7); // in_ch·kw includes ragged 2:4 tails
            let width = 1 + g.index(8);
            let batch = 1 + g.index(5);
            let stride = 1 + g.index(2);
            let t_out = 1 + g.index(3);
            let d_in = in_ch * width;
            let in_block = batch * d_in;
            let w = g.vec_of(out_ch * in_ch * kw, |r| r.uniform(-1.0, 1.0));
            let qw = prune_quantize_rows_2of4(&w, out_ch, in_ch * kw);
            let bias = g.vec_of(out_ch, |r| r.uniform(-0.5, 0.5));
            let ext = g.vec_of((kw - 1 + t_out * stride) * in_block, |r| r.uniform(-2.0, 2.0));
            let out_block = batch * out_ch * width;
            let mut fused = vec![0.0; t_out * out_block];
            let mut naive = vec![0.0; t_out * out_block];
            conv_steps_int4_sparse_into(
                &qw.vals, &qw.idxs, &qw.scale, &bias, &ext, t_out, stride, batch, in_ch,
                out_ch, kw, width, &mut fused,
            );
            conv_steps_int4_sparse_naive_into(
                &qw.vals, &qw.idxs, &qw.scale, &bias, &ext, t_out, stride, batch, in_ch,
                out_ch, kw, width, &mut naive,
            );
            crate::prop_assert!(fused == naive, "sparse conv diverged from naive oracle");
            Ok(())
        });
    }

    #[test]
    fn int8_conv_factored_form_matches_dequantized_reference() {
        prop::check("gemm-int8-conv-vs-dequant", 25, |g| {
            let in_ch = 1 + g.index(3);
            let out_ch = 1 + g.index(3);
            let kw = 1 + g.index(3);
            let width = 1 + g.index(6);
            let batch = 1 + g.index(4);
            let t_out = 1 + g.index(2);
            let d_in = in_ch * width;
            let in_block = batch * d_in;
            let q = g.vec_of(out_ch * in_ch * kw, |r| r.range_i64(-128, 127) as i8);
            let scale = g.vec_of(out_ch, |r| r.uniform(0.001, 0.05));
            let zp = g.vec_of(out_ch, |r| r.range_i64(-20, 20) as f32);
            let bias = g.vec_of(out_ch, |r| r.uniform(-0.5, 0.5));
            let ext = g.vec_of((kw - 1 + t_out) * in_block, |r| r.uniform(-2.0, 2.0));
            let out_block = batch * out_ch * width;
            let mut wsum = Vec::new();
            let mut fused = vec![0.0; t_out * out_block];
            conv_steps_int8_into(
                &q, &scale, &zp, &bias, &ext, t_out, 1, batch, in_ch, out_ch, kw, width,
                &mut wsum, &mut fused,
            );
            let deq: Vec<f32> = q
                .iter()
                .enumerate()
                .map(|(idx, &v)| (v as f32 - zp[idx / (in_ch * kw)]) * scale[idx / (in_ch * kw)])
                .collect();
            let mut reference = vec![0.0; t_out * out_block];
            conv_steps_into(
                &deq, &bias, &ext, t_out, 1, batch, in_ch, out_ch, kw, width, &mut reference,
            );
            for (i, (a, b)) in fused.iter().zip(&reference).enumerate() {
                let tol = 1e-3 * (1.0 + a.abs().max(b.abs()));
                crate::prop_assert!(
                    (a - b).abs() <= tol,
                    "int8 conv elem {i}: {a} vs {b}"
                );
            }
            Ok(())
        });
    }
}
