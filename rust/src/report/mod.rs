//! Regenerates every table and figure of the paper's evaluation (§5) as
//! aligned text tables + ASCII charts (and CSV via `--csv`). See
//! DESIGN.md's per-experiment index: T1, T2, F9, F10a/b, F11, H1, H2.

use crate::accel::{
    simulate_step, HypWorkload, KernelClass, SimMode, StepReport,
};
use crate::accel::controller::inter_step_state_bytes;
use crate::config::{AccelConfig, Layer, ModelConfig};
use crate::power::ChipBudget;
use crate::util::table::{bar_chart, Table};

/// Table 1 — the command set (regenerated from the `Command` enum so the
/// doc never drifts from the implementation).
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table 1 — Commands provided by the command decoder",
        &["Command", "Parameters", "Description"],
    );
    t.row(&[
        "ConfigureASR_AcousticScoring".into(),
        "n, setup_addr, kernel_addr".into(),
        "Configure kernel n of the acoustic scoring phase (call with incremental n)".into(),
    ]);
    t.row(&[
        "ConfigureASR_HypExpansion".into(),
        "kernel_addr".into(),
        "Configure the hypothesis expansion kernel".into(),
    ]);
    t.row(&[
        "ConfigureBeamWidth".into(),
        "beam".into(),
        "Set the hypothesis unit's pruning beam".into(),
    ]);
    t.row(&[
        "CleanDecoding".into(),
        "".into(),
        "Reset hypothesis memory / internal state for a new utterance".into(),
    ]);
    t.row(&[
        "DecodingStep".into(),
        "signal_addr".into(),
        "Decode a signal chunk, extending the current hypotheses".into(),
    ]);
    t
}

/// Table 2 — accelerator configuration.
pub fn table2(accel: &AccelConfig) -> Table {
    let kb = |b: usize| format!("{} KB", b / 1024);
    let mut t = Table::new(
        "Table 2 — Configuration parameters of the accelerator",
        &["Parameter", "Value"],
    );
    t.row(&["Frequency".into(), format!("{} MHz", accel.frequency_hz / 1_000_000)]);
    t.row(&["Hypothesis Memory".into(), kb(accel.hyp_mem_bytes)]);
    t.row(&["I-Cache".into(), kb(accel.shared_icache_bytes)]);
    t.row(&["Shared Memory".into(), kb(accel.shared_mem_bytes)]);
    t.row(&["Model Memory / D-Cache".into(), kb(accel.model_mem_bytes)]);
    t.row(&["Num. PEs".into(), accel.num_pes.to_string()]);
    t.row(&["PE i-Cache".into(), kb(accel.pe_icache_bytes)]);
    t.row(&["PE d-Cache".into(), kb(accel.pe_dcache_bytes)]);
    t.row(&["MAC vector size".into(), accel.mac_vector_width.to_string()]);
    t
}

/// Fig. 9 — per-layer model-data size (KB), conv layers and FC layers.
pub fn fig9(model: &ModelConfig) -> (Table, String) {
    let mut t = Table::new(
        "Fig. 9 — Size (KB) of each layer of the TDS DNN",
        &["Layer", "Kind", "Size (KB)"],
    );
    let mut conv_items = Vec::new();
    let mut fc_items = Vec::new();
    for layer in model.layers() {
        let kb = layer.model_bytes(model.precision) as f64 / 1024.0;
        match &layer {
            Layer::Conv { .. } => {
                t.row(&[layer.name().into(), "conv".into(), format!("{kb:.2}")]);
                conv_items.push((layer.name().to_string(), kb));
            }
            Layer::Fc { .. } => {
                t.row(&[layer.name().into(), "fc".into(), format!("{kb:.1}")]);
                fc_items.push((layer.name().to_string(), kb));
            }
            Layer::LayerNorm { .. } => {}
        }
    }
    let charts = format!(
        "{}\n{}",
        bar_chart("Fig. 9 (left) — convolutional layers", &conv_items, "KB", 40),
        bar_chart("Fig. 9 (right) — fully-connected layers", &fc_items, "KB", 40)
    );
    (t, charts)
}

/// Fig. 10 — area and peak power by component + dynamic/static split.
pub fn fig10(accel: &AccelConfig) -> (Table, String) {
    let b = ChipBudget::for_config(accel);
    let mut t = Table::new(
        "Fig. 10 — Area and peak power by component (32 nm)",
        &["Component", "Area (mm2)", "Area %", "Leakage (mW)", "Peak dyn (mW)", "Peak (mW)"],
    );
    let total_area = b.total_area_mm2();
    for c in &b.components {
        t.row(&[
            c.name.clone(),
            format!("{:.3}", c.area_mm2),
            format!("{:.1}%", 100.0 * c.area_mm2 / total_area),
            format!("{:.1}", c.leakage_w * 1e3),
            format!("{:.1}", c.peak_dynamic_w * 1e3),
            format!("{:.1}", c.peak_w() * 1e3),
        ]);
    }
    t.row(&[
        "TOTAL".into(),
        format!("{:.2}", total_area),
        "100%".into(),
        format!("{:.1}", b.total_leakage_w() * 1e3),
        format!("{:.1}", b.total_peak_dynamic_w() * 1e3),
        format!("{:.1}", b.total_peak_w() * 1e3),
    ]);
    t.footnote = Some(format!(
        "paper: 11.68 mm2 total, execution unit 65% (here {:.0}%), \
         shared+model memories 32% (here {:.0}%), hypothesis unit <1% ; \
         peak ~1.8 W with ~0.8 W static (here {:.2} W / {:.2} W)",
        100.0 * b.execution_unit_share(),
        100.0 * b.memories_share(),
        b.total_peak_w(),
        b.total_leakage_w(),
    ));
    let split = bar_chart(
        "Fig. 10b — static vs dynamic peak power",
        &[
            ("static (leakage)".into(), b.total_leakage_w()),
            ("dynamic (peak)".into(), b.total_peak_dynamic_w()),
        ],
        "W",
        40,
    );
    (t, split)
}

/// Fig. 11 — execution time of every kernel in a decoding step.
pub fn fig11(model: &ModelConfig, accel: &AccelConfig, mode: SimMode) -> (Table, String, StepReport) {
    let hyp = HypWorkload::default();
    let report = simulate_step(model, accel, &hyp, mode);
    let mut t = Table::new(
        "Fig. 11 — Execution time per kernel (one decoding step)",
        &["Kernel", "Class", "Threads", "Instructions", "Cycles", "Time (us)"],
    );
    let us = |c: u64| c as f64 * accel.cycle_s() * 1e6;
    for k in &report.kernels {
        t.row(&[
            k.name.clone(),
            format!("{:?}", k.class),
            k.threads.to_string(),
            k.instrs.to_string(),
            k.cycles().to_string(),
            format!("{:.1}", us(k.cycles())),
        ]);
    }
    // The paper plots conv + hyp-expansion on the left axis, FC + feature
    // extraction on the right.
    let mut left = Vec::new();
    let mut right = Vec::new();
    for k in &report.kernels {
        let ms = us(k.cycles()) / 1e3;
        match k.class {
            KernelClass::Conv | KernelClass::HypExpansion => {
                left.push((k.name.clone(), ms));
            }
            KernelClass::Fc | KernelClass::FeatureExtraction => {
                right.push((k.name.clone(), ms));
            }
            KernelClass::LayerNorm | KernelClass::Rescore => {}
        }
    }
    let charts = format!(
        "{}\n{}",
        bar_chart("Fig. 11 (left) — conv + hypothesis expansion", &left, "ms", 40),
        bar_chart("Fig. 11 (right) — FC + feature extraction", &right, "ms", 40)
    );
    (t, charts, report)
}

/// §5.4 headline: decoding-step time, real-time factor, phase split.
pub fn headline(model: &ModelConfig, accel: &AccelConfig) -> Table {
    let hyp = HypWorkload::default();
    let ideal = simulate_step(model, accel, &hyp, SimMode::Ideal);
    let detailed = simulate_step(model, accel, &hyp, SimMode::Detailed);
    let budget = ChipBudget::for_config(accel);
    let mut t = Table::new(
        "Headline (§5.3–§5.4) — paper vs simulated",
        &["Metric", "Paper", "This repo"],
    );
    let ms = ideal.seconds(accel) * 1e3;
    t.row(&["Decoding step (80 ms audio)".into(), "~40 ms".into(), format!("{ms:.1} ms")]);
    t.row(&[
        "Real-time factor".into(),
        "2x".into(),
        format!("{:.2}x", ideal.rtf(model, accel)),
    ]);
    t.row(&[
        "Step w/ DMA+setup modeled".into(),
        "(hidden by Fig. 7 pipelining)".into(),
        format!("{:.1} ms (+{:.1}%)", detailed.seconds(accel) * 1e3,
            100.0 * (detailed.total_cycles as f64 / ideal.total_cycles as f64 - 1.0)),
    ]);
    t.row(&[
        "Inter-step state in shared mem".into(),
        "~275 KB".into(),
        format!("{:.0} KB", inter_step_state_bytes(model) as f64 / 1024.0),
    ]);
    t.row(&[
        "Total area (32 nm)".into(),
        "11.68 mm2".into(),
        format!("{:.2} mm2", budget.total_area_mm2()),
    ]);
    t.row(&[
        "Peak power".into(),
        ">1.8 W".into(),
        format!("{:.2} W", budget.total_peak_w()),
    ]);
    t.row(&[
        "Static power".into(),
        "~0.8 W".into(),
        format!("{:.2} W", budget.total_leakage_w()),
    ]);
    let isa = crate::am::KernelIsa::active();
    t.row(&[
        "Accelerator peak MAC rate".into(),
        "32 GMAC/s (8 PEs × 8-wide @ 500 MHz)".into(),
        format!("{:.0} GMAC/s", crate::accel::kernels::peak_gmacs(accel)),
    ]);
    t.row(&[
        "Host AM kernel ISA (engine)".into(),
        "n/a (ASRPU is the device)".into(),
        format!("{} ({}×f32)", isa.as_str(), isa.simd_lanes()),
    ]);
    t
}

/// Everything, concatenated (the `report all` subcommand).
pub fn all_reports() -> String {
    let accel = AccelConfig::paper();
    let model = ModelConfig::paper_tds();
    let mut out = String::new();
    out.push_str(&table1().render());
    out.push('\n');
    out.push_str(&table2(&accel).render());
    out.push('\n');
    let (t9, c9) = fig9(&model);
    out.push_str(&t9.render());
    out.push_str(&c9);
    out.push('\n');
    let (t10, c10) = fig10(&accel);
    out.push_str(&t10.render());
    out.push_str(&c10);
    out.push('\n');
    let (t11, c11, _) = fig11(&model, &accel, SimMode::Ideal);
    out.push_str(&t11.render());
    out.push_str(&c11);
    out.push('\n');
    out.push_str(&headline(&model, &accel).render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_covers_all_five_commands() {
        let t = table1();
        assert_eq!(t.rows.len(), 5);
    }

    #[test]
    fn table2_matches_paper_values() {
        let t = table2(&AccelConfig::paper());
        let r = t.render();
        for needle in ["500 MHz", "24 KB", "64 KB", "512 KB", "1024 KB", "8", "4 KB"] {
            assert!(r.contains(needle), "missing {needle} in:\n{r}");
        }
    }

    #[test]
    fn fig9_has_18_conv_and_29_fc_rows() {
        let (t, charts) = fig9(&ModelConfig::paper_tds());
        assert_eq!(t.rows.len(), 18 + 29);
        assert!(charts.contains("convolutional"));
    }

    #[test]
    fn fig11_totals_match_headline() {
        let accel = AccelConfig::paper();
        let model = ModelConfig::paper_tds();
        let (_, _, report) = fig11(&model, &accel, SimMode::Ideal);
        let ms = report.seconds(&accel) * 1e3;
        assert!((27.0..55.0).contains(&ms), "{ms}");
    }

    #[test]
    fn all_reports_renders() {
        let r = all_reports();
        assert!(r.contains("Table 1"));
        assert!(r.contains("Fig. 9"));
        assert!(r.contains("Fig. 10"));
        assert!(r.contains("Fig. 11"));
        assert!(r.contains("Headline"));
        assert!(r.len() > 4000);
    }
}
