//! The serving front-end: a JSON-lines TCP server multiplexing many
//! streaming sessions onto a sharded pool of device workers — the shape
//! of the paper's §4.1 deployment (a host process feeding DecodingStep
//! commands to ASRPU devices), extended with the queueing, backpressure,
//! sharding and metrics a production router needs.
//!
//! ## Protocol v2 (one JSON object per line)
//!
//!   → {"op":"hello"}                  ← {"proto":2,"server":"asrpu",
//!                                        "versions":[1,2],"ops":[...]}
//!   → {"op":"open"}                   ← {"session":N}
//!   → {"op":"feed","session":N,
//!      "samples":[...]}               ← {"steps":K,"partial":"..."}
//!   → {"op":"finish","session":N}     ← {"text":"...","rtf":X,...}
//!   → {"op":"resume","session":N}     ← {"session":N,"steps":K,
//!                                        "frames":F,"buffered_samples":B,
//!                                        "partial":"..."}
//!   → {"op":"stats"}                  ← {"summary":"...","workers":W,
//!                                        "shards":[...]}
//!   → {"op":"config"}                 ← {"proto":2,"backend":"...",
//!                                        "precision":"...","workers":W,...}
//!   → {"op":"pool","action":"add"}    ← {"shard":N,"workers":W}
//!   → {"op":"pool","action":"drain",
//!      "shard":N}                      ← {"shard":N,"state":"retired",
//!                                        "migrated":M}
//!   → {"op":"pool","action":"status"} ← {"workers":W,"max_workers":M,
//!                                        "draining":D,"shards":[...]}
//!
//! Errors are structured: `{"error":{"code":"...","message":"..."}}`
//! with stable machine-readable codes (`bad_request`, `unknown_op`,
//! `unknown_session`, `session_shed`, `backpressure`, `shutdown`,
//! `internal`).
//!
//! **v1 compatibility:** the v1 line protocol (open/feed/finish/stats,
//! no handshake) is a strict subset of v2 — v1 clients keep working
//! unchanged; they simply never send `hello`/`config`. (v1 returned
//! errors as a plain string under `"error"`; v2 keeps the `"error"` key
//! so presence checks still work, and adds the code/message structure.)
//!
//! Architecture: connection threads parse requests and enqueue them on a
//! bounded channel (backpressure = immediate error response when full);
//! `hello` is answered on the connection thread (static capability
//! data); everything else flows through the
//! [`ShardPool`](super::ShardPool) router, which assigns sessions to
//! per-worker shards (`ShardConfig::workers`, each shard its own
//! lane-batched device loop over the shared model), rebalances queued
//! sessions off hot shards, answers `stats` by aggregating per-shard
//! snapshots, and serves `config` from shard 0's engine. With one
//! worker (the default) this degenerates to exactly the single
//! serialized device thread of the paper's host loop.

use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

use crate::util::json::{Json, JsonObj};

use super::engine::Engine;
use super::shard::{RouterMsg, ShardPool};

/// Protocol version this server speaks.
pub const PROTO_VERSION: u64 = 2;
/// Protocol versions whose request lines the server accepts.
pub const PROTO_ACCEPTED: &[u64] = &[1, 2];
/// Ops the server understands. `resume` re-attaches a reconnecting
/// client to its session: the reply reports consumed steps/samples (the
/// server's acknowledged state, restored from a checkpoint if the
/// session's worker died) so the client replays only unacknowledged
/// audio. `pool` is the elastic-pool control surface: `add` scales a
/// worker up, `drain` migrates a shard empty and retires it, `status`
/// reports every shard's lifecycle.
pub const OPS: &[&str] =
    &["hello", "open", "feed", "finish", "resume", "nbest", "stats", "config", "pool"];

/// Machine-readable error codes (stable across releases; clients branch
/// on these, not on message text).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// The request line was not valid JSON / missing required fields.
    BadRequest,
    /// `op` named something the server does not implement.
    UnknownOp,
    /// The referenced session id is not open.
    UnknownSession,
    /// The referenced session was shed by the overload policy before it
    /// ever decoded (`shed_never_started`): nothing was lost — reopen
    /// and resend from the start.
    SessionShed,
    /// The device queue is full; retry later.
    Backpressure,
    /// The server is shutting down.
    Shutdown,
    /// Engine-side failure (details in the message).
    Internal,
}

impl ErrCode {
    /// Every code the server can emit (conformance tests sweep this).
    pub const ALL: &'static [ErrCode] = &[
        ErrCode::BadRequest,
        ErrCode::UnknownOp,
        ErrCode::UnknownSession,
        ErrCode::SessionShed,
        ErrCode::Backpressure,
        ErrCode::Shutdown,
        ErrCode::Internal,
    ];

    /// The wire string for this code.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrCode::BadRequest => "bad_request",
            ErrCode::UnknownOp => "unknown_op",
            ErrCode::UnknownSession => "unknown_session",
            ErrCode::SessionShed => "session_shed",
            ErrCode::Backpressure => "backpressure",
            ErrCode::Shutdown => "shutdown",
            ErrCode::Internal => "internal",
        }
    }
}

/// A parsed request line: either answered on the connection thread or
/// forwarded to the shard router.
enum Request {
    Hello,
    Msg(RouterMsg),
}

/// Server handle (owns the listener + the shard pool behind it).
pub struct Server {
    /// The bound address (useful with port 0).
    pub addr: String,
    pool: ShardPool,
}

pub(crate) fn obj(pairs: &[(&str, Json)]) -> Json {
    let mut o = JsonObj::new();
    for (k, v) in pairs {
        o.insert(*k, v.clone());
    }
    Json::Obj(o)
}

/// Structured v2 error payload: `{"error":{"code":..., "message":...}}`.
/// Public so conformance tests (and alternative front-ends) can assert
/// the exact wire shape of every [`ErrCode`].
pub fn err_json(code: ErrCode, msg: &str) -> Json {
    obj(&[(
        "error",
        obj(&[
            ("code", Json::Str(code.as_str().to_string())),
            ("message", Json::Str(msg.to_string())),
        ]),
    )])
}

/// A `backpressure` error payload carrying the overload policy's
/// `retry_after_ms` hint inside the error object — how long a
/// well-behaved client should back off before retrying. Every
/// policy-driven bounce (admission refusal, saturated shard queue)
/// carries the hint; presence-of-`error` checks from v1 keep working.
pub fn backpressure_json(msg: &str, retry_after_ms: u64) -> Json {
    obj(&[(
        "error",
        obj(&[
            ("code", Json::Str(ErrCode::Backpressure.as_str().to_string())),
            ("message", Json::Str(msg.to_string())),
            ("retry_after_ms", Json::Num(retry_after_ms as f64)),
        ]),
    )])
}

/// The `hello` handshake response (static capability data).
fn hello_json() -> Json {
    obj(&[
        ("proto", Json::Num(PROTO_VERSION as f64)),
        ("server", Json::Str("asrpu".to_string())),
        (
            "versions",
            Json::Arr(PROTO_ACCEPTED.iter().map(|&v| Json::Num(v as f64)).collect()),
        ),
        (
            "ops",
            Json::Arr(OPS.iter().map(|o| Json::Str(o.to_string())).collect()),
        ),
    ])
}

/// The `config` introspection response: what this device pool is
/// serving (answered by shard 0's worker — every shard serves the same
/// engine configuration by construction).
pub(crate) fn config_json(engine: &Engine) -> Json {
    let m = &engine.model_cfg;
    obj(&[
        ("proto", Json::Num(PROTO_VERSION as f64)),
        ("backend", Json::Str(engine.backend().name().to_string())),
        (
            "precision",
            Json::Str(engine.backend().precision().as_str().to_string()),
        ),
        (
            "precisions",
            Json::Str(engine.backend().precision_map().to_string()),
        ),
        (
            "kernel_isa",
            Json::Str(engine.backend().kernel_isa().as_str().to_string()),
        ),
        ("model", Json::Str(m.name.clone())),
        ("tokens", Json::Num(m.tokens as f64)),
        ("sample_rate", Json::Num(m.sample_rate as f64)),
        ("samples_per_step", Json::Num(m.samples_per_step() as f64)),
        ("step_seconds", Json::Num(m.step_seconds())),
        ("stages", Json::Num(engine.pipeline().stages.len() as f64)),
        (
            "weight_bytes_per_step",
            Json::Num(engine.backend().weight_bytes_per_step() as f64),
        ),
        ("max_batch", Json::Num(engine.batch_cfg.max_batch as f64)),
        ("max_wait_frames", Json::Num(engine.batch_cfg.max_wait_frames as f64)),
        ("workers", Json::Num(engine.shard_cfg.workers as f64)),
        (
            "max_workers",
            Json::Num(engine.shard_cfg.effective_max_workers() as f64),
        ),
        (
            "drain_deadline_ms",
            Json::Num(engine.shard_cfg.drain_deadline_ms as f64),
        ),
        (
            "rebalance_threshold",
            Json::Num(engine.shard_cfg.rebalance_threshold as f64),
        ),
        (
            "checkpoint_interval",
            Json::Num(engine.shard_cfg.checkpoint_interval as f64),
        ),
        ("beam", Json::Num(engine.dec_cfg.beam as f64)),
        ("max_hyps", Json::Num(engine.dec_cfg.max_hyps as f64)),
        (
            "admit_sessions_per_shard",
            Json::Num(engine.overload.admit_sessions_per_shard as f64),
        ),
        ("retry_after_ms", Json::Num(engine.overload.retry_after_ms as f64)),
        (
            "shed_never_started",
            Json::Num(u64::from(engine.overload.shed_never_started) as f64),
        ),
        ("shed_memory", Json::Num(engine.overload.shed_memory as f64)),
        ("route_retries", Json::Num(engine.overload.route_retries as f64)),
        ("route_backoff_ms", Json::Num(engine.overload.route_backoff_ms as f64)),
        ("degrade_levels", Json::Num(engine.overload.levels.len() as f64)),
        ("nbest", Json::Num(engine.nbest_n() as f64)),
        (
            "rescore",
            Json::Num(u64::from(engine.rescorer().is_some()) as f64),
        ),
    ])
}

/// Parse one request line (v1 or v2).
fn parse_request(line: &str, reply: mpsc::Sender<Json>) -> Result<Request, (ErrCode, String)> {
    let v = Json::parse(line).map_err(|e| (ErrCode::BadRequest, format!("bad json: {e}")))?;
    let op = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| (ErrCode::BadRequest, "missing 'op'".to_string()))?;
    match op {
        "hello" => Ok(Request::Hello),
        "open" => Ok(Request::Msg(RouterMsg::Open { reply })),
        "stats" => Ok(Request::Msg(RouterMsg::Stats { reply })),
        "config" => Ok(Request::Msg(RouterMsg::Config { reply })),
        "feed" | "finish" | "resume" | "nbest" => {
            let session = v
                .get("session")
                .and_then(Json::as_f64)
                .ok_or_else(|| (ErrCode::BadRequest, "missing 'session'".to_string()))?
                as u64;
            if op == "finish" {
                return Ok(Request::Msg(RouterMsg::Finish { session, reply }));
            }
            if op == "resume" {
                return Ok(Request::Msg(RouterMsg::Resume { session, reply }));
            }
            if op == "nbest" {
                return Ok(Request::Msg(RouterMsg::Nbest { session, reply }));
            }
            let samples = v
                .get("samples")
                .and_then(Json::as_arr)
                .ok_or_else(|| (ErrCode::BadRequest, "missing 'samples'".to_string()))?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) as f32)
                .collect();
            Ok(Request::Msg(RouterMsg::Feed {
                session,
                samples,
                enqueued: Instant::now(),
                reply,
            }))
        }
        "pool" => {
            let action = v
                .get("action")
                .and_then(Json::as_str)
                .ok_or_else(|| (ErrCode::BadRequest, "missing 'action'".to_string()))?;
            match action {
                "add" => Ok(Request::Msg(RouterMsg::PoolAdd { reply })),
                "status" => Ok(Request::Msg(RouterMsg::PoolStatus { reply })),
                "drain" => {
                    let shard = v
                        .get("shard")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| (ErrCode::BadRequest, "missing 'shard'".to_string()))?
                        as usize;
                    Ok(Request::Msg(RouterMsg::PoolDrain { shard, reply }))
                }
                other => Err((
                    ErrCode::BadRequest,
                    format!("unknown pool action '{other}' (expected add|drain|status)"),
                )),
            }
        }
        other => Err((ErrCode::UnknownOp, format!("unknown op '{other}'"))),
    }
}

fn handle_conn(
    stream: TcpStream,
    jobs: mpsc::SyncSender<RouterMsg>,
    retry_after_ms: u64,
) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (tx, rx) = mpsc::channel();
        let response = match parse_request(&line, tx) {
            Err((code, msg)) => err_json(code, &msg),
            // Static capability data: answered without touching the
            // router queue (a handshake must not hit backpressure).
            Ok(Request::Hello) => hello_json(),
            Ok(Request::Msg(msg)) => match jobs.try_send(msg) {
                // The conn thread's own bounce carries the same
                // retry_after_ms hint policy bounces do — one
                // backpressure shape, wherever the queue saturates.
                Err(mpsc::TrySendError::Full(_)) => {
                    backpressure_json("queue full", retry_after_ms)
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    err_json(ErrCode::Shutdown, "server shutting down")
                }
                Ok(()) => rx
                    .recv()
                    .unwrap_or_else(|_| {
                        err_json(ErrCode::Internal, "device loop dropped request")
                    }),
            },
        };
        writeln!(writer, "{response}")?;
    }
    let _ = peer;
    Ok(())
}

impl Server {
    /// Bind and serve. `make_engine` runs on shard 0's device thread
    /// (PJRT handles are not `Send`); the engine carries its own
    /// batching (`EngineBuilder::batch`) and sharding
    /// (`EngineBuilder::shards`) policy — with `workers > 1` the pool
    /// seeds that many device workers from `Engine::clone_worker`.
    /// Blocks until the engine is built so construction errors (builder
    /// validation, artifact loading) surface here instead of as a
    /// silently dead device loop; serving then continues on background
    /// threads.
    pub fn start(
        addr: &str,
        make_engine: impl FnOnce() -> Result<Engine> + Send + 'static,
        queue_depth: usize,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?.to_string();
        let pool = ShardPool::start(make_engine, queue_depth)?;
        let accept_pool = pool.sender();
        let retry_hint = pool.retry_after_ms();
        std::thread::Builder::new()
            .name("asrpu-accept".into())
            .spawn(move || {
                for stream in listener.incoming().flatten() {
                    let tx = accept_pool.clone();
                    // Each conn thread carries its own copy of the
                    // policy's retry hint so its queue-full bounce
                    // matches the router's policy bounces.
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, tx, retry_hint);
                    });
                }
            })?;
        Ok(Server { addr: local, pool })
    }

    /// Number of device workers serving this endpoint.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Stop the router and every device worker (best-effort).
    pub fn shutdown(&self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::TdsModel;
    use crate::config::{BatchConfig, ModelConfig, ShardConfig};

    fn start_test_server() -> Server {
        Server::start(
            "127.0.0.1:0",
            || {
                Ok(Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    .batch(BatchConfig::default())
                    .build()?)
            },
            64,
        )
        .unwrap()
    }

    fn roundtrip(addr: &str, lines: &[String]) -> Vec<Json> {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut out = Vec::new();
        for l in lines {
            writeln!(writer, "{l}").unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            out.push(Json::parse(resp.trim()).unwrap());
        }
        out
    }

    #[test]
    fn v1_client_open_feed_finish_still_works() {
        // A v1 client: no hello handshake, v1 ops only. Must work
        // unchanged against the v2 server.
        let server = start_test_server();
        let samples: Vec<String> =
            (0..3200).map(|i| format!("{:.4}", (i as f32 * 0.01).sin() * 0.1)).collect();
        let feed = format!(
            r#"{{"op":"feed","session":1,"samples":[{}]}}"#,
            samples.join(",")
        );
        let resps = roundtrip(
            &server.addr,
            &[
                r#"{"op":"open"}"#.to_string(),
                feed,
                r#"{"op":"finish","session":1}"#.to_string(),
                r#"{"op":"stats"}"#.to_string(),
            ],
        );
        assert_eq!(resps[0].get("session").unwrap().as_f64(), Some(1.0));
        // 3200 samples = 2 steps (needs 1520, consumes 1280 each).
        assert_eq!(resps[1].get("steps").unwrap().as_f64(), Some(2.0));
        assert!(resps[2].get("text").is_some(), "{:?}", resps[2]);
        let summary = resps[3].get("summary").unwrap().as_str().unwrap().to_string();
        assert!(summary.contains("sessions 1/1"), "{summary}");
        server.shutdown();
    }

    #[test]
    fn hello_reports_capabilities() {
        let server = start_test_server();
        let resps = roundtrip(&server.addr, &[r#"{"op":"hello"}"#.to_string()]);
        assert_eq!(resps[0].get("proto").unwrap().as_f64(), Some(2.0));
        let versions = resps[0].get("versions").unwrap().as_arr().unwrap();
        assert_eq!(versions.len(), 2);
        let ops: Vec<&str> = resps[0]
            .get("ops")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(Json::as_str)
            .collect();
        for op in ["open", "feed", "finish", "stats", "config", "hello"] {
            assert!(ops.contains(&op), "missing op {op} in {ops:?}");
        }
        server.shutdown();
    }

    #[test]
    fn config_introspects_backend_and_policy() {
        let server = start_test_server();
        let resps = roundtrip(&server.addr, &[r#"{"op":"config"}"#.to_string()]);
        let c = &resps[0];
        assert_eq!(c.get("backend").unwrap().as_str(), Some("native-f32"));
        assert_eq!(c.get("precision").unwrap().as_str(), Some("f32"));
        // The per-layer map rides along in CLI syntax (uniform here).
        assert_eq!(c.get("precisions").unwrap().as_str(), Some("f32"));
        // The host kernel ISA is whatever dispatch resolved for this
        // process (runtime detection or ASRPU_KERNEL_ISA) — assert it is
        // present and in-vocabulary rather than pinning a host-dependent
        // value.
        let isa = c.get("kernel_isa").unwrap().as_str().unwrap();
        assert_eq!(
            crate::am::KernelIsa::parse(isa),
            Some(crate::am::KernelIsa::active())
        );
        assert_eq!(c.get("model").unwrap().as_str(), Some("tiny-tds"));
        assert_eq!(c.get("tokens").unwrap().as_f64(), Some(27.0));
        assert_eq!(
            c.get("max_batch").unwrap().as_f64(),
            Some(BatchConfig::default().max_batch as f64)
        );
        // Sharding policy is introspectable (default: one worker).
        assert_eq!(c.get("workers").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            c.get("rebalance_threshold").unwrap().as_f64(),
            Some(ShardConfig::default().rebalance_threshold as f64)
        );
        // Stage count: features + AM layers + hyp expansion.
        let stages = c.get("stages").unwrap().as_f64().unwrap() as usize;
        assert_eq!(stages, ModelConfig::tiny_tds().layers().len() + 2);
        server.shutdown();
    }

    #[test]
    fn sharded_server_serves_and_reports_worker_count() {
        // Two workers behind the same TCP endpoint: sessions open on
        // different shards, and both stats and config expose the pool.
        let server = Server::start(
            "127.0.0.1:0",
            || {
                Ok(Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    .shards(ShardConfig {
                        workers: 2,
                        rebalance_threshold: 2,
                        ..ShardConfig::default()
                    })
                    .build()?)
            },
            64,
        )
        .unwrap();
        assert_eq!(server.workers(), 2);
        let samples: Vec<String> = (0..1600)
            .map(|i| format!("{:.4}", (i as f32 * 0.01).sin() * 0.1))
            .collect();
        let joined = samples.join(",");
        let resps = roundtrip(
            &server.addr,
            &[
                r#"{"op":"open"}"#.to_string(),
                r#"{"op":"open"}"#.to_string(),
                format!(r#"{{"op":"feed","session":1,"samples":[{joined}]}}"#),
                format!(r#"{{"op":"feed","session":2,"samples":[{joined}]}}"#),
                r#"{"op":"finish","session":1}"#.to_string(),
                r#"{"op":"finish","session":2}"#.to_string(),
                r#"{"op":"config"}"#.to_string(),
                r#"{"op":"stats"}"#.to_string(),
            ],
        );
        assert_eq!(resps[2].get("steps").unwrap().as_f64(), Some(1.0));
        assert!(resps[4].get("text").is_some(), "{:?}", resps[4]);
        assert!(resps[5].get("text").is_some(), "{:?}", resps[5]);
        assert_eq!(resps[6].get("workers").unwrap().as_f64(), Some(2.0));
        assert_eq!(resps[7].get("workers").unwrap().as_f64(), Some(2.0));
        let shards = resps[7].get("shards").unwrap().as_arr().unwrap();
        assert_eq!(shards.len(), 2);
        // Deterministic least-loaded assignment: one session per shard.
        let finished: f64 = shards
            .iter()
            .map(|s| {
                let sum = s.get("summary").unwrap().as_str().unwrap();
                assert!(sum.contains("sessions 1/1"), "{sum}");
                1.0
            })
            .sum();
        assert_eq!(finished, 2.0);
        server.shutdown();
    }

    #[test]
    fn batched_feeds_report_occupancy() {
        // Two sessions fed from one connection: the second feed finds both
        // sessions open with one staged, so the device batches them when
        // the wait budget allows — and stats must expose batch counters
        // either way.
        let server = start_test_server();
        let samples: Vec<String> = (0..1600)
            .map(|i| format!("{:.4}", (i as f32 * 0.01).sin() * 0.1))
            .collect();
        let joined = samples.join(",");
        let resps = roundtrip(
            &server.addr,
            &[
                r#"{"op":"open"}"#.to_string(),
                r#"{"op":"open"}"#.to_string(),
                format!(r#"{{"op":"feed","session":1,"samples":[{joined}]}}"#),
                format!(r#"{{"op":"feed","session":2,"samples":[{joined}]}}"#),
                r#"{"op":"finish","session":1}"#.to_string(),
                r#"{"op":"finish","session":2}"#.to_string(),
                r#"{"op":"stats"}"#.to_string(),
            ],
        );
        assert_eq!(resps[2].get("steps").unwrap().as_f64(), Some(1.0));
        assert_eq!(resps[3].get("steps").unwrap().as_f64(), Some(1.0));
        assert!(resps[4].get("batch_occupancy").is_some(), "{:?}", resps[4]);
        let summary = resps[6].get("summary").unwrap().as_str().unwrap().to_string();
        assert!(summary.contains("batches"), "{summary}");
        assert!(summary.contains("sessions 2/2"), "{summary}");
        server.shutdown();
    }

    #[test]
    fn resume_reattaches_over_the_wire() {
        // A "reconnecting" client (fresh TCP connection) re-attaches to
        // its session with `resume` and learns the server's progress.
        let server = start_test_server();
        let samples: Vec<String> = (0..3200)
            .map(|i| format!("{:.4}", (i as f32 * 0.01).sin() * 0.1))
            .collect();
        let joined = samples.join(",");
        let resps = roundtrip(
            &server.addr,
            &[
                r#"{"op":"open"}"#.to_string(),
                format!(r#"{{"op":"feed","session":1,"samples":[{joined}]}}"#),
            ],
        );
        assert_eq!(resps[1].get("steps").unwrap().as_f64(), Some(2.0));
        // New connection: resume the same session.
        let resps2 = roundtrip(
            &server.addr,
            &[
                r#"{"op":"resume","session":1}"#.to_string(),
                r#"{"op":"resume","session":404}"#.to_string(),
                r#"{"op":"finish","session":1}"#.to_string(),
            ],
        );
        assert_eq!(resps2[0].get("session").unwrap().as_f64(), Some(1.0));
        assert_eq!(resps2[0].get("steps").unwrap().as_f64(), Some(2.0));
        assert!(resps2[0].get("buffered_samples").unwrap().as_f64().unwrap() < 1520.0);
        assert!(resps2[0].get("partial").is_some());
        assert_eq!(
            resps2[1]
                .get("error")
                .and_then(|e| e.get("code"))
                .and_then(Json::as_str),
            Some("unknown_session")
        );
        assert!(resps2[2].get("text").is_some(), "{:?}", resps2[2]);
        server.shutdown();
    }

    #[test]
    fn malformed_requests_get_structured_error_codes() {
        let server = start_test_server();
        let resps = roundtrip(
            &server.addr,
            &[
                "not json".to_string(),
                r#"{"op":"nope"}"#.to_string(),
                r#"{"op":"feed","session":999,"samples":[0.0]}"#.to_string(),
                r#"{"op":"finish","session":999}"#.to_string(),
            ],
        );
        let code = |r: &Json| {
            r.get("error")
                .and_then(|e| e.get("code"))
                .and_then(Json::as_str)
                .map(str::to_string)
        };
        assert_eq!(code(&resps[0]).as_deref(), Some("bad_request"));
        assert_eq!(code(&resps[1]).as_deref(), Some("unknown_op"));
        assert_eq!(code(&resps[2]).as_deref(), Some("unknown_session"));
        assert_eq!(code(&resps[3]).as_deref(), Some("unknown_session"));
        // v1-style presence check keeps working on structured errors.
        for r in &resps {
            assert!(r.get("error").is_some(), "{r:?}");
        }
        server.shutdown();
    }

    #[test]
    fn start_surfaces_engine_construction_errors() {
        // A misconfigured engine must fail Server::start itself, not
        // leave a bound server with a dead device loop.
        let err = Server::start(
            "127.0.0.1:0",
            || {
                Ok(Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    .batch(BatchConfig { max_batch: 0, max_wait_frames: 8 })
                    .build()?)
            },
            8,
        )
        .err();
        let msg = format!("{:#}", err.expect("start must fail"));
        assert!(msg.contains("engine init failed"), "{msg}");
        assert!(msg.contains("batch"), "{msg}");
    }

    #[test]
    fn concurrent_sessions_are_isolated() {
        let server = start_test_server();
        let addr = server.addr.clone();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let n = 1600 + i * 320;
                    let samples: Vec<String> =
                        (0..n).map(|t| format!("{:.3}", (t as f32 * 0.02).sin() * 0.2)).collect();
                    let resps = roundtrip(
                        &addr,
                        &[
                            r#"{"op":"open"}"#.to_string(),
                            format!(
                                r#"{{"op":"feed","session":SESS,"samples":[{}]}}"#,
                                samples.join(",")
                            ),
                        ],
                    );
                    let sess = resps[0].get("session").unwrap().as_f64().unwrap();
                    // Re-issue feed with the real session id.
                    let resps2 = roundtrip(
                        &addr,
                        &[
                            format!(
                                r#"{{"op":"feed","session":{sess},"samples":[{}]}}"#,
                                samples.join(",")
                            ),
                            format!(r#"{{"op":"finish","session":{sess}}}"#),
                        ],
                    );
                    assert!(resps2[1].get("text").is_some());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }
}
