//! The serving front-end: a JSON-lines TCP server multiplexing many
//! streaming sessions onto one engine — the shape of the paper's §4.1
//! deployment (a host process feeding DecodingStep commands to a single
//! ASRPU device), extended with the queueing, backpressure and metrics a
//! production router needs.
//!
//! ## Protocol v2 (one JSON object per line)
//!
//!   → {"op":"hello"}                  ← {"proto":2,"server":"asrpu",
//!                                        "versions":[1,2],"ops":[...]}
//!   → {"op":"open"}                   ← {"session":N}
//!   → {"op":"feed","session":N,
//!      "samples":[...]}               ← {"steps":K,"partial":"..."}
//!   → {"op":"finish","session":N}     ← {"text":"...","rtf":X,...}
//!   → {"op":"stats"}                  ← {"summary":"..."}
//!   → {"op":"config"}                 ← {"proto":2,"backend":"...",
//!                                        "precision":"...","model":...}
//!
//! Errors are structured: `{"error":{"code":"...","message":"..."}}`
//! with stable machine-readable codes (`bad_request`, `unknown_op`,
//! `unknown_session`, `backpressure`, `shutdown`, `internal`).
//!
//! **v1 compatibility:** the v1 line protocol (open/feed/finish/stats,
//! no handshake) is a strict subset of v2 — v1 clients keep working
//! unchanged; they simply never send `hello`/`config`. (v1 returned
//! errors as a plain string under `"error"`; v2 keeps the `"error"` key
//! so presence checks still work, and adds the code/message structure.)
//!
//! Architecture: connection threads parse requests and enqueue jobs on a
//! bounded channel (backpressure = immediate error response when full);
//! `hello` is answered on the connection thread (static capability data);
//! everything else serializes through a single device thread that owns
//! the engine and all session state — mirroring the serialized
//! DecodingStep semantics of the hardware.
//!
//! Feeds drain through the lane-batched execution core: the device loop
//! stages each feed behind a [`Batcher`] and fuses ready sessions into
//! one `Engine::step_batch` call. A batch flushes when it is full, when
//! every open session is already staged (a lone stream never waits), or
//! when the oldest staged lane exhausts the configured wait budget. The
//! batching policy comes from the engine itself
//! (`EngineBuilder::batch`).

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

use crate::config::Precision;
use crate::util::json::{Json, JsonObj};

use super::engine::{Batcher, Engine, Session};
use super::metrics::ServeMetrics;

/// Protocol version this server speaks.
pub const PROTO_VERSION: u64 = 2;
/// Protocol versions whose request lines the server accepts.
pub const PROTO_ACCEPTED: &[u64] = &[1, 2];
/// Ops the server understands.
pub const OPS: &[&str] = &["hello", "open", "feed", "finish", "stats", "config"];

/// Machine-readable error codes (stable across releases; clients branch
/// on these, not on message text).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// The request line was not valid JSON / missing required fields.
    BadRequest,
    /// `op` named something the server does not implement.
    UnknownOp,
    /// The referenced session id is not open.
    UnknownSession,
    /// The device queue is full; retry later.
    Backpressure,
    /// The server is shutting down.
    Shutdown,
    /// Engine-side failure (details in the message).
    Internal,
}

impl ErrCode {
    /// The wire string for this code.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrCode::BadRequest => "bad_request",
            ErrCode::UnknownOp => "unknown_op",
            ErrCode::UnknownSession => "unknown_session",
            ErrCode::Backpressure => "backpressure",
            ErrCode::Shutdown => "shutdown",
            ErrCode::Internal => "internal",
        }
    }
}

/// A queued unit of device work.
pub(crate) enum Job {
    Open { reply: mpsc::Sender<Json> },
    Feed { session: u64, samples: Vec<f32>, enqueued: Instant, reply: mpsc::Sender<Json> },
    Finish { session: u64, reply: mpsc::Sender<Json> },
    Stats { reply: mpsc::Sender<Json> },
    Config { reply: mpsc::Sender<Json> },
    Shutdown,
}

/// A parsed request line: either answered on the connection thread or
/// forwarded to the device loop.
enum Request {
    Hello,
    Job(Job),
}

/// Server handle (owns the listener thread).
pub struct Server {
    pub addr: String,
    jobs: mpsc::SyncSender<Job>,
}

fn obj(pairs: &[(&str, Json)]) -> Json {
    let mut o = JsonObj::new();
    for (k, v) in pairs {
        o.insert(*k, v.clone());
    }
    Json::Obj(o)
}

/// Structured v2 error: `{"error":{"code":..., "message":...}}`.
fn err_json(code: ErrCode, msg: &str) -> Json {
    obj(&[(
        "error",
        obj(&[
            ("code", Json::Str(code.as_str().to_string())),
            ("message", Json::Str(msg.to_string())),
        ]),
    )])
}

/// The `hello` handshake response (static capability data).
fn hello_json() -> Json {
    obj(&[
        ("proto", Json::Num(PROTO_VERSION as f64)),
        ("server", Json::Str("asrpu".to_string())),
        (
            "versions",
            Json::Arr(PROTO_ACCEPTED.iter().map(|&v| Json::Num(v as f64)).collect()),
        ),
        (
            "ops",
            Json::Arr(OPS.iter().map(|o| Json::Str(o.to_string())).collect()),
        ),
    ])
}

/// The `config` introspection response: what this device is serving.
fn config_json(engine: &Engine) -> Json {
    let m = &engine.model_cfg;
    let precision = match engine.backend().precision() {
        Precision::F32 => "f32",
        Precision::Int8 => "int8",
    };
    obj(&[
        ("proto", Json::Num(PROTO_VERSION as f64)),
        ("backend", Json::Str(engine.backend().name().to_string())),
        ("precision", Json::Str(precision.to_string())),
        ("model", Json::Str(m.name.clone())),
        ("tokens", Json::Num(m.tokens as f64)),
        ("sample_rate", Json::Num(m.sample_rate as f64)),
        ("samples_per_step", Json::Num(m.samples_per_step() as f64)),
        ("step_seconds", Json::Num(m.step_seconds())),
        ("stages", Json::Num(engine.pipeline().stages.len() as f64)),
        (
            "weight_bytes_per_step",
            Json::Num(engine.backend().weight_bytes_per_step() as f64),
        ),
        ("max_batch", Json::Num(engine.batch_cfg.max_batch as f64)),
        ("max_wait_frames", Json::Num(engine.batch_cfg.max_wait_frames as f64)),
        ("beam", Json::Num(engine.dec_cfg.beam as f64)),
        ("max_hyps", Json::Num(engine.dec_cfg.max_hyps as f64)),
    ])
}

/// A feed waiting for its batch to flush.
struct StagedFeed {
    session: u64,
    reply: mpsc::Sender<Json>,
    enqueued: Instant,
}

/// Run the pending batch: pull its sessions out of the map, fuse their
/// ready steps through `Engine::step_batch`, record occupancy/latency,
/// then answer every staged feed with its session's step count + partial.
///
/// Known coarseness, acceptable at this layer: if one session was fed
/// twice before the flush (two connections), both replies report the
/// same since-staging step delta; and a batch-level engine error is
/// reported to every staged feed in the batch, not just the failing
/// lane's.
fn flush_batch(
    engine: &Engine,
    sessions: &mut HashMap<u64, Session>,
    batcher: &mut Batcher,
    staged: &mut Vec<StagedFeed>,
    metrics: &mut ServeMetrics,
) {
    let ids = batcher.take();
    // Pull the batch's sessions out of the map so every lane can be
    // borrowed mutably at once; they go back right after the fused step.
    let mut lanes: Vec<(u64, Session, usize)> = Vec::with_capacity(ids.len());
    for id in ids {
        if let Some(s) = sessions.remove(&id) {
            let steps_before = s.metrics.steps;
            lanes.push((id, s, steps_before));
        }
    }
    let occupancy = lanes.iter().filter(|(_, s, _)| engine.ready_steps(s) > 0).count();
    let t0 = Instant::now();
    let result = {
        let mut refs: Vec<&mut Session> = lanes.iter_mut().map(|(_, s, _)| s).collect();
        engine.step_batch(&mut refs)
    };
    if occupancy > 0 {
        metrics.record_batch(occupancy, t0.elapsed());
    }
    let err = result.err().map(|e| format!("feed failed: {e:#}"));
    for (id, s, steps_before) in lanes {
        let steps = s.metrics.steps - steps_before;
        metrics.steps_executed += steps as u64;
        metrics.audio_seconds += steps as f64 * engine.model_cfg.step_seconds();
        let partial = engine.partial(&s).map(|t| t.text).unwrap_or_default();
        sessions.insert(id, s);
        staged.retain(|f| {
            if f.session != id {
                return true;
            }
            let resp = match &err {
                Some(msg) => err_json(ErrCode::Internal, msg),
                None => obj(&[
                    ("steps", Json::Num(steps as f64)),
                    ("partial", Json::Str(partial.clone())),
                ]),
            };
            metrics.feed_latency.record(f.enqueued.elapsed());
            let _ = f.reply.send(resp);
            false
        });
    }
    // Staged feeds whose session vanished from the map (finished from
    // another connection mid-batch): answer rather than hang the client.
    for f in staged.drain(..) {
        let _ = f
            .reply
            .send(err_json(ErrCode::UnknownSession, "session closed before its batch ran"));
    }
}

/// Run the device loop over the job channel (blocks). Exposed for
/// in-process use (tests, examples) without TCP. The batching policy is
/// the engine's own (`Engine::batcher`).
pub(crate) fn device_loop(engine: Engine, jobs: mpsc::Receiver<Job>) {
    let mut sessions: HashMap<u64, Session> = HashMap::new();
    let mut next_id: u64 = 1;
    let mut metrics = ServeMetrics::default();
    let mut batcher = engine.batcher();
    let mut staged: Vec<StagedFeed> = Vec::new();
    loop {
        // Enforce the wait budget even under sustained job traffic: a
        // queued message makes recv_timeout return Ok without ever timing
        // out, so an expired partial batch must flush here, not just on
        // the Timeout arm.
        if !staged.is_empty() && batcher.wait_budget().is_zero() {
            flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics);
        }
        // Block for the next job; with feeds staged, cap the wait at the
        // batcher's remaining budget so a partial batch still flushes.
        let job = if staged.is_empty() {
            match jobs.recv() {
                Ok(j) => j,
                Err(_) => break,
            }
        } else {
            match jobs.recv_timeout(batcher.wait_budget()) {
                Ok(j) => j,
                Err(mpsc::RecvTimeoutError::Timeout) => {
                    flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics);
                    continue;
                }
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics);
                    break;
                }
            }
        };
        match job {
            Job::Shutdown => {
                flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics);
                break;
            }
            Job::Open { reply } => {
                let resp = match engine.open(false) {
                    Ok(s) => {
                        let id = next_id;
                        next_id += 1;
                        sessions.insert(id, s);
                        metrics.sessions_opened += 1;
                        obj(&[("session", Json::Num(id as f64))])
                    }
                    Err(e) => err_json(ErrCode::Internal, &format!("open failed: {e:#}")),
                };
                let _ = reply.send(resp);
            }
            Job::Feed { session, samples, enqueued, reply } => {
                match sessions.get_mut(&session) {
                    None => {
                        let _ = reply.send(err_json(ErrCode::UnknownSession, "unknown session"));
                    }
                    Some(s) => {
                        engine.push_audio(s, &samples);
                        staged.push(StagedFeed { session, reply, enqueued });
                        // Flush when the batch is full — or when every open
                        // session is already staged, since no further lane
                        // can arrive before some staged client unblocks.
                        if batcher.push(session) || batcher.len() >= sessions.len() {
                            flush_batch(
                                &engine,
                                &mut sessions,
                                &mut batcher,
                                &mut staged,
                                &mut metrics,
                            );
                        }
                    }
                }
            }
            Job::Finish { session, reply } => {
                // Any staged work (this session's included) runs first so
                // the transcript covers all fed audio.
                if !staged.is_empty() {
                    flush_batch(&engine, &mut sessions, &mut batcher, &mut staged, &mut metrics);
                }
                batcher.remove(session);
                let resp = match sessions.remove(&session) {
                    None => err_json(ErrCode::UnknownSession, "unknown session"),
                    Some(mut s) => match engine.finish(&mut s) {
                        Ok(t) => {
                            metrics.sessions_finished += 1;
                            metrics.compute_seconds += s.metrics.compute_s;
                            obj(&[
                                ("text", Json::Str(t.text)),
                                ("score", Json::Num(t.score as f64)),
                                ("rtf", Json::Num(s.metrics.rtf())),
                                ("steps", Json::Num(s.metrics.steps as f64)),
                                ("batch_occupancy", Json::Num(s.metrics.avg_batch_occupancy())),
                            ])
                        }
                        Err(e) => err_json(ErrCode::Internal, &format!("finish failed: {e:#}")),
                    },
                };
                let _ = reply.send(resp);
            }
            Job::Stats { reply } => {
                let _ = reply.send(obj(&[("summary", Json::Str(metrics.summary()))]));
            }
            Job::Config { reply } => {
                let _ = reply.send(config_json(&engine));
            }
        }
    }
}

/// Parse one request line (v1 or v2).
fn parse_request(line: &str, reply: mpsc::Sender<Json>) -> Result<Request, (ErrCode, String)> {
    let v = Json::parse(line).map_err(|e| (ErrCode::BadRequest, format!("bad json: {e}")))?;
    let op = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| (ErrCode::BadRequest, "missing 'op'".to_string()))?;
    match op {
        "hello" => Ok(Request::Hello),
        "open" => Ok(Request::Job(Job::Open { reply })),
        "stats" => Ok(Request::Job(Job::Stats { reply })),
        "config" => Ok(Request::Job(Job::Config { reply })),
        "feed" | "finish" => {
            let session = v
                .get("session")
                .and_then(Json::as_f64)
                .ok_or_else(|| (ErrCode::BadRequest, "missing 'session'".to_string()))?
                as u64;
            if op == "finish" {
                return Ok(Request::Job(Job::Finish { session, reply }));
            }
            let samples = v
                .get("samples")
                .and_then(Json::as_arr)
                .ok_or_else(|| (ErrCode::BadRequest, "missing 'samples'".to_string()))?
                .iter()
                .map(|x| x.as_f64().unwrap_or(0.0) as f32)
                .collect();
            Ok(Request::Job(Job::Feed { session, samples, enqueued: Instant::now(), reply }))
        }
        other => Err((ErrCode::UnknownOp, format!("unknown op '{other}'"))),
    }
}

fn handle_conn(stream: TcpStream, jobs: mpsc::SyncSender<Job>) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (tx, rx) = mpsc::channel();
        let response = match parse_request(&line, tx) {
            Err((code, msg)) => err_json(code, &msg),
            // Static capability data: answered without touching the
            // device queue (a handshake must not hit backpressure).
            Ok(Request::Hello) => hello_json(),
            Ok(Request::Job(job)) => match jobs.try_send(job) {
                Err(mpsc::TrySendError::Full(_)) => {
                    err_json(ErrCode::Backpressure, "queue full")
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    err_json(ErrCode::Shutdown, "server shutting down")
                }
                Ok(()) => rx
                    .recv()
                    .unwrap_or_else(|_| {
                        err_json(ErrCode::Internal, "device loop dropped request")
                    }),
            },
        };
        writeln!(writer, "{response}")?;
    }
    let _ = peer;
    Ok(())
}

impl Server {
    /// Bind and serve. `make_engine` runs on the device thread (PJRT
    /// handles are not `Send`); the engine carries its own batching
    /// policy (`EngineBuilder::batch`). Blocks until the engine is built
    /// so construction errors (builder validation, artifact loading)
    /// surface here instead of as a silently dead device loop; serving
    /// then continues on background threads.
    pub fn start(
        addr: &str,
        make_engine: impl FnOnce() -> Result<Engine> + Send + 'static,
        queue_depth: usize,
    ) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?.to_string();
        let (jobs_tx, jobs_rx) = mpsc::sync_channel::<Job>(queue_depth);
        let (init_tx, init_rx) = mpsc::channel::<Result<(), String>>();
        std::thread::Builder::new()
            .name("asrpu-device".into())
            .spawn(move || match make_engine() {
                Ok(engine) => {
                    let _ = init_tx.send(Ok(()));
                    device_loop(engine, jobs_rx);
                }
                Err(e) => {
                    let _ = init_tx.send(Err(format!("{e:#}")));
                }
            })?;
        match init_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => anyhow::bail!("engine init failed: {msg}"),
            Err(_) => anyhow::bail!("engine init thread died"),
        }
        let accept_tx = jobs_tx.clone();
        std::thread::Builder::new()
            .name("asrpu-accept".into())
            .spawn(move || {
                for stream in listener.incoming().flatten() {
                    let tx = accept_tx.clone();
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, tx);
                    });
                }
            })?;
        Ok(Server { addr: local, jobs: jobs_tx })
    }

    pub fn shutdown(&self) {
        let _ = self.jobs.try_send(Job::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::TdsModel;
    use crate::config::{BatchConfig, ModelConfig};

    fn start_test_server() -> Server {
        Server::start(
            "127.0.0.1:0",
            || {
                Ok(Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    .batch(BatchConfig::default())
                    .build()?)
            },
            64,
        )
        .unwrap()
    }

    fn roundtrip(addr: &str, lines: &[String]) -> Vec<Json> {
        let stream = TcpStream::connect(addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);
        let mut out = Vec::new();
        for l in lines {
            writeln!(writer, "{l}").unwrap();
            let mut resp = String::new();
            reader.read_line(&mut resp).unwrap();
            out.push(Json::parse(resp.trim()).unwrap());
        }
        out
    }

    #[test]
    fn v1_client_open_feed_finish_still_works() {
        // A v1 client: no hello handshake, v1 ops only. Must work
        // unchanged against the v2 server.
        let server = start_test_server();
        let samples: Vec<String> = (0..3200).map(|i| format!("{:.4}", (i as f32 * 0.01).sin() * 0.1)).collect();
        let feed = format!(
            r#"{{"op":"feed","session":1,"samples":[{}]}}"#,
            samples.join(",")
        );
        let resps = roundtrip(
            &server.addr,
            &[
                r#"{"op":"open"}"#.to_string(),
                feed,
                r#"{"op":"finish","session":1}"#.to_string(),
                r#"{"op":"stats"}"#.to_string(),
            ],
        );
        assert_eq!(resps[0].get("session").unwrap().as_f64(), Some(1.0));
        // 3200 samples = 2 steps (needs 1520, consumes 1280 each).
        assert_eq!(resps[1].get("steps").unwrap().as_f64(), Some(2.0));
        assert!(resps[2].get("text").is_some(), "{:?}", resps[2]);
        let summary = resps[3].get("summary").unwrap().as_str().unwrap().to_string();
        assert!(summary.contains("sessions 1/1"), "{summary}");
        server.shutdown();
    }

    #[test]
    fn hello_reports_capabilities() {
        let server = start_test_server();
        let resps = roundtrip(&server.addr, &[r#"{"op":"hello"}"#.to_string()]);
        assert_eq!(resps[0].get("proto").unwrap().as_f64(), Some(2.0));
        let versions = resps[0].get("versions").unwrap().as_arr().unwrap();
        assert_eq!(versions.len(), 2);
        let ops: Vec<&str> = resps[0]
            .get("ops")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter_map(Json::as_str)
            .collect();
        for op in ["open", "feed", "finish", "stats", "config", "hello"] {
            assert!(ops.contains(&op), "missing op {op} in {ops:?}");
        }
        server.shutdown();
    }

    #[test]
    fn config_introspects_backend_and_policy() {
        let server = start_test_server();
        let resps = roundtrip(&server.addr, &[r#"{"op":"config"}"#.to_string()]);
        let c = &resps[0];
        assert_eq!(c.get("backend").unwrap().as_str(), Some("native-f32"));
        assert_eq!(c.get("precision").unwrap().as_str(), Some("f32"));
        assert_eq!(c.get("model").unwrap().as_str(), Some("tiny-tds"));
        assert_eq!(c.get("tokens").unwrap().as_f64(), Some(27.0));
        assert_eq!(
            c.get("max_batch").unwrap().as_f64(),
            Some(BatchConfig::default().max_batch as f64)
        );
        // Stage count: features + AM layers + hyp expansion.
        let stages = c.get("stages").unwrap().as_f64().unwrap() as usize;
        assert_eq!(stages, ModelConfig::tiny_tds().layers().len() + 2);
        server.shutdown();
    }

    #[test]
    fn batched_feeds_report_occupancy() {
        // Two sessions fed from one connection: the second feed finds both
        // sessions open with one staged, so the device batches them when
        // the wait budget allows — and stats must expose batch counters
        // either way.
        let server = start_test_server();
        let samples: Vec<String> = (0..1600)
            .map(|i| format!("{:.4}", (i as f32 * 0.01).sin() * 0.1))
            .collect();
        let joined = samples.join(",");
        let resps = roundtrip(
            &server.addr,
            &[
                r#"{"op":"open"}"#.to_string(),
                r#"{"op":"open"}"#.to_string(),
                format!(r#"{{"op":"feed","session":1,"samples":[{joined}]}}"#),
                format!(r#"{{"op":"feed","session":2,"samples":[{joined}]}}"#),
                r#"{"op":"finish","session":1}"#.to_string(),
                r#"{"op":"finish","session":2}"#.to_string(),
                r#"{"op":"stats"}"#.to_string(),
            ],
        );
        assert_eq!(resps[2].get("steps").unwrap().as_f64(), Some(1.0));
        assert_eq!(resps[3].get("steps").unwrap().as_f64(), Some(1.0));
        assert!(resps[4].get("batch_occupancy").is_some(), "{:?}", resps[4]);
        let summary = resps[6].get("summary").unwrap().as_str().unwrap().to_string();
        assert!(summary.contains("batches"), "{summary}");
        assert!(summary.contains("sessions 2/2"), "{summary}");
        server.shutdown();
    }

    #[test]
    fn malformed_requests_get_structured_error_codes() {
        let server = start_test_server();
        let resps = roundtrip(
            &server.addr,
            &[
                "not json".to_string(),
                r#"{"op":"nope"}"#.to_string(),
                r#"{"op":"feed","session":999,"samples":[0.0]}"#.to_string(),
                r#"{"op":"finish","session":999}"#.to_string(),
            ],
        );
        let code = |r: &Json| {
            r.get("error")
                .and_then(|e| e.get("code"))
                .and_then(Json::as_str)
                .map(str::to_string)
        };
        assert_eq!(code(&resps[0]).as_deref(), Some("bad_request"));
        assert_eq!(code(&resps[1]).as_deref(), Some("unknown_op"));
        assert_eq!(code(&resps[2]).as_deref(), Some("unknown_session"));
        assert_eq!(code(&resps[3]).as_deref(), Some("unknown_session"));
        // v1-style presence check keeps working on structured errors.
        for r in &resps {
            assert!(r.get("error").is_some(), "{r:?}");
        }
        server.shutdown();
    }

    #[test]
    fn start_surfaces_engine_construction_errors() {
        // A misconfigured engine must fail Server::start itself, not
        // leave a bound server with a dead device loop.
        let err = Server::start(
            "127.0.0.1:0",
            || {
                Ok(Engine::builder()
                    .native(TdsModel::random(ModelConfig::tiny_tds(), 5))
                    .batch(BatchConfig { max_batch: 0, max_wait_frames: 8 })
                    .build()?)
            },
            8,
        )
        .err();
        let msg = format!("{:#}", err.expect("start must fail"));
        assert!(msg.contains("engine init failed"), "{msg}");
        assert!(msg.contains("batch"), "{msg}");
    }

    #[test]
    fn concurrent_sessions_are_isolated() {
        let server = start_test_server();
        let addr = server.addr.clone();
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let n = 1600 + i * 320;
                    let samples: Vec<String> =
                        (0..n).map(|t| format!("{:.3}", (t as f32 * 0.02).sin() * 0.2)).collect();
                    let resps = roundtrip(
                        &addr,
                        &[
                            r#"{"op":"open"}"#.to_string(),
                            format!(
                                r#"{{"op":"feed","session":SESS,"samples":[{}]}}"#,
                                samples.join(",")
                            ),
                        ],
                    );
                    let sess = resps[0].get("session").unwrap().as_f64().unwrap();
                    // Re-issue feed with the real session id.
                    let resps2 = roundtrip(
                        &addr,
                        &[
                            format!(
                                r#"{{"op":"feed","session":{sess},"samples":[{}]}}"#,
                                samples.join(",")
                            ),
                            format!(r#"{{"op":"finish","session":{sess}}}"#),
                        ],
                    );
                    assert!(resps2[1].get("text").is_some());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        server.shutdown();
    }
}
