//! The relocatable session-state object: everything one streaming
//! session is, as data — the refactor that turns shard-resident implicit
//! state into something the router can move, checkpoint and restore.
//!
//! A [`SessionSnapshot`] composes the three state layers every session
//! carries:
//!
//! * **acoustic** — the backend's per-lane streaming state, serialized
//!   by [`AmBackend::snapshot_lane`](super::backend::AmBackend) into
//!   named tensors (native: conv histories; XLA: device buffers copied
//!   to host);
//! * **decoder** — the beam state as a
//!   [`DecoderSnapshot`](crate::decoder::DecoderSnapshot) (hypothesis
//!   set, LM contexts, backtrack arena, pruner stats);
//! * **engine** — buffered not-yet-consumed audio plus the session's
//!   step/audio counters ([`SessionMetrics`]).
//!
//! Identity is part of the snapshot: the backend name and model name
//! are recorded and validated on restore, so a snapshot can never be
//! revived against different weights and silently decode garbage.
//!
//! ## Wire format
//!
//! ```text
//! magic    : 8 bytes = b"ASRPUSNP"
//! version  : u32 le  = SNAPSHOT_VERSION
//! len      : u64 le  — payload byte length
//! crc32    : u32 le  — IEEE CRC-32 over the payload
//! payload  : a util::tensor_io container (deterministic bytes)
//! ```
//!
//! The payload is an ordinary tensor container: `meta.*` identity and
//! counter tensors, `audio.buffered` (f32 samples), `dec.*` decoder
//! tensors and `am.*` backend tensors. Encoding is deterministic (the
//! container preserves order and payload bytes verbatim), decode
//! verifies magic, version and checksum before parsing, and every
//! checkpoint/migration in [`super::shard`] ships these exact bytes —
//! the serialization path is the production path, not a test fixture.
#![deny(missing_docs)]

use anyhow::{bail, ensure, Context, Result};

use crate::decoder::DecoderSnapshot;
use crate::util::tensor_io::{u64_from_words, u64_words, Tensor, TensorFile};

use super::engine::SessionMetrics;

/// Snapshot format version; bumped on any layout change so a newer
/// server refuses stale checkpoints instead of misparsing them.
/// Version 2 extended `meta.metrics` with the graceful-degradation
/// counters (degraded steps, rung transitions, last rung in effect).
/// Version 3 widened `dec.counters` from 14 to 24 words (expansion-side
/// arc counters) and added the optional `dec.lat.*` lattice tensors.
pub const SNAPSHOT_VERSION: u32 = 3;

const MAGIC: &[u8; 8] = b"ASRPUSNP";

/// IEEE CRC-32 (reflected, polynomial 0xEDB88320) — bitwise, no table:
/// snapshots are kilobytes and checksummed once per checkpoint, so
/// simplicity beats throughput here.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// A complete, self-describing copy of one session's state. Plain data
/// (`Send`), produced by [`Engine::snapshot`](super::Engine::snapshot)
/// and consumed by [`Engine::restore`](super::Engine::restore);
/// [`Self::encode`]/[`Self::decode`] are the byte round-trip shards and
/// checkpoints ship.
#[derive(Debug, Clone)]
pub struct SessionSnapshot {
    /// Backend that produced the acoustic tensors (`native-f32` | …);
    /// restore refuses a mismatch.
    pub backend: String,
    /// Model name the session was decoding with; restore refuses a
    /// mismatch.
    pub model: String,
    /// Audio staged but not yet consumed by a decoding step.
    pub buffered: Vec<f32>,
    /// The session's accumulated step/audio/latency counters.
    pub metrics: SessionMetrics,
    /// Backend-defined acoustic lane state (names unprefixed here;
    /// `am.`-prefixed inside the encoded container).
    pub am: TensorFile,
    /// The beam/decoder lane state.
    pub decoder: DecoderSnapshot,
}

/// Encode a `u64` as its `[lo, hi]` u32 words.
fn push_u64(out: &mut Vec<u32>, v: u64) {
    out.extend_from_slice(&u64_words(v));
}

/// Encode an `f64` bit pattern as two u32 words (lo, hi) — lossless.
fn push_f64(out: &mut Vec<u32>, v: f64) {
    push_u64(out, v.to_bits());
}

impl SessionSnapshot {
    /// Serialize to the versioned, checksummed wire format.
    pub fn encode(&self) -> Vec<u8> {
        let mut tf = TensorFile::new();
        let str_tensor = |name: &str, s: &str| {
            Tensor {
                name: name.to_string(),
                dims: vec![s.len()],
                data: crate::util::tensor_io::TensorData::I8(
                    s.as_bytes().iter().map(|&b| b as i8).collect(),
                ),
            }
        };
        tf.push(str_tensor("meta.backend", &self.backend));
        tf.push(str_tensor("meta.model", &self.model));
        let m = &self.metrics;
        let mut counters = Vec::with_capacity(22);
        push_u64(&mut counters, m.steps as u64);
        push_u64(&mut counters, m.batched_steps as u64);
        push_u64(&mut counters, m.batch_lanes as u64);
        push_u64(&mut counters, m.snapshots_taken as u64);
        push_f64(&mut counters, m.audio_s);
        push_f64(&mut counters, m.compute_s);
        push_f64(&mut counters, m.am_s);
        push_f64(&mut counters, m.search_s);
        push_u64(&mut counters, m.degraded_steps as u64);
        push_u64(&mut counters, m.degrade_transitions as u64);
        push_u64(&mut counters, m.degrade_level as u64);
        tf.push(Tensor::u32("meta.metrics", vec![counters.len()], counters));
        tf.push(Tensor::f32(
            "audio.buffered",
            vec![self.buffered.len()],
            self.buffered.clone(),
        ));
        self.decoder.write_tensors(&mut tf);
        for t in &self.am.tensors {
            tf.push(Tensor {
                name: format!("am.{}", t.name),
                dims: t.dims.clone(),
                data: t.data.clone(),
            });
        }
        let payload = tf.to_bytes().expect("snapshot tensors must validate");
        let mut out = Vec::with_capacity(24 + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&crc32(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Parse and verify (magic, version, length, checksum) an encoded
    /// snapshot.
    pub fn decode(bytes: &[u8]) -> Result<SessionSnapshot> {
        ensure!(bytes.len() >= 24, "snapshot truncated: {} bytes", bytes.len());
        ensure!(&bytes[..8] == MAGIC, "bad magic: not a session snapshot");
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != SNAPSHOT_VERSION {
            bail!("snapshot version {version}, this build reads {SNAPSHOT_VERSION}");
        }
        let len = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[20..24].try_into().unwrap());
        let payload = bytes
            .get(24..24 + len)
            .context("snapshot payload truncated")?;
        ensure!(24 + len == bytes.len(), "trailing bytes after snapshot payload");
        let actual = crc32(payload);
        ensure!(
            actual == crc,
            "snapshot checksum mismatch: stored {crc:#010x}, computed {actual:#010x}"
        );
        let tf = TensorFile::from_bytes(payload).context("parsing snapshot payload")?;
        let read_str = |name: &str| -> Result<String> {
            let t = tf.require(name)?;
            let bytes: Vec<u8> = t.as_i8()?.iter().map(|&b| b as u8).collect();
            String::from_utf8(bytes).with_context(|| format!("{name} not utf-8"))
        };
        let backend = read_str("meta.backend")?;
        let model = read_str("meta.model")?;
        let counters = tf.require("meta.metrics")?.as_u32()?;
        ensure!(
            counters.len() == 22,
            "snapshot metrics: expected 22 words, got {}",
            counters.len()
        );
        let word = |i: usize| u64_from_words(counters[2 * i], counters[2 * i + 1]);
        let metrics = SessionMetrics {
            steps: word(0) as usize,
            batched_steps: word(1) as usize,
            batch_lanes: word(2) as usize,
            snapshots_taken: word(3) as usize,
            audio_s: f64::from_bits(word(4)),
            compute_s: f64::from_bits(word(5)),
            am_s: f64::from_bits(word(6)),
            search_s: f64::from_bits(word(7)),
            degraded_steps: word(8) as usize,
            degrade_transitions: word(9) as usize,
            degrade_level: word(10) as usize,
        };
        let buffered = tf.require("audio.buffered")?.as_f32()?.to_vec();
        let decoder = DecoderSnapshot::read_tensors(&tf)?;
        let mut am = TensorFile::new();
        for t in &tf.tensors {
            if let Some(name) = t.name.strip_prefix("am.") {
                am.push(Tensor {
                    name: name.to_string(),
                    dims: t.dims.clone(),
                    data: t.data.clone(),
                });
            }
        }
        Ok(SessionSnapshot { backend, model, buffered, metrics, am, decoder })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DecoderConfig;
    use crate::decoder::BeamDecoder;

    #[test]
    fn crc32_known_vectors() {
        // The standard IEEE check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    fn sample_snapshot() -> SessionSnapshot {
        let lex = crate::synth::spec::lexicon();
        let lm = crate::lm::NgramLm::estimate(
            &crate::synth::spec::sample_corpus(20, 3),
            0.4,
        )
        .unwrap();
        let dec = BeamDecoder::new(&lex, &lm, DecoderConfig::default()).unwrap();
        let state = dec.start();
        let mut am = TensorFile::new();
        am.push(Tensor::f32("conv0", vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        SessionSnapshot {
            backend: "native-f32".into(),
            model: "tiny-tds".into(),
            buffered: vec![0.25, -0.5, 0.75],
            metrics: SessionMetrics {
                steps: 7,
                audio_s: 0.56,
                compute_s: 0.01,
                am_s: 0.006,
                search_s: 0.004,
                batched_steps: 5,
                batch_lanes: 9,
                snapshots_taken: 3,
                degraded_steps: 2,
                degrade_transitions: 4,
                degrade_level: 1,
            },
            am,
            decoder: crate::decoder::DecoderSnapshot::capture(&state),
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let snap = sample_snapshot();
        let bytes = snap.encode();
        let back = SessionSnapshot::decode(&bytes).unwrap();
        assert_eq!(back.backend, "native-f32");
        assert_eq!(back.model, "tiny-tds");
        assert_eq!(back.buffered, snap.buffered);
        assert_eq!(back.metrics.steps, 7);
        assert_eq!(back.metrics.batched_steps, 5);
        assert_eq!(back.metrics.batch_lanes, 9);
        assert_eq!(back.metrics.snapshots_taken, 3);
        assert_eq!(back.metrics.degraded_steps, 2);
        assert_eq!(back.metrics.degrade_transitions, 4);
        assert_eq!(back.metrics.degrade_level, 1);
        assert_eq!(back.metrics.audio_s, 0.56);
        assert_eq!(back.metrics.compute_s, 0.01);
        assert_eq!(back.am.get("conv0").unwrap(), snap.am.get("conv0").unwrap());
        assert_eq!(back.decoder, snap.decoder);
        // Deterministic: equal snapshots encode to equal bytes.
        assert_eq!(bytes, back.encode());
    }

    #[test]
    fn decode_rejects_corruption() {
        let snap = sample_snapshot();
        let good = snap.encode();
        // Truncation.
        assert!(SessionSnapshot::decode(&good[..10]).is_err());
        assert!(SessionSnapshot::decode(&good[..good.len() - 1]).is_err());
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(SessionSnapshot::decode(&bad).is_err());
        // Unknown version.
        let mut bad = good.clone();
        bad[8] = 99;
        let err = format!("{:#}", SessionSnapshot::decode(&bad).unwrap_err());
        assert!(err.contains("version"), "{err}");
        // Payload bit flip → checksum mismatch.
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 1] ^= 0x01;
        let err = format!("{:#}", SessionSnapshot::decode(&bad).unwrap_err());
        assert!(err.contains("checksum"), "{err}");
        // Trailing garbage.
        let mut bad = good.clone();
        bad.push(0);
        assert!(SessionSnapshot::decode(&bad).is_err());
    }
}
