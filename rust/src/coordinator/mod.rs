//! Streaming coordinator: the acoustic-backend contract ([`backend`]),
//! validated engine construction ([`builder`]), the engine itself (the
//! per-session decode pipeline), the relocatable session-state object
//! ([`snapshot`] — the serialized form live migration, recovery
//! checkpoints and client resume all ship), the sharded worker pool and
//! session router ([`shard`] — N device workers over one shared model,
//! with deterministic assignment, live-session rebalancing and
//! dead-shard recovery), the serving front-end (JSON-lines TCP,
//! protocol v2, bounded queue — the §4.1 host-process shape generalized
//! to a worker pool) and serving metrics. Overload resilience —
//! admission control, load shedding, retry/backoff routing, graceful
//! degradation and worker liveness supervision — is policy-driven
//! (`config::OverloadPolicy`, default off) and lives in [`shard`].

pub mod backend;
pub mod builder;
pub mod engine;
pub mod metrics;
pub mod server;
pub mod shard;
pub mod snapshot;

pub use backend::{
    AmBackend, AmLaneState, AmLanes, NativeBackend, QuantizedBackend, StepScratch, XlaBackend,
};
pub use builder::{BuildError, EngineBuilder};
pub use engine::{Batcher, Engine, FaultHooks, NbestResult, Session, SessionMetrics, WorkerSeed};
pub use metrics::{LatencyStats, ServeMetrics, ShardMetrics, ShardSnapshot};
pub use server::Server;
pub use shard::{Finished, NbestFinished, NbestHyp, Resumed, ShardPool};
pub use snapshot::{SessionSnapshot, SNAPSHOT_VERSION};
