//! Streaming coordinator: the engine (per-session decode pipeline), the
//! serving front-end (JSON-lines TCP, bounded queue, single device
//! thread — the §4.1 host-process shape) and serving metrics.

pub mod engine;
pub mod metrics;
pub mod server;

pub use engine::{Backend, Batcher, Engine, Session, SessionMetrics};
pub use metrics::{LatencyStats, ServeMetrics};
pub use server::Server;
