//! Streaming coordinator: the acoustic-backend contract ([`backend`]),
//! validated engine construction ([`builder`]), the engine itself (the
//! per-session decode pipeline), the serving front-end (JSON-lines TCP,
//! protocol v2, bounded queue, single device thread — the §4.1
//! host-process shape) and serving metrics.

pub mod backend;
pub mod builder;
pub mod engine;
pub mod metrics;
pub mod server;

pub use backend::{
    AmBackend, AmLaneState, AmLanes, NativeBackend, QuantizedBackend, StepScratch, XlaBackend,
};
pub use builder::{BuildError, EngineBuilder};
pub use engine::{Batcher, Engine, Session, SessionMetrics};
pub use metrics::{LatencyStats, ServeMetrics};
pub use server::Server;
