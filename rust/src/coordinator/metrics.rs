//! Serving metrics: latency percentiles, throughput, queue depth — what
//! a deployment of the paper's "main process + ASRPU" loop would watch.

use std::time::Duration;

/// Online latency recorder (stores all samples; serving runs here are
/// bounded, so simplicity beats a sketch).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_ms.push(d.as_secs_f64() * 1e3);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.samples_ms.iter().cloned().fold(0.0, f64::max)
    }
}

/// Aggregate serving counters.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub sessions_opened: u64,
    pub sessions_finished: u64,
    pub steps_executed: u64,
    pub audio_seconds: f64,
    pub compute_seconds: f64,
    pub rejected_backpressure: u64,
    /// Queue-wait + execution latency per feed request.
    pub feed_latency: LatencyStats,
    /// Fused device batches executed by the lane-batched core.
    pub batches_executed: u64,
    /// Σ lanes over those batches (occupancy numerator).
    pub batch_lanes: u64,
    /// Wall-clock latency of each fused batch (all its steps).
    pub batch_latency: LatencyStats,
}

impl ServeMetrics {
    /// Aggregate real-time factor across all sessions.
    pub fn rtf(&self) -> f64 {
        if self.compute_seconds == 0.0 {
            f64::INFINITY
        } else {
            self.audio_seconds / self.compute_seconds
        }
    }

    /// Mean sessions fused per device batch (1.0 = batching never found
    /// lane-mates; 0.0 = no batches ran).
    pub fn avg_batch_occupancy(&self) -> f64 {
        if self.batches_executed == 0 {
            0.0
        } else {
            self.batch_lanes as f64 / self.batches_executed as f64
        }
    }

    /// Record one fused batch execution.
    pub fn record_batch(&mut self, lanes: usize, latency: Duration) {
        self.batches_executed += 1;
        self.batch_lanes += lanes as u64;
        self.batch_latency.record(latency);
    }

    pub fn summary(&self) -> String {
        format!(
            "sessions {}/{} steps {} audio {:.1}s rtf {:.1}x \
             feed p50 {:.2}ms p99 {:.2}ms max {:.2}ms rejected {} \
             batches {} occ {:.2} batch p99 {:.2}ms",
            self.sessions_finished,
            self.sessions_opened,
            self.steps_executed,
            self.audio_seconds,
            self.rtf(),
            self.feed_latency.percentile(50.0),
            self.feed_latency.percentile(99.0),
            self.feed_latency.max(),
            self.rejected_backpressure,
            self.batches_executed,
            self.avg_batch_occupancy(),
            self.batch_latency.percentile(99.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut l = LatencyStats::default();
        for i in 1..=100 {
            l.record(Duration::from_millis(i));
        }
        assert!((l.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((l.percentile(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(l.max(), 100.0);
        assert!((l.mean() - 50.5).abs() < 0.01);
    }

    #[test]
    fn empty_stats_are_zero() {
        let l = LatencyStats::default();
        assert_eq!(l.percentile(99.0), 0.0);
        assert_eq!(l.mean(), 0.0);
        let m = ServeMetrics::default();
        assert!(m.rtf().is_infinite());
        assert_eq!(m.avg_batch_occupancy(), 0.0);
    }

    #[test]
    fn batch_occupancy_averages() {
        let mut m = ServeMetrics::default();
        m.record_batch(4, Duration::from_millis(2));
        m.record_batch(2, Duration::from_millis(4));
        assert_eq!(m.batches_executed, 2);
        assert!((m.avg_batch_occupancy() - 3.0).abs() < 1e-9);
        assert_eq!(m.batch_latency.count(), 2);
        let s = m.summary();
        assert!(s.contains("batches 2 occ 3.00"), "{s}");
    }
}
