//! Serving metrics: latency percentiles, throughput, queue depth — what
//! a deployment of the paper's "main process + ASRPU" loop would watch.

use std::time::Duration;

/// Online latency recorder (stores all samples; serving runs here are
/// bounded, so simplicity beats a sketch).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_ms.push(d.as_secs_f64() * 1e3);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.samples_ms.iter().cloned().fold(0.0, f64::max)
    }
}

/// Aggregate serving counters.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    pub sessions_opened: u64,
    pub sessions_finished: u64,
    pub steps_executed: u64,
    pub audio_seconds: f64,
    pub compute_seconds: f64,
    pub rejected_backpressure: u64,
    /// Queue-wait + execution latency per feed request.
    pub feed_latency: LatencyStats,
}

impl ServeMetrics {
    /// Aggregate real-time factor across all sessions.
    pub fn rtf(&self) -> f64 {
        if self.compute_seconds == 0.0 {
            f64::INFINITY
        } else {
            self.audio_seconds / self.compute_seconds
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "sessions {}/{} steps {} audio {:.1}s rtf {:.1}x \
             feed p50 {:.2}ms p99 {:.2}ms max {:.2}ms rejected {}",
            self.sessions_finished,
            self.sessions_opened,
            self.steps_executed,
            self.audio_seconds,
            self.rtf(),
            self.feed_latency.percentile(50.0),
            self.feed_latency.percentile(99.0),
            self.feed_latency.max(),
            self.rejected_backpressure,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut l = LatencyStats::default();
        for i in 1..=100 {
            l.record(Duration::from_millis(i));
        }
        assert!((l.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((l.percentile(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(l.max(), 100.0);
        assert!((l.mean() - 50.5).abs() < 0.01);
    }

    #[test]
    fn empty_stats_are_zero() {
        let l = LatencyStats::default();
        assert_eq!(l.percentile(99.0), 0.0);
        assert_eq!(l.mean(), 0.0);
        let m = ServeMetrics::default();
        assert!(m.rtf().is_infinite());
    }
}
