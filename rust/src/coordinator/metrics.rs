//! Serving metrics: latency percentiles, throughput, queue depth — what
//! a deployment of the paper's "main process + ASRPU" loop would watch.

use std::time::Duration;

/// Retained latency samples per recorder. Bounds both long-run memory
/// and the per-snapshot clone cost of sharded stats aggregation;
/// percentiles describe the most recent window once the cap is hit.
const MAX_SAMPLES: usize = 4096;

/// Online latency recorder over a bounded sample window (the oldest
/// samples are overwritten once [`MAX_SAMPLES`] are retained, so a
/// long-lived server's stats stay O(1) in memory and snapshot cost).
#[derive(Debug, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
    /// Overwrite cursor once the window is full.
    cursor: usize,
}

impl Clone for LatencyStats {
    fn clone(&self) -> Self {
        LatencyStats { samples_ms: self.samples_ms.clone(), cursor: self.cursor }
    }

    /// Capacity-reusing copy: the destination's sample buffer is
    /// overwritten in place, so the workers' per-flush stats-cache
    /// publish allocates nothing once the window capacity is warm.
    fn clone_from(&mut self, source: &Self) {
        self.samples_ms.clear();
        self.samples_ms.extend_from_slice(&source.samples_ms);
        self.cursor = source.cursor;
    }
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.record_ms(d.as_secs_f64() * 1e3);
    }

    fn record_ms(&mut self, v: f64) {
        if self.samples_ms.len() < MAX_SAMPLES {
            self.samples_ms.push(v);
        } else {
            // Cycle over the whole buffer — a recorder grown past the
            // cap by `merge` still evicts every sample, not just the
            // first window.
            let n = self.samples_ms.len();
            self.samples_ms[self.cursor % n] = v;
            self.cursor = (self.cursor + 1) % n;
        }
    }

    /// Samples currently retained (capped at [`MAX_SAMPLES`] for
    /// recorders that only `record`; merged aggregates hold the union).
    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_ms.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn max(&self) -> f64 {
        self.samples_ms.iter().cloned().fold(0.0, f64::max)
    }

    /// Fold another recorder's retained samples into this one (shard
    /// aggregation): the true union, deliberately *not* re-capped —
    /// otherwise the last-merged shard's window would overwrite every
    /// earlier shard's and aggregate percentiles would hide slow
    /// shards. Aggregation recorders are transient (built per stats
    /// snapshot from ≤ `MAX_SAMPLES` per shard), so the union stays
    /// bounded by the worker count.
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples_ms.extend_from_slice(&other.samples_ms);
    }
}

/// Aggregate serving counters.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Sessions this worker currently accounts for having opened:
    /// locally opened plus adopted, minus evicted-away (migration moves
    /// the count with the session so per-shard opened/finished balance).
    pub sessions_opened: u64,
    pub sessions_finished: u64,
    pub steps_executed: u64,
    pub audio_seconds: f64,
    pub compute_seconds: f64,
    /// Requests bounced with `backpressure` at this shard's queue
    /// (counted router-side and folded into stats snapshots).
    pub rejected_backpressure: u64,
    /// Sessions this worker adopted from another shard (rebalancing
    /// migrations — live, mid-utterance sessions included — and
    /// dead-shard recovery restores).
    pub sessions_adopted: u64,
    /// Sessions this worker snapshotted and handed away to a colder
    /// shard (the evict half of a live migration).
    pub sessions_migrated_out: u64,
    /// Recovery checkpoints shipped to the router (cadence:
    /// `ShardConfig::checkpoint_interval`).
    pub checkpoints_published: u64,
    /// Fused batches executed at a reduced-quality degrade rung
    /// (overload: the worker's backlog crossed the policy ladder).
    pub degraded_batches: u64,
    /// Never-started sessions this worker destroyed on the router's
    /// overload-shedding notice.
    pub sessions_shed: u64,
    /// Queue-wait + execution latency per feed request.
    pub feed_latency: LatencyStats,
    /// Fused device batches executed by the lane-batched core.
    pub batches_executed: u64,
    /// Σ lanes over those batches (occupancy numerator).
    pub batch_lanes: u64,
    /// Wall-clock latency of each fused batch (all its steps).
    pub batch_latency: LatencyStats,
}

impl Clone for ServeMetrics {
    fn clone(&self) -> Self {
        ServeMetrics {
            sessions_opened: self.sessions_opened,
            sessions_finished: self.sessions_finished,
            steps_executed: self.steps_executed,
            audio_seconds: self.audio_seconds,
            compute_seconds: self.compute_seconds,
            rejected_backpressure: self.rejected_backpressure,
            sessions_adopted: self.sessions_adopted,
            sessions_migrated_out: self.sessions_migrated_out,
            checkpoints_published: self.checkpoints_published,
            degraded_batches: self.degraded_batches,
            sessions_shed: self.sessions_shed,
            feed_latency: self.feed_latency.clone(),
            batch_lanes: self.batch_lanes,
            batches_executed: self.batches_executed,
            batch_latency: self.batch_latency.clone(),
        }
    }

    /// Capacity-reusing copy (see [`LatencyStats::clone_from`]): the
    /// workers publish their counters into the shared stats cache after
    /// every state-changing job, and this keeps that publish free of
    /// heap allocation in the steady state.
    fn clone_from(&mut self, source: &Self) {
        self.sessions_opened = source.sessions_opened;
        self.sessions_finished = source.sessions_finished;
        self.steps_executed = source.steps_executed;
        self.audio_seconds = source.audio_seconds;
        self.compute_seconds = source.compute_seconds;
        self.rejected_backpressure = source.rejected_backpressure;
        self.sessions_adopted = source.sessions_adopted;
        self.sessions_migrated_out = source.sessions_migrated_out;
        self.checkpoints_published = source.checkpoints_published;
        self.degraded_batches = source.degraded_batches;
        self.sessions_shed = source.sessions_shed;
        self.feed_latency.clone_from(&source.feed_latency);
        self.batch_lanes = source.batch_lanes;
        self.batches_executed = source.batches_executed;
        self.batch_latency.clone_from(&source.batch_latency);
    }
}

impl ServeMetrics {
    /// Aggregate real-time factor across all sessions.
    pub fn rtf(&self) -> f64 {
        if self.compute_seconds == 0.0 {
            f64::INFINITY
        } else {
            self.audio_seconds / self.compute_seconds
        }
    }

    /// Mean sessions fused per device batch (1.0 = batching never found
    /// lane-mates; 0.0 = no batches ran).
    pub fn avg_batch_occupancy(&self) -> f64 {
        if self.batches_executed == 0 {
            0.0
        } else {
            self.batch_lanes as f64 / self.batches_executed as f64
        }
    }

    /// Record one fused batch execution.
    pub fn record_batch(&mut self, lanes: usize, latency: Duration) {
        self.batches_executed += 1;
        self.batch_lanes += lanes as u64;
        self.batch_latency.record(latency);
    }

    /// Fold a per-shard snapshot into an aggregate: counters add,
    /// latency samples concatenate (so aggregate percentiles are over
    /// every shard's requests).
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.sessions_opened += other.sessions_opened;
        self.sessions_finished += other.sessions_finished;
        self.steps_executed += other.steps_executed;
        self.audio_seconds += other.audio_seconds;
        self.compute_seconds += other.compute_seconds;
        self.rejected_backpressure += other.rejected_backpressure;
        self.sessions_adopted += other.sessions_adopted;
        self.sessions_migrated_out += other.sessions_migrated_out;
        self.checkpoints_published += other.checkpoints_published;
        self.degraded_batches += other.degraded_batches;
        self.sessions_shed += other.sessions_shed;
        self.feed_latency.merge(&other.feed_latency);
        self.batches_executed += other.batches_executed;
        self.batch_lanes += other.batch_lanes;
        self.batch_latency.merge(&other.batch_latency);
    }

    pub fn summary(&self) -> String {
        format!(
            "sessions {}/{} steps {} audio {:.1}s rtf {:.1}x \
             feed p50 {:.2}ms p99 {:.2}ms max {:.2}ms rejected {} \
             batches {} occ {:.2} batch p99 {:.2}ms adopted {} migrated {} ckpt {} \
             degraded {} shed {}",
            self.sessions_finished,
            self.sessions_opened,
            self.steps_executed,
            self.audio_seconds,
            self.rtf(),
            self.feed_latency.percentile(50.0),
            self.feed_latency.percentile(99.0),
            self.feed_latency.max(),
            self.rejected_backpressure,
            self.batches_executed,
            self.avg_batch_occupancy(),
            self.batch_latency.percentile(99.0),
            self.sessions_adopted,
            self.sessions_migrated_out,
            self.checkpoints_published,
            self.degraded_batches,
            self.sessions_shed,
        )
    }
}

/// Where a worker shard is in its elastic lifecycle. The router stamps
/// this into each stats snapshot; the per-shard caches themselves only
/// ever describe a live worker's counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardLifecycle {
    /// Serving normally: accepts new sessions and jobs.
    Active,
    /// Draining: still serves its existing sessions (jobs flow) but the
    /// router places no *new* sessions on it while its live sessions
    /// pipeline-migrate off.
    Draining,
    /// Drained and shut down cleanly; the slot is never reused and the
    /// shard no longer counts against the concurrent-worker ceiling.
    Retired,
    /// The worker thread died (panic or channel teardown); its sessions
    /// were re-adopted onto survivors from their checkpoints.
    Dead,
}

impl ShardLifecycle {
    /// The wire string `stats` reports for this state.
    pub fn as_str(self) -> &'static str {
        match self {
            ShardLifecycle::Active => "active",
            ShardLifecycle::Draining => "draining",
            ShardLifecycle::Retired => "retired",
            ShardLifecycle::Dead => "dead",
        }
    }
}

/// One shard's live status. Workers publish a fresh copy into a shared
/// per-shard cache after every state-changing job (and before replying
/// to it), so the router serves `stats` from the caches without ever
/// waiting on a worker's queue.
#[derive(Debug, Clone)]
pub struct ShardSnapshot {
    /// Shard index (0 = the primary device thread).
    pub shard: usize,
    /// Sessions currently open on this shard.
    pub open_sessions: usize,
    /// Jobs queued to (or in flight on) this shard's worker.
    pub queue_depth: usize,
    /// Monotone publish counter — the worker's heartbeat. A live worker
    /// under traffic keeps advancing it; a dead or wedged one does not.
    pub heartbeats: u64,
    /// The degrade rung the worker last selected (0 = full quality).
    pub degrade_level: usize,
    /// The shard's elastic lifecycle state (stamped by the router when
    /// it assembles the stats payload; workers always publish `Active`).
    pub lifecycle: ShardLifecycle,
    /// The shard's serving counters.
    pub serve: ServeMetrics,
}

impl ShardSnapshot {
    /// The initial cache value for a freshly spawned shard.
    pub fn empty(shard: usize) -> Self {
        ShardSnapshot {
            shard,
            open_sessions: 0,
            queue_depth: 0,
            heartbeats: 0,
            degrade_level: 0,
            lifecycle: ShardLifecycle::Active,
            serve: ServeMetrics::default(),
        }
    }
}

/// Aggregated view over every worker shard — the payload behind the
/// serving protocol's `stats` op in sharded deployments.
#[derive(Debug, Clone, Default)]
pub struct ShardMetrics {
    /// Per-shard snapshots, in shard order.
    pub shards: Vec<ShardSnapshot>,
}

impl ShardMetrics {
    /// The aggregate counters across all shards.
    pub fn total(&self) -> ServeMetrics {
        let mut t = ServeMetrics::default();
        for s in &self.shards {
            t.merge(&s.serve);
        }
        t
    }

    /// Open-session imbalance (hottest − coldest shard) — what the
    /// router's rebalance threshold is compared against.
    pub fn imbalance(&self) -> usize {
        let max = self.shards.iter().map(|s| s.open_sessions).max().unwrap_or(0);
        let min = self.shards.iter().map(|s| s.open_sessions).min().unwrap_or(0);
        max - min
    }

    /// One-line aggregate + per-shard occupancy/queue summary.
    pub fn summary(&self) -> String {
        let mut out = format!("{} shard(s) | {}", self.shards.len(), self.total().summary());
        for s in &self.shards {
            out.push_str(&format!(
                " | shard{} sessions {} queue {} rtf {:.1}x",
                s.shard,
                s.open_sessions,
                s.queue_depth,
                s.serve.rtf()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut l = LatencyStats::default();
        for i in 1..=100 {
            l.record(Duration::from_millis(i));
        }
        assert!((l.percentile(50.0) - 50.0).abs() <= 1.0);
        assert!((l.percentile(99.0) - 99.0).abs() <= 1.0);
        assert_eq!(l.max(), 100.0);
        assert!((l.mean() - 50.5).abs() < 0.01);
    }

    #[test]
    fn empty_stats_are_zero() {
        let l = LatencyStats::default();
        assert_eq!(l.percentile(99.0), 0.0);
        assert_eq!(l.mean(), 0.0);
        let m = ServeMetrics::default();
        assert!(m.rtf().is_infinite());
        assert_eq!(m.avg_batch_occupancy(), 0.0);
    }

    #[test]
    fn merge_adds_counters_and_unions_latency() {
        let mut a = ServeMetrics {
            sessions_opened: 3,
            steps_executed: 10,
            audio_seconds: 1.0,
            compute_seconds: 0.5,
            ..ServeMetrics::default()
        };
        a.feed_latency.record(Duration::from_millis(2));
        let mut b = ServeMetrics {
            sessions_opened: 1,
            sessions_adopted: 1,
            audio_seconds: 1.0,
            compute_seconds: 0.5,
            ..ServeMetrics::default()
        };
        b.feed_latency.record(Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.sessions_opened, 4);
        assert_eq!(a.sessions_adopted, 1);
        assert_eq!(a.feed_latency.count(), 2);
        assert!((a.rtf() - 2.0).abs() < 1e-9);
        assert!(a.summary().contains("adopted 1"), "{}", a.summary());
    }

    #[test]
    fn shard_metrics_aggregate_and_imbalance() {
        let snap = |shard, open, steps| ShardSnapshot {
            shard,
            open_sessions: open,
            queue_depth: shard,
            heartbeats: 0,
            degrade_level: 0,
            lifecycle: ShardLifecycle::Active,
            serve: ServeMetrics { steps_executed: steps, ..ServeMetrics::default() },
        };
        let m = ShardMetrics { shards: vec![snap(0, 5, 100), snap(1, 2, 40)] };
        assert_eq!(m.imbalance(), 3);
        assert_eq!(m.total().steps_executed, 140);
        let s = m.summary();
        assert!(s.starts_with("2 shard(s)"), "{s}");
        assert!(s.contains("shard1 sessions 2 queue 1"), "{s}");
        assert_eq!(ShardMetrics::default().imbalance(), 0);
    }

    #[test]
    fn batch_occupancy_averages() {
        let mut m = ServeMetrics::default();
        m.record_batch(4, Duration::from_millis(2));
        m.record_batch(2, Duration::from_millis(4));
        assert_eq!(m.batches_executed, 2);
        assert!((m.avg_batch_occupancy() - 3.0).abs() < 1e-9);
        assert_eq!(m.batch_latency.count(), 2);
        let s = m.summary();
        assert!(s.contains("batches 2 occ 3.00"), "{s}");
    }
}
