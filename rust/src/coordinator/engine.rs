//! The streaming ASR engine — the functional counterpart of the paper's
//! "main process + accelerator" loop (§4.1): audio arrives in chunks,
//! every 80 ms of accumulated signal triggers a decoding step (feature
//! extraction → acoustic scoring → hypothesis expansion), hypotheses are
//! carried across steps, and `finish` extracts the transcript.
//!
//! The acoustic model runs through either backend:
//!  * **Xla** — the AOT artifacts via PJRT (`runtime::XlaAm`); python is
//!    never on this path;
//!  * **Native** — the in-crate mirror (`am::TdsModel`), used when
//!    artifacts are absent and as the cross-check oracle in tests.
//!
//! Frame alignment: decoding step *k* emits feature frames `k·8 … k·8+7`
//! on the absolute 10 ms grid, which requires 15 ms of lookahead
//! (`samples_per_step = 1520` for a 1280-sample step) — so streaming
//! features equal offline features exactly, matching training.

use anyhow::Result;
use std::time::Instant;

use crate::am::{TdsModel, TdsState};
use crate::config::{DecoderConfig, ModelConfig};
use crate::decoder::{BeamDecoder, DecodeState, Transcript};
use crate::dsp::Mfcc;
use crate::lexicon::Lexicon;
use crate::lm::NgramLm;
use crate::runtime::{Runtime, XlaAm};
use crate::synth::spec;

/// Acoustic-model backend.
pub enum Backend {
    Native { model: TdsModel, mfcc: Mfcc },
    Xla { am: XlaAm },
}

enum AmState {
    Native(TdsState),
    Xla(crate::runtime::xla_am::XlaState),
}

/// The engine: one per process; sessions are cheap.
pub struct Engine {
    pub model_cfg: ModelConfig,
    backend: Backend,
    pub lexicon: Lexicon,
    pub lm: NgramLm,
    pub dec_cfg: DecoderConfig,
}

/// Per-utterance decoding session.
pub struct Session {
    /// Buffered samples not yet consumed by a step.
    buf: Vec<f32>,
    am_state: AmState,
    pub decode: DecodeState,
    /// Collected log-probs (for greedy-baseline comparisons), if enabled.
    pub logits: Option<Vec<f32>>,
    pub metrics: SessionMetrics,
}

/// Timing and search statistics for one session.
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionMetrics {
    pub steps: usize,
    pub audio_s: f64,
    pub compute_s: f64,
    /// Wall-clock of AM (mfcc+model) vs decoder within compute_s.
    pub am_s: f64,
    pub search_s: f64,
}

impl SessionMetrics {
    /// Real-time factor (>1 = faster than real time).
    pub fn rtf(&self) -> f64 {
        if self.compute_s == 0.0 {
            f64::INFINITY
        } else {
            self.audio_s / self.compute_s
        }
    }
}

impl Engine {
    /// Build with the synthetic-protocol lexicon and an LM estimated
    /// from the word chain (2000 sentences, fixed seed — deterministic).
    pub fn with_backend(backend: Backend, dec_cfg: DecoderConfig) -> Result<Self> {
        let model_cfg = match &backend {
            Backend::Native { model, .. } => model.cfg.clone(),
            Backend::Xla { am } => am.meta.model.clone(),
        };
        let lexicon = spec::lexicon();
        let corpus = spec::sample_corpus(2000, 7777);
        let lm = NgramLm::estimate(&corpus, 0.4)?;
        anyhow::ensure!(
            model_cfg.tokens == lexicon.tokens.len(),
            "model emits {} tokens but lexicon has {}",
            model_cfg.tokens,
            lexicon.tokens.len()
        );
        Ok(Engine { model_cfg, backend, lexicon, lm, dec_cfg })
    }

    /// Native backend from an in-memory model.
    pub fn native(model: TdsModel, dec_cfg: DecoderConfig) -> Result<Self> {
        let mfcc = Mfcc::for_model(&model.cfg);
        Self::with_backend(Backend::Native { model, mfcc }, dec_cfg)
    }

    /// XLA backend from the artifacts directory.
    pub fn from_artifacts(
        runtime: &Runtime,
        dir: &std::path::Path,
        dec_cfg: DecoderConfig,
    ) -> Result<Self> {
        let am = XlaAm::load(runtime, dir)?;
        Self::with_backend(Backend::Xla { am }, dec_cfg)
    }

    fn decoder(&self) -> Result<BeamDecoder<'_>> {
        BeamDecoder::new(&self.lexicon, &self.lm, self.dec_cfg.clone())
    }

    /// Open a session. `collect_logits` keeps per-frame log-probs for
    /// baseline comparisons (costs memory; off for serving).
    pub fn open(&self, collect_logits: bool) -> Result<Session> {
        let am_state = match &self.backend {
            Backend::Native { model, .. } => AmState::Native(model.state()),
            Backend::Xla { am } => AmState::Xla(am.state()?),
        };
        Ok(Session {
            buf: Vec::with_capacity(2 * self.model_cfg.samples_per_step()),
            am_state,
            decode: self.decoder()?.start(),
            logits: if collect_logits { Some(Vec::new()) } else { None },
            metrics: SessionMetrics::default(),
        })
    }

    /// Feed audio; runs as many decoding steps as the buffer allows.
    /// Returns the number of steps executed.
    pub fn feed(&self, s: &mut Session, samples: &[f32]) -> Result<usize> {
        s.buf.extend_from_slice(samples);
        let need = self.model_cfg.samples_per_step();
        let step_len = self.model_cfg.step_len;
        let mut ran = 0;
        while s.buf.len() >= need {
            self.run_step(s)?;
            s.buf.drain(..step_len);
            ran += 1;
        }
        Ok(ran)
    }

    fn run_step(&self, s: &mut Session) -> Result<()> {
        let t0 = Instant::now();
        let need = self.model_cfg.samples_per_step();
        let window = &s.buf[..need];
        let logits = match (&self.backend, &mut s.am_state) {
            (Backend::Native { model, mfcc }, AmState::Native(state)) => {
                let feats = mfcc.extract(window);
                debug_assert_eq!(
                    feats.len(),
                    self.model_cfg.frames_per_step() * self.model_cfg.n_mels
                );
                model.step(state, &feats)
            }
            (Backend::Xla { am }, AmState::Xla(state)) => {
                let feats = am.mfcc(window)?;
                am.step(state, &feats)?
            }
            _ => unreachable!("backend/state mismatch"),
        };
        let t_am = Instant::now();
        if let Some(all) = &mut s.logits {
            all.extend_from_slice(&logits);
        }
        let decoder = self.decoder()?;
        for frame in logits.chunks(self.model_cfg.tokens) {
            decoder.step(&mut s.decode, frame);
        }
        let t_end = Instant::now();
        s.metrics.steps += 1;
        s.metrics.audio_s += self.model_cfg.step_seconds();
        s.metrics.am_s += (t_am - t0).as_secs_f64();
        s.metrics.search_s += (t_end - t_am).as_secs_f64();
        s.metrics.compute_s += (t_end - t0).as_secs_f64();
        Ok(())
    }

    /// Flush buffered audio (zero-padding to whole steps) and extract the
    /// final transcript.
    pub fn finish(&self, s: &mut Session) -> Result<Transcript> {
        let step_len = self.model_cfg.step_len;
        let lookahead = self.model_cfg.samples_per_step() - step_len;
        if !s.buf.is_empty() {
            // Pad so every real sample is covered by a step (+ lookahead).
            let target = s.buf.len().div_ceil(step_len) * step_len + lookahead;
            s.buf.resize(target, 0.0);
            while s.buf.len() >= self.model_cfg.samples_per_step() {
                self.run_step(s)?;
                s.buf.drain(..step_len);
            }
        }
        Ok(self.decoder()?.finish(&s.decode))
    }

    /// Current best partial transcript (streaming UX, §2.4).
    pub fn partial(&self, s: &Session) -> Result<Transcript> {
        Ok(self.decoder()?.finish(&s.decode))
    }

    /// Convenience: decode a whole utterance.
    pub fn decode_utterance(&self, samples: &[f32]) -> Result<(Transcript, SessionMetrics)> {
        let mut s = self.open(false)?;
        self.feed(&mut s, samples)?;
        let t = self.finish(&mut s)?;
        Ok((t, s.metrics))
    }

    /// Greedy baseline over collected logits (requires `collect_logits`).
    pub fn greedy_of(&self, s: &Session) -> Result<Transcript> {
        let logits = s
            .logits
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("session did not collect logits"))?;
        Ok(self.decoder()?.greedy(logits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::Synthesizer;
    use crate::util::rng::Rng;

    fn native_engine() -> Engine {
        // Random weights: decode quality is meaningless, but shapes,
        // streaming and search must all hold together.
        let model = TdsModel::random(ModelConfig::tiny_tds(), 11);
        Engine::native(model, DecoderConfig::default()).unwrap()
    }

    #[test]
    fn feed_runs_steps_at_80ms_granularity() {
        let e = native_engine();
        let mut s = e.open(false).unwrap();
        // 1279 samples: no step (needs 1280 + 240 lookahead).
        assert_eq!(e.feed(&mut s, &vec![0.0; 1279]).unwrap(), 0);
        // +241 = 1520 total: one step.
        assert_eq!(e.feed(&mut s, &vec![0.0; 241]).unwrap(), 1);
        // Ten more steps' worth at once.
        assert_eq!(e.feed(&mut s, &vec![0.0; 12800]).unwrap(), 10);
        assert_eq!(s.metrics.steps, 11);
        assert!((s.metrics.audio_s - 11.0 * 0.08).abs() < 1e-9);
    }

    #[test]
    fn chunk_size_does_not_change_result() {
        // Feeding sample-by-sample chunks vs all at once must give the
        // same transcript (streaming correctness).
        let e = native_engine();
        let mut rng = Rng::new(3);
        let u = Synthesizer::default().render(&[1, 2], &mut rng);
        let (t_all, _) = e.decode_utterance(&u.samples).unwrap();
        let mut s = e.open(false).unwrap();
        for chunk in u.samples.chunks(333) {
            e.feed(&mut s, chunk).unwrap();
        }
        let t_chunked = e.finish(&mut s).unwrap();
        assert_eq!(t_all.text, t_chunked.text);
        assert!((t_all.score - t_chunked.score).abs() < 1e-3);
    }

    #[test]
    fn partial_transcripts_available_mid_stream() {
        let e = native_engine();
        let mut rng = Rng::new(5);
        let u = Synthesizer::default().render(&[0, 7, 3], &mut rng);
        let mut s = e.open(false).unwrap();
        e.feed(&mut s, &u.samples[..u.samples.len() / 2]).unwrap();
        // Must not panic and must be a valid (possibly empty) transcript.
        let p = e.partial(&s).unwrap();
        assert!(p.words.len() <= 10);
    }

    #[test]
    fn metrics_accumulate() {
        let e = native_engine();
        let mut rng = Rng::new(7);
        let u = Synthesizer::default().render(&[4], &mut rng);
        let (_, m) = e.decode_utterance(&u.samples).unwrap();
        assert!(m.steps >= 5, "utterance shorter than expected: {}", m.steps);
        assert!(m.compute_s > 0.0);
        assert!((m.am_s + m.search_s - m.compute_s).abs() < 1e-6);
    }

    #[test]
    fn greedy_requires_collected_logits() {
        let e = native_engine();
        let s = e.open(false).unwrap();
        assert!(e.greedy_of(&s).is_err());
        let mut s = e.open(true).unwrap();
        e.feed(&mut s, &vec![0.0; 1520]).unwrap();
        assert!(e.greedy_of(&s).is_ok());
    }
}
