//! The streaming ASR engine — the functional counterpart of the paper's
//! "main process + accelerator" loop (§4.1): audio arrives in chunks,
//! every 80 ms of accumulated signal triggers a decoding step (feature
//! extraction → acoustic scoring → hypothesis expansion), hypotheses are
//! carried across steps, and `finish` extracts the transcript.
//!
//! The acoustic model runs behind the object-safe
//! [`AmBackend`](super::backend::AmBackend) trait — the engine never
//! names a concrete backend. `native-f32`, `native-int8` and `xla`
//! implementations ship in [`super::backend`]; anything else plugs in
//! through [`EngineBuilder::backend`]. Construction goes through
//! [`Engine::builder`] exclusively; the builder validates model, search,
//! and batching configuration together and reports typed errors.
//!
//! The decoding-step *program* the engine executes — features, one stage
//! per AM layer, hypothesis expansion per acoustic vector — is published
//! as [`Engine::pipeline`], the same [`PipelineDesc`] the accelerator
//! simulator derives its kernel program from (`accel::build_step_kernels`),
//! so functional serving and cycle-approximate simulation share one
//! source of truth.
//!
//! Steady-state allocation discipline: the engine owns one
//! [`EngineScratch`] arena (the backend's [`StepScratch`] — AM activation
//! buffers, MFCC scratch, feature staging — plus decoder candidate
//! buffers, the logits/block staging buffers and the ready-lane index
//! list). After the first fused step at a given batch shape warms the
//! arena, [`Engine::step_batch`] reuses every arena buffer in place. The
//! AM half of that claim is proven with a counting allocator
//! (`tests/alloc_free.rs`); the engine and decoder layers are asserted
//! via pointer/capacity fingerprint tests (`step_batch_scratch_is_reused_
//! across_calls` below, and the decoder's two-pass stability test). Two
//! containers may still legitimately allocate in steady state: each
//! session's backtrack arena (one entry per committed word,
//! amortized-O(log) reallocations per utterance) and the decoder
//! candidate buffer while the live hypothesis set is still growing
//! toward its high-water mark. The PJRT backend additionally allocates
//! inside the runtime per step (see KNOWN_FAILURES.md).
//!
//! Frame alignment: decoding step *k* emits feature frames `k·8 … k·8+7`
//! on the absolute 10 ms grid, which requires 15 ms of lookahead
//! (`samples_per_step = 1520` for a 1280-sample step) — so streaming
//! features equal offline features exactly, matching training.

use anyhow::Result;
use std::borrow::Cow;
use std::cell::{Cell, RefCell};
use std::time::{Duration, Instant};

use crate::config::{
    BatchConfig, DecoderConfig, ModelConfig, OverloadPolicy, PipelineDesc, ShardConfig, StageDesc,
};
use crate::decoder::{
    BeamDecoder, DecodeScratch, DecodeState, DecoderSnapshot, NbestEntry, RescoreStats, Rescored,
    Rescorer, Transcript,
};
use crate::lexicon::Lexicon;
use crate::lm::NgramLm;
use crate::util::tensor_io::TensorFile;

use super::backend::{AmBackend, AmLaneState, AmLanes, StepScratch};
use super::builder::EngineBuilder;
use super::snapshot::SessionSnapshot;

/// Reusable per-engine buffers for the fused step loop. See the module
/// docs for the ownership story.
#[derive(Default)]
struct EngineScratch {
    /// The backend-facing half: AM arena + MFCC scratch + feature staging.
    step: StepScratch,
    dec: DecodeScratch,
    logits: Vec<f32>,
    block: Vec<f32>,
    ready: Vec<usize>,
}

/// The engine: one per process; sessions are cheap. Built exclusively
/// through [`Engine::builder`].
pub struct Engine {
    pub model_cfg: ModelConfig,
    backend: Box<dyn AmBackend>,
    pub lexicon: Lexicon,
    pub lm: NgramLm,
    pub dec_cfg: DecoderConfig,
    /// Dynamic-batching policy the serving loop derives its [`Batcher`]
    /// from (validated by the builder).
    pub batch_cfg: BatchConfig,
    /// Multi-worker sharding policy the serving layer spawns its
    /// [`ShardPool`](super::ShardPool) from (validated by the builder:
    /// `workers > 1` requires a backend that supports
    /// [`clone_worker`](Self::clone_worker)).
    pub shard_cfg: ShardConfig,
    /// Overload policy the serving layer consults for admission control,
    /// shedding, retry/backoff and the graceful-degradation ladder
    /// (validated by the builder; default = everything off).
    pub overload: OverloadPolicy,
    /// Cached lexicon-word → LM-word mapping (O(vocabulary) to build;
    /// decoders borrow it so per-drain construction is allocation-free).
    word_lm_ids: Vec<u32>,
    /// N-best list length served by [`Self::nbest`] (0 = the lattice
    /// subsystem is off and sessions decode exactly as before).
    nbest_n: usize,
    /// Optional second-pass rescorer applied to the N-best list at
    /// utterance finish ([`EngineBuilder::rescore`]).
    rescorer: Option<Rescorer>,
    /// Running statistics over the N-best lists this engine has served —
    /// the measured input the simulator sizes its rescore kernel from
    /// (`HypWorkload::with_rescore_stats`) instead of a fixed constant.
    rescore_stats: Cell<RescoreStats>,
    scratch: RefCell<EngineScratch>,
    /// Test/ops fault-injection hooks (see [`FaultHooks`]).
    faults: FaultHooks,
    /// Steps executed so far (the fault hooks' odometer).
    served_steps: Cell<u64>,
    /// The degrade rung currently in effect (0 = full quality). Set by
    /// the serving worker from its measured backlog before each drain;
    /// [`Self::decoder`] serves the rung's search parameters.
    degrade_level: Cell<usize>,
}

/// Test/ops fault-injection hooks, resolved by [`EngineBuilder::build`]
/// from explicit setters or the `ASRPU_FAULT_AFTER_STEPS`,
/// `ASRPU_FAULT_PANIC_AFTER_STEPS`, `ASRPU_FAULT_REPLY_DELAY_MS` and
/// `ASRPU_FAULT_TEARDOWN_DELAY_MS` environment variables. All default
/// to off.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultHooks {
    /// Once this many decoding steps have executed, every further
    /// scoring attempt fails with an error — the only way the serving
    /// protocol's `internal` error is reachable over a real socket with
    /// the native backends.
    pub after_steps: Option<u64>,
    /// Once this many decoding steps have executed, the next scoring
    /// attempt panics — simulating a worker thread dying spontaneously
    /// mid-serve (the liveness supervisor's test hook).
    pub panic_after_steps: Option<u64>,
    /// Sleep this long before a serving worker answers each flushed
    /// feed — simulating a slow shard for retry/backoff and chaos tests.
    pub reply_delay_ms: Option<u64>,
    /// Sleep this long between a serving worker's panic being caught and
    /// its death report reaching the liveness slot — holding the
    /// teardown window open so tests can land jobs on the dying channel
    /// deterministically.
    pub teardown_delay_ms: Option<u64>,
}

/// Everything a worker thread needs to assemble its own [`Engine`] over
/// the shared model: the backend clone (weights behind `Arc`), copies of
/// the lexicon/LM/search configuration, and the cached word→LM mapping.
///
/// Unlike the engine itself — whose backend trait object carries no
/// `Send` bound, because PJRT handles must stay on their build thread —
/// a seed is `Send`: it is produced by [`Engine::clone_worker`] on the
/// primary device thread and shipped to the worker thread, which turns
/// it into that shard's engine with [`WorkerSeed::into_engine`].
pub struct WorkerSeed {
    backend: Box<dyn AmBackend + Send>,
    lexicon: Lexicon,
    lm: NgramLm,
    dec_cfg: DecoderConfig,
    batch_cfg: BatchConfig,
    shard_cfg: ShardConfig,
    overload: OverloadPolicy,
    word_lm_ids: Vec<u32>,
    nbest_n: usize,
    rescorer: Option<Rescorer>,
    faults: FaultHooks,
}

impl WorkerSeed {
    /// Duplicate this seed without consuming it: the elastic pool's
    /// router keeps one template seed and mints a fresh seed from it for
    /// every runtime `add_worker`, so scale-up never needs a device
    /// thread in the loop. `None` when the backend cannot be duplicated
    /// (the same backends for which [`Engine::clone_worker`] is `None`).
    pub fn clone_seed(&self) -> Option<WorkerSeed> {
        Some(WorkerSeed {
            backend: self.backend.clone_worker()?,
            lexicon: self.lexicon.clone(),
            lm: self.lm.clone(),
            dec_cfg: self.dec_cfg.clone(),
            batch_cfg: self.batch_cfg.clone(),
            shard_cfg: self.shard_cfg.clone(),
            overload: self.overload.clone(),
            word_lm_ids: self.word_lm_ids.clone(),
            nbest_n: self.nbest_n,
            rescorer: self.rescorer.clone(),
            faults: self.faults,
        })
    }

    /// Assemble the worker's engine (fresh scratch arenas; shared
    /// weights). Call this on the worker's own thread.
    pub fn into_engine(self) -> Engine {
        Engine::assemble(
            self.backend,
            self.lexicon,
            self.lm,
            self.dec_cfg,
            self.batch_cfg,
            self.shard_cfg,
            self.overload,
            self.word_lm_ids,
            self.nbest_n,
            self.rescorer,
            self.faults,
        )
    }
}

/// Per-utterance decoding session.
pub struct Session {
    /// Buffered samples not yet consumed by a step.
    buf: Vec<f32>,
    /// Backend-owned acoustic state (opaque to the engine).
    am_state: AmLaneState,
    pub decode: DecodeState,
    /// Collected log-probs (for greedy-baseline comparisons), if enabled.
    pub logits: Option<Vec<f32>>,
    pub metrics: SessionMetrics,
}

impl Session {
    /// Samples staged but not yet consumed by a decoding step (the
    /// serving protocol's `resume` op reports this so a reconnecting
    /// client knows exactly how much audio the server holds).
    pub fn buffered_samples(&self) -> usize {
        self.buf.len()
    }

}

/// What [`Engine::nbest`] returns: the 1-best transcript (bit-identical
/// to [`Engine::finish`]), the exact N-best list from the session's
/// lattice, and — when the engine carries a rescorer — the second-pass
/// re-ranking of that list.
pub struct NbestResult {
    /// The 1-best transcript, exactly as `finish` would report it.
    pub transcript: Transcript,
    /// Exact N-best paths, best first (first-pass scores).
    pub entries: Vec<NbestEntry>,
    /// Second-pass re-ranking (present iff a rescorer is configured).
    pub rescored: Option<Vec<Rescored>>,
}

/// Timing and search statistics for one session.
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionMetrics {
    pub steps: usize,
    pub audio_s: f64,
    pub compute_s: f64,
    /// Wall-clock of AM (mfcc+model) vs decoder within compute_s.
    pub am_s: f64,
    pub search_s: f64,
    /// Steps that ran through the lane-batched path.
    pub batched_steps: usize,
    /// Σ batch occupancy over those steps (lanes this session shared its
    /// fused steps with, itself included).
    pub batch_lanes: usize,
    /// Snapshots captured of this session so far. Strictly increasing
    /// across the session's whole lifetime — restore copies it and the
    /// next capture increments further — so it orders checkpoints and
    /// migration snapshots globally (step counts cannot: two captures
    /// at the same step differ in buffered audio).
    pub snapshots_taken: usize,
    /// Steps executed while a degrade rung (level > 0) was in effect —
    /// the per-session record that a transcript was produced (partly)
    /// under graceful degradation.
    pub degraded_steps: usize,
    /// Times the rung in effect changed between this session's
    /// consecutive steps (initial engagement from full quality counts).
    pub degrade_transitions: usize,
    /// The rung in effect at this session's most recent step (0 = full
    /// quality). Carried through snapshots so a migrated session counts
    /// its transition onto a differently-loaded shard.
    pub degrade_level: usize,
}

impl SessionMetrics {
    /// Real-time factor (>1 = faster than real time).
    pub fn rtf(&self) -> f64 {
        if self.compute_s == 0.0 {
            f64::INFINITY
        } else {
            self.audio_s / self.compute_s
        }
    }

    /// Mean lanes per fused step this session took part in (1.0 = batched
    /// path but always alone; 0.0 = never batched).
    pub fn avg_batch_occupancy(&self) -> f64 {
        if self.batched_steps == 0 {
            0.0
        } else {
            self.batch_lanes as f64 / self.batched_steps as f64
        }
    }
}

/// Collects sessions with a full decoding step buffered into dynamic
/// batches for [`Engine::step_batch`]. A pending batch closes when
/// `max_batch` lanes are staged or the oldest lane has waited
/// `max_wait_frames` feature frames; the server additionally flushes
/// early when every open session is already staged (no one left to wait
/// for), so a lone stream never pays the wait.
pub struct Batcher {
    cfg: BatchConfig,
    max_wait: Duration,
    pending: Vec<u64>,
    oldest: Option<Instant>,
    /// Degrade-ladder lane cap: when set, the batch closes at
    /// `min(cfg.max_batch, cap)` lanes (tightened batch budget under
    /// overload; `None` restores the configured policy exactly).
    cap: Option<usize>,
}

impl Batcher {
    pub fn new(cfg: BatchConfig, model: &ModelConfig) -> Self {
        let max_wait = cfg.max_wait(model);
        Batcher { cfg, max_wait, pending: Vec::new(), oldest: None, cap: None }
    }

    /// Tighten (or restore) the lane budget — the degrade ladder's batch
    /// half. `None` or a cap ≥ `max_batch` serves the configured policy.
    pub fn set_cap(&mut self, cap: Option<usize>) {
        self.cap = cap;
    }

    /// The lane budget currently in force.
    pub fn effective_max_batch(&self) -> usize {
        match self.cap {
            Some(c) => c.clamp(1, self.cfg.max_batch),
            None => self.cfg.max_batch,
        }
    }

    /// Stage a session id (idempotent). Returns true if the batch is now
    /// full and should flush.
    pub fn push(&mut self, id: u64) -> bool {
        if !self.contains(id) {
            self.pending.push(id);
        }
        if self.oldest.is_none() {
            self.oldest = Some(Instant::now());
        }
        self.is_full()
    }

    pub fn is_full(&self) -> bool {
        self.pending.len() >= self.effective_max_batch()
    }

    pub fn len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Whether `id` is currently staged (the router's migration guard:
    /// a staged session has a feed reply in flight and must not move).
    pub fn contains(&self, id: u64) -> bool {
        self.pending.contains(&id)
    }

    /// Remaining wall-clock budget before the pending batch must flush.
    pub fn wait_budget(&self) -> Duration {
        match self.oldest {
            None => self.max_wait,
            Some(t0) => self.max_wait.saturating_sub(t0.elapsed()),
        }
    }

    /// Drain the pending lane set for execution.
    pub fn take(&mut self) -> Vec<u64> {
        self.oldest = None;
        std::mem::take(&mut self.pending)
    }

    /// Forget a session (e.g. finished before its batch flushed).
    pub fn remove(&mut self, id: u64) {
        self.pending.retain(|&p| p != id);
        if self.pending.is_empty() {
            self.oldest = None;
        }
    }
}

/// [`AmLanes`] view over the ready subset of a session slice — the
/// backend reads audio and writes per-lane acoustic state directly
/// through the sessions, so the engine never materializes per-lane
/// reference vectors.
struct ReadyLanes<'a, 'b> {
    lanes: &'a mut [&'b mut Session],
    ready: &'a [usize],
    need: usize,
}

impl AmLanes for ReadyLanes<'_, '_> {
    fn lane_count(&self) -> usize {
        self.ready.len()
    }

    fn samples(&self, lane: usize) -> &[f32] {
        &self.lanes[self.ready[lane]].buf[..self.need]
    }

    fn state(&mut self, lane: usize) -> &mut AmLaneState {
        &mut self.lanes[self.ready[lane]].am_state
    }
}

impl Engine {
    /// Start building an engine — the only construction path. The
    /// builder supplies the synthetic-protocol lexicon and an LM
    /// estimated from the word chain (fixed seed — deterministic) unless
    /// overridden.
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Assemble from pre-validated parts ([`EngineBuilder::build`] and
    /// [`WorkerSeed::into_engine`] only).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn assemble(
        backend: Box<dyn AmBackend>,
        lexicon: Lexicon,
        lm: NgramLm,
        dec_cfg: DecoderConfig,
        batch_cfg: BatchConfig,
        shard_cfg: ShardConfig,
        overload: OverloadPolicy,
        word_lm_ids: Vec<u32>,
        nbest_n: usize,
        rescorer: Option<Rescorer>,
        faults: FaultHooks,
    ) -> Engine {
        Engine {
            model_cfg: backend.model_cfg().clone(),
            backend,
            lexicon,
            lm,
            dec_cfg,
            batch_cfg,
            shard_cfg,
            overload,
            word_lm_ids,
            nbest_n,
            rescorer,
            rescore_stats: Cell::new(RescoreStats::default()),
            scratch: RefCell::new(EngineScratch::default()),
            faults,
            served_steps: Cell::new(0),
            degrade_level: Cell::new(0),
        }
    }

    /// Duplicate this engine for another worker shard: the backend
    /// shares its immutable model ([`AmBackend::clone_worker`] — an
    /// `Arc` refcount for the native backends), configuration and the
    /// cached word→LM mapping are copied, and the worker gets fresh
    /// scratch arenas. `None` when the backend cannot be duplicated
    /// (PJRT); the builder rejects `workers > 1` for such backends, so
    /// sharded construction paths never observe `None` here.
    pub fn clone_worker(&self) -> Option<WorkerSeed> {
        Some(WorkerSeed {
            backend: self.backend.clone_worker()?,
            lexicon: self.lexicon.clone(),
            lm: self.lm.clone(),
            dec_cfg: self.dec_cfg.clone(),
            batch_cfg: self.batch_cfg.clone(),
            shard_cfg: self.shard_cfg.clone(),
            overload: self.overload.clone(),
            word_lm_ids: self.word_lm_ids.clone(),
            nbest_n: self.nbest_n,
            rescorer: self.rescorer.clone(),
            faults: self.faults,
        })
    }

    /// The acoustic backend being served (name, precision, DMA metadata
    /// — the serving protocol's `config` op reads this).
    pub fn backend(&self) -> &dyn AmBackend {
        self.backend.as_ref()
    }

    /// The decoding-step program this engine executes, as the shared
    /// stage description the simulator also consumes
    /// (`accel::build_step_kernels`): one source of truth for "one
    /// program per decoder part". When a second-pass rescorer is
    /// configured, the finish-time [`StageDesc::Rescore`] stage appears
    /// at the end of the list — the simulator sizes its kernel from the
    /// same description. The backend's per-layer precision map rides
    /// along ([`PipelineDesc::precisions`]), so the simulator charges
    /// each layer's weight DMA at the width actually served.
    pub fn pipeline(&self) -> PipelineDesc {
        let mut p =
            PipelineDesc::for_model_mixed(&self.model_cfg, self.backend.precision_map());
        if self.rescorer.is_some() {
            p.stages.push(StageDesc::Rescore { nbest: self.nbest_n });
        }
        p
    }

    /// The configured N-best list length (0 = lattice subsystem off).
    pub fn nbest_n(&self) -> usize {
        self.nbest_n
    }

    /// The configured second-pass rescorer, if any.
    pub fn rescorer(&self) -> Option<&Rescorer> {
        self.rescorer.as_ref()
    }

    /// Measured statistics over every N-best list this engine has served
    /// (zeroed at construction; workers measure independently). Feed
    /// this to `accel::HypWorkload::with_rescore_stats` so the simulated
    /// rescore cost reflects real utterance lengths.
    pub fn rescore_stats(&self) -> RescoreStats {
        self.rescore_stats.get()
    }

    /// A batcher configured with this engine's batching policy.
    pub fn batcher(&self) -> Batcher {
        Batcher::new(self.batch_cfg.clone(), &self.model_cfg)
    }

    fn decoder(&self) -> Result<BeamDecoder<'_>> {
        // At level 0 `decoder_at` returns the configured DecoderConfig
        // unchanged — post-drain full-quality parity is exact.
        BeamDecoder::with_word_ids(
            &self.lexicon,
            &self.lm,
            self.overload.decoder_at(&self.dec_cfg, self.degrade_level.get()),
            Cow::Borrowed(&self.word_lm_ids),
        )
    }

    /// Step onto (or off) a degrade rung: subsequent decoding steps use
    /// the rung's search parameters. Levels beyond the ladder clamp to
    /// the deepest rung; 0 restores the configured full quality exactly.
    /// The serving worker calls this with
    /// [`OverloadPolicy::level_for_backlog`] before each drain.
    pub fn set_degrade_level(&self, level: usize) {
        self.degrade_level.set(level.min(self.overload.levels.len()));
    }

    /// The degrade rung currently in effect (0 = full quality).
    pub fn degrade_level(&self) -> usize {
        self.degrade_level.get()
    }

    /// The injected reply delay, if the slow-shard fault hook is armed.
    pub fn fault_reply_delay(&self) -> Option<Duration> {
        self.faults.reply_delay_ms.map(Duration::from_millis)
    }

    /// The injected teardown delay, if the slow-teardown fault hook is
    /// armed (holds a dying worker's death report back so tests can hit
    /// the teardown window deterministically).
    pub fn fault_teardown_delay(&self) -> Option<Duration> {
        self.faults.teardown_delay_ms.map(Duration::from_millis)
    }

    /// Open a session. `collect_logits` keeps per-frame log-probs for
    /// baseline comparisons (costs memory; off for serving).
    pub fn open(&self, collect_logits: bool) -> Result<Session> {
        let mut decode = self.decoder()?.start();
        if self.nbest_n > 0 {
            decode.enable_lattice();
        }
        Ok(Session {
            buf: Vec::with_capacity(2 * self.model_cfg.samples_per_step()),
            am_state: self.backend.open_state()?,
            decode,
            logits: if collect_logits { Some(Vec::new()) } else { None },
            metrics: SessionMetrics::default(),
        })
    }

    /// Capture a session as a relocatable [`SessionSnapshot`]: the
    /// backend's acoustic lane state, the full decoder state, the
    /// buffered-but-unconsumed audio and the session counters, stamped
    /// with this engine's backend and model identity. The session keeps
    /// decoding; the snapshot is an independent deep copy.
    ///
    /// `&mut` because device-backed acoustic states may need a
    /// synchronizing download. Fails when the backend does not support
    /// lane snapshots (such sessions are shard-pinned).
    ///
    /// The collected-logits baseline buffer (`collect_logits`) is
    /// deliberately not part of the snapshot: it is a debugging aid,
    /// unbounded in size, and never enabled by the serving path.
    pub fn snapshot(&self, s: &mut Session) -> Result<SessionSnapshot> {
        // Consume a capture sequence number first (even a failed capture
        // burns one): the serving layer orders checkpoints by it.
        s.metrics.snapshots_taken += 1;
        let mut am = TensorFile::new();
        self.backend.snapshot_lane(&mut s.am_state, &mut am)?;
        Ok(SessionSnapshot {
            backend: self.backend.name().to_string(),
            model: self.model_cfg.name.clone(),
            buffered: s.buf.clone(),
            metrics: s.metrics,
            am,
            decoder: DecoderSnapshot::capture(&s.decode),
        })
    }

    /// Rebuild a session from a snapshot taken by [`Self::snapshot`] on
    /// an engine serving the same backend and model (validated; weights
    /// are assumed identical when both names match — worker shards share
    /// one model by construction). The restored session continues
    /// decoding bit-identically to the original
    /// (`tests/snapshot_parity.rs`).
    pub fn restore(&self, snap: &SessionSnapshot) -> Result<Session> {
        anyhow::ensure!(
            snap.backend == self.backend.name(),
            "snapshot from backend '{}' cannot restore on '{}'",
            snap.backend,
            self.backend.name()
        );
        anyhow::ensure!(
            snap.model == self.model_cfg.name,
            "snapshot of model '{}' cannot restore on '{}'",
            snap.model,
            self.model_cfg.name
        );
        // A checksum proves transport integrity, not semantic validity:
        // range-check every decoder id against this engine's resources
        // so a corrupt-at-source snapshot fails here instead of
        // panicking mid-decode on the adopting worker.
        snap.decoder.validate_bounds(
            self.lexicon.num_nodes(),
            self.lm.vocab_len(),
            self.lexicon.words.len(),
            self.lexicon.tokens.len(),
        )?;
        let mut decode = snap.decoder.restore();
        // A lattice captured in the snapshot rides along untouched. If
        // this engine wants one and the snapshot has none (migration
        // from a pre-lattice shard), seed it from the restored frontier
        // — N-best covers the words decoded from here on, prefixed by
        // the already-committed backtrack.
        if self.nbest_n > 0 {
            decode.enable_lattice();
        }
        Ok(Session {
            buf: snap.buffered.clone(),
            am_state: self.backend.restore_lane(&snap.am)?,
            decode,
            logits: None,
            metrics: snap.metrics,
        })
    }

    /// The fault hooks' gate: panic or fail once the configured step
    /// budget is spent (no-op in normal operation). The panic hook fires
    /// first so a worker armed with both dies rather than erroring.
    fn check_fault(&self) -> Result<()> {
        if let Some(limit) = self.faults.panic_after_steps {
            if self.served_steps.get() >= limit {
                panic!(
                    "injected worker panic after {limit} decoding steps \
                     (fault_panic_after_steps hook)"
                );
            }
        }
        if let Some(limit) = self.faults.after_steps {
            if self.served_steps.get() >= limit {
                anyhow::bail!(
                    "injected backend fault after {limit} decoding steps (fault_after_steps hook)"
                );
            }
        }
        Ok(())
    }

    /// Record the degrade rung a step executed under into the session's
    /// metrics (transition count + degraded-step odometer).
    fn record_degrade(&self, m: &mut SessionMetrics) {
        let level = self.degrade_level.get();
        if level != m.degrade_level {
            m.degrade_transitions += 1;
            m.degrade_level = level;
        }
        if level > 0 {
            m.degraded_steps += 1;
        }
    }

    /// Feed audio; runs as many decoding steps as the buffer allows.
    /// Returns the number of steps executed.
    pub fn feed(&self, s: &mut Session, samples: &[f32]) -> Result<usize> {
        s.buf.extend_from_slice(samples);
        let need = self.model_cfg.samples_per_step();
        if s.buf.len() < need {
            return Ok(0);
        }
        let step_len = self.model_cfg.step_len;
        // One decoder for the whole drain (built only when steps will
        // run); it borrows the engine's cached word→LM id mapping.
        let decoder = self.decoder()?;
        let mut ran = 0;
        while s.buf.len() >= need {
            self.run_step(s, &decoder)?;
            s.buf.drain(..step_len);
            ran += 1;
        }
        Ok(ran)
    }

    /// Stage audio into a session **without** running decoding steps —
    /// the batching server buffers here, then drains ready sessions
    /// together through [`Self::step_batch`].
    pub fn push_audio(&self, s: &mut Session, samples: &[f32]) {
        s.buf.extend_from_slice(samples);
    }

    /// Decoding steps `s` could run right now from its buffered audio.
    pub fn ready_steps(&self, s: &Session) -> usize {
        let need = self.model_cfg.samples_per_step();
        if s.buf.len() < need {
            0
        } else {
            (s.buf.len() - need) / self.model_cfg.step_len + 1
        }
    }

    /// Run fused decoding steps across every lane with a full step
    /// buffered, repeating until no lane is ready; returns total
    /// (lane, step) executions. All lanes advance through the backend's
    /// batched scoring entry point — one weight stream serves all lanes
    /// on the native backends — and per-lane results stay identical to
    /// scalar [`Self::feed`] (bit-identical for native f32/int8). All
    /// transient buffers come from the engine scratch arena and are
    /// reused in place after warm-up (see the module docs for the precise
    /// allocation story).
    ///
    /// On `Err` the fused step is poisoned: backend lane states may have
    /// advanced while no lane's audio was drained, so the batch's
    /// sessions must be finished or discarded, not retried with the same
    /// audio (the serving loop reports the failure to every staged feed;
    /// see `AmBackend::score_step_batch` for the contract).
    pub fn step_batch(&self, lanes: &mut [&mut Session]) -> Result<usize> {
        let need = self.model_cfg.samples_per_step();
        if !lanes.iter().any(|s| s.buf.len() >= need) {
            return Ok(0);
        }
        // Built once per drain, and only when at least one step will run.
        let decoder = self.decoder()?;
        let step_len = self.model_cfg.step_len;
        let tokens = self.model_cfg.tokens;
        let vps = self.model_cfg.vectors_per_step();
        let lane_out = vps * tokens;
        let mut total = 0usize;
        let mut guard = self.scratch.borrow_mut();
        let EngineScratch { step, dec, logits, block, ready } = &mut *guard;
        loop {
            ready.clear();
            for (i, s) in lanes.iter().enumerate() {
                if s.buf.len() >= need {
                    ready.push(i);
                }
            }
            if ready.is_empty() {
                return Ok(total);
            }
            self.check_fault()?;
            let t0 = Instant::now();
            let b = ready.len();
            // AM phase: one fused scoring pass over all ready lanes,
            // whatever the backend.
            {
                let mut am_lanes = ReadyLanes { lanes: &mut *lanes, ready, need };
                self.backend.score_step_batch(&mut am_lanes, step, logits)?;
            }
            debug_assert_eq!(logits.len(), b * lane_out);
            let t_am = Instant::now();
            for (l, &i) in ready.iter().enumerate() {
                if let Some(all) = &mut lanes[i].logits {
                    all.extend_from_slice(&logits[l * lane_out..(l + 1) * lane_out]);
                }
            }
            // Decoder phase: re-block lane-major logits into per-frame
            // [B × tokens] rows (fully overwritten per frame), then
            // advance all lanes lane-major — expand every lane into one
            // flat candidate table, then prune each lane's slice with
            // the same deterministic total-order sort (the offloadable
            // shape of Braun et al., arXiv:1910.10032; bit-identical to
            // per-lane stepping).
            block.resize(b * tokens, 0.0);
            for f in 0..vps {
                for l in 0..b {
                    let src = (l * vps + f) * tokens;
                    block[l * tokens..(l + 1) * tokens]
                        .copy_from_slice(&logits[src..src + tokens]);
                }
                decoder.batch_begin(dec);
                for (l, &i) in ready.iter().enumerate() {
                    decoder.batch_expand(
                        &mut lanes[i].decode,
                        &block[l * tokens..(l + 1) * tokens],
                        dec,
                    );
                }
                for (l, &i) in ready.iter().enumerate() {
                    decoder.batch_prune(&mut lanes[i].decode, l, dec);
                }
            }
            let t_end = Instant::now();
            self.served_steps.set(self.served_steps.get() + b as u64);
            // Fused wall time is shared: attribute an even share per lane
            // so per-session RTF stays meaningful under batching.
            let am_share = (t_am - t0).as_secs_f64() / b as f64;
            let search_share = (t_end - t_am).as_secs_f64() / b as f64;
            for &i in ready.iter() {
                let s = &mut *lanes[i];
                s.buf.drain(..step_len);
                self.record_degrade(&mut s.metrics);
                s.metrics.steps += 1;
                s.metrics.batched_steps += 1;
                s.metrics.batch_lanes += b;
                s.metrics.audio_s += self.model_cfg.step_seconds();
                s.metrics.am_s += am_share;
                s.metrics.search_s += search_share;
                s.metrics.compute_s += am_share + search_share;
            }
            total += b;
        }
    }

    fn run_step(&self, s: &mut Session, decoder: &BeamDecoder) -> Result<()> {
        self.check_fault()?;
        let t0 = Instant::now();
        let need = self.model_cfg.samples_per_step();
        let mut guard = self.scratch.borrow_mut();
        let EngineScratch { step, dec, logits, .. } = &mut *guard;
        self.backend.score_step(&mut s.am_state, &s.buf[..need], step, logits)?;
        let t_am = Instant::now();
        if let Some(all) = &mut s.logits {
            all.extend_from_slice(logits);
        }
        for row in logits.chunks(self.model_cfg.tokens) {
            decoder.step_with(&mut s.decode, row, dec);
        }
        let t_end = Instant::now();
        self.served_steps.set(self.served_steps.get() + 1);
        self.record_degrade(&mut s.metrics);
        s.metrics.steps += 1;
        s.metrics.audio_s += self.model_cfg.step_seconds();
        s.metrics.am_s += (t_am - t0).as_secs_f64();
        s.metrics.search_s += (t_end - t_am).as_secs_f64();
        s.metrics.compute_s += (t_end - t0).as_secs_f64();
        Ok(())
    }

    /// Flush buffered audio (zero-padding to whole steps) so the decoder
    /// state reflects every real sample — the shared front half of
    /// [`Self::finish`] and [`Self::nbest`].
    fn drain_padded(&self, s: &mut Session, decoder: &BeamDecoder) -> Result<()> {
        let step_len = self.model_cfg.step_len;
        let lookahead = self.model_cfg.samples_per_step() - step_len;
        if !s.buf.is_empty() {
            // Pad so every real sample is covered by a step (+ lookahead).
            let target = s.buf.len().div_ceil(step_len) * step_len + lookahead;
            s.buf.resize(target, 0.0);
            while s.buf.len() >= self.model_cfg.samples_per_step() {
                self.run_step(s, decoder)?;
                s.buf.drain(..step_len);
            }
        }
        Ok(())
    }

    /// Flush buffered audio (zero-padding to whole steps) and extract the
    /// final transcript.
    pub fn finish(&self, s: &mut Session) -> Result<Transcript> {
        let decoder = self.decoder()?;
        self.drain_padded(s, &decoder)?;
        Ok(decoder.finish(&s.decode))
    }

    /// Flush buffered audio and extract the transcript **and** the exact
    /// N-best list (plus the second-pass re-ranking when a rescorer is
    /// configured). The transcript is the same value [`Self::finish`]
    /// would return — bit-identical scores — and the N-best's top entry
    /// matches it. Fails on engines built without
    /// [`EngineBuilder::nbest`].
    pub fn nbest(&self, s: &mut Session) -> Result<NbestResult> {
        anyhow::ensure!(
            self.nbest_n > 0,
            "engine built without N-best (EngineBuilder::nbest)"
        );
        let decoder = self.decoder()?;
        self.drain_padded(s, &decoder)?;
        let transcript = decoder.finish(&s.decode);
        let entries = decoder.nbest(&s.decode, self.nbest_n);
        let mut stats = self.rescore_stats.get();
        stats.record(&entries);
        self.rescore_stats.set(stats);
        let rescored = self.rescorer.as_ref().map(|r| {
            r.rescore(&entries, &self.lexicon, &self.lm, self.dec_cfg.lm_weight)
        });
        Ok(NbestResult { transcript, entries, rescored })
    }

    /// Current best partial transcript (streaming UX, §2.4).
    pub fn partial(&self, s: &Session) -> Result<Transcript> {
        Ok(self.decoder()?.finish(&s.decode))
    }

    /// Convenience: decode a whole utterance.
    pub fn decode_utterance(&self, samples: &[f32]) -> Result<(Transcript, SessionMetrics)> {
        let mut s = self.open(false)?;
        self.feed(&mut s, samples)?;
        let t = self.finish(&mut s)?;
        Ok((t, s.metrics))
    }

    /// Greedy baseline over collected logits (requires `collect_logits`).
    pub fn greedy_of(&self, s: &Session) -> Result<Transcript> {
        let logits = s
            .logits
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("session did not collect logits"))?;
        Ok(self.decoder()?.greedy(logits))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::am::TdsModel;
    use crate::config::{Precision, StageDesc};
    use crate::synth::Synthesizer;
    use crate::util::rng::Rng;

    fn native_engine() -> Engine {
        // Random weights: decode quality is meaningless, but shapes,
        // streaming and search must all hold together.
        Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
            .build()
            .unwrap()
    }

    #[test]
    fn feed_runs_steps_at_80ms_granularity() {
        let e = native_engine();
        let mut s = e.open(false).unwrap();
        // 1279 samples: no step (needs 1280 + 240 lookahead).
        assert_eq!(e.feed(&mut s, &vec![0.0; 1279]).unwrap(), 0);
        // +241 = 1520 total: one step.
        assert_eq!(e.feed(&mut s, &vec![0.0; 241]).unwrap(), 1);
        // Ten more steps' worth at once.
        assert_eq!(e.feed(&mut s, &vec![0.0; 12800]).unwrap(), 10);
        assert_eq!(s.metrics.steps, 11);
        assert!((s.metrics.audio_s - 11.0 * 0.08).abs() < 1e-9);
    }

    #[test]
    fn chunk_size_does_not_change_result() {
        // Feeding sample-by-sample chunks vs all at once must give the
        // same transcript (streaming correctness).
        let e = native_engine();
        let mut rng = Rng::new(3);
        let u = Synthesizer::default().render(&[1, 2], &mut rng);
        let (t_all, _) = e.decode_utterance(&u.samples).unwrap();
        let mut s = e.open(false).unwrap();
        for chunk in u.samples.chunks(333) {
            e.feed(&mut s, chunk).unwrap();
        }
        let t_chunked = e.finish(&mut s).unwrap();
        assert_eq!(t_all.text, t_chunked.text);
        assert!((t_all.score - t_chunked.score).abs() < 1e-3);
    }

    #[test]
    fn partial_transcripts_available_mid_stream() {
        let e = native_engine();
        let mut rng = Rng::new(5);
        let u = Synthesizer::default().render(&[0, 7, 3], &mut rng);
        let mut s = e.open(false).unwrap();
        e.feed(&mut s, &u.samples[..u.samples.len() / 2]).unwrap();
        // Must not panic and must be a valid (possibly empty) transcript.
        let p = e.partial(&s).unwrap();
        assert!(p.words.len() <= 10);
    }

    #[test]
    fn metrics_accumulate() {
        let e = native_engine();
        let mut rng = Rng::new(7);
        let u = Synthesizer::default().render(&[4], &mut rng);
        let (_, m) = e.decode_utterance(&u.samples).unwrap();
        assert!(m.steps >= 5, "utterance shorter than expected: {}", m.steps);
        assert!(m.compute_s > 0.0);
        assert!((m.am_s + m.search_s - m.compute_s).abs() < 1e-6);
    }

    #[test]
    fn engine_exposes_backend_and_pipeline() {
        let e = native_engine();
        assert_eq!(e.backend().name(), "native-f32");
        assert_eq!(e.backend().precision(), Precision::F32);
        let p = e.pipeline();
        assert_eq!(p, PipelineDesc::for_model(&e.model_cfg));
        p.validate().unwrap();
        // features + AM layers + hyp expansion, in order.
        assert_eq!(p.stages.first(), Some(&StageDesc::Features));
        assert_eq!(p.am_stage_count(), e.model_cfg.layers().len());
        assert_eq!(p.hyp_repeats(), e.model_cfg.vectors_per_step());
    }

    #[test]
    fn step_batch_matches_scalar_feed_transcripts() {
        // Four sessions decoded through the fused batch path must produce
        // exactly the transcripts (text AND score) of four scalar feeds.
        let e = native_engine();
        let synth = Synthesizer::default();
        let utts: Vec<Vec<f32>> = (0..4u64)
            .map(|i| {
                let mut rng = Rng::new(40 + i);
                synth.render(&[i as u32, (i + 3) as u32], &mut rng).samples
            })
            .collect();
        let scalar: Vec<_> = utts
            .iter()
            .map(|u| e.decode_utterance(u).unwrap().0)
            .collect();
        let mut sessions: Vec<Session> = (0..4).map(|_| e.open(false).unwrap()).collect();
        // Stage audio in uneven chunks, stepping the batch as we go so
        // lanes join and leave ready sets at different times.
        let chunk = 1000;
        let max_len = utts.iter().map(Vec::len).max().unwrap();
        let mut off = 0;
        while off < max_len {
            for (s, u) in sessions.iter_mut().zip(&utts) {
                if off < u.len() {
                    e.push_audio(s, &u[off..(off + chunk).min(u.len())]);
                }
            }
            off += chunk;
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            e.step_batch(&mut refs).unwrap();
        }
        for (s, t_ref) in sessions.iter_mut().zip(&scalar) {
            let t = e.finish(s).unwrap();
            assert_eq!(t.text, t_ref.text);
            assert_eq!(t.score, t_ref.score);
            assert!(s.metrics.batched_steps > 0);
            assert!(s.metrics.avg_batch_occupancy() >= 1.0);
        }
    }

    #[test]
    fn step_batch_runs_all_buffered_steps() {
        let e = native_engine();
        let mut a = e.open(false).unwrap();
        let mut b = e.open(false).unwrap();
        // Lane a: 3 steps buffered; lane b: 1 step; fused loop must drain
        // both fully (occupancy 2 then 1 then 1).
        e.push_audio(&mut a, &vec![0.0; 1520 + 2 * 1280]);
        e.push_audio(&mut b, &vec![0.0; 1520]);
        assert_eq!(e.ready_steps(&a), 3);
        assert_eq!(e.ready_steps(&b), 1);
        let mut refs = vec![&mut a, &mut b];
        let ran = e.step_batch(&mut refs).unwrap();
        assert_eq!(ran, 4);
        assert_eq!(a.metrics.steps, 3);
        assert_eq!(b.metrics.steps, 1);
        assert_eq!(e.ready_steps(&a), 0);
        // b shared its single step with a: occupancy 2.
        assert_eq!(b.metrics.batch_lanes, 2);
        assert_eq!(a.metrics.batch_lanes, 2 + 1 + 1);
    }

    #[test]
    fn step_batch_scratch_is_reused_across_calls() {
        // After one warmed fused step at a given batch shape, subsequent
        // fused steps must not move or regrow any engine scratch buffer.
        let e = native_engine();
        let mut sessions: Vec<Session> = (0..3).map(|_| e.open(false).unwrap()).collect();
        let chunk = vec![0.0f32; 1520];
        for s in sessions.iter_mut() {
            e.push_audio(s, &chunk);
        }
        {
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            e.step_batch(&mut refs).unwrap();
        }
        // The decoder scratch is excluded: its candidate buffer tracks
        // the (growing) live hypothesis set; its reuse is covered by the
        // decoder's own two-pass stability test.
        let fingerprint = |e: &Engine| {
            let sc = e.scratch.borrow();
            (
                sc.step.fingerprint(),
                (sc.logits.as_ptr() as usize, sc.logits.capacity()),
                (sc.block.as_ptr() as usize, sc.block.capacity()),
                sc.ready.capacity(),
            )
        };
        let fp = fingerprint(&e);
        for _ in 0..4 {
            for s in sessions.iter_mut() {
                e.push_audio(s, &chunk);
            }
            let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
            e.step_batch(&mut refs).unwrap();
            assert_eq!(fp, fingerprint(&e), "engine scratch reallocated");
        }
    }

    #[test]
    fn quantized_engine_decodes_end_to_end() {
        let model = TdsModel::random(ModelConfig::tiny_tds(), 11);
        let e = Engine::builder()
            .native(model)
            .precision(Precision::Int8)
            .build()
            .unwrap();
        assert_eq!(e.model_cfg.precision, Precision::Int8);
        assert_eq!(e.backend().name(), "native-int8");
        let mut rng = Rng::new(13);
        let u = Synthesizer::default().render(&[2, 5], &mut rng);
        let (t, m) = e.decode_utterance(&u.samples).unwrap();
        assert!(m.steps > 0);
        assert!(t.words.len() <= 10);
        // Batched int8 path matches scalar int8 path exactly.
        let (t_ref, _) = e.decode_utterance(&u.samples).unwrap();
        let mut s = e.open(false).unwrap();
        e.push_audio(&mut s, &u.samples);
        let mut refs = vec![&mut s];
        e.step_batch(&mut refs).unwrap();
        let t_batched = e.finish(&mut s).unwrap();
        assert_eq!(t_ref.text, t_batched.text);
        assert_eq!(t_ref.score, t_batched.score);
        assert_eq!(t.text, t_ref.text);
    }

    #[test]
    fn mixed_precision_engine_decodes_and_publishes_its_map() {
        use crate::config::PrecisionMap;
        let map = PrecisionMap::parse("int4,output.fc=int8,g0.sub=f32").unwrap();
        let e = Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
            .precision_map(map.clone())
            .build()
            .unwrap();
        assert_eq!(e.backend().name(), "native-mixed");
        assert_eq!(e.backend().precision_map(), map);
        // The published pipeline carries the same per-layer map the
        // backend serves — the simulator's DMA accounting source.
        let p = e.pipeline();
        assert_eq!(p.precisions, map);
        p.validate().unwrap();
        // Batched mixed path matches the scalar mixed path exactly.
        let mut rng = Rng::new(17);
        let u = Synthesizer::default().render(&[1, 6], &mut rng);
        let (t_ref, m) = e.decode_utterance(&u.samples).unwrap();
        assert!(m.steps > 0);
        let mut s = e.open(false).unwrap();
        e.push_audio(&mut s, &u.samples);
        let mut refs = vec![&mut s];
        e.step_batch(&mut refs).unwrap();
        let t_batched = e.finish(&mut s).unwrap();
        assert_eq!(t_ref.text, t_batched.text);
        assert_eq!(t_ref.score, t_batched.score);
    }

    #[test]
    fn batcher_policy_full_take_remove() {
        let cfg = crate::config::BatchConfig { max_batch: 2, max_wait_frames: 8 };
        let model = ModelConfig::tiny_tds();
        let mut b = Batcher::new(cfg, &model);
        assert!(b.is_empty());
        assert!(!b.push(1));
        assert!(!b.push(1), "staging is idempotent");
        assert_eq!(b.len(), 1);
        assert!(b.push(2), "second lane fills the batch");
        assert!(b.wait_budget() <= std::time::Duration::from_millis(80));
        let ids = b.take();
        assert_eq!(ids, vec![1, 2]);
        assert!(b.is_empty());
        b.push(3);
        b.remove(3);
        assert!(b.is_empty());
        assert_eq!(b.wait_budget(), cfg_wait(&model));
    }

    fn cfg_wait(model: &ModelConfig) -> std::time::Duration {
        crate::config::BatchConfig { max_batch: 2, max_wait_frames: 8 }.max_wait(model)
    }

    #[test]
    fn engine_batcher_uses_built_policy() {
        let e = Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
            .batch(BatchConfig { max_batch: 2, max_wait_frames: 8 })
            .build()
            .unwrap();
        let mut b = e.batcher();
        assert!(!b.push(1));
        assert!(b.push(2), "policy max_batch=2 must fill at two lanes");
    }

    #[test]
    fn clone_worker_decodes_identically() {
        // A worker seed assembled into its own engine shares the model
        // and must produce bit-identical transcripts.
        let e = native_engine();
        let w = e.clone_worker().expect("native engines must clone").into_engine();
        assert_eq!(w.shard_cfg, e.shard_cfg);
        assert_eq!(w.batch_cfg, e.batch_cfg);
        let mut rng = Rng::new(21);
        let u = Synthesizer::default().render(&[3, 6], &mut rng);
        let (t_a, _) = e.decode_utterance(&u.samples).unwrap();
        let (t_b, _) = w.decode_utterance(&u.samples).unwrap();
        assert_eq!(t_a.text, t_b.text);
        assert_eq!(t_a.score, t_b.score);
    }

    #[test]
    fn snapshot_restore_mid_utterance_is_transcript_identical() {
        // Stream half an utterance, snapshot (through the full byte
        // encoding), restore into a worker-clone engine, finish there:
        // text AND score must equal the uninterrupted decode, for every
        // served weight format.
        for precision in [
            Precision::F32,
            Precision::Int8,
            Precision::Int4,
            Precision::Int4Sparse,
        ] {
            let e = Engine::builder()
                .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
                .precision(precision)
                .build()
                .unwrap();
            let mut rng = Rng::new(31);
            let u = Synthesizer::default().render(&[2, 7], &mut rng);
            let (t_ref, _) = e.decode_utterance(&u.samples).unwrap();
            let mut s = e.open(false).unwrap();
            let half = u.samples.len() / 2;
            e.feed(&mut s, &u.samples[..half]).unwrap();
            assert!(s.metrics.steps > 0, "first half must run steps");
            let snap = e.snapshot(&mut s).unwrap();
            let bytes = snap.encode();
            let snap = crate::coordinator::SessionSnapshot::decode(&bytes).unwrap();
            let w = e.clone_worker().unwrap().into_engine();
            let mut r = w.restore(&snap).unwrap();
            assert_eq!(r.metrics.steps, s.metrics.steps);
            w.feed(&mut r, &u.samples[half..]).unwrap();
            let t = w.finish(&mut r).unwrap();
            assert_eq!(t.text, t_ref.text, "{precision:?}");
            assert_eq!(t.score, t_ref.score, "{precision:?}");
        }
    }

    #[test]
    fn restore_rejects_mismatched_identity() {
        let f32_engine = native_engine();
        let int8_engine = Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
            .precision(Precision::Int8)
            .build()
            .unwrap();
        let mut s = f32_engine.open(false).unwrap();
        f32_engine.feed(&mut s, &vec![0.1; 1520]).unwrap();
        let snap = f32_engine.snapshot(&mut s).unwrap();
        let err = format!("{:#}", int8_engine.restore(&snap).unwrap_err());
        assert!(err.contains("backend"), "{err}");
    }

    #[test]
    fn fault_hook_fails_scoring_after_budget() {
        let e = Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
            .fault_after_steps(2)
            .build()
            .unwrap();
        let mut s = e.open(false).unwrap();
        // Two steps succeed, the third fails with the injected error.
        assert_eq!(e.feed(&mut s, &vec![0.0; 1520 + 1280]).unwrap(), 2);
        let err = format!("{:#}", e.feed(&mut s, &vec![0.0; 1280]).unwrap_err());
        assert!(err.contains("injected backend fault"), "{err}");
        // The batched path fails identically.
        let mut t = e.open(false).unwrap();
        e.push_audio(&mut t, &vec![0.0; 1520]);
        let mut refs = vec![&mut t];
        assert!(e.step_batch(&mut refs).is_err());
    }

    #[test]
    fn degrade_ladder_changes_search_and_restores_bit_exactly() {
        let dec = DecoderConfig::default();
        let batch = BatchConfig::default();
        let e = Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
            .overload(OverloadPolicy::reference_ladder(4, &dec, &batch))
            .build()
            .unwrap();
        let mut rng = Rng::new(51);
        let u = Synthesizer::default().render(&[1, 4, 2], &mut rng);
        let (t_ref, m_ref) = e.decode_utterance(&u.samples).unwrap();
        assert_eq!(m_ref.degraded_steps, 0);
        assert_eq!(m_ref.degrade_transitions, 0);
        // Deepest rung: every step records as degraded; out-of-ladder
        // levels clamp.
        e.set_degrade_level(99);
        assert_eq!(e.degrade_level(), 2);
        let (_, m_deg) = e.decode_utterance(&u.samples).unwrap();
        assert_eq!(m_deg.degraded_steps, m_deg.steps);
        assert_eq!(m_deg.degrade_transitions, 1);
        assert_eq!(m_deg.degrade_level, 2);
        // Back to full quality: bit-identical to the never-degraded run.
        e.set_degrade_level(0);
        let (t_back, m_back) = e.decode_utterance(&u.samples).unwrap();
        assert_eq!(t_back.text, t_ref.text);
        assert_eq!(t_back.score, t_ref.score);
        assert_eq!(m_back.degraded_steps, 0);
    }

    #[test]
    fn degrade_level_is_inert_without_a_ladder() {
        // Default policy has no rungs: any level clamps to 0 and serving
        // stays exactly the configured quality.
        let e = native_engine();
        e.set_degrade_level(3);
        assert_eq!(e.degrade_level(), 0);
    }

    #[test]
    fn batcher_cap_tightens_and_restores_lane_budget() {
        let cfg = crate::config::BatchConfig { max_batch: 4, max_wait_frames: 8 };
        let mut b = Batcher::new(cfg, &ModelConfig::tiny_tds());
        assert_eq!(b.effective_max_batch(), 4);
        b.set_cap(Some(2));
        assert_eq!(b.effective_max_batch(), 2);
        assert!(!b.push(1));
        assert!(b.push(2), "capped batch must close at two lanes");
        b.take();
        // A cap wider than the policy, and a zero cap, both clamp.
        b.set_cap(Some(99));
        assert_eq!(b.effective_max_batch(), 4);
        b.set_cap(Some(0));
        assert_eq!(b.effective_max_batch(), 1);
        b.set_cap(None);
        assert_eq!(b.effective_max_batch(), 4);
    }

    #[test]
    fn panic_hook_panics_after_budget() {
        let e = Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
            .fault_panic_after_steps(1)
            .build()
            .unwrap();
        let mut s = e.open(false).unwrap();
        assert_eq!(e.feed(&mut s, &vec![0.0; 1520]).unwrap(), 1);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = e.feed(&mut s, &vec![0.0; 1280]);
        }));
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("injected worker panic"), "{msg}");
    }

    #[test]
    fn nbest_engine_top_entry_matches_finish() {
        use crate::decoder::TrigramLm;
        let tri = TrigramLm::estimate(&crate::synth::spec::sample_corpus(200, 7777), 0.4).unwrap();
        let e = Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
            .nbest(4)
            .rescore(tri, 1.2)
            .build()
            .unwrap();
        assert_eq!(e.nbest_n(), 4);
        assert!(e.rescorer().is_some());
        // The pipeline gains exactly one trailing rescore stage.
        let p = e.pipeline();
        assert_eq!(p.stages.last(), Some(&StageDesc::Rescore { nbest: 4 }));
        p.validate().unwrap();
        // Transcripts are unchanged by the lattice, and the N-best's top
        // entry is bit-identical to finish.
        let plain = native_engine();
        let mut rng = Rng::new(61);
        let u = Synthesizer::default().render(&[2, 5, 1], &mut rng);
        let (t_ref, _) = plain.decode_utterance(&u.samples).unwrap();
        let mut s = e.open(false).unwrap();
        e.feed(&mut s, &u.samples).unwrap();
        let r = e.nbest(&mut s).unwrap();
        assert_eq!(r.transcript.text, t_ref.text);
        assert_eq!(r.transcript.score, t_ref.score);
        assert!(!r.entries.is_empty());
        assert_eq!(r.entries[0].text, t_ref.text);
        assert_eq!(r.entries[0].score, t_ref.score);
        let rescored = r.rescored.expect("rescorer configured");
        assert_eq!(rescored.len(), r.entries.len());
        // Serving the list measured it: the simulator's rescore kernel
        // can now be sized from reality.
        let st = e.rescore_stats();
        assert_eq!(st.lists, 1);
        assert_eq!(st.entries as usize, r.entries.len());
        assert!(st.avg_words().is_some());
        // Every second-pass entry keeps its exact first-pass score.
        for re in &rescored {
            assert!(r.entries.iter().any(|en| en.score == re.first_pass));
            assert!(re.second_pass.is_finite());
        }
    }

    #[test]
    fn nbest_requires_configuration() {
        let e = native_engine();
        assert_eq!(e.nbest_n(), 0);
        let mut s = e.open(false).unwrap();
        let err = format!("{:#}", e.nbest(&mut s).unwrap_err());
        assert!(err.contains("without N-best"), "{err}");
    }

    #[test]
    fn rescore_implies_nbest() {
        use crate::decoder::TrigramLm;
        let tri = TrigramLm::estimate(&crate::synth::spec::sample_corpus(50, 7777), 0.4).unwrap();
        let e = Engine::builder()
            .native(TdsModel::random(ModelConfig::tiny_tds(), 11))
            .rescore(tri, 1.0)
            .build()
            .unwrap();
        assert_eq!(e.nbest_n(), 8);
    }

    #[test]
    fn greedy_requires_collected_logits() {
        let e = native_engine();
        let s = e.open(false).unwrap();
        assert!(e.greedy_of(&s).is_err());
        let mut s = e.open(true).unwrap();
        e.feed(&mut s, &vec![0.0; 1520]).unwrap();
        assert!(e.greedy_of(&s).is_ok());
    }
}
