//! Fluent, validating construction of [`Engine`] — the single way
//! engines are built (CLI, server, examples, benches and tests all go
//! through here).
//!
//! ```no_run
//! use asrpu::am::TdsModel;
//! use asrpu::config::{ModelConfig, Precision};
//! use asrpu::coordinator::Engine;
//!
//! let engine = Engine::builder()
//!     .native(TdsModel::random(ModelConfig::tiny_tds(), 1))
//!     .precision(Precision::Int8)
//!     .beam(10.0)
//!     .build()
//!     .unwrap();
//! # let _ = engine;
//! ```
//!
//! Misconfiguration is reported through the typed [`BuildError`] — never
//! a panic — so callers (the serve CLI, tests) can branch on what went
//! wrong.
#![deny(missing_docs)]

use std::fmt;
use std::path::{Path, PathBuf};

use crate::am::TdsModel;
use crate::config::{
    BatchConfig, DecoderConfig, OverloadPolicy, Precision, PrecisionMap, ShardConfig,
};
use crate::decoder::{BeamDecoder, Rescorer, TrigramLm};
use crate::lexicon::Lexicon;
use crate::lm::NgramLm;
use crate::runtime::Runtime;
use crate::synth::spec;

use super::backend::{AmBackend, NativeBackend, QuantizedBackend, XlaBackend};
use super::engine::{Engine, FaultHooks};

/// Why an [`EngineBuilder`] refused to produce an engine.
#[derive(Debug, Clone, PartialEq)]
pub enum BuildError {
    /// No model / backend was supplied.
    MissingModel,
    /// The decoder configuration failed validation.
    Decoder(String),
    /// The batching configuration failed validation.
    Batch(String),
    /// The sharding configuration failed validation, or asks for more
    /// workers than the chosen backend supports.
    Shard(String),
    /// The overload policy (admission / degrade ladder) failed
    /// validation.
    Overload(String),
    /// The requested precision cannot be applied to the chosen backend.
    Precision(String),
    /// The model's output tokens don't match the lexicon's token set.
    TokenMismatch {
        /// Tokens the acoustic model emits.
        model_tokens: usize,
        /// Tokens the lexicon spells words with.
        lexicon_tokens: usize,
    },
    /// The artifacts directory could not be loaded (missing files, a
    /// crate built without the `xla` feature, PJRT errors, …).
    Artifacts {
        /// The directory that was probed.
        dir: PathBuf,
        /// Human-readable cause.
        message: String,
    },
    /// Model preparation failed (quantization, LM estimation, word→LM
    /// mapping).
    Model(String),
}

impl fmt::Display for BuildError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BuildError::MissingModel => {
                write!(f, "no model configured: call .native(), .artifacts() or .backend()")
            }
            BuildError::Decoder(m) => write!(f, "invalid decoder config: {m}"),
            BuildError::Batch(m) => write!(f, "invalid batch config: {m}"),
            BuildError::Shard(m) => write!(f, "invalid shard config: {m}"),
            BuildError::Overload(m) => write!(f, "invalid overload policy: {m}"),
            BuildError::Precision(m) => write!(f, "invalid precision request: {m}"),
            BuildError::TokenMismatch { model_tokens, lexicon_tokens } => write!(
                f,
                "model emits {model_tokens} tokens but lexicon has {lexicon_tokens}"
            ),
            BuildError::Artifacts { dir, message } => {
                write!(f, "loading artifacts from {}: {message}", dir.display())
            }
            BuildError::Model(m) => write!(f, "preparing model: {m}"),
        }
    }
}

impl std::error::Error for BuildError {}

/// What the builder will wrap into the engine's backend.
enum BackendChoice {
    /// An in-memory f32 model, precision applied at build time.
    Native(TdsModel),
    /// A ready backend (XLA artifacts, or a caller-supplied plug-in).
    Custom(Box<dyn AmBackend>),
    /// An eagerly-attempted load that failed; surfaced at build().
    Failed(BuildError),
}

/// Builder for [`Engine`]: model source, weight precision, search and
/// batching configuration, lexicon and language model — validated
/// together at [`EngineBuilder::build`]. Defaults: no model (an error at
/// build), model-native precision, default decoder/batch config, the
/// synthetic-protocol lexicon and a corpus-estimated LM.
#[derive(Default)]
pub struct EngineBuilder {
    backend: Option<BackendChoice>,
    precision: Option<Precision>,
    precision_map: Option<PrecisionMap>,
    decoder: DecoderConfig,
    batch: BatchConfig,
    shards: ShardConfig,
    overload: OverloadPolicy,
    lexicon: Option<Lexicon>,
    lm: Option<NgramLm>,
    nbest: usize,
    rescorer: Option<Rescorer>,
    fault_after_steps: Option<u64>,
    fault_panic_after_steps: Option<u64>,
    fault_reply_delay_ms: Option<u64>,
    fault_teardown_delay_ms: Option<u64>,
}

impl EngineBuilder {
    /// Start from defaults (no model; default decoder/batch config;
    /// synthetic-protocol lexicon and corpus-estimated LM).
    pub fn new() -> Self {
        Self::default()
    }

    /// Serve an in-memory f32 model through the native backend (the
    /// [`Self::precision`] knob may still quantize it at build time).
    pub fn native(mut self, model: TdsModel) -> Self {
        self.backend = Some(BackendChoice::Native(model));
        self
    }

    /// Serve the AOT artifacts in `dir` through the PJRT backend. The
    /// load happens immediately; failures surface as
    /// [`BuildError::Artifacts`] from [`Self::build`].
    pub fn artifacts(mut self, runtime: &Runtime, dir: impl AsRef<Path>) -> Self {
        let dir = dir.as_ref();
        self.backend = Some(match XlaBackend::load(runtime, dir) {
            Ok(b) => BackendChoice::Custom(Box::new(b)),
            Err(e) => BackendChoice::Failed(BuildError::Artifacts {
                dir: dir.to_path_buf(),
                message: format!("{e:#}"),
            }),
        });
        self
    }

    /// Plug in any [`AmBackend`] implementation — the open end of the
    /// API: new model families serve without touching the engine.
    pub fn backend(mut self, backend: Box<dyn AmBackend>) -> Self {
        self.backend = Some(BackendChoice::Custom(backend));
        self
    }

    /// Weight precision for the native backend (the quantized formats —
    /// `Int8`, packed `Int4`, 2:4 sparse `Int4Sparse` — are applied to
    /// the supplied f32 model at build time). Requesting a precision a
    /// custom/XLA backend doesn't already have is a [`BuildError`].
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = Some(precision);
        self
    }

    /// Per-layer weight-precision map for the native backend (the output
    /// of the compile-side calibration pass) — quantizes each conv/FC
    /// layer at its resolved format at build time. Wins over
    /// [`Self::precision`]; the two conflict unless the scalar precision
    /// equals the map's default.
    pub fn precision_map(mut self, map: PrecisionMap) -> Self {
        self.precision_map = Some(map);
        self
    }

    /// Replace the whole decoder configuration.
    pub fn decoder(mut self, cfg: DecoderConfig) -> Self {
        self.decoder = cfg;
        self
    }

    /// Convenience: set just the beam width.
    pub fn beam(mut self, beam: f32) -> Self {
        self.decoder.beam = beam;
        self
    }

    /// Dynamic-batching policy the serving loop will use.
    pub fn batch(mut self, cfg: BatchConfig) -> Self {
        self.batch = cfg;
        self
    }

    /// Multi-worker sharding policy the serving layer will use. Asking
    /// for more than one worker requires a backend whose
    /// [`AmBackend::clone_worker`] can duplicate it (the native f32/int8
    /// backends share their weights behind an `Arc`; the PJRT backend is
    /// single-worker) — validated at [`Self::build`].
    pub fn shards(mut self, cfg: ShardConfig) -> Self {
        self.shards = cfg;
        self
    }

    /// Convenience: set just the worker count.
    pub fn workers(mut self, workers: usize) -> Self {
        self.shards.workers = workers;
        self
    }

    /// Overload policy the serving layer will enforce: per-shard
    /// admission limit with `retry_after_ms` backpressure hints,
    /// never-started-session shedding, bounded retry/backoff routing and
    /// the graceful-degradation ladder. Defaults to
    /// [`OverloadPolicy::default`] — everything off.
    pub fn overload(mut self, policy: OverloadPolicy) -> Self {
        self.overload = policy;
        self
    }

    /// Replace the default synthetic-protocol lexicon.
    pub fn lexicon(mut self, lexicon: Lexicon) -> Self {
        self.lexicon = Some(lexicon);
        self
    }

    /// Replace the default corpus-estimated n-gram language model.
    pub fn lm(mut self, lm: NgramLm) -> Self {
        self.lm = Some(lm);
        self
    }

    /// Record an exact lattice per session and serve N-best lists of
    /// length `n` from [`Engine::nbest`]. `0` turns the lattice
    /// subsystem off (the default); the search itself — and every
    /// transcript — is unchanged either way.
    pub fn nbest(mut self, n: usize) -> Self {
        self.nbest = n;
        self
    }

    /// Rescore the N-best list with a second-pass (trigram) LM at
    /// utterance finish, weighted by `weight`. Implies
    /// [`Self::nbest`]`(8)` when no explicit N-best length was set.
    pub fn rescore(mut self, lm: TrigramLm, weight: f32) -> Self {
        self.rescorer = Some(Rescorer { lm, weight });
        self
    }

    /// Fault-injection hook for tests and conformance suites: after
    /// `steps` decoding steps the engine's scoring paths fail with an
    /// injected error, which the serving layer surfaces as the
    /// `internal` protocol code — otherwise unreachable over a socket,
    /// because the native backends never fail mid-serve. Defaults to
    /// off; the `ASRPU_FAULT_AFTER_STEPS` environment variable is the
    /// env-gated equivalent (read at [`Self::build`], so every
    /// construction path honors it; this explicit setter wins over it).
    pub fn fault_after_steps(mut self, steps: u64) -> Self {
        self.fault_after_steps = Some(steps);
        self
    }

    /// Fault-injection hook for the liveness supervisor's tests: after
    /// `steps` decoding steps the engine's scoring path *panics*,
    /// simulating a worker thread dying spontaneously mid-serve (the
    /// `kill_worker` drill and real panics then share one recovery
    /// path). Defaults to off; `ASRPU_FAULT_PANIC_AFTER_STEPS` is the
    /// env-gated equivalent (read at [`Self::build`]; this explicit
    /// setter wins over it).
    pub fn fault_panic_after_steps(mut self, steps: u64) -> Self {
        self.fault_panic_after_steps = Some(steps);
        self
    }

    /// Fault-injection hook for retry/backoff and chaos tests: serving
    /// workers sleep this long before answering each flushed feed,
    /// simulating a slow shard. Defaults to off;
    /// `ASRPU_FAULT_REPLY_DELAY_MS` is the env-gated equivalent (read at
    /// [`Self::build`]; this explicit setter wins over it).
    pub fn fault_reply_delay_ms(mut self, millis: u64) -> Self {
        self.fault_reply_delay_ms = Some(millis);
        self
    }

    /// Fault-injection hook for the supervisor's teardown-window tests:
    /// a dying worker sleeps this long between catching its panic and
    /// reporting death to the liveness slot, so a test can land jobs on
    /// the already-torn-down channel deterministically. Defaults to off;
    /// `ASRPU_FAULT_TEARDOWN_DELAY_MS` is the env-gated equivalent (read
    /// at [`Self::build`]; this explicit setter wins over it).
    pub fn fault_teardown_delay_ms(mut self, millis: u64) -> Self {
        self.fault_teardown_delay_ms = Some(millis);
        self
    }

    /// Validate everything and assemble the engine.
    pub fn build(self) -> Result<Engine, BuildError> {
        // Cheap config validation first — fail fast before any expensive
        // backend work (int8 quantization is a full pass over the model).
        self.decoder
            .validate()
            .map_err(|e| BuildError::Decoder(format!("{e:#}")))?;
        self.batch
            .validate()
            .map_err(|e| BuildError::Batch(format!("{e:#}")))?;
        self.shards
            .validate()
            .map_err(|e| BuildError::Shard(format!("{e:#}")))?;
        self.overload
            .validate()
            .map_err(|e| BuildError::Overload(format!("{e:#}")))?;
        let choice = self.backend.ok_or(BuildError::MissingModel)?;
        let backend: Box<dyn AmBackend> = match choice {
            BackendChoice::Failed(e) => return Err(e),
            BackendChoice::Native(model) => {
                if let Some(map) = &self.precision_map {
                    if let Some(p) = self.precision {
                        if p != map.default {
                            return Err(BuildError::Precision(format!(
                                "precision({p}) conflicts with precision_map default {}",
                                map.default
                            )));
                        }
                    }
                    if map.is_uniform() && map.default == Precision::F32 {
                        Box::new(NativeBackend::new(model))
                    } else {
                        Box::new(
                            QuantizedBackend::quantize_mixed(&model, map)
                                .map_err(|e| BuildError::Model(format!("{e:#}")))?,
                        )
                    }
                } else {
                    match self.precision.unwrap_or(model.cfg.precision) {
                        Precision::F32 => Box::new(NativeBackend::new(model)),
                        Precision::Int8 => Box::new(
                            QuantizedBackend::quantize(&model)
                                .map_err(|e| BuildError::Model(format!("{e:#}")))?,
                        ),
                        Precision::Int4 => Box::new(
                            QuantizedBackend::quantize_int4(&model)
                                .map_err(|e| BuildError::Model(format!("{e:#}")))?,
                        ),
                        Precision::Int4Sparse => Box::new(
                            QuantizedBackend::quantize_int4_sparse(&model)
                                .map_err(|e| BuildError::Model(format!("{e:#}")))?,
                        ),
                    }
                }
            }
            BackendChoice::Custom(b) => {
                if let Some(p) = self.precision {
                    if p != b.precision() {
                        return Err(BuildError::Precision(format!(
                            "backend '{}' serves {:?} weights; requested {p:?} \
                             (re-quantization applies to .native() models only)",
                            b.name(),
                            b.precision()
                        )));
                    }
                }
                if let Some(map) = &self.precision_map {
                    if *map != b.precision_map() {
                        return Err(BuildError::Precision(format!(
                            "backend '{}' has a fixed per-layer precision map \
                             (re-calibration applies to .native() models only)",
                            b.name()
                        )));
                    }
                }
                b
            }
        };
        // Multi-worker serving — including a static single worker that
        // may *scale up* at runtime (max_workers > 1) — needs a backend
        // every worker thread can hold a handle to; probe with one
        // (cheap, Arc-refcount) clone.
        if self.shards.effective_max_workers() > 1 && backend.clone_worker().is_none() {
            return Err(BuildError::Shard(format!(
                "backend '{}' cannot serve {} workers: it does not support \
                 clone_worker() (device handles are thread-bound)",
                backend.name(),
                self.shards.effective_max_workers()
            )));
        }
        let lexicon = self.lexicon.unwrap_or_else(spec::lexicon);
        let model_tokens = backend.model_cfg().tokens;
        if model_tokens != lexicon.tokens.len() {
            return Err(BuildError::TokenMismatch {
                model_tokens,
                lexicon_tokens: lexicon.tokens.len(),
            });
        }
        let lm = match self.lm {
            Some(lm) => lm,
            // 2000 sentences, fixed seed — deterministic across builds.
            None => NgramLm::estimate(&spec::sample_corpus(2000, 7777), 0.4)
                .map_err(|e| BuildError::Model(format!("{e:#}")))?,
        };
        let word_lm_ids = BeamDecoder::word_lm_ids(&lexicon, &lm)
            .map_err(|e| BuildError::Model(format!("{e:#}")))?;
        // Env-gated fault hooks: resolved here so every construction
        // path (new(), default(), struct update) honors them uniformly;
        // explicit builder settings take precedence.
        let env_u64 = |name: &str| std::env::var(name).ok().and_then(|v| v.parse().ok());
        let faults = FaultHooks {
            after_steps: self.fault_after_steps.or_else(|| env_u64("ASRPU_FAULT_AFTER_STEPS")),
            panic_after_steps: self
                .fault_panic_after_steps
                .or_else(|| env_u64("ASRPU_FAULT_PANIC_AFTER_STEPS")),
            reply_delay_ms: self
                .fault_reply_delay_ms
                .or_else(|| env_u64("ASRPU_FAULT_REPLY_DELAY_MS")),
            teardown_delay_ms: self
                .fault_teardown_delay_ms
                .or_else(|| env_u64("ASRPU_FAULT_TEARDOWN_DELAY_MS")),
        };
        // Rescoring consumes the N-best list, so it implies one.
        let nbest = if self.nbest == 0 && self.rescorer.is_some() { 8 } else { self.nbest };
        Ok(Engine::assemble(
            backend,
            lexicon,
            lm,
            self.decoder,
            self.batch,
            self.shards,
            self.overload,
            word_lm_ids,
            nbest,
            self.rescorer,
            faults,
        ))
    }
}
