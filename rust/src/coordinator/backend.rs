//! The acoustic-model backend contract — the programmability seam of the
//! engine.
//!
//! ASRPU's thesis is that the hardware survives model churn because each
//! decoder part is *a program*, not a circuit. The serving engine mirrors
//! that: acoustic scoring is behind the object-safe [`AmBackend`] trait,
//! so a new model family, a different numeric format or a remote
//! execution path plugs into [`super::Engine`] without the engine
//! learning its name. Three implementations ship in-crate:
//!
//! * [`NativeBackend`] — the in-crate f32 TDS mirror (`am::TdsModel`);
//! * [`QuantizedBackend`] — int8 weights with f32 accumulate
//!   (`am::QuantizedTdsModel`);
//! * [`XlaBackend`] — the AOT artifacts via PJRT (`runtime::XlaAm`),
//!   including a default batched step that drains every ready lane
//!   through the engine's scratch arena (previously the engine
//!   special-cased XLA into a scalar fallback).
//!
//! Contract highlights:
//!
//! * **State is opaque.** Sessions hold an [`AmLaneState`] the backend
//!   created; only the backend downcasts it. Mixing states across
//!   backends is a programming error and panics with a clear message.
//! * **Scratch is caller-owned.** Both scoring entry points write through
//!   a [`StepScratch`] arena and an output buffer owned by the engine, so
//!   steady-state serving stays allocation-free for the native backends
//!   (the PJRT path still allocates inside the runtime per step — see
//!   KNOWN_FAILURES.md).
//! * **Metadata is queryable.** [`AmBackend::precision`] and
//!   [`AmBackend::weight_bytes_per_step`] feed the simulator/power
//!   models and the serving protocol's `config` introspection op.
#![deny(missing_docs)]

use anyhow::Result;
use std::any::Any;
use std::path::Path;
use std::sync::Arc;

use crate::am::{
    KernelIsa, LaneStates, QuantizedTdsModel, Scratch as AmScratch, TdsModel, TdsState,
};
use crate::config::{ModelConfig, Precision, PrecisionMap};
use crate::dsp::{mfcc::Scratch as MfccScratch, Mfcc};
use crate::runtime::xla_am::XlaState;
use crate::runtime::{Runtime, XlaAm};
use crate::util::tensor_io::TensorFile;

/// Type-erased per-session acoustic state. Created by
/// [`AmBackend::open_state`]; the owning backend downcasts it back in its
/// scoring entry points.
pub struct AmLaneState {
    inner: Box<dyn Any>,
}

impl AmLaneState {
    /// Wrap a backend's concrete session state.
    pub fn new<T: 'static>(state: T) -> Self {
        AmLaneState { inner: Box::new(state) }
    }

    /// Recover the concrete state. Panics if the state was created by a
    /// different backend (sessions are engine-bound; this cannot happen
    /// through the public API).
    pub fn downcast_mut<T: 'static>(&mut self) -> &mut T {
        self.inner
            .downcast_mut::<T>()
            .expect("session state does not belong to this backend")
    }
}

/// Reusable buffers for one scoring step, owned by the engine and lent to
/// the backend: feature-extraction scratch plus the AM activation arena.
/// After warm-up at a given batch shape every buffer is recycled in place
/// (capacity-fingerprint test in `coordinator::engine`).
#[derive(Default)]
pub struct StepScratch {
    /// AM activation ping-pong / conv gather / int8 partial sums.
    pub am: AmScratch,
    /// MFCC frame pipeline scratch.
    pub mfcc: MfccScratch,
    /// One-frame staging buffer for the MFCC extractor.
    pub frame: Vec<f32>,
    /// Gathered feature frames, lane-major `[B × (frames × n_mels)]`.
    pub feats: Vec<f32>,
}

impl StepScratch {
    /// Pointer/capacity fingerprint — lets tests assert steady-state
    /// buffer reuse without a counting allocator.
    pub fn fingerprint(&self) -> ([(usize, usize); 4], (usize, usize), (usize, usize)) {
        (
            self.am.fingerprint(),
            (self.frame.as_ptr() as usize, self.frame.capacity()),
            (self.feats.as_ptr() as usize, self.feats.capacity()),
        )
    }
}

/// Batched-step view of the ready lanes: buffered audio (read) and
/// per-lane acoustic state (write), borrowed one lane at a time so the
/// engine never materializes per-lane reference vectors.
pub trait AmLanes {
    /// Number of ready lanes in this fused step.
    fn lane_count(&self) -> usize;
    /// One lane's buffered audio, exactly `samples_per_step` samples.
    fn samples(&self, lane: usize) -> &[f32];
    /// One lane's acoustic state.
    fn state(&mut self, lane: usize) -> &mut AmLaneState;
}

/// An acoustic-scoring backend: everything the engine needs to turn
/// buffered audio into per-step log-probabilities, plus the metadata the
/// cost models and the serving protocol introspect.
///
/// Object-safe by design — the engine holds `Box<dyn AmBackend>` and new
/// workloads plug in without touching `coordinator::engine`.
pub trait AmBackend {
    /// Stable backend identifier (`native-f32` | `native-int8` | `xla` |
    /// custom).
    fn name(&self) -> &'static str;

    /// The model geometry this backend serves.
    fn model_cfg(&self) -> &ModelConfig;

    /// Weight precision — drives the simulator's DMA-byte accounting and
    /// the power model (int8 ⇒ 4× less weight traffic, §3.4). For a
    /// mixed-precision backend this is the dominant (default) format;
    /// [`Self::precision_map`] carries the per-layer assignment.
    fn precision(&self) -> Precision {
        self.model_cfg().precision
    }

    /// Per-layer weight-precision assignment. Defaults to uniform at
    /// [`Self::precision`]; backends built from a calibrated map override
    /// it so the simulator sizes each AM layer's weight DMA from the
    /// format the backend actually stores.
    fn precision_map(&self) -> PrecisionMap {
        PrecisionMap::uniform(self.precision())
    }

    /// Model-data bytes staged per decoding step (shared across fused
    /// lanes) — the DMA-traffic metadata the power model consumes.
    fn weight_bytes_per_step(&self) -> u64 {
        self.model_cfg().model_bytes() as u64
    }

    /// The host SIMD ISA this backend's AM kernels dispatch to —
    /// introspection metadata for the serving `config` op and perf
    /// accounting. Never a correctness knob: the native kernels are
    /// bit-identical under every ISA (`tests/simd_parity.rs`). The
    /// default reports [`KernelIsa::active`], which is right for the
    /// native backends; backends that do not run the host kernels (XLA
    /// artifacts execute whatever the AOT compiler emitted) may
    /// override.
    fn kernel_isa(&self) -> KernelIsa {
        KernelIsa::active()
    }

    /// Fresh per-session streaming state (conv histories, device
    /// buffers, …).
    fn open_state(&self) -> Result<AmLaneState>;

    /// Score one lane's decoding step: `samples_per_step` audio samples
    /// in, `vectors_per_step × tokens` log-probs out. `out` is resized
    /// and fully overwritten; all transients come from `sc`.
    fn score_step(
        &self,
        state: &mut AmLaneState,
        samples: &[f32],
        sc: &mut StepScratch,
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// Score one fused decoding step over every ready lane. `out` becomes
    /// lane-major `[B × (vectors_per_step × tokens)]`, resized and fully
    /// overwritten. Implementations must keep per-lane results identical
    /// to [`Self::score_step`] on the same lane alone — batching is a
    /// throughput decision, never a transcript decision.
    ///
    /// **Error contract:** an `Err` poisons the fused step — some lanes'
    /// acoustic states may already have advanced (e.g. a mid-batch
    /// device failure on the PJRT path), so callers must treat every
    /// lane in the batch as unsteppable: finish or discard those
    /// sessions rather than retrying the same audio against the
    /// advanced state.
    fn score_step_batch(
        &self,
        lanes: &mut dyn AmLanes,
        sc: &mut StepScratch,
        out: &mut Vec<f32>,
    ) -> Result<()>;

    /// Whether this backend implements the
    /// [`Self::snapshot_lane`]/[`Self::restore_lane`] pair. The serving
    /// layer uses this to refuse state-destroying fallbacks: sessions
    /// of a backend without snapshots are pinned to their shard, never
    /// checkpointed, and after a worker crash they are reported lost
    /// (`unknown_session`) instead of being silently re-opened fresh.
    fn supports_lane_snapshots(&self) -> bool {
        false
    }

    /// Serialize one lane's streaming state into named tensors — the
    /// acoustic half of a session snapshot (live migration, recovery
    /// checkpoints, client resume). Tensor names are backend-private;
    /// the engine namespaces them inside the snapshot container.
    ///
    /// Contract: [`Self::restore_lane`] on the written tensors must
    /// yield a state that scores **bit-identically** to the original
    /// from the next step onward (`&mut` because device-backed states
    /// may need a synchronizing download).
    ///
    /// Default: unsupported — such a backend's sessions are pinned to
    /// their shard and never checkpointed; everything else keeps
    /// working.
    fn snapshot_lane(&self, state: &mut AmLaneState, tf: &mut TensorFile) -> Result<()> {
        let _ = (state, tf);
        anyhow::bail!("backend '{}' does not support lane snapshots", self.name())
    }

    /// Rebuild a lane state from tensors written by
    /// [`Self::snapshot_lane`], validating every shape against this
    /// backend's model geometry. Default: unsupported (see
    /// [`Self::snapshot_lane`]).
    fn restore_lane(&self, tf: &TensorFile) -> Result<AmLaneState> {
        let _ = tf;
        anyhow::bail!("backend '{}' does not support lane snapshots", self.name())
    }

    /// Duplicate this backend for another worker shard, sharing the
    /// immutable model (native backends hold their weights behind an
    /// `Arc`, so a worker clone costs a refcount, not a weight copy).
    /// Per-worker mutable state (scratch arenas, session lane states) is
    /// never shared — each worker brings its own.
    ///
    /// Returns `None` when the backend cannot run on another thread
    /// (PJRT device handles are not `Send`); `EngineBuilder` rejects
    /// multi-worker [`ShardConfig`](crate::config::ShardConfig)s for
    /// such backends, so sharded serving paths never observe `None`.
    fn clone_worker(&self) -> Option<Box<dyn AmBackend + Send>> {
        None
    }
}

/// Adapter presenting [`AmLanes`] states to the native AM step driver.
struct ErasedLanes<'a> {
    lanes: &'a mut dyn AmLanes,
}

impl LaneStates for ErasedLanes<'_> {
    fn lane_count(&self) -> usize {
        self.lanes.lane_count()
    }

    fn state(&mut self, lane: usize) -> &mut TdsState {
        self.lanes.state(lane).downcast_mut::<TdsState>()
    }
}

/// The in-crate f32 backend: MFCC front-end + native TDS model, fused
/// over lanes through the register-blocked kernels in `am::gemm`. The
/// weights live behind an `Arc` so worker shards share one copy.
pub struct NativeBackend {
    model: Arc<TdsModel>,
    mfcc: Mfcc,
}

impl NativeBackend {
    /// Wrap an in-memory f32 model (front-end geometry derived from its
    /// config).
    pub fn new(model: TdsModel) -> Self {
        let mfcc = Mfcc::for_model(&model.cfg);
        NativeBackend { model: Arc::new(model), mfcc }
    }
}

impl AmBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native-f32"
    }

    fn model_cfg(&self) -> &ModelConfig {
        &self.model.cfg
    }

    fn open_state(&self) -> Result<AmLaneState> {
        Ok(AmLaneState::new(self.model.state()))
    }

    fn score_step(
        &self,
        state: &mut AmLaneState,
        samples: &[f32],
        sc: &mut StepScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let StepScratch { am, mfcc, frame, feats } = sc;
        feats.clear();
        self.mfcc.extract_into(samples, mfcc, frame, feats);
        let mut lanes = [state.downcast_mut::<TdsState>()];
        self.model.step_batch_into(&mut lanes[..], feats, am, out);
        Ok(())
    }

    fn score_step_batch(
        &self,
        lanes: &mut dyn AmLanes,
        sc: &mut StepScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let StepScratch { am, mfcc, frame, feats } = sc;
        feats.clear();
        for i in 0..lanes.lane_count() {
            self.mfcc.extract_into(lanes.samples(i), mfcc, frame, feats);
        }
        debug_assert_eq!(
            feats.len(),
            lanes.lane_count() * self.model.cfg.frames_per_step() * self.model.cfg.n_mels
        );
        let mut states = ErasedLanes { lanes };
        self.model.step_batch_into(&mut states, feats, am, out);
        Ok(())
    }

    fn supports_lane_snapshots(&self) -> bool {
        true
    }

    fn snapshot_lane(&self, state: &mut AmLaneState, tf: &mut TensorFile) -> Result<()> {
        state.downcast_mut::<TdsState>().write_tensors(tf);
        Ok(())
    }

    fn restore_lane(&self, tf: &TensorFile) -> Result<AmLaneState> {
        let mut st = self.model.state();
        st.read_tensors(tf)?;
        Ok(AmLaneState::new(st))
    }

    fn clone_worker(&self) -> Option<Box<dyn AmBackend + Send>> {
        Some(Box::new(NativeBackend {
            model: Arc::clone(&self.model),
            mfcc: self.mfcc.clone(),
        }))
    }
}

/// The quantized backend: sub-f32 weights with f32 accumulate
/// (`am::quant`) — uniform int8, packed int4, 2:4 structured-sparse
/// int4, or a calibrated per-layer mix; same streaming state as the f32
/// backend. Weights live behind an `Arc` so worker shards share one
/// copy.
pub struct QuantizedBackend {
    model: Arc<QuantizedTdsModel>,
    mfcc: Mfcc,
}

impl QuantizedBackend {
    /// Wrap an already-quantized model.
    pub fn new(model: QuantizedTdsModel) -> Self {
        let mfcc = Mfcc::for_model(&model.cfg);
        QuantizedBackend { model: Arc::new(model), mfcc }
    }

    /// Quantize an f32 model uniformly to int8 and wrap the result.
    pub fn quantize(model: &TdsModel) -> Result<Self> {
        Ok(Self::new(QuantizedTdsModel::from_model(model)?))
    }

    /// Quantize an f32 model uniformly to packed int4.
    pub fn quantize_int4(model: &TdsModel) -> Result<Self> {
        Self::quantize_mixed(model, &PrecisionMap::uniform(Precision::Int4))
    }

    /// Prune + quantize an f32 model uniformly to 2:4 sparse int4.
    pub fn quantize_int4_sparse(model: &TdsModel) -> Result<Self> {
        Self::quantize_mixed(model, &PrecisionMap::uniform(Precision::Int4Sparse))
    }

    /// Quantize an f32 model with a calibrated per-layer precision map
    /// (the output of `python/compile/calibrate.py`).
    pub fn quantize_mixed(model: &TdsModel, map: &PrecisionMap) -> Result<Self> {
        Ok(Self::new(QuantizedTdsModel::from_model_mixed(model, map)?))
    }
}

impl AmBackend for QuantizedBackend {
    fn name(&self) -> &'static str {
        let map = self.model.precision_map();
        if !map.is_uniform() {
            return "native-mixed";
        }
        match map.default {
            Precision::Int8 => "native-int8",
            Precision::Int4 => "native-int4",
            Precision::Int4Sparse => "native-int4-sparse",
            Precision::F32 => "native-mixed",
        }
    }

    fn model_cfg(&self) -> &ModelConfig {
        &self.model.cfg
    }

    fn precision_map(&self) -> PrecisionMap {
        self.model.precision_map().clone()
    }

    fn open_state(&self) -> Result<AmLaneState> {
        Ok(AmLaneState::new(self.model.state()))
    }

    fn score_step(
        &self,
        state: &mut AmLaneState,
        samples: &[f32],
        sc: &mut StepScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let StepScratch { am, mfcc, frame, feats } = sc;
        feats.clear();
        self.mfcc.extract_into(samples, mfcc, frame, feats);
        let mut lanes = [state.downcast_mut::<TdsState>()];
        self.model.step_batch_into(&mut lanes[..], feats, am, out);
        Ok(())
    }

    fn score_step_batch(
        &self,
        lanes: &mut dyn AmLanes,
        sc: &mut StepScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let StepScratch { am, mfcc, frame, feats } = sc;
        feats.clear();
        for i in 0..lanes.lane_count() {
            self.mfcc.extract_into(lanes.samples(i), mfcc, frame, feats);
        }
        let mut states = ErasedLanes { lanes };
        self.model.step_batch_into(&mut states, feats, am, out);
        Ok(())
    }

    fn supports_lane_snapshots(&self) -> bool {
        true
    }

    fn snapshot_lane(&self, state: &mut AmLaneState, tf: &mut TensorFile) -> Result<()> {
        state.downcast_mut::<TdsState>().write_tensors(tf);
        Ok(())
    }

    fn restore_lane(&self, tf: &TensorFile) -> Result<AmLaneState> {
        let mut st = self.model.state();
        st.read_tensors(tf)?;
        Ok(AmLaneState::new(st))
    }

    fn clone_worker(&self) -> Option<Box<dyn AmBackend + Send>> {
        Some(Box::new(QuantizedBackend {
            model: Arc::clone(&self.model),
            mfcc: self.mfcc.clone(),
        }))
    }
}

/// The artifact backend: MFCC and the streaming TDS step both execute as
/// AOT-compiled XLA computations through PJRT. The batched entry point
/// drains every ready lane through the caller's output arena — the
/// engine's fused loop is uniform across backends (the scalar-fallback
/// special case is gone); what still allocates per step is the PJRT
/// runtime's own host/device buffers (see KNOWN_FAILURES.md).
///
/// PJRT device handles are not `Send`, so this backend keeps the default
/// [`AmBackend::clone_worker`] (`None`): it serves single-worker only,
/// and the builder rejects `ShardConfig { workers: >1 }` for it.
pub struct XlaBackend {
    am: XlaAm,
}

impl XlaBackend {
    /// Wrap a loaded artifact model.
    pub fn new(am: XlaAm) -> Self {
        XlaBackend { am }
    }

    /// Load everything from an artifacts directory.
    pub fn load(runtime: &Runtime, dir: &Path) -> Result<Self> {
        Ok(Self::new(XlaAm::load(runtime, dir)?))
    }
}

impl AmBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn model_cfg(&self) -> &ModelConfig {
        &self.am.meta.model
    }

    fn open_state(&self) -> Result<AmLaneState> {
        Ok(AmLaneState::new(self.am.state()?))
    }

    fn score_step(
        &self,
        state: &mut AmLaneState,
        samples: &[f32],
        _sc: &mut StepScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        // The PJRT mfcc path hands back an owned Vec either way; copying
        // it into scratch would only add a memcpy.
        let feats = self.am.mfcc(samples)?;
        out.clear();
        self.am.step_into(state.downcast_mut::<XlaState>(), &feats, out)?;
        debug_assert_eq!(
            out.len(),
            self.am.meta.model.vectors_per_step() * self.am.meta.model.tokens
        );
        Ok(())
    }

    fn score_step_batch(
        &self,
        lanes: &mut dyn AmLanes,
        _sc: &mut StepScratch,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        out.clear();
        for i in 0..lanes.lane_count() {
            let feats = self.am.mfcc(lanes.samples(i))?;
            self.am.step_into(lanes.state(i).downcast_mut::<XlaState>(), &feats, out)?;
        }
        Ok(())
    }

    // Device states snapshot through host-side copies: download on
    // capture, upload on restore. Slower than the native path but still
    // bit-exact — the device never rounds its own stored f32 state.
    // (On the stub runtime the calls fail, but the builder's
    // single-worker restriction for XLA means nothing migrates there
    // anyway.)
    fn supports_lane_snapshots(&self) -> bool {
        true
    }

    fn snapshot_lane(&self, state: &mut AmLaneState, tf: &mut TensorFile) -> Result<()> {
        self.am.snapshot_state(state.downcast_mut::<XlaState>(), tf)
    }

    fn restore_lane(&self, tf: &TensorFile) -> Result<AmLaneState> {
        Ok(AmLaneState::new(self.am.restore_state(tf)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::rng::Rng;

    #[test]
    fn native_backend_metadata() {
        let b = NativeBackend::new(TdsModel::random(ModelConfig::tiny_tds(), 1));
        assert_eq!(b.name(), "native-f32");
        assert_eq!(b.precision(), Precision::F32);
        assert_eq!(b.weight_bytes_per_step(), b.model_cfg().model_bytes() as u64);
        // Native backends report whatever the dispatch layer resolved.
        assert_eq!(b.kernel_isa(), KernelIsa::active());
    }

    #[test]
    fn quantized_backend_reports_int8_and_quarter_bytes() {
        let model = TdsModel::random(ModelConfig::tiny_tds(), 2);
        let f32_bytes = NativeBackend::new(model.clone()).weight_bytes_per_step();
        let q = QuantizedBackend::quantize(&model).unwrap();
        assert_eq!(q.name(), "native-int8");
        assert_eq!(q.precision(), Precision::Int8);
        assert_eq!(4 * q.weight_bytes_per_step(), f32_bytes);
    }

    #[test]
    fn scalar_and_batched_scoring_agree_through_the_trait() {
        // The trait contract: score_step_batch on one lane == score_step.
        struct OneLane<'a> {
            samples: &'a [f32],
            state: &'a mut AmLaneState,
        }
        impl AmLanes for OneLane<'_> {
            fn lane_count(&self) -> usize {
                1
            }
            fn samples(&self, _lane: usize) -> &[f32] {
                self.samples
            }
            fn state(&mut self, _lane: usize) -> &mut AmLaneState {
                &mut *self.state
            }
        }
        let model = TdsModel::random(ModelConfig::tiny_tds(), 3);
        let map = PrecisionMap::parse("int4,output.fc=int8,g0.sub=f32").unwrap();
        let backends: Vec<Box<dyn AmBackend>> = vec![
            Box::new(NativeBackend::new(model.clone())),
            Box::new(QuantizedBackend::quantize(&model).unwrap()),
            Box::new(QuantizedBackend::quantize_int4(&model).unwrap()),
            Box::new(QuantizedBackend::quantize_int4_sparse(&model).unwrap()),
            Box::new(QuantizedBackend::quantize_mixed(&model, &map).unwrap()),
        ];
        let mut rng = Rng::new(5);
        let cfg = model.cfg.clone();
        let samples: Vec<f32> =
            (0..cfg.samples_per_step()).map(|_| rng.uniform(-0.5, 0.5)).collect();
        for b in &backends {
            let mut sc = StepScratch::default();
            let mut s1 = b.open_state().unwrap();
            let mut s2 = b.open_state().unwrap();
            let mut scalar = Vec::new();
            b.score_step(&mut s1, &samples, &mut sc, &mut scalar).unwrap();
            let mut batched = Vec::new();
            let mut lanes = OneLane { samples: &samples, state: &mut s2 };
            b.score_step_batch(&mut lanes, &mut sc, &mut batched).unwrap();
            assert_eq!(scalar, batched, "backend {}", b.name());
            assert_eq!(scalar.len(), cfg.vectors_per_step() * cfg.tokens);
        }
    }

    #[test]
    fn native_clone_worker_scores_identically() {
        // A worker clone shares the model and must score bit-identically
        // to the original backend on the same audio.
        let model = TdsModel::random(ModelConfig::tiny_tds(), 9);
        let originals: Vec<Box<dyn AmBackend>> = vec![
            Box::new(NativeBackend::new(model.clone())),
            Box::new(QuantizedBackend::quantize(&model).unwrap()),
            Box::new(QuantizedBackend::quantize_int4(&model).unwrap()),
            Box::new(QuantizedBackend::quantize_int4_sparse(&model).unwrap()),
        ];
        let mut rng = Rng::new(8);
        let samples: Vec<f32> = (0..model.cfg.samples_per_step())
            .map(|_| rng.uniform(-0.5, 0.5))
            .collect();
        for b in &originals {
            let clone = b.clone_worker().expect("native backends must shard");
            assert_eq!(clone.name(), b.name());
            assert_eq!(clone.precision(), b.precision());
            assert_eq!(clone.weight_bytes_per_step(), b.weight_bytes_per_step());
            let mut sc = StepScratch::default();
            let mut out_a = Vec::new();
            let mut out_b = Vec::new();
            let mut st_a = b.open_state().unwrap();
            let mut st_b = clone.open_state().unwrap();
            b.score_step(&mut st_a, &samples, &mut sc, &mut out_a).unwrap();
            clone.score_step(&mut st_b, &samples, &mut sc, &mut out_b).unwrap();
            assert_eq!(out_a, out_b, "backend {}", b.name());
        }
    }

    #[test]
    fn native_lane_snapshots_restore_bit_identically() {
        // Snapshot after one step, restore, then score the same next
        // step on both: outputs must be bit-equal for f32 and int8.
        let model = TdsModel::random(ModelConfig::tiny_tds(), 12);
        let map = PrecisionMap::parse("int4_sparse,output.fc=int8").unwrap();
        let backends: Vec<Box<dyn AmBackend>> = vec![
            Box::new(NativeBackend::new(model.clone())),
            Box::new(QuantizedBackend::quantize(&model).unwrap()),
            Box::new(QuantizedBackend::quantize_int4(&model).unwrap()),
            Box::new(QuantizedBackend::quantize_mixed(&model, &map).unwrap()),
        ];
        let mut rng = Rng::new(77);
        let n = model.cfg.samples_per_step();
        let first: Vec<f32> = (0..n).map(|_| rng.uniform(-0.5, 0.5)).collect();
        let second: Vec<f32> = (0..n).map(|_| rng.uniform(-0.5, 0.5)).collect();
        for b in &backends {
            let mut sc = StepScratch::default();
            let mut out = Vec::new();
            let mut live = b.open_state().unwrap();
            b.score_step(&mut live, &first, &mut sc, &mut out).unwrap();
            let mut tf = TensorFile::new();
            b.snapshot_lane(&mut live, &mut tf).unwrap();
            let mut restored = b.restore_lane(&tf).unwrap();
            let mut out_live = Vec::new();
            let mut out_rest = Vec::new();
            b.score_step(&mut live, &second, &mut sc, &mut out_live).unwrap();
            b.score_step(&mut restored, &second, &mut sc, &mut out_rest).unwrap();
            assert_eq!(out_live, out_rest, "backend {}", b.name());
        }
    }

    #[test]
    fn below_int8_backends_report_format_metadata() {
        let model = TdsModel::random(ModelConfig::tiny_tds(), 4);
        let i4 = QuantizedBackend::quantize_int4(&model).unwrap();
        assert_eq!(i4.name(), "native-int4");
        assert_eq!(i4.precision(), Precision::Int4);
        assert!(i4.precision_map().is_uniform());
        let sp = QuantizedBackend::quantize_int4_sparse(&model).unwrap();
        assert_eq!(sp.name(), "native-int4-sparse");
        assert_eq!(sp.precision(), Precision::Int4Sparse);
        // Sub-byte formats shrink the headline weight bytes: int4 is at
        // most half of int8, and 2:4 sparse undercuts packed int4.
        let i8b = QuantizedBackend::quantize(&model).unwrap().weight_bytes_per_step();
        assert!(2 * i4.weight_bytes_per_step() <= i8b);
        assert!(sp.weight_bytes_per_step() < i4.weight_bytes_per_step());
        let map = PrecisionMap::parse("int4,output.fc=int8,g0.sub=f32").unwrap();
        let mixed = QuantizedBackend::quantize_mixed(&model, &map).unwrap();
        assert_eq!(mixed.name(), "native-mixed");
        assert_eq!(mixed.precision(), Precision::Int4);
        assert_eq!(mixed.precision_map(), map);
        // Overrides naming nonexistent layers are rejected up front.
        let bad = PrecisionMap::parse("int4,nope=int8").unwrap();
        assert!(QuantizedBackend::quantize_mixed(&model, &bad).is_err());
    }

    #[test]
    fn snapshot_default_is_unsupported() {
        // A backend that keeps the trait defaults reports "no snapshots"
        // instead of panicking — its sessions simply stay pinned.
        struct Opaque(ModelConfig);
        impl AmBackend for Opaque {
            fn name(&self) -> &'static str {
                "opaque"
            }
            fn model_cfg(&self) -> &ModelConfig {
                &self.0
            }
            fn open_state(&self) -> Result<AmLaneState> {
                Ok(AmLaneState::new(()))
            }
            fn score_step(
                &self,
                _state: &mut AmLaneState,
                _samples: &[f32],
                _sc: &mut StepScratch,
                _out: &mut Vec<f32>,
            ) -> Result<()> {
                Ok(())
            }
            fn score_step_batch(
                &self,
                _lanes: &mut dyn AmLanes,
                _sc: &mut StepScratch,
                _out: &mut Vec<f32>,
            ) -> Result<()> {
                Ok(())
            }
        }
        let b = Opaque(ModelConfig::tiny_tds());
        assert!(!b.supports_lane_snapshots(), "default must advertise no support");
        let mut st = b.open_state().unwrap();
        let mut tf = TensorFile::new();
        let err = format!("{:#}", b.snapshot_lane(&mut st, &mut tf).unwrap_err());
        assert!(err.contains("does not support lane snapshots"), "{err}");
        assert!(b.restore_lane(&tf).is_err());
        let native = NativeBackend::new(TdsModel::random(ModelConfig::tiny_tds(), 1));
        assert!(native.supports_lane_snapshots());
    }

    #[test]
    fn lane_state_downcast_mismatch_panics() {
        let r = std::panic::catch_unwind(|| {
            let mut st = AmLaneState::new(42u32);
            let _: &mut TdsState = st.downcast_mut();
        });
        assert!(r.is_err());
    }
}
